"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once per constants change (``make artifacts``); the Rust binary is
self-contained afterwards. Usage:

    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import constants as C
from .model import EXPORTS, example_inputs


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps uniformly with to_tuple1/tuple accessors)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the detector's baked weights (and any
    # fitted tables) must survive the text round-trip — the default elides
    # them to "constant({...})", which the Rust-side parser reads as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def lower_all():
    specs = example_inputs()
    return {name: to_hlo_text(jax.jit(fn).lower(*specs[name]))
            for name, fn in EXPORTS.items()}


def meta() -> dict:
    """Machine-readable artifact contract for the Rust runtime
    (rust/src/runtime/artifacts.rs parses this)."""
    return {
        "version": 1,
        "dt_s": C.DT_S,
        "window": C.WINDOW,
        "horizon": C.HORIZON,
        "cold_steps": C.COLD_STEPS,
        "harmonics": C.HARMONICS,
        "recent": C.RECENT,
        "pgd_iters": C.PGD_ITERS,
        "l_warm_s": C.L_WARM_S,
        "l_cold_s": C.L_COLD_S,
        "w_max": C.W_MAX,
        "img_size": C.IMG_SIZE,
        "det_classes": C.DET_CLASSES,
        "param_names": C.PARAM_NAMES,
        "state_names": C.STATE_NAMES,
        "default_params": C.default_params_vec(),
        "modules": {
            "forecast": {
                "file": "forecast.hlo.txt",
                "inputs": [["history", [C.WINDOW]], ["gamma_clip", []]],
                "outputs": [["lambda_hat", [C.HORIZON]]],
            },
            "mpc": {
                "file": "mpc.hlo.txt",
                "inputs": [["z0", [3 * C.HORIZON]], ["lambda_hat", [C.HORIZON]],
                           ["ready", [C.HORIZON]], ["state", [C.N_STATE]],
                           ["params", [C.N_PARAMS]]],
                "outputs": [["z", [3 * C.HORIZON]], ["cost", [1]]],
            },
            "detector": {
                "file": "detector.hlo.txt",
                "inputs": [["img", [1, C.IMG_SIZE, C.IMG_SIZE, 3]]],
                "outputs": [["scores", [1, C.DET_CLASSES]]],
            },
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single module")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    specs = example_inputs()
    for name, fn in EXPORTS.items():
        if args.only and name != args.only:
            continue
        text = to_hlo_text(jax.jit(fn).lower(*specs[name]))
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta(), f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
