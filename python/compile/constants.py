"""Shared shape/parameter constants for the AOT artifacts.

These are baked into the lowered HLO (static shapes) and exported to
``artifacts/meta.json`` so the Rust runtime and the pure-Rust mirrors agree
byte-for-byte on layouts. Mirrored by ``rust/src/config/constants.rs``.
"""

# --- control-loop geometry -------------------------------------------------
# The control interval must be coarse enough that the H-step horizon spans
# the workload's inter-burst gaps (50-800 s) — otherwise the predictive
# prewarming the paper describes cannot engage; see DESIGN.md §Timescale.
DT_S = 30.0         # MPC control interval (seconds per step)
WINDOW = 120        # forecast history window W (samples of DT_S; 1 hour)
HORIZON = 24        # MPC prediction horizon H (steps; 12 minutes)
COLD_STEPS = 1      # D = ceil(L_cold / DT_S): steps until a cold start is warm
HARMONICS = 8       # K: number of Fourier harmonics kept (Eq. 1)
RECENT = 20         # M: trailing samples used for statistical clipping (Eq. 2)

# --- testbed constants (Sec. IV) -------------------------------------------
L_WARM_S = 0.280    # warm execution latency
L_COLD_S = 10.5     # cold start initialization latency
W_MAX = 64.0        # max concurrent replicas (32 vCPU / 0.5 vCPU each)
# Planning-model service rate: containers per step are sized so each step's
# demand drains within DRAIN_TARGET_S of user latency (not the full DT_S) —
# this keeps sub-step queueing delay visible to the step-granular planner.
DRAIN_TARGET_S = 1.5
MU = DRAIN_TARGET_S / L_WARM_S  # per-container service budget per step

# --- MPC solver -------------------------------------------------------------
PGD_ITERS = 300     # projected Adam iterations per control step
ADAM_B2 = 0.999     # Adam second-moment decay (baked into kernel + mirror)

# params vector layout for the MPC artifact (f32[16]); keep in sync with
# rust/src/mpc/problem.rs::Weights::to_params_vec.
PARAM_NAMES = [
    "alpha",      # 0  cold delay cost weight (Eq. 3)
    "beta",       # 1  queue waiting cost weight (Eq. 4)
    "gamma",      # 2  overprovisioning penalty weight (Eq. 6)
    "delta",      # 3  cold start cost weight (Eq. 5)
    "eta",        # 4  reclaim reward weight (Eq. 7)
    "rho1",       # 5  warm-count smoothness weight (Eq. 8)
    "rho2",       # 6  cold-start smoothness weight (Eq. 8)
    "rho_me",     # 7  mutual-exclusivity penalty weight (Eq. 18, relaxed)
    "kappa",      # 8  quadratic penalty weight for coupled constraints
    "mu",         # 9  warm service rate (1/L_warm)
    "l_cold",     # 10 cold start latency (s)
    "l_warm",     # 11 warm execution latency (s)
    "w_max",      # 12 max warm containers
    "lr",         # 13 Adam learning rate
    "momentum",   # 14 Adam beta1 (first-moment decay)
    "grad_clip",  # 15 per-coordinate gradient clip (stabilizes penalties)
]
N_PARAMS = len(PARAM_NAMES)

# state vector layout for the MPC artifact (f32[4])
STATE_NAMES = ["q0", "w0", "x_prev", "reserved"]
N_STATE = len(STATE_NAMES)

DEFAULT_WEIGHTS = {
    "alpha": 16.0,
    "beta": 107.0,  # waiting a step costs ~DT_S user-seconds: beta*l_warm ~= DT_S
    "gamma": 0.0002,
    "delta": 2.0,
    "eta": 0.005,
    "rho1": 0.2,
    "rho2": 0.02,
    "rho_me": 2.0,
    "kappa": 0.5,
    "mu": MU,
    "l_cold": L_COLD_S,
    "l_warm": L_WARM_S,
    "w_max": W_MAX,
    "lr": 0.5,
    "momentum": 0.9,
    "grad_clip": 5000.0,
}

# --- detector payload (EfficientDet stand-in) -------------------------------
IMG_SIZE = 32       # input image side (NHWC, 3 channels)
DET_CLASSES = 8     # output detection scores
DET_SEED = 20250710 # fixed weight seed baked into the artifact


def default_params_vec():
    return [DEFAULT_WEIGHTS[name] for name in PARAM_NAMES]
