"""L1 Pallas kernels (interpret=True) + pure-jnp oracles."""

from .fourier import fourier_synth
from .qp import pgd_step

__all__ = ["fourier_synth", "pgd_step"]
