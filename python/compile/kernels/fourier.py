"""L1 Pallas kernel: Fourier harmonic synthesis (Eq. 1).

Reconstructs the forecast lambda_hat(t) = a t^2 + b t + c
+ sum_i A_i cos(2 pi f_i t + phi_i) on an H-point future time grid from K
harmonics extracted by the L2 forecast graph.

TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of a per-thread
loop over harmonics (the GPU formulation), the kernel materializes the
(H x K) phase matrix in VMEM, applies cos on the VPU, and contracts with
the amplitude vector as an MXU-shaped (H x K) @ (K x 1) product. For the
deployed sizes (H = 24, K = 8) the whole problem is a single block:
VMEM footprint = (H*K + 3K + 2H + 3) * 4 B < 1 KiB.

``interpret=True`` is mandatory here: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TWO_PI = 6.283185307179586


def _synth_kernel(coeffs_ref, amps_ref, freqs_ref, phases_ref, tvec_ref, out_ref):
    """Single-block kernel body: out[h] = trend(t_h) + cos-row(h) . amps."""
    t = tvec_ref[...]                                   # [H]
    c = coeffs_ref[...]                                 # [3] ascending powers
    trend = c[0] + c[1] * t + c[2] * t * t              # VPU elementwise
    # (H x K) phase matrix resident in VMEM
    ang = TWO_PI * t[:, None] * freqs_ref[...][None, :] + phases_ref[...][None, :]
    basis = jnp.cos(ang)                                # VPU transcendental
    # MXU-shaped contraction: (H,K) @ (K,) with f32 accumulation
    harm = jnp.dot(basis, amps_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = trend + harm


@functools.partial(jax.jit, static_argnames=())
def fourier_synth(coeffs, amps, freqs, phases, tvec):
    """Evaluate the harmonic forecast model on a time grid.

    Args:
      coeffs: f32[3] quadratic trend coefficients (c, b, a) ascending.
      amps / freqs / phases: f32[K] harmonic parameters (zero-amp padding ok).
      tvec: f32[H] evaluation times (absolute sample indices).

    Returns:
      f32[H] raw (unclipped) forecast.
    """
    horizon = tvec.shape[0]
    return pl.pallas_call(
        _synth_kernel,
        out_shape=jax.ShapeDtypeStruct((horizon,), jnp.float32),
        interpret=True,
    )(coeffs.astype(jnp.float32), amps.astype(jnp.float32),
      freqs.astype(jnp.float32), phases.astype(jnp.float32),
      tvec.astype(jnp.float32))
