"""L1 Pallas kernel: one Adam step of the MPC horizon QP.

This is the optimizer's hot spot: the L2 solver (model.mpc_solve) runs N of
these steps per control step inside a lax.scan. The kernel evaluates the
objective (Eq. 9 with quadratic penalties for the coupled constraints
Eq. 12-18), its *hand-derived* gradient, an Adam moment update with bias
correction, and the box projection — all fused in a single block.

Adam (rather than plain projected gradient) matters here: the decision
blocks have wildly different gradient scales (serving pressure grows with
queue length; prewarm pressure arrives only through the penalty coupling),
and the per-coordinate step normalization is what lets backlog-drain
scenarios converge within the 300-iteration budget (see DESIGN.md §Perf).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the horizon rollout
q = q0 + Lsum (lam - s), w = w0 + Lsum (ready - r) is a prefix sum. A GPU
port would use a parallel scan; here the prefix sum and its adjoint are
expressed as matmuls with a strictly-lower-triangular ones matrix built
from ``broadcasted_iota`` directly in VMEM — an O(H^2) contraction the MXU
executes in a handful of passes for H <= 128, cheaper than a serialized
scan. The hinge masks and penalty gradients are VPU elementwise ops fused
into the same invocation. For the deployed H = 24 everything (a few H x H
f32 matrices) is ~7 KiB of VMEM — a single block, no HBM double-buffering.

``interpret=True`` is mandatory (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ADAM_B2 = 0.999
ADAM_EPS = 1e-8
DT_S = 30.0        # control interval, baked like cold_steps (see ref.py)
UTIL_TARGET = 0.8  # steady-flow utilization target for capacity sizing


def _adam_kernel(cold_steps, z_ref, m_ref, v_ref, it_ref, lam_ref, rdy_ref,
                 state_ref, params_ref, z_out, m_out, v_out, cost_out):
    horizon = lam_ref.shape[0]
    z = z_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    it = it_ref[...][0]  # 1-based iteration number (bias correction)
    lam = lam_ref[...]
    rdy = rdy_ref[...]
    state = state_ref[...]
    p = params_ref[...]
    (alpha, beta, gamma, delta, eta, rho1, rho2, rho_me, kappa, mu,
     l_cold, l_warm, w_max, lr, b1, grad_clip) = [p[i] for i in range(16)]
    q0, w0, x_prev = state[0], state[1], state[2]

    x = z[:horizon]
    r = z[horizon:2 * horizon]
    s = z[2 * horizon:]

    # --- triangular / shift operators built in VMEM from iota ---------------
    row = jax.lax.broadcasted_iota(jnp.int32, (horizon, horizon), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (horizon, horizon), 1)
    lsum = (row > col).astype(jnp.float32)          # strict lower: prefix sum
    shift_d = (row - col == cold_steps).astype(jnp.float32)  # readyCold shift

    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)

    # --- rollout (Eq. 10-11): MXU prefix-sum matmuls -------------------------
    ready = rdy + dot(shift_d, x)
    u = ready - r                                    # w_{k+1} - w_k
    q = q0 + dot(lsum, lam - s)
    w = w0 + dot(lsum, u)

    relu = lambda t: jnp.maximum(t, 0.0)
    # effective demand = utilization-normalized forecast flow + backlog
    # amortized over the cold window (see ref.cost_ref for the derivation)
    inv_dd = 1.0 / (cold_steps + 1.0)
    flow_scale = mu * l_warm / (UTIL_TARGET * DT_S)
    demand = lam * flow_scale + relu(q - lam) * inv_dd  # excess backlog only
    # serving uses the TRUE per-step throughput; mu (drain target) only
    # shapes provisioning — see ref.cost_ref
    mu_full = DT_S / l_warm
    # hinges (objective) and penalty residuals (constraints)
    h_cold = relu(demand - mu * w)                   # Eq. 3
    h_over = relu(mu * w - demand)                   # Eq. 6
    v_sq = relu(s - q)                               # Eq. 12a
    v_sw = relu(s - mu_full * w)                     # Eq. 12b
    v_rw = relu(r - w)                               # Eq. 13/15
    v_wmax = relu(w - w_max)                         # Eq. 16 upper
    v_qneg = relu(-q)                                # Eq. 17
    v_wneg = relu(-w)                                # Eq. 16 lower

    # smoothness deltas (Eq. 8): dw_k = u_{k-1} (dw_0 = 0), dx vs x_prev
    tail_mask = (jax.lax.iota(jnp.float32, horizon) < horizon - 1).astype(jnp.float32)
    x_shift = jnp.concatenate([x_prev[None], x[:-1]])
    dx = x - x_shift

    # --- objective value (Eq. 9 + penalties) --------------------------------
    cost = (
        alpha * (l_cold + l_warm) * jnp.sum(h_cold)
        + beta * l_warm * jnp.sum(q)
        + delta * jnp.sum(x)
        + gamma * jnp.sum(h_over)
        - eta * jnp.sum(r)
        + rho1 * jnp.sum(tail_mask * u * u)
        + rho2 * jnp.sum(dx * dx)
        + rho_me * jnp.sum(x * r)
        + kappa * jnp.sum(v_sq**2 + v_sw**2 + v_rw**2
                          + v_wmax**2 + v_qneg**2 + v_wneg**2)
    )

    # --- hand-derived gradient ----------------------------------------------
    m_cold = (h_cold > 0.0).astype(jnp.float32)
    m_over = (h_over > 0.0).astype(jnp.float32)
    g_w = (-alpha * (l_cold + l_warm) * mu * m_cold
           + gamma * mu * m_over
           + kappa * (-2.0 * mu_full * v_sw - 2.0 * v_rw
                      + 2.0 * v_wmax - 2.0 * v_wneg))
    # demand depends on q (backlog term): chain rule through both hinges
    m_qpos = (q - lam > 0.0).astype(jnp.float32)
    g_q = (beta * l_warm
           + alpha * (l_cold + l_warm) * m_cold * m_qpos * inv_dd
           - gamma * m_over * m_qpos * inv_dd
           + kappa * (-2.0 * v_sq - 2.0 * v_qneg))

    # adjoints of the prefix sums: transpose = strictly-upper matmul
    g_u = dot(lsum.T, g_w) + 2.0 * rho1 * tail_mask * u
    ddx = dx - jnp.concatenate([dx[1:], jnp.zeros((1,), jnp.float32)])
    g_x = dot(shift_d.T, g_u) + delta + rho_me * r + 2.0 * rho2 * ddx
    g_r = -g_u - eta + rho_me * x + kappa * 2.0 * v_rw
    g_s = -dot(lsum.T, g_q * jnp.ones((horizon,), jnp.float32)) \
        + kappa * (2.0 * v_sq + 2.0 * v_sw)
    grad = jnp.concatenate([g_x, g_r, g_s])
    # per-coordinate clip: penalty gradients scale with kappa * violation * H
    grad = jnp.clip(grad, -grad_clip, grad_clip)

    # --- Adam moment update + box projection (Eq. 14-17) ---------------------
    m_next = b1 * m + (1.0 - b1) * grad
    v_next = ADAM_B2 * v + (1.0 - ADAM_B2) * grad * grad
    m_hat = m_next / (1.0 - b1**it)
    v_hat = v_next / (1.0 - ADAM_B2**it)
    # per-block step scale: the serving block ranges over [0, mu_full*w_max]
    # — ~10x the prewarm/reclaim blocks — and Adam's normalized step would
    # otherwise cap its movement at lr*iters (see DESIGN.md §Perf)
    ones = jnp.ones((horizon,), jnp.float32)
    lr_vec = jnp.concatenate([ones, ones, ones * (mu_full / mu)]) * lr
    step = lr_vec * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS)
    ub = jnp.concatenate([
        jnp.full((horizon,), w_max, jnp.float32),
        jnp.full((horizon,), w_max, jnp.float32),
        jnp.full((horizon,), mu_full * w_max, jnp.float32),
    ])
    z_next = jnp.clip(z - step, 0.0, ub)

    z_out[...] = z_next
    m_out[...] = m_next
    v_out[...] = v_next
    cost_out[...] = cost[None]


@functools.partial(jax.jit, static_argnames=("cold_steps",))
def pgd_step(z, m, v, it, lam, rdy, state, params, *, cold_steps):
    """One fused Adam step. Mirrors ref.pgd_step_ref (jax.grad oracle).

    Args:
      z: f32[3H] decision vector concat(x, r, s).
      m, v: f32[3H] Adam first/second moments.
      it: f32[1] 1-based iteration number (bias correction).
      lam: f32[H] forecasted arrivals per step.
      rdy: f32[H] pre-horizon cold starts completing at step k (k < D).
      state: f32[4] = (q0, w0, x_prev, reserved).
      params: f32[16] weight vector (constants.PARAM_NAMES layout).
      cold_steps: static D = ceil(L_cold / dt).

    Returns:
      (z_next, m_next, v_next, cost at the *input* z) — f32[3H] x3 + f32[1].
    """
    horizon = lam.shape[0]
    kernel = functools.partial(_adam_kernel, cold_steps)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((3 * horizon,), jnp.float32),
            jax.ShapeDtypeStruct((3 * horizon,), jnp.float32),
            jax.ShapeDtypeStruct((3 * horizon,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(z.astype(jnp.float32), m.astype(jnp.float32), v.astype(jnp.float32),
      it.astype(jnp.float32), lam.astype(jnp.float32), rdy.astype(jnp.float32),
      state.astype(jnp.float32), params.astype(jnp.float32))
