"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is written *independently* of the kernels (cumulative sums
and ``jax.grad`` instead of triangular matmuls and hand gradients) so that a
kernel bug cannot be mirrored by an oracle bug. pytest + hypothesis compare
the two implementations across shapes and regimes.
"""

import jax
import jax.numpy as jnp

# Control interval (baked, like cold_steps): see constants.DT_S.
DT_S = 30.0
# Target utilization for steady-flow capacity sizing (Little's law with
# headroom): a container serves dt/l_warm requests per step at 100%.
UTIL_TARGET = 0.8

# ---------------------------------------------------------------------------
# MPC horizon rollout and cost (Eq. 3-18)
# ---------------------------------------------------------------------------


def split_z(z, horizon):
    """z = concat(x, r, s) -> (x, r, s)."""
    x = z[:horizon]
    r = z[horizon : 2 * horizon]
    s = z[2 * horizon :]
    return x, r, s


def rollout_ref(z, lam, rdy, state, cold_steps):
    """System dynamics (Eq. 10-11) via cumulative sums.

    Returns (q, w): queue length and warm-container count at the *start* of
    each step k = 0..H-1. ``rdy[k]`` counts cold starts issued before the
    horizon that finish at step k (readyCold for k < D); cold starts issued
    inside the horizon contribute x[k - D] for k >= D.
    """
    horizon = lam.shape[0]
    x, r, s = split_z(z, horizon)
    q0, w0 = state[0], state[1]
    if cold_steps > 0:
        shifted = jnp.roll(x, cold_steps).at[:cold_steps].set(0.0)
    else:
        shifted = x
    ready = rdy + shifted
    # state at start of step k: cumulative effect of steps 0..k-1
    dq = lam - s
    dw = ready - r
    q = q0 + jnp.concatenate([jnp.zeros(1), jnp.cumsum(dq)[:-1]])
    w = w0 + jnp.concatenate([jnp.zeros(1), jnp.cumsum(dw)[:-1]])
    return q, w


def cost_ref(z, lam, rdy, state, params, cold_steps):
    """Total MPC objective (Eq. 9) + quadratic penalties for the coupled
    constraints (Eq. 12-18). Scalar."""
    horizon = lam.shape[0]
    x, r, s = split_z(z, horizon)
    (alpha, beta, gamma, delta, eta, rho1, rho2, rho_me, kappa, mu,
     l_cold, l_warm, w_max) = [params[i] for i in range(13)]
    q, w = rollout_ref(z, lam, rdy, state, cold_steps)
    relu = jax.nn.relu

    # Effective demand: forecast arrivals plus the queued backlog amortized
    # over the cold-start window. Eq. 3's lambda_k counts "incoming
    # requests"; a standing queue is exactly unserved incoming work, and
    # without this term the penalty-relaxed solver has no first-order
    # pressure to provision for backlog drain (cvxpy's exact coupled
    # constraints gave the paper this pressure for free).
    # Steady flow is normalized by achievable per-step throughput at the
    # target utilization, while backlog is normalized by the drain-target
    # rate (mu): mu = drain_target / l_warm, so lam's scale factor is
    # drain_target / (UTIL * dt) = mu * l_warm / (UTIL * dt). This sizes the
    # pool by Little's law under steady load and by fast-drain capacity
    # under backlog, with a single mu * w capacity axis.
    flow_scale = mu * l_warm / (UTIL_TARGET * DT_S)
    # only the backlog in EXCESS of one step's natural flow counts: the
    # Eq. 10 convention stores each step's arrivals in q for one step, so
    # steady state has q ~= lam without any true backlog
    demand = lam * flow_scale + relu(q - lam) / (cold_steps + 1.0)
    # True per-step serving throughput (Eq. 12's capacity): a warm container
    # completes dt / l_warm requests per step. The drain-target mu only
    # shapes *provisioning* (Eq. 3/6); using it for serving would create
    # phantom in-model queues that re-inflate the pool.
    mu_full = DT_S / l_warm
    cold_delay = alpha * jnp.sum(relu(demand - mu * w)) * (l_cold + l_warm)  # Eq. 3
    wait_cost = beta * jnp.sum(q) * l_warm                                # Eq. 4
    cold_start = delta * jnp.sum(x)                                       # Eq. 5
    overprov = gamma * jnp.sum(relu(mu * w - demand))                     # Eq. 6
    reclaim = -eta * jnp.sum(r)                                           # Eq. 7
    w_ext = jnp.concatenate([state[1:2], w])                              # w_{-1} = w0
    x_ext = jnp.concatenate([state[2:3], x])                              # x_{-1} = x_prev
    smooth = rho1 * jnp.sum(jnp.diff(w_ext) ** 2) + rho2 * jnp.sum(jnp.diff(x_ext) ** 2)  # Eq. 8
    excl = rho_me * jnp.sum(x * r)                                        # Eq. 18 relaxed

    pen = (
        jnp.sum(relu(s - q) ** 2)          # Eq. 12: s_k <= q_k
        + jnp.sum(relu(s - mu_full * w) ** 2)  # Eq. 12: s_k <= serving capacity
        + jnp.sum(relu(r - w) ** 2)        # Eq. 13/15
        + jnp.sum(relu(w - w_max) ** 2)    # Eq. 16
        + jnp.sum(relu(-q) ** 2)           # Eq. 17
        + jnp.sum(relu(-w) ** 2)           # Eq. 16 lower
    )
    return (cold_delay + wait_cost + cold_start + overprov + reclaim
            + smooth + excl + kappa * pen)


def bounds_ref(params, horizon):
    """Per-coordinate box upper bounds for z (lower bounds are all 0)."""
    w_max, l_warm = params[12], params[11]
    mu_full = DT_S / l_warm
    ub_x = jnp.full((horizon,), w_max)            # Eq. 14
    ub_r = jnp.full((horizon,), w_max)            # Eq. 15
    ub_s = jnp.full((horizon,), mu_full * w_max)  # true serving ceiling
    return jnp.concatenate([ub_x, ub_r, ub_s])


ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def pgd_step_ref(z, m, v, it, lam, rdy, state, params, cold_steps):
    """One projected Adam step, gradient via jax.grad (the kernel oracle).

    `it` is the 1-based iteration count (f32[1]) for bias correction.
    Returns (z_next, m_next, v_next, cost_at_z).
    """
    lr, b1, grad_clip = params[13], params[14], params[15]
    cost, grad = jax.value_and_grad(cost_ref)(z, lam, rdy, state, params, cold_steps)
    grad = jnp.clip(grad, -grad_clip, grad_clip)
    m_next = b1 * m + (1.0 - b1) * grad
    v_next = ADAM_B2 * v + (1.0 - ADAM_B2) * grad * grad
    t = it[0]
    m_hat = m_next / (1.0 - b1**t)
    v_hat = v_next / (1.0 - ADAM_B2**t)
    # per-block step scale (see the kernel): serving block moves ~10x faster
    h = lam.shape[0]
    mu, l_warm = params[9], params[11]
    ones = jnp.ones((h,))
    lr_vec = jnp.concatenate([ones, ones, ones * ((DT_S / l_warm) / mu)]) * lr
    z_next = jnp.clip(z - lr_vec * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS),
                      0.0, bounds_ref(params, lam.shape[0]))
    return z_next, m_next, v_next, cost


# ---------------------------------------------------------------------------
# Fourier harmonic synthesis (Eq. 1)
# ---------------------------------------------------------------------------


def fourier_synth_ref(coeffs, amps, freqs, phases, tvec):
    """lambda_hat(t) = a t^2 + b t + c + sum_i A_i cos(2 pi f_i t + phi_i).

    coeffs = (c, b, a) ascending powers; amps/freqs/phases are K-vectors
    (zero-amplitude padding is harmless); tvec is the evaluation grid.
    """
    trend = coeffs[0] + coeffs[1] * tvec + coeffs[2] * tvec**2
    harm = jnp.sum(
        amps[None, :] * jnp.cos(2.0 * jnp.pi * freqs[None, :] * tvec[:, None]
                                + phases[None, :]),
        axis=1,
    )
    return trend + harm


def dft_ref(resid):
    """Real DFT via explicit projection (no jnp.fft, to match the portable
    matmul lowering): returns (re, im) of X_j = sum_t resid_t e^{-i 2pi j t / W}
    for j = 0..W//2."""
    w = resid.shape[0]
    j = jnp.arange(w // 2 + 1, dtype=jnp.float32)
    t = jnp.arange(w, dtype=jnp.float32)
    ang = 2.0 * jnp.pi * j[:, None] * t[None, :] / w
    re = jnp.cos(ang) @ resid
    im = -(jnp.sin(ang) @ resid)
    return re, im
