"""L2: the controller's JAX compute graphs, calling the L1 Pallas kernels.

Three graphs are AOT-lowered by ``aot.py`` and executed from the Rust
coordinator through PJRT (Python is never on the request path):

* ``forecast``      — Fourier-harmonic invocation forecast (Eq. 1-2).
* ``mpc_solve``     — N projected-gradient steps of the horizon QP (Eq. 9-18).
* ``detector``      — small conv-net standing in for the EfficientDet
                      function payload (DESIGN.md substitution table).

Portability constraints (xla_extension 0.5.1 CPU on the Rust side):
no ``jnp.linalg`` (would lower to LAPACK custom-calls), no ``jnp.fft``
(DFT is an explicit matmul projection instead), no ``lax.top_k``
(descending ``lax.sort_key_val`` + slice). Everything lowers to vanilla
HLO ops: dot/cos/sin/atan2/sort/while.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import constants as C
from .kernels import fourier_synth, pgd_step

TWO_PI = 2.0 * jnp.pi


# ---------------------------------------------------------------------------
# Invocation forecast (Sec. III-A)
# ---------------------------------------------------------------------------


def _quadratic_trend(history):
    """Least-squares fit of a t^2 + b t + c over t = 0..W-1.

    Closed-form 3x3 normal equations with a cofactor inverse (no
    jnp.linalg.solve: that would emit a LAPACK custom-call the Rust PJRT
    client cannot execute). t is normalized to [0, 1] for conditioning and
    coefficients are mapped back to sample units.
    """
    w = history.shape[0]
    t = jnp.arange(w, dtype=jnp.float32) / w
    v = jnp.stack([jnp.ones_like(t), t, t * t], axis=1)      # Vandermonde [W,3]
    a = v.T @ v                                              # [3,3]
    b = v.T @ history                                        # [3]
    # cofactor inverse of the symmetric 3x3
    c00 = a[1, 1] * a[2, 2] - a[1, 2] * a[2, 1]
    c01 = a[0, 2] * a[2, 1] - a[0, 1] * a[2, 2]
    c02 = a[0, 1] * a[1, 2] - a[0, 2] * a[1, 1]
    c11 = a[0, 0] * a[2, 2] - a[0, 2] * a[2, 0]
    c12 = a[0, 2] * a[1, 0] - a[0, 0] * a[1, 2]
    c22 = a[0, 0] * a[1, 1] - a[0, 1] * a[1, 0]
    det = a[0, 0] * c00 + a[0, 1] * (a[1, 2] * a[2, 0] - a[1, 0] * a[2, 2]) \
        + a[0, 2] * (a[1, 0] * a[2, 1] - a[1, 1] * a[2, 0])
    inv = jnp.array([[c00, c01, c02], [c01, c11, c12], [c02, c12, c22]]) / det
    coeffs_norm = inv @ b                                    # (c, b, a) in t/W units
    # map back: trend(t) = c + (b/W) t + (a/W^2) t^2 with t in samples
    return jnp.array([coeffs_norm[0], coeffs_norm[1] / w, coeffs_norm[2] / (w * w)])


def _dft_matmul(resid):
    """Real DFT by explicit projection: X_j = sum_t resid_t e^{-2pi i j t/W}.

    A (W/2+1 x W) cos/sin matmul — O(W^2) but W = 240, and it lowers to two
    plain HLO dots that run anywhere (DESIGN.md §Hardware-Adaptation).
    """
    w = resid.shape[0]
    j = jnp.arange(w // 2 + 1, dtype=jnp.float32)
    t = jnp.arange(w, dtype=jnp.float32)
    ang = TWO_PI * j[:, None] * t[None, :] / w
    re = jnp.cos(ang) @ resid
    im = -(jnp.sin(ang) @ resid)
    return re, im


def forecast(history, gamma_clip):
    """Clipped Fourier forecast over the next H steps (Eq. 1-2).

    Args:
      history: f32[W] per-interval arrival counts (most recent last).
      gamma_clip: f32[] confidence multiplier for statistical clipping.

    Returns:
      f32[H] forecast lambda_hat for steps t = W .. W+H-1, elementwise in
      [0, mean_recent + gamma_clip * std_recent].
    """
    w = history.shape[0]
    coeffs = _quadratic_trend(history)
    t = jnp.arange(w, dtype=jnp.float32)
    trend = coeffs[0] + coeffs[1] * t + coeffs[2] * t * t
    resid = history - trend

    re, im = _dft_matmul(resid)
    nbins = re.shape[0]
    power = re * re + im * im
    # exclude DC; select the K strongest harmonics by power via a
    # descending sort (lax.top_k is avoided for HLO portability)
    power = power.at[0].set(-1.0)
    neg_power, order = jax.lax.sort_key_val(-power, jnp.arange(nbins))
    top = order[: C.HARMONICS]
    amps = 2.0 * jnp.sqrt(-neg_power[: C.HARMONICS] + 1e-12) / w
    freqs = top.astype(jnp.float32) / w
    phases = jnp.arctan2(im[top], re[top])

    tfut = w + jnp.arange(C.HORIZON, dtype=jnp.float32)
    raw = fourier_synth(coeffs, amps, freqs, phases, tfut)   # L1 kernel

    recent = history[-C.RECENT:]
    mean_r = jnp.mean(recent)
    std_r = jnp.std(recent)
    return jnp.clip(raw, 0.0, mean_r + gamma_clip * std_r)   # Eq. 2


# ---------------------------------------------------------------------------
# MPC solve (Sec. III-B)
# ---------------------------------------------------------------------------


def mpc_solve(z0, lam, rdy, state, params):
    """Run PGD_ITERS fused kernel steps; return (z*, cost trace tail).

    Args:
      z0: f32[3H] warm-start decision vector (previous plan, shifted).
      lam: f32[H] forecast; rdy: f32[H] pre-horizon readyCold schedule.
      state: f32[4] (q0, w0, x_prev, -); params: f32[16] weights.

    Returns:
      (z f32[3H], cost f32[1]) — cost is the objective at the final iterate.
    """
    # feasible serving seed: start the s-block at the forecast level so the
    # relaxed rollout does not fabricate a transient backlog (whose demand
    # pressure would inflate prewarming) while Adam ramps s from zero
    h = C.HORIZON
    z0 = z0.at[2 * h:].set(jnp.maximum(z0[2 * h:], lam))
    m0 = jnp.zeros_like(z0)
    v0 = jnp.zeros_like(z0)

    def body(carry, it):
        z, m, v = carry
        z_next, m_next, v_next, cost = pgd_step(z, m, v, it[None], lam, rdy,
                                                state, params,
                                                cold_steps=C.COLD_STEPS)
        return (z_next, m_next, v_next), cost

    (z, m, v), costs = jax.lax.scan(
        body, (z0, m0, v0),
        jnp.arange(1, C.PGD_ITERS + 1, dtype=jnp.float32))
    # one extra evaluation to report the cost at the *final* iterate
    _, _, _, final_cost = pgd_step(z, m, v,
                                   jnp.array([C.PGD_ITERS + 1.0], jnp.float32),
                                   lam, rdy, state, params,
                                   cold_steps=C.COLD_STEPS)
    return z, final_cost


# ---------------------------------------------------------------------------
# Detector payload (Sec. IV "Function")
# ---------------------------------------------------------------------------


def _detector_weights():
    """Fixed seeded weights, baked into the artifact as HLO constants."""
    rng = np.random.default_rng(C.DET_SEED)

    def he(shape, fan_in):
        return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)

    return {
        "conv1": he((3, 3, 3, 16), 3 * 9),
        "conv2": he((3, 3, 16, 32), 16 * 9),
        "dense": he((32 * (C.IMG_SIZE // 4) ** 2, C.DET_CLASSES), 32 * 64),
        "bias": np.zeros((C.DET_CLASSES,), np.float32),
    }


def detector(img):
    """Object-detection stand-in: conv-relu-pool x2 + dense scores.

    Args:
      img: f32[1, IMG, IMG, 3] NHWC frame.
    Returns:
      f32[1, DET_CLASSES] detection scores.
    """
    wts = _detector_weights()
    dn = jax.lax.conv_dimension_numbers(img.shape, wts["conv1"].shape,
                                        ("NHWC", "HWIO", "NHWC"))

    def block(x, kernel):
        x = jax.lax.conv_general_dilated(x, kernel, (1, 1), "SAME",
                                         dimension_numbers=dn)
        x = jax.nn.relu(x)
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    x = block(img, wts["conv1"])
    dn = jax.lax.conv_dimension_numbers(x.shape, wts["conv2"].shape,
                                        ("NHWC", "HWIO", "NHWC"))
    x = block(x, wts["conv2"])
    x = x.reshape((1, -1))
    return x @ wts["dense"] + wts["bias"]


# ---------------------------------------------------------------------------
# Example-input builders shared by aot.py and the tests
# ---------------------------------------------------------------------------


def example_inputs():
    """ShapeDtypeStructs for each exported graph, in argument order."""
    f32 = jnp.float32
    return {
        "forecast": (jax.ShapeDtypeStruct((C.WINDOW,), f32),
                     jax.ShapeDtypeStruct((), f32)),
        "mpc": (jax.ShapeDtypeStruct((3 * C.HORIZON,), f32),
                jax.ShapeDtypeStruct((C.HORIZON,), f32),
                jax.ShapeDtypeStruct((C.HORIZON,), f32),
                jax.ShapeDtypeStruct((C.N_STATE,), f32),
                jax.ShapeDtypeStruct((C.N_PARAMS,), f32)),
        "detector": (jax.ShapeDtypeStruct((1, C.IMG_SIZE, C.IMG_SIZE, 3), f32),),
    }


EXPORTS = {
    "forecast": forecast,
    "mpc": mpc_solve,
    "detector": detector,
}
