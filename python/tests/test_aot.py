"""AOT artifact contract: HLO text is portable and meta.json is consistent."""

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import constants as C
from compile import model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_meta_matches_constants():
    m = aot.meta()
    assert m["window"] == C.WINDOW
    assert m["horizon"] == C.HORIZON
    assert m["cold_steps"] == C.COLD_STEPS
    assert m["param_names"] == C.PARAM_NAMES
    assert len(m["default_params"]) == C.N_PARAMS
    mods = m["modules"]
    assert mods["forecast"]["inputs"][0][1] == [C.WINDOW]
    assert mods["mpc"]["inputs"][0][1] == [3 * C.HORIZON]
    assert mods["mpc"]["outputs"][0][1] == [3 * C.HORIZON]


def test_lowered_hlo_has_no_elided_constants():
    """Elided constants ('constant({...})') silently become zeros on the
    Rust side — the regression behind the detector all-zeros bug."""
    for name, text in aot.lower_all().items():
        assert "{...}" not in text, f"{name}: elided constant in HLO text"


def test_lowered_hlo_has_no_custom_calls():
    """xla_extension 0.5.1 CPU can't run jaxlib custom-calls (LAPACK/FFT
    handlers are not registered there) — the graphs must lower clean."""
    for name, text in aot.lower_all().items():
        assert "custom-call" not in text, f"{name}: custom-call in HLO"


def test_entry_layouts():
    lowered = aot.lower_all()
    assert f"f32[{C.WINDOW}]" in lowered["forecast"]
    assert f"f32[{3 * C.HORIZON}]" in lowered["mpc"]
    assert f"f32[1,{C.IMG_SIZE},{C.IMG_SIZE},3]" in lowered["detector"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "meta.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_artifacts_on_disk_are_current():
    with open(os.path.join(ART, "meta.json")) as f:
        disk = json.load(f)
    assert disk == aot.meta(), "artifacts stale: run `make artifacts`"
    for mod in disk["modules"].values():
        assert os.path.exists(os.path.join(ART, mod["file"]))


def test_detector_deterministic_and_finite():
    img = jnp.full((1, C.IMG_SIZE, C.IMG_SIZE, 3), 0.25, jnp.float32)
    a = np.asarray(model.detector(img))
    b = np.asarray(model.detector(img))
    assert a.shape == (1, C.DET_CLASSES)
    assert np.isfinite(a).all()
    np.testing.assert_array_equal(a, b)
    # different inputs -> different scores (weights are not degenerate)
    c = np.asarray(model.detector(img * 2.0))
    assert not np.allclose(a, c)
