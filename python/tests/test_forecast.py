"""L2 forecast graph: trend fit, DFT, harmonic selection, clipping (Eq. 1-2)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import constants as C
from compile import model
from compile.kernels.ref import dft_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def test_quadratic_trend_exact_recovery():
    """Fitting an exact quadratic must recover it to f32 precision."""
    t = np.arange(C.WINDOW, dtype=np.float32)
    y = 3.0 + 0.05 * t - 1e-4 * t * t
    coeffs = np.asarray(model._quadratic_trend(jnp.array(y)))
    fit = coeffs[0] + coeffs[1] * t + coeffs[2] * t * t
    np.testing.assert_allclose(fit, y, rtol=1e-4, atol=1e-2)


def test_dft_matmul_matches_numpy_rfft():
    rng = np.random.default_rng(11)
    y = rng.standard_normal(C.WINDOW).astype(np.float32)
    re, im = model._dft_matmul(jnp.array(y))
    want = np.fft.rfft(y)
    np.testing.assert_allclose(np.asarray(re), want.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(im), want.imag, rtol=1e-3, atol=1e-3)


def test_dft_ref_agrees_with_model_dft():
    rng = np.random.default_rng(12)
    y = rng.standard_normal(64).astype(np.float32)
    re_m, im_m = model._dft_matmul(jnp.array(y))
    re_r, im_r = dft_ref(jnp.array(y))
    np.testing.assert_allclose(np.asarray(re_m), np.asarray(re_r), atol=1e-3)
    np.testing.assert_allclose(np.asarray(im_m), np.asarray(im_r), atol=1e-3)


def test_pure_harmonic_extrapolation():
    """A noiseless periodic signal on the DFT grid extrapolates ~exactly."""
    t = np.arange(C.WINDOW, dtype=np.float32)
    period = 24.0  # 120/24 = 5 cycles -> exactly on-grid
    y = 20.0 + 6.0 * np.cos(2 * np.pi * t / period + 0.7)
    lam = np.asarray(model.forecast(jnp.array(y.astype(np.float32)),
                                    jnp.float32(6.0)))
    tf = C.WINDOW + np.arange(C.HORIZON, dtype=np.float32)
    want = 20.0 + 6.0 * np.cos(2 * np.pi * tf / period + 0.7)
        # tolerance: trend-fit leakage across 5 cycles + f32; the shape match
    # (phase + amplitude) is what matters for the controller
    np.testing.assert_allclose(lam, want, atol=2.5)


@given(st.integers(0, 2**31 - 1), st.floats(0.5, 5.0, width=32))
def test_clipping_bounds_hold(seed, gamma_clip):
    """Eq. 2: every forecast value lies in [0, mean + gamma * std]."""
    rng = np.random.default_rng(seed)
    y = np.maximum(0.0, rng.normal(30, 15, C.WINDOW)).astype(np.float32)
    lam = np.asarray(model.forecast(jnp.array(y), jnp.float32(gamma_clip)))
    recent = y[-C.RECENT:]
    hi = recent.mean() + gamma_clip * recent.std() + 1e-3
    assert (lam >= 0.0).all()
    assert (lam <= hi).all(), (lam.max(), hi)


def test_constant_history_predicts_constant():
    y = np.full(C.WINDOW, 12.0, np.float32)
    lam = np.asarray(model.forecast(jnp.array(y), jnp.float32(3.0)))
    # std of recent is 0 -> clip ceiling is exactly the mean
    np.testing.assert_allclose(lam, 12.0, atol=0.2)


def test_forecast_shape_dtype():
    y = jnp.zeros(C.WINDOW, jnp.float32)
    lam = model.forecast(y, jnp.float32(3.0))
    assert lam.shape == (C.HORIZON,)
    assert lam.dtype == jnp.float32
