"""L1 fourier_synth kernel vs pure-jnp oracle (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fourier_synth
from compile.kernels.ref import fourier_synth_ref

settings.register_profile("ci", max_examples=60, deadline=None)
settings.load_profile("ci")

finite = st.floats(-50.0, 50.0, allow_nan=False, width=32)


@st.composite
def synth_case(draw):
    horizon = draw(st.integers(1, 96))
    k = draw(st.integers(1, 32))
    coeffs = draw(st.lists(st.floats(-2.0, 2.0, width=32), min_size=3, max_size=3))
    amps = draw(st.lists(st.floats(0.0, 30.0, width=32), min_size=k, max_size=k))
    freqs = draw(st.lists(st.floats(0.0, 0.5, width=32), min_size=k, max_size=k))
    phases = draw(st.lists(st.floats(-3.25, 3.25, width=32), min_size=k, max_size=k))
    t0 = draw(st.integers(0, 2000))
    return coeffs, amps, freqs, phases, t0, horizon


@given(synth_case())
def test_kernel_matches_ref(case):
    coeffs, amps, freqs, phases, t0, horizon = case
    c = jnp.array(coeffs, jnp.float32)
    a = jnp.array(amps, jnp.float32)
    f = jnp.array(freqs, jnp.float32)
    p = jnp.array(phases, jnp.float32)
    t = jnp.arange(t0, t0 + horizon, dtype=jnp.float32)
    got = fourier_synth(c, a, f, p, t)
    want = fourier_synth_ref(c, a, f, p, t)
    assert got.shape == (horizon,)
    # f32 tolerance: the phase product 2*pi*f*t reaches ~3e3 rad, so f32
    # argument-reduction error alone is ~2e-4 rad * sum(amps) of amplitude
    atol = 0.01 + 3e-4 * float(jnp.sum(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=atol)


def test_zero_amplitude_padding_is_identity():
    """Zero-amp harmonics must not perturb the trend (padding contract)."""
    c = jnp.array([1.0, 0.5, -0.01], jnp.float32)
    t = jnp.arange(16, dtype=jnp.float32)
    a = jnp.zeros(8, jnp.float32)
    f = jnp.linspace(0.0, 0.4, 8).astype(jnp.float32)
    p = jnp.ones(8, jnp.float32)
    got = fourier_synth(c, a, f, p, t)
    want = c[0] + c[1] * t + c[2] * t * t
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_single_harmonic_exact():
    """One pure harmonic: kernel must reproduce cos exactly (f32)."""
    c = jnp.zeros(3, jnp.float32)
    t = jnp.arange(48, dtype=jnp.float32)
    got = fourier_synth(c, jnp.array([2.0], jnp.float32),
                        jnp.array([0.125], jnp.float32),
                        jnp.array([0.5], jnp.float32), t)
    want = 2.0 * np.cos(2 * np.pi * 0.125 * np.asarray(t) + 0.5)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("horizon", [1, 24, 128])
def test_output_dtype_and_shape(horizon):
    c = jnp.zeros(3, jnp.float32)
    k = 4
    out = fourier_synth(c, jnp.ones(k), jnp.full(k, 0.1), jnp.zeros(k),
                        jnp.arange(horizon, dtype=jnp.float32))
    assert out.dtype == jnp.float32
    assert out.shape == (horizon,)
