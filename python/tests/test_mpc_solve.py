"""L2 mpc_solve graph: convergence, feasibility, and control behaviour.

Scenarios use the deployed scale: dt = 30 s steps, so lam is requests per
30-second bin (a 300 req/s burst is a 900-request bin) and mu is the
drain-target service rate (~10.7 requests/step/container).
"""

import jax.numpy as jnp
import numpy as np

from compile import constants as C
from compile import model
from compile.kernels import ref


def params_vec(**over):
    d = dict(C.DEFAULT_WEIGHTS)
    d.update(over)
    return jnp.array([d[n] for n in C.PARAM_NAMES], jnp.float32)


def solve(lam, q0=0.0, w0=0.0, x_prev=0.0, rdy=None, **weights):
    horizon = C.HORIZON
    lam = jnp.array(lam, jnp.float32)
    rdy = jnp.zeros(horizon, jnp.float32) if rdy is None else jnp.array(rdy, jnp.float32)
    state = jnp.array([q0, w0, x_prev, 0.0], jnp.float32)
    params = params_vec(**weights)
    z0 = jnp.zeros(3 * horizon, jnp.float32)
    z, cost = model.mpc_solve(z0, lam, rdy, state, params)
    x = np.asarray(z[:horizon])
    r = np.asarray(z[horizon:2 * horizon])
    s = np.asarray(z[2 * horizon:])
    return x, r, s, float(cost[0]), (lam, rdy, state, params, z)


def test_solver_descends_from_cold_start():
    lam = np.full(C.HORIZON, 200.0, np.float32)
    _, _, _, cost, (lamv, rdy, state, params, z) = solve(lam, q0=100.0)
    z0 = jnp.zeros_like(z)
    c0 = float(ref.cost_ref(z0, lamv, rdy, state, params, C.COLD_STEPS))
    assert cost < c0


def test_burst_forecast_triggers_prewarming():
    """A predicted 900-request bin must trigger cold starts ahead of it."""
    lam = np.zeros(C.HORIZON, np.float32)
    burst_at = 14
    lam[burst_at:burst_at + 2] = 900.0
    x, r, s, _, _ = solve(lam)
    assert x[:burst_at].sum() > 3.0, x
    # and the plan does not reclaim away the pool it is building
    assert r[:burst_at].sum() < x[:burst_at].sum()


def test_backlog_drives_scale_out():
    """A standing 900-deep queue with a tiny pool must prewarm and serve."""
    lam = np.full(C.HORIZON, 30.0, np.float32)
    x, r, s, _, _ = solve(lam, q0=900.0, w0=2.0)
    assert x[0] >= 1.0, x
    assert s[0] > 10.0, s


def test_idle_forecast_reclaims_warm_pool():
    """Zero forecast + large warm pool: step 0 reclaims, never prewarms."""
    lam = np.zeros(C.HORIZON, np.float32)
    x, r, s, _, _ = solve(lam, w0=20.0, gamma=0.05, eta=0.2)
    assert r[:4].sum() > 1.0, r
    assert x[0] < r[0], (x[0], r[0])  # actuated step reclaims (repair zeroes x)


def test_queue_drain_serves_requests():
    """A standing queue with warm capacity available is served."""
    lam = np.zeros(C.HORIZON, np.float32)
    x, r, s, _, _ = solve(lam, q0=100.0, w0=10.0)
    assert s[:4].sum() > 40.0, s


def test_mutual_exclusivity_first_step():
    """The actuated step never both prewarms and reclaims materially."""
    rng = np.random.default_rng(9)
    lam = rng.uniform(0, 300, C.HORIZON).astype(np.float32)
    x, r, _, _, _ = solve(lam, q0=50.0, w0=8.0)
    overlap0 = min(x[0], r[0])
    assert overlap0 < 1.0, (x[0], r[0])


def test_warm_start_converges_no_worse():
    lam = np.full(C.HORIZON, 250.0, np.float32)
    horizon = C.HORIZON
    lamv = jnp.array(lam)
    rdy = jnp.zeros(horizon, jnp.float32)
    state = jnp.array([50.0, 5.0, 0.0, 0.0], jnp.float32)
    params = params_vec()
    z_cold, c_cold = model.mpc_solve(jnp.zeros(3 * horizon, jnp.float32),
                                     lamv, rdy, state, params)
    z_warm, c_warm = model.mpc_solve(z_cold, lamv, rdy, state, params)
    assert float(c_warm[0]) <= float(c_cold[0]) * 1.05 + 1.0


def test_flow_normalization_sizes_steady_pool():
    """Steady 360 req/step from an established pool must stay near
    Little's-law capacity (~5 containers at 80% util), not balloon to the
    drain-target sizing (~34) or the cap (64)."""
    lam = np.full(C.HORIZON, 360.0, np.float32)
    x, r, s, _, (lamv, rdy, state, params, z) = solve(lam, q0=0.0, w0=6.0)
    q, w = ref.rollout_ref(z, lamv, rdy, state, C.COLD_STEPS)
    # judge the actuated (near) region: receding horizon never executes the
    # tail, where the relaxed transient accumulates extra pool
    w_near = float(np.asarray(w)[:8].mean())
    assert 2.0 <= w_near <= 30.0, f"steady pool {w_near} mis-sized"
