"""L1 pgd_step kernel vs the jax.grad oracle (hypothesis sweeps).

The oracle computes the gradient of ref.cost_ref with jax.grad; the kernel
uses a hand-derived fused gradient. Agreement across random problems is the
core correctness signal for the optimizer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import constants as C
from compile.kernels import pgd_step
from compile.kernels import ref

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


def params_vec(**over):
    d = dict(C.DEFAULT_WEIGHTS)
    d.update(over)
    return jnp.array([d[n] for n in C.PARAM_NAMES], jnp.float32)


@st.composite
def problem(draw):
    horizon = draw(st.sampled_from([4, 8, 24, 48]))
    cold_steps = draw(st.integers(0, horizon - 1))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    z = rng.uniform(0, 20, 3 * horizon).astype(np.float32)
    vel = rng.uniform(-5, 5, 3 * horizon).astype(np.float32)
    lam = rng.uniform(0, 100, horizon).astype(np.float32)
    rdy = np.zeros(horizon, np.float32)
    if cold_steps > 0:
        rdy[:cold_steps] = rng.integers(0, 4, cold_steps)
    state = np.array([rng.uniform(0, 50), rng.uniform(0, 30),
                      rng.uniform(0, 10), 0.0], np.float32)
    weights = {
        "alpha": draw(st.floats(0.0, 5.0, width=32)),
        "beta": draw(st.floats(0.0, 5.0, width=32)),
        "gamma": draw(st.floats(0.0, 1.0, width=32)),
        "delta": draw(st.floats(0.0, 5.0, width=32)),
        "eta": draw(st.floats(0.0, 1.0, width=32)),
        "rho1": draw(st.floats(0.0, 0.5, width=32)),
        "rho2": draw(st.floats(0.0, 0.5, width=32)),
        "rho_me": draw(st.floats(0.0, 2.0, width=32)),
        "kappa": draw(st.floats(0.125, 50.0, width=32)),
    }
    return horizon, cold_steps, z, vel, lam, rdy, state, params_vec(**weights)


@given(problem(), st.integers(1, 500))
def test_kernel_matches_grad_oracle(case, it):
    horizon, cold_steps, z, vel, lam, rdy, state, params = case
    m = jnp.zeros(3 * horizon, jnp.float32)
    v = jnp.abs(jnp.array(vel))  # second moment must be nonnegative
    itv = jnp.array([float(it)], jnp.float32)
    zk, mk, vk, ck = pgd_step(jnp.array(z), m, v, itv, jnp.array(lam),
                              jnp.array(rdy), jnp.array(state), params,
                              cold_steps=cold_steps)
    zr, mr, vr, cr = ref.pgd_step_ref(jnp.array(z), m, v, itv, jnp.array(lam),
                                      jnp.array(rdy), jnp.array(state), params,
                                      cold_steps)
    np.testing.assert_allclose(float(ck[0]), float(cr), rtol=2e-5, atol=1e-3)
    scale = max(1.0, float(jnp.max(jnp.abs(mr))))
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr),
                               rtol=1e-4, atol=2e-4 * scale)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr),
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(zk), np.asarray(zr),
                               rtol=1e-4, atol=2e-3)


def test_projection_respects_bounds():
    """Iterates always stay inside the box (Eq. 14-17) even with a huge lr."""
    horizon = C.HORIZON
    rng = np.random.default_rng(7)
    z = jnp.array(rng.uniform(0, 60, 3 * horizon), jnp.float32)
    vel = jnp.zeros(3 * horizon, jnp.float32)
    lam = jnp.array(rng.uniform(0, 300, horizon), jnp.float32)
    rdy = jnp.zeros(horizon, jnp.float32)
    state = jnp.array([100.0, 0.0, 0.0, 0.0], jnp.float32)
    params = params_vec(lr=10.0)
    zk, _, _, _ = pgd_step(z, vel, vel, jnp.ones(1), lam, rdy, state, params,
                           cold_steps=C.COLD_STEPS)
    zk = np.asarray(zk)
    w_max, mu = C.W_MAX, C.MU
    assert (zk >= 0.0).all()
    assert (zk[:horizon] <= w_max + 1e-4).all()
    assert (zk[horizon:2 * horizon] <= w_max + 1e-4).all()
    assert (zk[2 * horizon:] <= mu * w_max + 1e-3).all()


def test_gradient_descends():
    """A small step from a random point must not increase the cost."""
    horizon = 24
    rng = np.random.default_rng(3)
    z = jnp.array(rng.uniform(0, 10, 3 * horizon), jnp.float32)
    lam = jnp.array(rng.uniform(0, 50, horizon), jnp.float32)
    rdy = jnp.zeros(horizon, jnp.float32)
    state = jnp.array([5.0, 3.0, 1.0, 0.0], jnp.float32)
    params = params_vec(lr=1e-5, momentum=0.0)
    zero = jnp.zeros(3 * horizon, jnp.float32)
    one = jnp.ones(1, jnp.float32)
    z1, m1, v1, c0 = pgd_step(z, zero, zero, one, lam, rdy, state, params,
                              cold_steps=11)
    _, _, _, c1 = pgd_step(z1, m1, v1, one + 1.0, lam, rdy, state, params,
                           cold_steps=11)
    assert float(c1[0]) <= float(c0[0]) + 1e-3


def test_zero_weights_zero_gradient():
    """With every weight zero the objective is identically 0 and z is fixed
    (up to projection into the box)."""
    horizon = 8
    z = jnp.array(np.linspace(0, 5, 3 * horizon), jnp.float32)
    vel = jnp.zeros(3 * horizon, jnp.float32)
    lam = jnp.full((horizon,), 10.0, jnp.float32)
    rdy = jnp.zeros(horizon, jnp.float32)
    state = jnp.array([1.0, 1.0, 1.0, 0.0], jnp.float32)
    zeros = {n: 0.0 for n in C.PARAM_NAMES
             if n not in ("mu", "l_cold", "l_warm", "w_max", "lr", "momentum", "grad_clip")}
    params = params_vec(**zeros)
    z1, m1, v1, c = pgd_step(z, vel, vel, jnp.ones(1), lam, rdy, state,
                             params, cold_steps=2)
    assert float(c[0]) == 0.0
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z), atol=1e-7)
    np.testing.assert_allclose(np.asarray(m1), 0.0, atol=1e-7)


@pytest.mark.parametrize("cold_steps", [0, 1, 11, 23])
def test_ready_shift_boundaries(cold_steps):
    """readyCold windowing: cold starts contribute exactly D steps later."""
    horizon = 24
    x = np.zeros(horizon, np.float32)
    x[0] = 4.0
    z = jnp.array(np.concatenate([x, np.zeros(2 * horizon)]), jnp.float32)
    lam = jnp.zeros(horizon, jnp.float32)
    rdy = jnp.zeros(horizon, jnp.float32)
    state = jnp.array([0.0, 0.0, 0.0, 0.0], jnp.float32)
    q, w = ref.rollout_ref(z, lam, rdy, state, cold_steps)
    w = np.asarray(w)
    for k in range(horizon):
        expect = 4.0 if k > cold_steps else 0.0
        assert w[k] == expect, (k, w[k], expect)
