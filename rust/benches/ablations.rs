//! Ablation benches: shaping on/off, horizon, clipping gamma, alpha —
//! the sensitivity analysis Sec. V-E calls for.

use mpc_serverless::experiments::ablations;
use mpc_serverless::util::bench::Table;

fn main() {
    println!("=== Ablations (bursty workload) ===");

    let (with, without) = ablations::shaping_ablation(1800.0, 17);
    println!("\n-- request shaping --");
    let mut t = Table::new(&["variant", "mean ms", "p95 ms", "cold requests", "forced"]);
    t.row(&["with shaping".into(), format!("{:.0}", with.mean_ms),
            format!("{:.0}", with.p95_ms), with.cold_requests.to_string(), "-".into()]);
    t.row(&["no shaping".into(), format!("{:.0}", without.mean_ms),
            format!("{:.0}", without.p95_ms), without.cold_requests.to_string(), "-".into()]);
    t.print();

    println!("\n-- horizon H --");
    let mut t = Table::new(&["H", "mean ms", "p95 ms", "mean warm"]);
    for (h, r) in ablations::horizon_sweep(1800.0, 19, &[8, 16, 24]) {
        t.row(&[h.to_string(), format!("{:.0}", r.mean_ms),
                format!("{:.0}", r.p95_ms), format!("{:.1}", r.mean_warm)]);
    }
    t.print();

    println!("\n-- clipping confidence gamma (Eq. 2) --");
    let mut t = Table::new(&["gamma", "mean ms", "p95 ms", "mean warm"]);
    for (g, r) in ablations::gamma_sweep(1800.0, 21, &[1.0, 3.0, 5.0]) {
        t.row(&[g.to_string(), format!("{:.0}", r.mean_ms),
                format!("{:.0}", r.p95_ms), format!("{:.1}", r.mean_warm)]);
    }
    t.print();

    println!("\n-- cold-delay weight alpha (Eq. 3) --");
    let mut t = Table::new(&["alpha", "mean ms", "cold requests", "mean warm"]);
    for (a, r) in ablations::alpha_sweep(1800.0, 23, &[1.0, 4.0, 8.0, 16.0]) {
        t.row(&[a.to_string(), format!("{:.0}", r.mean_ms),
                r.cold_requests.to_string(), format!("{:.1}", r.mean_warm)]);
    }
    t.print();
}
