//! Fig. 10 (new scenario axis): multi-tenant scaling — aggregate and
//! per-function tail latency vs function count under Zipf popularity,
//! every policy on the same interleaved workload.

use mpc_serverless::config::{FleetConfig, Policy, TraceKind};
use mpc_serverless::experiments::tenant::run_tenant_matrix;
use mpc_serverless::util::bench::Table;

fn main() {
    let duration_s = 1800.0;
    let seed = 3;
    println!(
        "=== Fig. 10: multi-tenant scaling (bursty, {:.0} min, zipf 1.1) ===",
        duration_s / 60.0
    );
    let mut t = Table::new(&[
        "functions", "policy", "p50 ms", "p99 ms", "cold %", "evictions", "mean warm",
    ]);
    for functions in [1u32, 2, 4, 8, 16] {
        let m = run_tenant_matrix(
            TraceKind::SyntheticBursty,
            duration_s,
            seed,
            functions,
            1.1,
            &FleetConfig::default(),
        );
        for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
            let r = m.report(policy);
            let cold_pct = if r.completed > 0 {
                100.0 * r.cold_requests as f64 / r.completed as f64
            } else {
                0.0
            };
            t.row(&[
                functions.to_string(),
                r.policy.clone(),
                format!("{:.0}", r.p50_ms),
                format!("{:.0}", r.p99_ms),
                format!("{cold_pct:.1}"),
                r.counters.evictions.to_string(),
                format!("{:.1}", r.mean_warm),
            ]);
        }
    }
    t.print();
    println!("\nmore functions = a fragmented warm pool inside one replica budget;");
    println!("per-function prewarm splitting + shaping keeps the tail flat where");
    println!("reactive scheduling pays a cold start per (function, burst) pair.");
}
