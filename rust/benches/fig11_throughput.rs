//! Fig. 11 (new scenario axis): simulator throughput at fleet scale —
//! events/second and wall-clock as nodes × functions × load grow.
//!
//! This is the macro-benchmark behind BENCH_throughput.json: the control
//! plane's own bookkeeping must stay near-free (SPES's observation, and
//! the premise of the paper's Fig. 8 overhead claim), so events/sec
//! should stay roughly flat as the fleet and the function count scale.
//! Before the indexed-platform refactor every control step scanned all
//! containers (O(nodes × functions × containers)); the 8×32 cells are
//! the regression canary for that cost.
//!
//! Wall-clock columns vary run to run; every other column is
//! deterministic in the seed. To refresh the committed record, write the
//! sweep to a scratch file and copy its `cells` array into the
//! `after.cells` slot of BENCH_throughput.json (which also carries the
//! protocol and the before/after provenance — do not overwrite it):
//!   mpc-serverless bench-throughput --out BENCH_throughput.after.json

use mpc_serverless::config::{PlacementPolicy, Policy, TraceKind};
use mpc_serverless::experiments::throughput::run_sweep;

fn main() {
    let duration_s = 600.0;
    let seed = 42;
    println!(
        "=== Fig. 11: simulator throughput (bursty, {:.0} min per cell, seed {seed}) ===",
        duration_s / 60.0
    );
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        let sweep = run_sweep(
            policy,
            TraceKind::SyntheticBursty,
            duration_s,
            seed,
            &[1, 2, 4, 8],
            &[1],
            &[1, 8, 32],
            &[1, 4],
            PlacementPolicy::WarmFirst,
        );
        println!("\n-- {} --", policy.name());
        sweep.print_table();
        println!("{}", sweep.to_json());
    }
    println!("\nflat events/sec across the grid = O(1) platform gauges doing their job;");
    println!("a slope in the functions or nodes column means a scan crept back in.");
}
