//! Fig. 12 (new scenario axis): fleet elasticity — the drain → rejoin
//! scenario under each migration policy, for the reactive baseline and
//! the MPC controller.
//!
//! What to look for (docs/ARCHITECTURE.md "Fleet elasticity"):
//!
//! * the rejoin columns must be nonzero — the drained node reabsorbs
//!   load after restore (dispatches via placement, prewarms via the
//!   live-capacity-scaled MPC budget);
//! * under `demand-gap` / `idle-spread` the migrations column shows
//!   idle warm capacity moving between nodes (MPC cells only — the
//!   rebalancing pass actuates from the control loop, so the reactive
//!   baseline never migrates);
//! * p99 / cold-start deltas vs `off` quantify what rebalancing buys on
//!   this workload.

use mpc_serverless::config::{MigrationPolicy, Policy};
use mpc_serverless::experiments::elasticity::{print_table, run_sweep, ElasticityParams};

fn main() {
    let params = ElasticityParams {
        duration_s: 1800.0,
        seed: 3,
        ..Default::default()
    };
    println!(
        "=== Fig. 12: fleet elasticity (bursty, {:.0} min, {} nodes, drain node {} @ {:.0}s, rejoin @ {:.0}s) ===",
        params.duration_s / 60.0,
        params.nodes,
        params.fail_node,
        params.fail_at_s,
        params.restore_at_s
    );
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        println!("\n-- {} --", policy.name());
        let cells = run_sweep(&params, &[policy], &MigrationPolicy::ALL);
        print_table(&cells, params.fail_node);
    }
    println!("\nnonzero rejoin columns = the restored node reabsorbed load;");
    println!("migrations move idle warm capacity toward forecast demand (MPC cells).");
}
