//! Fig. 13 (new scenario axis): adaptive per-function keep-alive — the
//! retention leg of the control triangle — vs the fixed profile windows,
//! on the resource-time vs P99 frontier.
//!
//! What to look for (docs/ARCHITECTURE.md "Retention control"):
//!
//! * the adaptive rows must show materially lower idle / keep-alive
//!   container-seconds than their fixed twins — during forecast lulls
//!   the horizon clamps to the floor and the sweep drains the idle pool
//!   the fixed policy would have held for the full profile window;
//! * `saved s` / `early exp` quantify the earlier-than-profile expiries
//!   (structurally zero on the fixed rows);
//! * P99 should hold roughly level: the forecasts that shrink a
//!   function's horizon during a lull are the same ones that re-prewarm
//!   it before the next burst, so the trade is asymmetric — that is the
//!   SPES (arXiv:2403.17574) observation this axis reproduces, and the
//!   paper's 34% resource-usage headline is the target.

use mpc_serverless::experiments::keepalive::{
    print_table, run_sweep, KeepAliveParams, DEFAULT_SCENARIOS,
};

fn main() {
    let params = KeepAliveParams {
        duration_s: 1800.0,
        seed: 3,
        ..Default::default()
    };
    println!(
        "=== Fig. 13: adaptive keep-alive (MPC, {:.0} min, floor {:.0}s, idle-cost {} / cold-weight {}) ===",
        params.duration_s / 60.0,
        params.min_s,
        params.idle_cost,
        params.cold_weight
    );
    let cells = run_sweep(&params, &DEFAULT_SCENARIOS);
    print_table(&cells);
    println!("\nadaptive rows should sit strictly left on the resource axis (idle/keep-alive s)");
    println!("at equal-or-better P99 — the resource-time vs tail-latency frontier.");
}
