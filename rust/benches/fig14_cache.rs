//! Fig. 14 (new scenario axis): the per-node image/layer cache — dynamic
//! cold-start cost — swept over a capacity ladder against the
//! constant-`L_cold` baseline (`--image-cache off`).
//!
//! What to look for (docs/ARCHITECTURE.md "Cold-start fidelity"):
//!
//! * `pulled MiB` must fall monotonically as the per-node store grows
//!   (LRU inclusion: a bigger cache never pulls more on the same access
//!   sequence), and the layer hit-rate must rise with it;
//! * `eff L_cold s` — the mean cost the charging sites actually billed —
//!   shrinks toward the irreducible init slice as capacity absorbs the
//!   image distribution, and P99 follows;
//! * the `off` row keeps every cache counter at zero: it is the
//!   regression-pinned constant-cost seed path, byte-identical to the
//!   pre-cache simulator.

use mpc_serverless::experiments::cache::{print_table, run_sweep, CacheParams};

fn main() {
    let params = CacheParams {
        duration_s: 1800.0,
        seed: 3,
        ..Default::default()
    };
    println!(
        "=== Fig. 14: image-cache capacity ladder (MPC, {:.0} min, {} nodes x {} functions, {} MiB/s pulls, init-frac {}) ===",
        params.duration_s / 60.0,
        params.nodes,
        params.functions,
        params.bandwidth_mibps,
        params.init_fraction
    );
    let cells = run_sweep(&params);
    print_table(&cells);
    println!("\nlarger rungs should pull fewer bytes at a rising hit-rate, dragging the effective");
    println!("cold cost — and with it the tail — down toward the init-only floor.");
}
