//! Fig. 1: cold-start motivation — response time per request and the
//! warm-container staircase for 50 random-arrival requests (OpenWhisk).

use mpc_serverless::experiments::fig1;
use mpc_serverless::util::bench::Table;

fn main() {
    println!("=== Fig. 1: cold start motivation (50 requests, OpenWhisk default) ===");
    let mut t = Table::new(&["seed", "cold starts", "warm mean s", "cold mean s", "ratio"]);
    for seed in [42, 7, 19] {
        let r = fig1::run(seed);
        t.row(&[seed.to_string(), r.cold_starts.to_string(),
                format!("{:.3}", r.warm_exec_mean_s),
                format!("{:.2}", r.cold_response_mean_s),
                format!("{:.0}x", r.cold_response_mean_s / r.warm_exec_mean_s.max(1e-9))]);
    }
    t.print();
    println!("\npaper: 8 cold starts, 0.28 s warm, ~10.5 s cold (38x)");
}
