//! Fig. 4: forecast accuracy + runtime, Fourier vs ARIMA, on the
//! Azure-like and synthetic bursty traces.

use mpc_serverless::experiments::fig4;
use mpc_serverless::util::bench::Table;

fn main() {
    println!("=== Fig. 4: forecast error, Fourier vs ARIMA (4 h rolling, horizon eval) ===");
    let evals = fig4::run(14400.0, 11);
    let mut t = Table::new(&["trace", "predictor", "accuracy %", "WAPE", "sMAPE", "RMSE", "ms/call"]);
    for e in &evals {
        t.row(&[e.trace.clone(), e.predictor.clone(),
                format!("{:.1}", e.accuracy_pct), format!("{:.3}", e.wape),
                format!("{:.3}", e.smape), format!("{:.2}", e.rmse),
                format!("{:.3}", e.mean_runtime_ms)]);
    }
    t.print();
    println!("\npaper: azure 86.2% (fourier) vs 82.5% (arima); synthetic ~95% both;");
    println!("       runtime 0.1 ms vs 10 ms (their ARIMA = statsmodels MLE refit)");
}
