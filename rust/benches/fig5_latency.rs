//! Fig. 5: % improvement in total response time (mean/p90/p95) over the
//! OpenWhisk default policy, for MPC-Scheduler and IceBreaker, on both
//! workloads (60-minute runs from a cold platform).

use mpc_serverless::config::{Policy, TraceKind};
use mpc_serverless::experiments::fig5_7::run_matrix;
use mpc_serverless::util::bench::Table;

fn main() {
    println!("=== Fig. 5: response-time improvement over OpenWhisk (60 min) ===");
    for trace in [TraceKind::AzureLike, TraceKind::SyntheticBursty] {
        let m = run_matrix(trace, 3600.0, 3);
        println!("\n-- {} --", trace.name());
        let mut t = Table::new(&["policy", "mean %", "p90 %", "p95 %", "mean ms", "cold"]);
        for (p, r) in [(Policy::Mpc, &m.mpc), (Policy::IceBreaker, &m.icebreaker)] {
            let i = m.improvement(p);
            t.row(&[p.name().to_string(), format!("{:+.1}", i.mean_pct),
                    format!("{:+.1}", i.p90_pct), format!("{:+.1}", i.p95_pct),
                    format!("{:.0}", r.mean_ms), r.counters.cold_starts.to_string()]);
        }
        t.row(&["openwhisk".into(), "0.0".into(), "0.0".into(), "0.0".into(),
                format!("{:.0}", m.openwhisk.mean_ms),
                m.openwhisk.counters.cold_starts.to_string()]);
        t.print();
    }
    println!("\npaper: azure 17.9/20.6/23.6 (MPC), 13.9/17.1/18.0 (IB);");
    println!("       synthetic 82.9/85.5/82.6 (MPC), 67.7/51.1/45.4 (IB)");
}
