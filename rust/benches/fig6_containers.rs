//! Fig. 6: % reduction in warm-container usage (1-minute samples) vs the
//! OpenWhisk default policy.

use mpc_serverless::config::{Policy, TraceKind};
use mpc_serverless::experiments::fig5_7::run_matrix;
use mpc_serverless::util::bench::Table;

fn main() {
    println!("=== Fig. 6: warm-container usage reduction vs OpenWhisk (60 min) ===");
    for trace in [TraceKind::AzureLike, TraceKind::SyntheticBursty] {
        let m = run_matrix(trace, 3600.0, 3);
        println!("\n-- {} --", trace.name());
        let mut t = Table::new(&["policy", "mean warm", "reduction %"]);
        for (p, r) in [(Policy::Mpc, &m.mpc), (Policy::IceBreaker, &m.icebreaker)] {
            t.row(&[p.name().to_string(), format!("{:.1}", r.mean_warm),
                    format!("{:+.1}", m.improvement(p).warm_usage_pct)]);
        }
        t.row(&["openwhisk".into(), format!("{:.1}", m.openwhisk.mean_warm), "0.0".into()]);
        t.print();
    }
    println!("\npaper: azure 34.8% (MPC) / 17.4% (IB); synthetic 19.1% / 14.8%");
}
