//! Fig. 7: % reduction in keep-alive duration (last activation to
//! reclamation) vs the OpenWhisk default policy.

use mpc_serverless::config::{Policy, TraceKind};
use mpc_serverless::experiments::fig5_7::run_matrix;
use mpc_serverless::util::bench::Table;

fn main() {
    println!("=== Fig. 7: keep-alive duration reduction vs OpenWhisk (60 min) ===");
    for trace in [TraceKind::AzureLike, TraceKind::SyntheticBursty] {
        let m = run_matrix(trace, 3600.0, 3);
        println!("\n-- {} --", trace.name());
        let mut t = Table::new(&["policy", "keep-alive s", "reduction %", "idle s"]);
        for (p, r) in [(Policy::Mpc, &m.mpc), (Policy::IceBreaker, &m.icebreaker)] {
            t.row(&[p.name().to_string(), format!("{:.0}", r.keepalive_total_s),
                    format!("{:+.1}", m.improvement(p).keepalive_pct),
                    format!("{:.0}", r.idle_total_s)]);
        }
        t.row(&["openwhisk".into(), format!("{:.0}", m.openwhisk.keepalive_total_s),
                "0.0".into(), format!("{:.0}", m.openwhisk.idle_total_s)]);
        t.print();
    }
    println!("\npaper: azure 64.3% (MPC) / 43.0% (IB); synthetic 15.7% / 11.3%");
}
