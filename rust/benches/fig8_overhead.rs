//! Fig. 8: control-step overhead breakdown — forecast vs optimizer —
//! on the in-process mirror and (when artifacts exist) the HLO runtime.

use mpc_serverless::config::Weights;
use mpc_serverless::experiments::fig8;
use mpc_serverless::forecast::FourierForecaster;
use mpc_serverless::mpc::RustSolver;
use mpc_serverless::runtime::{ArtifactMeta, Engine, ForecastModule, HloForecaster, HloSolver, MpcModule};
use mpc_serverless::util::bench::Table;

fn main() {
    println!("=== Fig. 8: control overhead per step ===");
    let mut t = Table::new(&["backend", "forecast ms (mean)", "optimizer ms (mean)", "optimizer p95"]);
    let mut r = fig8::run_rust(50);
    t.row(&[r.backend.clone(), format!("{:.3}", r.forecast_ms.mean()),
            format!("{:.3}", r.solve_ms.mean()), format!("{:.3}", r.solve_ms.p95())]);

    if ArtifactMeta::available() {
        let meta = ArtifactMeta::load(&ArtifactMeta::default_dir()).unwrap();
        let engine = Engine::cpu().unwrap();
        let fc = ForecastModule::load(&engine, &meta).unwrap();
        let mp = MpcModule::load(&engine, &meta).unwrap();
        let mut f = HloForecaster::new(fc, 5.0);
        let mut s = HloSolver::new(mp, Weights::default());
        let mut hr = fig8::measure("hlo-pjrt", &mut f, &mut s, meta.horizon,
                                   meta.window, 30, 99);
        t.row(&[hr.backend.clone(), format!("{:.3}", hr.forecast_ms.mean()),
                format!("{:.3}", hr.solve_ms.mean()), format!("{:.3}", hr.solve_ms.p95())]);
    } else {
        println!("(artifacts missing: HLO row skipped — run `make artifacts`)");
    }
    // keep the rust mirror row honest about variance
    let _ = (&mut r.forecast_ms, &mut FourierForecaster::default(), RustSolver::new(Weights::default(), 1, 1));
    t.print();
    println!("\npaper: forecast 0.1 ms, optimizer 38 ms (cvxpy); budget = 30 s interval");
}
