//! Fig. 9 (new scenario axis): fleet scaling — latency, cold-start rate,
//! and keep-alive container-seconds vs invoker node count under each
//! placement policy, at fixed total capacity (64 replicas split evenly),
//! plus the simulator's wall-clock throughput per cell.

use std::time::Instant;

use mpc_serverless::config::{
    secs, ExperimentConfig, FleetConfig, PlacementPolicy, Policy, TraceKind,
};
use mpc_serverless::experiments::fig4::trace_for;
use mpc_serverless::experiments::run_experiment;
use mpc_serverless::util::bench::Table;

fn main() {
    let duration_s = 1800.0;
    let seed = 3;
    let trace = trace_for(TraceKind::SyntheticBursty, secs(duration_s), seed);
    println!(
        "=== Fig. 9: fleet scaling (bursty, {:.0} min, {} requests, 64 total replicas) ===",
        duration_s / 60.0,
        trace.len()
    );
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        println!("\n-- {} --", policy.name());
        let mut t = Table::new(&[
            "nodes", "placement", "p50 ms", "p99 ms", "cold", "keep-alive s", "sim ms",
        ]);
        for nodes in [1u32, 2, 4, 8] {
            let capacities =
                mpc_serverless::cluster::fleet::split_capacity(64, nodes).expect("nodes <= 64");
            for placement in PlacementPolicy::ALL {
                let cfg = ExperimentConfig {
                    trace: TraceKind::SyntheticBursty,
                    fleet: FleetConfig {
                        nodes,
                        capacities: Some(capacities.clone()),
                        placement,
                        ..Default::default()
                    },
                    duration: secs(duration_s),
                    seed,
                    ..Default::default()
                };
                let t0 = Instant::now();
                let r = run_experiment(&cfg, policy, &trace);
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                t.row(&[
                    nodes.to_string(),
                    placement.name().to_string(),
                    format!("{:.0}", r.p50_ms),
                    format!("{:.0}", r.p99_ms),
                    r.counters.cold_starts.to_string(),
                    format!("{:.0}", r.keepalive_total_s),
                    format!("{wall_ms:.0}"),
                ]);
            }
        }
        t.print();
    }
    println!("\nfixed total capacity: more nodes = more warm-pool fragmentation;");
    println!("warm-first placement recovers most of the single-pool reuse.");
}
