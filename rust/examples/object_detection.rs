//! Fig. 1 reproduction: the EfficientDet-style object-detection function on
//! the OpenWhisk default policy — 50 requests with random arrivals against
//! a cold platform, showing the ~38x cold/warm response gap and the
//! warm-container staircase.
//!
//!     cargo run --release --example object_detection

use mpc_serverless::experiments::fig1;

fn main() {
    let r = fig1::run(42);
    println!("Fig. 1(a): response time per request (s)");
    for (i, rt) in r.response_times_s.iter().enumerate() {
        let bar = "#".repeat((rt / 0.25).min(60.0) as usize);
        let tag = if *rt > 5.0 { " <- cold start" } else { "" };
        println!("  req {:>2}  {:>7.3} s  {}{}", i + 1, rt, bar, tag);
    }
    println!("\nFig. 1(b): warm containers over time");
    let mut last = u32::MAX;
    for (t, w) in &r.warm_over_time {
        if *w != last {
            println!("  t = {:>6.1} s  warm = {}", t, w);
            last = *w;
        }
    }
    println!(
        "\ncold starts: {} | warm exec mean: {:.3} s | cold response mean: {:.2} s ({}x)",
        r.cold_starts,
        r.warm_exec_mean_s,
        r.cold_response_mean_s,
        (r.cold_response_mean_s / r.warm_exec_mean_s.max(1e-9)) as u32
    );
}
