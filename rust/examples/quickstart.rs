//! Quickstart: run the MPC scheduler on a short bursty workload and print
//! the end-to-end latency/resource summary next to the OpenWhisk baseline.
//!
//!     cargo run --release --example quickstart

use mpc_serverless::config::{secs, ExperimentConfig, Policy, TraceKind};
use mpc_serverless::experiments::run_experiment;
use mpc_serverless::workload::synthetic::{generate, SyntheticConfig};

fn main() {
    let cfg = ExperimentConfig {
        trace: TraceKind::SyntheticBursty,
        duration: secs(1200.0),
        seed: 7,
        ..Default::default()
    };
    let trace = generate(&SyntheticConfig::default(), cfg.duration, cfg.seed);
    println!("workload: {} requests over {:.0} s\n", trace.len(), 1200.0);
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        let r = run_experiment(&cfg, policy, &trace);
        println!(
            "{:<10} mean {:>8.0} ms | p90 {:>8.0} ms | p95 {:>8.0} ms | cold starts {:>3} | mean warm {:>5.1} | keep-alive {:>7.0} s",
            r.policy, r.mean_ms, r.p90_ms, r.p95_ms, r.counters.cold_starts, r.mean_warm, r.keepalive_total_s
        );
    }
    println!("\n(see examples/trace_replay.rs for the full HLO-backed pipeline)");
}
