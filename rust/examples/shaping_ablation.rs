//! Ablation: predictive request shaping on/off (the paper's key insight,
//! Sec. V-B) plus horizon and alpha sweeps.
//!
//!     cargo run --release --example shaping_ablation

use mpc_serverless::experiments::ablations;
use mpc_serverless::util::bench::Table;

fn main() {
    let (with, without) = ablations::shaping_ablation(1800.0, 17);
    println!("== request shaping ablation (bursty workload, 30 min) ==");
    let mut t = Table::new(&["variant", "mean ms", "p90 ms", "p95 ms", "cold requests"]);
    for (name, r) in [("with shaping", &with), ("no shaping", &without)] {
        t.row(&[name.to_string(), format!("{:.0}", r.mean_ms),
                format!("{:.0}", r.p90_ms), format!("{:.0}", r.p95_ms),
                format!("{}", r.cold_requests)]);
    }
    t.print();

    println!("\n== horizon sweep ==");
    let mut t = Table::new(&["H", "mean ms", "p95 ms", "mean warm"]);
    for (h, r) in ablations::horizon_sweep(1200.0, 19, &[8, 16, 24]) {
        t.row(&[h.to_string(), format!("{:.0}", r.mean_ms),
                format!("{:.0}", r.p95_ms), format!("{:.1}", r.mean_warm)]);
    }
    t.print();

    println!("\n== cold-delay weight (alpha) sweep ==");
    let mut t = Table::new(&["alpha", "mean ms", "cold requests", "mean warm"]);
    for (a, r) in ablations::alpha_sweep(1200.0, 23, &[1.0, 4.0, 8.0, 16.0]) {
        t.row(&[a.to_string(), format!("{:.0}", r.mean_ms),
                format!("{}", r.cold_requests), format!("{:.1}", r.mean_warm)]);
    }
    t.print();
}
