//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads ALL THREE AOT artifacts through PJRT — the Fourier forecaster,
//! the MPC solver, and the detector payload — and serves a 60-minute
//! bursty trace where every warm execution also runs the real detector
//! HLO on a synthetic frame. Python never runs here. Reports the latency
//! and throughput summary plus the measured control overhead (Fig. 8).
//!
//!     make artifacts && cargo run --release --example trace_replay [--duration-s 3600]

use std::time::Instant;

use mpc_serverless::config::{secs, ExperimentConfig, Policy, TraceKind};
use mpc_serverless::coordinator::controller::MpcScheduler;
use mpc_serverless::experiments::{run_experiment, run_with_scheduler};
use mpc_serverless::runtime::{
    ArtifactMeta, DetectorModule, Engine, ForecastModule, HloForecaster, HloSolver, MpcModule,
};
use mpc_serverless::util::cli::Cli;
use mpc_serverless::workload::synthetic::{generate, SyntheticConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    mpc_serverless::util::logging::init();
    let cli = Cli::new("trace_replay", "end-to-end HLO-backed serving run")
        .flag("duration-s", "3600", "trace duration in seconds")
        .flag("seed", "3", "workload seed");
    let args = match cli.parse(&std::env::args().skip(1).collect::<Vec<_>>()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            std::process::exit(2);
        }
    };
    let duration_s: f64 = args.get_f64("duration-s")?;
    let seed = args.get_u64("seed")?;

    if !ArtifactMeta::available() {
        return Err("artifacts missing — run `make artifacts` first".into());
    }
    let meta = ArtifactMeta::load(&ArtifactMeta::default_dir())?;
    let engine = Engine::cpu()?;
    let forecast = ForecastModule::load(&engine, &meta)?;
    let mpc = MpcModule::load(&engine, &meta)?;
    let detector = DetectorModule::load(&engine, &meta)?;
    println!("loaded artifacts: forecast + mpc + detector (window={}, horizon={})",
             meta.window, meta.horizon);

    // prove the payload path: run the detector on a synthetic frame per
    // simulated warm execution sample (the latency semantics come from the
    // calibrated L_warm; this keeps real compute on the serving path)
    let frame: Vec<f32> = (0..meta.img_size * meta.img_size * 3)
        .map(|i| (i % 255) as f32 / 255.0)
        .collect();
    let t0 = Instant::now();
    let scores = detector.detect(&frame)?;
    println!("detector smoke: scores[0..4] = {:?} ({:.2} ms/inference)",
             &scores[..4], t0.elapsed().as_secs_f64() * 1e3);

    let cfg = ExperimentConfig {
        trace: TraceKind::SyntheticBursty,
        duration: secs(duration_s),
        seed,
        ..Default::default()
    };
    let trace = generate(&SyntheticConfig::default(), cfg.duration, cfg.seed);
    println!("\nworkload: {} requests over {:.0} s", trace.len(), duration_s);

    // HLO-backed MPC scheduler (the deployed configuration)
    let sched = MpcScheduler::new(
        cfg.controller.clone(),
        Box::new(HloForecaster::new(forecast, cfg.controller.gamma_clip as f32)),
        Box::new(HloSolver::new(mpc, cfg.controller.weights)),
    );
    let wall = Instant::now();
    let r = run_with_scheduler(&cfg, Box::new(sched), &trace);
    let wall_s = wall.elapsed().as_secs_f64();

    // baseline for context
    let ow = run_experiment(&cfg, Policy::OpenWhisk, &trace);

    println!("\n== MPC (HLO-backed) vs OpenWhisk default ==");
    for rep in [&r, &ow] {
        println!(
            "{:<10} mean {:>8.0} ms | p90 {:>8.0} ms | p95 {:>8.0} ms | cold {:>4} | warm {:>5.1} | keep-alive {:>8.0} s",
            rep.policy, rep.mean_ms, rep.p90_ms, rep.p95_ms,
            rep.counters.cold_starts, rep.mean_warm, rep.keepalive_total_s
        );
    }
    println!(
        "\ncontrol overhead (Fig. 8, HLO path): forecast {:.3} ms | optimizer {:.3} ms per step",
        r.forecast_overhead_ms, r.solve_overhead_ms
    );
    println!(
        "simulated {} requests in {:.2} s wall ({:.0} req/s sim throughput)",
        r.completed, wall_s, r.completed as f64 / wall_s.max(1e-9)
    );
    println!("report: {}", r.to_json());
    Ok(())
}
