//! IceBreaker baseline (Roy et al., ASPLOS'22), adapted to a homogeneous
//! single node exactly as the paper's evaluation does (Sec. IV): the
//! server-heterogeneity placement is disabled; what remains is the
//! Fourier-based invocation predictor driving proactive prewarming and
//! utility-driven retention.
//!
//! Two deliberate limitations the paper exploits (Sec. II / V-B):
//! * arrivals are forwarded **immediately** — no request shaping, so a
//!   request landing before a prewarmed container is ready still eats the
//!   full cold start;
//! * prewarming completion is **not coordinated** with dispatch.

use std::time::Instant;

use crate::cluster::RequestId;
use crate::config::{ControllerConfig, Micros};
use crate::coordinator::{Ctx, Scheduler};
use crate::forecast::Forecaster;
use crate::util::timeseries::RingBuffer;
use crate::workload::tenant::{split_budget, FunctionId};

pub struct IceBreaker {
    cc: ControllerConfig,
    history: RingBuffer,
    arrivals_this_interval: u32,
    forecaster: Box<dyn Forecaster>,
    /// Idle containers beyond the forecast target are only reclaimed after
    /// staying unused for this long (utility retention window).
    pub retention: Micros,
    /// Number of horizon steps whose peak forecast sizes the warm pool
    /// (lead time covers the cold start latency).
    pub lead_steps: usize,
    /// Per-function EWMA of interval arrivals (multi-tenant prewarm
    /// split; empty in a single-tenant run).
    fn_recent: Vec<f64>,
    /// Per-function arrivals in the open interval.
    fn_arrivals: Vec<u32>,
}

impl IceBreaker {
    pub fn new(cc: ControllerConfig, forecaster: Box<dyn Forecaster>) -> Self {
        let window = cc.window;
        let lead = cc.cold_steps + 2;
        IceBreaker {
            cc,
            history: RingBuffer::new(window),
            arrivals_this_interval: 0,
            forecaster,
            retention: 240_000_000, // 4 min of unused warmth before release
            lead_steps: lead,
            fn_recent: Vec::new(),
            fn_arrivals: Vec::new(),
        }
    }

    /// Enable per-function arrival tracking for an `n`-function workload
    /// (no-op for `n <= 1`): IceBreaker's prewarm budget is then split by
    /// each function's recent arrival share, mirroring its per-function
    /// predictor without granting it the MPC's shaping advantage.
    pub fn with_functions(mut self, n: usize) -> Self {
        if n > 1 {
            self.fn_recent = vec![0.0; n];
            self.fn_arrivals = vec![0; n];
        }
        self
    }

    /// Warm-pool target: peak forecast over the lead window, converted to
    /// containers via the service rate (utility function, homogeneous form).
    fn target_warm(&mut self, lam: &[f64]) -> u32 {
        let lead = self.lead_steps.min(lam.len());
        let peak = lam[..lead].iter().cloned().fold(0.0f64, f64::max);
        (peak / self.cc.weights.mu).ceil() as u32
    }
}

impl Scheduler for IceBreaker {
    fn on_arrival(&mut self, req: RequestId, ctx: &mut Ctx) {
        self.arrivals_this_interval += 1;
        if !self.fn_arrivals.is_empty() {
            let f = ctx.func_of(req) as usize;
            if let Some(c) = self.fn_arrivals.get_mut(f) {
                *c += 1;
            }
        }
        ctx.dispatch(req); // no shaping
    }

    fn on_control_tick(&mut self, ctx: &mut Ctx) {
        self.history.push(self.arrivals_this_interval as f64);
        self.arrivals_this_interval = 0;
        for (recent, arr) in self.fn_recent.iter_mut().zip(&mut self.fn_arrivals) {
            // EWMA so a function's share survives short gaps between its
            // invocations (IceBreaker's utility window analog)
            *recent = 0.7 * *recent + 0.3 * *arr as f64;
            *arr = 0;
        }

        let pad = self.history.recent_mean(self.cc.window);
        let hist = self.history.to_padded_vec(pad);
        let t0 = Instant::now();
        let lam = self.forecaster.forecast(&hist, self.cc.horizon);
        let forecast_ns = t0.elapsed().as_nanos() as f64;
        let t1 = Instant::now();
        let target = self.target_warm(&lam);
        let decide_ns = t1.elapsed().as_nanos() as f64;
        ctx.recorder.on_control_overhead(forecast_ns, decide_ns);

        let provisioned = ctx.fleet.warm_count() + ctx.fleet.cold_starting_count();
        if provisioned < target {
            let need = target - provisioned;
            if self.fn_recent.len() > 1 {
                // split the budget by recent per-function arrival share
                for (f, n) in split_budget(&self.fn_recent, need).into_iter().enumerate() {
                    if n > 0 {
                        ctx.prewarm_for(f as FunctionId, n);
                    }
                }
            } else {
                ctx.prewarm(need);
            }
        } else if provisioned > target {
            // release only long-idle containers (retention-aware), never
            // below the forecast target
            let over = provisioned - target;
            let eligible = ctx
                .fleet
                .idle_containers_older_than(self.retention, ctx.now);
            let n = over.min(eligible);
            if n > 0 {
                ctx.reclaim(n);
            }
        }
    }

    fn tick_interval(&self) -> Option<Micros> {
        Some(self.cc.dt)
    }

    fn name(&self) -> &'static str {
        "icebreaker"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fleet;
    use crate::config::ExperimentConfig;
    use crate::coordinator::Ev;
    use crate::forecast::FourierForecaster;
    use crate::metrics::Recorder;
    use crate::simulator::EventQueue;

    fn make() -> (IceBreaker, Fleet, EventQueue<Ev>, Recorder, ExperimentConfig) {
        let cfg = ExperimentConfig::default();
        let sched = IceBreaker::new(
            cfg.controller.clone(),
            Box::new(FourierForecaster::default()),
        );
        let fleet = Fleet::new(&cfg.fleet, &cfg.platform, 5);
        (sched, fleet, EventQueue::new(), Recorder::new(16), cfg)
    }

    #[test]
    fn forwards_immediately() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        let mut ctx = Ctx {
            now: 0,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        ctx.recorder.on_arrival(0, 0);
        sched.on_arrival(0, &mut ctx);
        assert_eq!(ctx.fleet.counters().cold_starts, 1);
        assert_eq!(sched.queue_len(), 0);
    }

    #[test]
    fn sustained_load_triggers_prewarming() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        // steady history of 200 requests per 30 s interval
        for _ in 0..120 {
            sched.history.push(200.0);
        }
        let mut ctx = Ctx {
            now: 1_000_000,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        sched.on_control_tick(&mut ctx);
        // 200 req/step / mu(5.36 per step at the 1.5 s drain target) -> 38
        assert!(
            ctx.fleet.cold_starting_count() >= 15,
            "prewarmed {} containers",
            ctx.fleet.cold_starting_count()
        );
    }

    #[test]
    fn target_warm_uses_peak_over_lead() {
        let (mut sched, ..) = make();
        let mut lam = vec![0.0; 24];
        lam[2] = 53.0; // within lead window (cold_steps + 2 = 3)
        assert_eq!(sched.target_warm(&lam), 10); // ceil(53 / 5.357)
        let lam2 = vec![0.0; 24];
        assert_eq!(sched.target_warm(&lam2), 0);
    }
}
