//! Baseline policies the paper compares against (Sec. IV):
//! the OpenWhisk default reactive policy and IceBreaker adapted to a
//! homogeneous single node.

pub mod icebreaker;
pub mod openwhisk;

pub use icebreaker::IceBreaker;
pub use openwhisk::OpenWhiskDefault;
