//! OpenWhisk default policy (Sec. IV "Baseline Approaches"): purely
//! reactive — every arrival is forwarded immediately; a cold start is
//! triggered whenever no warm container is available; idle containers are
//! kept warm for a fixed 10-minute window (enforced by the platform's
//! keep-alive machinery, which the runner schedules).

use crate::cluster::RequestId;
use crate::coordinator::{Ctx, Scheduler};

#[derive(Debug, Default)]
pub struct OpenWhiskDefault;

impl Scheduler for OpenWhiskDefault {
    fn on_arrival(&mut self, req: RequestId, ctx: &mut Ctx) {
        ctx.dispatch(req);
    }

    fn name(&self) -> &'static str {
        "openwhisk"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fleet;
    use crate::config::ExperimentConfig;
    use crate::coordinator::Ev;
    use crate::metrics::Recorder;
    use crate::simulator::EventQueue;

    #[test]
    fn forwards_immediately_and_cold_starts() {
        let cfg = ExperimentConfig::default();
        let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 3);
        let mut events = EventQueue::new();
        let mut rec = Recorder::new(4);
        let mut sched = OpenWhiskDefault;
        let mut ctx = Ctx {
            now: 0,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        ctx.recorder.on_arrival(0, 0);
        sched.on_arrival(0, &mut ctx);
        assert_eq!(ctx.fleet.counters().cold_starts, 1);
        assert_eq!(ctx.events.len(), 1); // Ready event scheduled
        assert_eq!(sched.queue_len(), 0); // nothing held back
        assert!(sched.tick_interval().is_none());
    }
}
