//! Activation-completion log — the Grafana Loki analog.
//!
//! The paper's reclaim actuator (Algorithm 2) refuses to drain a container
//! until Loki shows a `[MessagingActiveAck] posted completion of activation`
//! record for every activation assigned to it. This module reproduces that
//! protocol: the platform appends an assignment record when an activation
//! starts and an ack when it completes; the safety check compares the two.

use std::collections::BTreeMap;

use crate::cluster::container::ContainerId;
use crate::cluster::RequestId;
use crate::config::Micros;

/// One `[MessagingActiveAck]`-style log line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AckRecord {
    pub container: ContainerId,
    pub activation: RequestId,
    pub time: Micros,
}

#[derive(Debug, Default)]
pub struct ActivationLog {
    /// assigned[c] = activations ever dispatched to container c.
    assigned: BTreeMap<ContainerId, u64>,
    /// acked[c] = completion acks observed for container c.
    acked: BTreeMap<ContainerId, u64>,
    /// Ring of recent ack lines (bounded, like a log retention window).
    recent: Vec<AckRecord>,
    cap: usize,
}

impl ActivationLog {
    pub fn new() -> Self {
        ActivationLog {
            cap: 4096,
            ..Default::default()
        }
    }

    /// Record an activation being assigned to a container.
    pub fn record_assignment(&mut self, container: ContainerId, _activation: RequestId) {
        *self.assigned.entry(container).or_insert(0) += 1;
    }

    /// Record a `[MessagingActiveAck] posted completion of activation` line.
    pub fn record_ack(&mut self, container: ContainerId, activation: RequestId, time: Micros) {
        *self.acked.entry(container).or_insert(0) += 1;
        if self.recent.len() == self.cap {
            self.recent.remove(0);
        }
        self.recent.push(AckRecord {
            container,
            activation,
            time,
        });
    }

    /// Algorithm 2 line 5-6: has this container completed *all* assigned
    /// in-flight activations? (True also for never-used prewarmed pods.)
    pub fn all_completed(&self, container: ContainerId) -> bool {
        let assigned = self.assigned.get(&container).copied().unwrap_or(0);
        let acked = self.acked.get(&container).copied().unwrap_or(0);
        acked >= assigned
    }

    /// Most recent ack lines (for debugging / the CLI `logs` command).
    pub fn recent(&self) -> &[AckRecord] {
        &self.recent
    }

    /// Drop per-container counters on reclaim (log hygiene).
    pub fn forget(&mut self, container: ContainerId) {
        self.assigned.remove(&container);
        self.acked.remove(&container);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unused_container_is_safe() {
        let log = ActivationLog::new();
        assert!(log.all_completed(7));
    }

    #[test]
    fn inflight_blocks_until_ack() {
        let mut log = ActivationLog::new();
        log.record_assignment(1, 100);
        assert!(!log.all_completed(1));
        log.record_ack(1, 100, 500);
        assert!(log.all_completed(1));
    }

    #[test]
    fn multiple_inflight_all_must_ack() {
        let mut log = ActivationLog::new();
        for req in 0..5 {
            log.record_assignment(2, req);
        }
        for req in 0..4 {
            log.record_ack(2, req, req * 10);
        }
        assert!(!log.all_completed(2));
        log.record_ack(2, 4, 100);
        assert!(log.all_completed(2));
    }

    #[test]
    fn forget_clears_state() {
        let mut log = ActivationLog::new();
        log.record_assignment(3, 1);
        log.forget(3);
        assert!(log.all_completed(3));
    }

    #[test]
    fn recent_ring_is_bounded() {
        let mut log = ActivationLog::new();
        log.cap = 4;
        for i in 0..10 {
            log.record_ack(1, i, i);
        }
        assert_eq!(log.recent().len(), 4);
        assert_eq!(log.recent()[0].activation, 6);
    }
}
