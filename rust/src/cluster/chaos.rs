//! Chaos engine — deterministic fault injection for the gauntlet runs.
//!
//! Two layers compose:
//!
//! * **Correlated schedules** ([`schedule_for`]): named presets that
//!   derive node-level fault timelines from the experiment shape —
//!   failure storms (overlapping drains), rolling restarts (staggered
//!   drain→restore waves), and flash crowds (the Zipf popularity inverts
//!   mid-run, see [`flash_window`]/[`apply_flash`]).
//! * **Invocation-level faults** ([`ChaosEngine`]): container spawn
//!   failures, execution failures, and straggler executions bounded by
//!   per-function timeouts, all governed by a retry/backoff policy.
//!
//! Everything is seeded: the engine carries its own salted xoshiro
//! stream (`seed ^ CHAOS_SALT`), so chaos runs are exactly as
//! reproducible as clean ones, and turning chaos off removes the stream
//! entirely — the seed path never observes it (the PR 5–7 byte-identity
//! pattern; see `rust/tests/chaos.rs`).

use std::collections::HashMap;

use crate::cluster::RequestId;
use crate::config::{
    ChaosConfig, ChaosMode, ExperimentConfig, Micros, NodeFailure, NodeRestore,
};
use crate::util::rng::Rng;
use crate::workload::tenant::{FunctionId, FunctionRegistry, TenantWorkload};

/// Salt separating the chaos RNG stream from the profile / assignment /
/// trace streams (same idiom as `PROFILE_SALT` etc.).
pub const CHAOS_SALT: u64 = 0xC4A0_5EED;

/// What the chaos engine decided for one execution at dispatch time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecFate {
    /// Runs to its scheduled completion.
    Normal,
    /// Straggler: completes late, at the given (timeout-safe) instant.
    Stretched(Micros),
    /// Killed at the per-function deadline; the request retries.
    TimedOut(Micros),
}

/// Seeded invocation-level fault injector. Owned by the fleet (one per
/// run) so every RNG draw happens in deterministic event order; when
/// chaos is off the fleet simply holds `None` and none of this exists.
#[derive(Debug, Clone)]
pub struct ChaosEngine {
    cfg: ChaosConfig,
    rng: Rng,
    /// Fault count per request (spawn failures, execution failures, and
    /// timeouts all consume the same retry budget).
    attempts: HashMap<RequestId, u32>,
    /// Per-function execution deadline: `timeout_factor × l_warm(f)`.
    timeouts: Vec<Micros>,
}

impl ChaosEngine {
    pub fn new(cfg: ChaosConfig, seed: u64, registry: &FunctionRegistry) -> Self {
        let timeouts = registry
            .profiles()
            .iter()
            .map(|p| {
                let t = p.l_warm as f64 * cfg.timeout_factor.max(1.0);
                if t.is_finite() {
                    t as Micros
                } else {
                    Micros::MAX
                }
            })
            .collect();
        ChaosEngine {
            cfg,
            rng: Rng::new(seed ^ CHAOS_SALT),
            attempts: HashMap::new(),
            timeouts,
        }
    }

    fn timeout_for(&self, func: FunctionId) -> Micros {
        self.timeouts
            .get(func as usize)
            .copied()
            .unwrap_or(Micros::MAX)
    }

    /// Roll a request-bound container spawn: does it fail before ready?
    pub fn spawn_fails(&mut self) -> bool {
        self.rng.chance(self.cfg.spawn_fail_p)
    }

    /// Roll a finished execution: does its result fail anyway?
    pub fn exec_fails(&mut self) -> bool {
        self.rng.chance(self.cfg.exec_fail_p)
    }

    /// Roll an execution starting at `start` with nominal completion
    /// `done_at`: normal, straggling (stretched duration), or killed at
    /// the per-function deadline — whichever the stretch crosses first.
    pub fn exec_fate(&mut self, func: FunctionId, start: Micros, done_at: Micros) -> ExecFate {
        let deadline = start.saturating_add(self.timeout_for(func));
        if self.rng.chance(self.cfg.straggler_p) {
            let dur = done_at.saturating_sub(start);
            let stretched = (dur as f64 * self.cfg.straggler_factor.max(1.0)) as u64;
            let late = start.saturating_add(stretched.max(dur));
            if late > deadline {
                return ExecFate::TimedOut(deadline);
            }
            return ExecFate::Stretched(late);
        }
        if done_at > deadline {
            // a nominal duration can still cross the deadline when the
            // timeout knob is set aggressively low
            return ExecFate::TimedOut(deadline);
        }
        ExecFate::Normal
    }

    /// A fault hit `req`: charge one attempt against its retry budget.
    /// `Some(backoff)` schedules the retry that far in the future
    /// (exponential: `retry_backoff × 2^(n−1)` for the n-th retry);
    /// `None` means the budget is exhausted and the request is dropped.
    pub fn retry_decision(&mut self, req: RequestId) -> Option<Micros> {
        let n = self.attempts.entry(req).or_insert(0);
        *n += 1;
        if *n > self.cfg.max_retries {
            return None;
        }
        // clamp the exponent so `--chaos-max-retries ≥ 64` can't drive
        // `1u64 << shift` into undefined-shift territory (shift ≥ 64
        // wraps to a zero/garbage backoff); past the clamp the
        // saturating multiply pins the schedule at u64::MAX
        let shift = (*n - 1).min(62) as u32;
        Some(self.cfg.retry_backoff.saturating_mul(1u64 << shift))
    }
}

/// Derive the preset node-fault timeline for an experiment. `Off`,
/// `Faults`, and `FlashCrowd` schedule no node events; the storm and the
/// rolling restart never touch node 0 and never leave the fleet without
/// a survivor, so the result always passes
/// [`crate::config::validate_fault_schedule`].
pub fn schedule_for(cfg: &ExperimentConfig) -> (Vec<NodeFailure>, Vec<NodeRestore>) {
    let nodes = cfg.fleet.nodes;
    let d = cfg.duration;
    let frac = |f: f64| -> Micros { (d as f64 * f) as Micros };
    let mut failures = Vec::new();
    let mut restores = Vec::new();
    match cfg.chaos.mode {
        ChaosMode::FailureStorm => {
            // half the fleet (rounded up, but always leaving node 0 and
            // at least one other survivor out) drains in a burst of
            // 15 s-staggered failures starting at 30% of the run; each
            // victim rejoins 120 s after it fell
            if nodes >= 2 {
                let k = nodes.div_ceil(2).min(nodes - 1);
                for i in 0..k {
                    let node = 1 + i;
                    let at = frac(0.3) + i as Micros * 15_000_000;
                    if at >= d {
                        break;
                    }
                    failures.push(NodeFailure { node, at });
                    let back = at + 120_000_000;
                    if back < d {
                        restores.push(NodeRestore {
                            node,
                            at: back,
                            cap: None,
                        });
                    }
                }
            }
        }
        ChaosMode::RollingRestart => {
            // one wave per node (node 0 excluded): 60 s down, waves 90 s
            // apart so windows never overlap and at most one node is
            // offline at a time
            for node in 1..nodes {
                let at = frac(0.2) + (node - 1) as Micros * 90_000_000;
                let back = at + 60_000_000;
                if back >= d {
                    break;
                }
                failures.push(NodeFailure { node, at });
                restores.push(NodeRestore {
                    node,
                    at: back,
                    cap: None,
                });
            }
        }
        ChaosMode::Off | ChaosMode::Faults | ChaosMode::FlashCrowd => {}
    }
    (failures, restores)
}

/// The flash-crowd window: the middle fifth of the run, [40%, 60%) of
/// the duration. `None` for every other mode.
pub fn flash_window(cfg: &ExperimentConfig) -> Option<(Micros, Micros)> {
    (cfg.chaos.mode == ChaosMode::FlashCrowd).then(|| {
        let d = cfg.duration as f64;
        ((d * 0.4) as Micros, (d * 0.6) as Micros)
    })
}

/// Invert the Zipf popularity inside the flash window: every arrival in
/// `[start, end)` has its function remapped `f → n−1−f`, so the cold
/// tail becomes the hot head exactly when the forecasts least expect it.
/// A no-op for single-tenant workloads (nothing to invert).
pub fn apply_flash(w: &TenantWorkload, (start, end): (Micros, Micros)) -> TenantWorkload {
    let mut out = w.clone();
    let n = out.registry.len() as FunctionId;
    if n <= 1 || out.funcs.is_empty() {
        return out;
    }
    for (i, &at) in out.arrivals.iter().enumerate() {
        if at >= start && at < end {
            out.funcs[i] = n - 1 - out.funcs[i];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{secs, validate_fault_schedule, ChaosConfig, FleetConfig};
    use crate::workload::Trace;

    fn registry(n: u32) -> FunctionRegistry {
        FunctionRegistry::synthesize(n, 1.1, &crate::config::PlatformConfig::default(), 7)
    }

    fn chaos_cfg(mode: ChaosMode) -> ExperimentConfig {
        ExperimentConfig {
            chaos: ChaosConfig {
                mode,
                ..Default::default()
            },
            fleet: FleetConfig {
                nodes: 4,
                ..Default::default()
            },
            duration: secs(1800.0),
            ..Default::default()
        }
    }

    #[test]
    fn engine_is_deterministic_in_seed() {
        let reg = registry(4);
        let cfg = ChaosConfig {
            mode: ChaosMode::Faults,
            ..Default::default()
        };
        let mut a = ChaosEngine::new(cfg, 42, &reg);
        let mut b = ChaosEngine::new(cfg, 42, &reg);
        for i in 0..200 {
            assert_eq!(a.spawn_fails(), b.spawn_fails());
            assert_eq!(a.exec_fate(i % 4, 0, 280_000), b.exec_fate(i % 4, 0, 280_000));
            assert_eq!(a.retry_decision(i as RequestId), b.retry_decision(i as RequestId));
        }
    }

    #[test]
    fn retry_budget_is_exponential_then_exhausted() {
        let reg = registry(1);
        let cfg = ChaosConfig {
            mode: ChaosMode::Faults,
            max_retries: 3,
            retry_backoff: secs(1.0),
            ..Default::default()
        };
        let mut e = ChaosEngine::new(cfg, 1, &reg);
        assert_eq!(e.retry_decision(9), Some(secs(1.0)));
        assert_eq!(e.retry_decision(9), Some(secs(2.0)));
        assert_eq!(e.retry_decision(9), Some(secs(4.0)));
        assert_eq!(e.retry_decision(9), None, "budget exhausted on the 4th fault");
        // other requests carry their own budgets
        assert_eq!(e.retry_decision(10), Some(secs(1.0)));
    }

    #[test]
    fn retry_backoff_saturates_at_the_shift_boundary() {
        // --chaos-max-retries ≥ 64 must never wrap `1u64 << shift` into a
        // zero/garbage backoff: the shift clamps at 62, so the schedule
        // is monotone non-decreasing across the boundary and beyond
        let reg = registry(1);
        let cfg = ChaosConfig {
            mode: ChaosMode::Faults,
            max_retries: 100,
            retry_backoff: 1, // 1 µs base keeps the raw shifts visible
            ..Default::default()
        };
        let mut e = ChaosEngine::new(cfg, 1, &reg);
        let mut prev = 0u64;
        for attempt in 1..=100u32 {
            let b = e.retry_decision(7).expect("inside the budget");
            assert!(b > 0, "backoff wrapped to zero at attempt {attempt}");
            assert!(b >= prev, "backoff regressed at attempt {attempt}");
            prev = b;
        }
        assert_eq!(prev, 1u64 << 62, "clamped shift from attempt 63 on");
        assert_eq!(e.retry_decision(7), None, "then the budget exhausts");
        // with a realistic base the product overflows instead: the
        // saturating multiply pins it at u64::MAX rather than wrapping
        let cfg = ChaosConfig {
            mode: ChaosMode::Faults,
            max_retries: 70,
            retry_backoff: secs(1.0),
            ..Default::default()
        };
        let mut e = ChaosEngine::new(cfg, 1, &reg);
        let mut last = 0u64;
        for _ in 0..70 {
            last = e.retry_decision(8).expect("inside the budget");
        }
        assert_eq!(last, u64::MAX, "saturated, not wrapped");
    }

    #[test]
    fn exec_fate_respects_the_deadline() {
        let reg = registry(1);
        let l_warm = reg.get(0).l_warm;
        let cfg = ChaosConfig {
            mode: ChaosMode::Faults,
            straggler_p: 1.0, // every execution straggles
            straggler_factor: 100.0,
            timeout_factor: 8.0,
            ..Default::default()
        };
        let mut e = ChaosEngine::new(cfg, 3, &reg);
        let deadline = (l_warm as f64 * 8.0) as Micros;
        // a 100× stretch blows far past the 8× deadline → killed there
        assert_eq!(
            e.exec_fate(0, 0, l_warm),
            ExecFate::TimedOut(deadline)
        );
        // a mild stretch below the deadline completes late
        let mild = ChaosConfig {
            straggler_factor: 2.0,
            ..cfg
        };
        let mut e = ChaosEngine::new(mild, 3, &reg);
        assert_eq!(e.exec_fate(0, 0, l_warm), ExecFate::Stretched(2 * l_warm));
        // non-stragglers at nominal duration are always Normal
        let never = ChaosConfig {
            straggler_p: 0.0,
            ..cfg
        };
        let mut e = ChaosEngine::new(never, 3, &reg);
        assert_eq!(e.exec_fate(0, 0, l_warm), ExecFate::Normal);
    }

    #[test]
    fn preset_schedules_pass_validation() {
        for mode in ChaosMode::ALL {
            for nodes in [1u32, 2, 3, 4, 8] {
                let mut cfg = chaos_cfg(mode);
                cfg.fleet.nodes = nodes;
                let (f, r) = schedule_for(&cfg);
                validate_fault_schedule(&f, &r, nodes, cfg.duration)
                    .unwrap_or_else(|e| panic!("{} @ {nodes} nodes: {e}", mode.name()));
                match mode {
                    ChaosMode::FailureStorm if nodes >= 3 => {
                        assert!(f.len() >= 2, "storm must drain several nodes")
                    }
                    ChaosMode::RollingRestart if nodes >= 2 => {
                        assert_eq!(f.len(), r.len(), "every wave restores")
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn flash_remap_inverts_only_inside_the_window() {
        let trace = Trace {
            arrivals: vec![secs(10.0), secs(800.0), secs(1700.0)],
        };
        let reg = registry(8);
        let w = TenantWorkload::assign(&trace, reg, 42);
        let cfg = chaos_cfg(ChaosMode::FlashCrowd);
        let (start, end) = flash_window(&cfg).expect("flash mode has a window");
        assert_eq!((start, end), (secs(720.0), secs(1080.0)));
        let flashed = apply_flash(&w, (start, end));
        assert_eq!(flashed.funcs[0], w.funcs[0], "before the window: untouched");
        assert_eq!(flashed.funcs[1], 7 - w.funcs[1], "inside: inverted");
        assert_eq!(flashed.funcs[2], w.funcs[2], "after: untouched");
        // single-tenant workloads have nothing to invert
        let single = TenantWorkload::assign(&trace, registry(1), 42);
        assert_eq!(apply_flash(&single, (start, end)).funcs, single.funcs);
        // other modes have no window at all
        assert_eq!(flash_window(&chaos_cfg(ChaosMode::Faults)), None);
    }
}
