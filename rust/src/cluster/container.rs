//! Container lifecycle FSM (the unit the whole paper schedules around).
//!
//! States: `ColdStarting` (initializing for `L_cold`; optionally carrying
//! the request that triggered it) → `Idle` (warm, ready) ⇄ `Busy`
//! (executing for `L_warm`) → removed (reclaim or keep-alive expiry).

use crate::cluster::RequestId;
use crate::config::Micros;
use crate::workload::tenant::FunctionId;

pub type ContainerId = u64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    /// Initializing; ready at `ready_at`. `pending` is the request that
    /// triggered this cold start (None for controller prewarms).
    ColdStarting {
        ready_at: Micros,
        pending: Option<RequestId>,
    },
    /// Warm and unoccupied since `since`.
    Idle { since: Micros },
    /// Executing `request`; completes at `until`.
    Busy { request: RequestId, until: Micros },
}

#[derive(Debug, Clone)]
pub struct Container {
    pub id: ContainerId,
    /// The function this container is initialized for. A warm container
    /// serves only its own function — the unit of warm-pool
    /// fragmentation the multi-tenant experiments measure.
    pub func: FunctionId,
    pub state: ContainerState,
    pub created_at: Micros,
    /// End of the most recent activation (== created_at before first use).
    pub last_used: Micros,
    /// Completed activations on this container.
    pub activations: u32,
    /// Accumulated idle (warm-but-unused) time, for keep-alive accounting.
    pub idle_accum: Micros,
}

impl Container {
    pub fn cold(
        id: ContainerId,
        func: FunctionId,
        now: Micros,
        ready_at: Micros,
        pending: Option<RequestId>,
    ) -> Self {
        Container {
            id,
            func,
            state: ContainerState::ColdStarting { ready_at, pending },
            created_at: now,
            last_used: now,
            activations: 0,
            idle_accum: 0,
        }
    }

    pub fn is_idle(&self) -> bool {
        matches!(self.state, ContainerState::Idle { .. })
    }

    pub fn is_busy(&self) -> bool {
        matches!(self.state, ContainerState::Busy { .. })
    }

    pub fn is_cold_starting(&self) -> bool {
        matches!(self.state, ContainerState::ColdStarting { .. })
    }

    /// Warm = initialized (idle or busy); the gauge Fig. 1b/6 plot.
    pub fn is_warm(&self) -> bool {
        self.is_idle() || self.is_busy()
    }

    /// Idle duration as of `now` (0 unless idle).
    pub fn idle_for(&self, now: Micros) -> Micros {
        match self.state {
            ContainerState::Idle { since } => now.saturating_sub(since),
            _ => 0,
        }
    }

    /// Transition: cold init finished → idle.
    /// Returns the request bound to this container, if any.
    pub fn finish_cold_start(&mut self, now: Micros) -> Option<RequestId> {
        match self.state {
            ContainerState::ColdStarting { pending, .. } => {
                self.state = ContainerState::Idle { since: now };
                // a fresh container's keep-alive clock starts when it is
                // ready, not when initialization began
                self.last_used = now;
                pending
            }
            _ => panic!("finish_cold_start on non-cold container {}", self.id),
        }
    }

    /// Transition: idle → busy on `request`, until `until`.
    pub fn start_execution(&mut self, request: RequestId, now: Micros, until: Micros) {
        match self.state {
            ContainerState::Idle { since } => {
                self.idle_accum += now.saturating_sub(since);
                self.state = ContainerState::Busy { request, until };
            }
            _ => panic!("start_execution on non-idle container {}", self.id),
        }
    }

    /// Transition: busy → idle; returns the completed request.
    pub fn finish_execution(&mut self, now: Micros) -> RequestId {
        match self.state {
            ContainerState::Busy { request, .. } => {
                self.state = ContainerState::Idle { since: now };
                self.last_used = now;
                self.activations += 1;
                request
            }
            _ => panic!("finish_execution on non-busy container {}", self.id),
        }
    }

    /// Now-independent reclaim ranking key: `last_used` in seconds plus
    /// the activation penalty. Algorithm 2's composite score at time
    /// `now` is `now_s − key`, so **ascending key = descending score** —
    /// the property that lets the platform keep idle containers in a
    /// pre-sorted reclaim order instead of re-scoring every candidate on
    /// each reclaim call. Always non-negative and finite, so its IEEE-754
    /// bit pattern is a valid `u64` ordering key.
    pub fn reclaim_key(&self) -> f64 {
        // activation count proxies CPU/memory pressure in the paper's
        // composite (heavily used containers are likely needed again)
        self.last_used as f64 / 1e6 + 0.1 * self.activations as f64
    }

    /// Composite reclaim-ranking score (Algorithm 2, line 1): prioritize
    /// long-idle, little-used containers. Higher = better reclaim
    /// candidate. For an idle container (`since == last_used`) this is
    /// `idle_s − 0.1 × activations`, expressed as `now_s −`
    /// [`Container::reclaim_key`] so the score's order is exactly the
    /// key's reversed order.
    pub fn reclaim_score(&self, now: Micros) -> f64 {
        now as f64 / 1e6 - self.reclaim_key()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_roundtrip() {
        let mut c = Container::cold(1, 0, 0, 10_500_000, Some(99));
        assert!(c.is_cold_starting());
        assert!(!c.is_warm());
        let pending = c.finish_cold_start(10_500_000);
        assert_eq!(pending, Some(99));
        assert!(c.is_idle());
        assert!(c.is_warm());
        c.start_execution(99, 10_500_000, 10_780_000);
        assert!(c.is_busy());
        let done = c.finish_execution(10_780_000);
        assert_eq!(done, 99);
        assert_eq!(c.activations, 1);
        assert_eq!(c.last_used, 10_780_000);
    }

    #[test]
    fn idle_accounting_accumulates() {
        let mut c = Container::cold(1, 0, 0, 100, None);
        c.finish_cold_start(100);
        c.start_execution(1, 600, 880); // idle 100..600 = 500
        c.finish_execution(880);
        c.start_execution(2, 1000, 1280); // idle 880..1000 = 120
        c.finish_execution(1280);
        assert_eq!(c.idle_accum, 620);
        assert_eq!(c.idle_for(2000), 720); // still idle since 1280
    }

    #[test]
    fn reclaim_score_prefers_long_idle_low_use() {
        let mut fresh = Container::cold(1, 0, 0, 0, None);
        fresh.finish_cold_start(0);
        let mut veteran = Container::cold(2, 0, 0, 0, None);
        veteran.finish_cold_start(0);
        for i in 0..50 {
            veteran.start_execution(i, i * 1000, i * 1000 + 1);
            veteran.finish_execution(i * 1000 + 1);
        }
        // same idle-since time for both → veteran scores lower
        let now = 100_000_000;
        assert!(fresh.reclaim_score(now) > veteran.reclaim_score(now));
        // ascending key == descending score (the reclaim-order invariant)
        assert!(fresh.reclaim_key() < veteran.reclaim_key());
    }

    #[test]
    fn reclaim_key_is_non_negative_and_now_independent() {
        let mut c = Container::cold(1, 0, 2_000_000, 3_000_000, None);
        c.finish_cold_start(3_000_000);
        assert!(c.reclaim_key() >= 0.0);
        let k = c.reclaim_key();
        // score = now_s − key at any now
        for now in [3_000_000u64, 50_000_000, 3_600_000_000] {
            assert_eq!(c.reclaim_score(now), now as f64 / 1e6 - k);
        }
        // an execution bumps last_used and activations, so the key grows
        c.start_execution(1, 4_000_000, 5_000_000);
        c.finish_execution(5_000_000);
        assert!(c.reclaim_key() > k);
    }

    #[test]
    #[should_panic(expected = "non-idle")]
    fn cannot_execute_on_cold_container() {
        let mut c = Container::cold(1, 0, 0, 100, None);
        c.start_execution(1, 0, 10);
    }
}
