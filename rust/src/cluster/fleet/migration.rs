//! Cross-node container migration planning — the fleet's rebalancing
//! pass (elasticity). Plugs in alongside [`super::placement`]: placement
//! decides where *new* work lands, migration moves *existing* idle warm
//! capacity when the standing allocation no longer matches demand
//! (tenant skew drift, a node rejoining cold, memory pressure building
//! on one node).
//!
//! Planners are pure functions over the fleet's indexed telemetry (no
//! mutation, no RNG): they return a list of [`MigrationMove`]s the
//! coordinator executes through [`super::Fleet::migrate`], which
//! re-validates each move — a planned move that no longer fits is
//! skipped, never forced.
//!
//! # Math-to-code: the demand-gap scoring rule
//!
//! For function `f` with forecast demand `d_f` (expected arrivals over
//! the cold-start lead window, supplied by the MPC's per-function
//! Fourier forecasts) and online nodes `n` with replica capacities
//! `c_n`:
//!
//! ```text
//! target(n, f) = d_f · c_n / Σ_m c_m          capacity-proportional share
//! supply(n, f) = warm(n, f) + coldStarting(n, f)
//! gap(n, f)    = target(n, f) − supply(n, f)
//! ```
//!
//! Each planned move takes the most over-provisioned donor
//! (`argmin gap ≤ −1`, holding a movable idle replica of `f`) and the
//! most under-provisioned receiver (`argmax gap ≥ +1`, with admission
//! headroom), then shifts both gaps by one. Functions are served in
//! descending-demand order under a shared per-pass move budget, so the
//! hottest function's gaps close first. The ±1 thresholds make the pass
//! idempotent: once every |gap| < 1 no further moves are planned, so a
//! balanced fleet stays untouched.

use crate::cluster::fleet::{Fleet, NodeId};
use crate::config::{MigrationConfig, MigrationPolicy};
use crate::workload::tenant::FunctionId;

/// One planned move: `func`'s LRU idle container leaves `from` for `to`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationMove {
    pub from: NodeId,
    pub to: NodeId,
    pub func: FunctionId,
}

/// Plan one rebalancing pass under `cfg.policy`. `demand` is the
/// caller's per-function demand forecast over the cold-start lead
/// window (index = [`FunctionId`]; the MPC supplies its per-function
/// forecasts, a single-tenant caller a one-element aggregate). With
/// [`MigrationPolicy::Off`] (the default) no moves are ever planned.
pub fn plan(cfg: &MigrationConfig, fleet: &Fleet, demand: &[f64]) -> Vec<MigrationMove> {
    match cfg.policy {
        MigrationPolicy::Off => Vec::new(),
        MigrationPolicy::DemandGap => plan_demand_gap(fleet, demand, cfg.max_moves_per_step),
        MigrationPolicy::IdleSpread => plan_idle_spread(fleet, cfg.max_moves_per_step),
    }
}

/// Forecast-driven planner (see the module-level scoring rule). All node
/// probes (`warm_count_for`, `cold_starting_for`, `idle_count_for`,
/// `headroom`) read the platform's incremental indices, so one pass is
/// O(functions × nodes + moves × nodes), independent of the container
/// population.
pub fn plan_demand_gap(fleet: &Fleet, demand: &[f64], max_moves: u32) -> Vec<MigrationMove> {
    let mut moves = Vec::new();
    let online: Vec<(NodeId, u32)> = fleet
        .nodes()
        .iter()
        .filter(|n| n.online)
        .map(|n| (n.id, n.platform.cfg.resource_cap()))
        .collect();
    let total_cap: u32 = online.iter().map(|&(_, c)| c).sum();
    if online.len() < 2 || total_cap == 0 {
        return moves;
    }
    // descending demand, ties to the lower function id
    let mut order: Vec<usize> = (0..demand.len()).collect();
    order.sort_by(|&a, &b| demand[b].total_cmp(&demand[a]).then(a.cmp(&b)));
    for f in order {
        if moves.len() as u32 >= max_moves {
            break;
        }
        let func = f as FunctionId;
        let d = demand[f].max(0.0);
        if d <= 0.0 {
            continue;
        }
        let mut gap: Vec<f64> = Vec::with_capacity(online.len());
        let mut movable: Vec<u32> = Vec::with_capacity(online.len());
        let mut headroom: Vec<u32> = Vec::with_capacity(online.len());
        for &(id, cap) in &online {
            let p = &fleet.node(id).platform;
            let supply = (p.warm_count_for(func) + p.cold_starting_for(func)) as f64;
            gap.push(d * cap as f64 / total_cap as f64 - supply);
            movable.push(p.idle_count_for(func));
            // replica headroom as the planning proxy; the executor
            // re-checks full admission (incl. the memory ledger)
            headroom.push(if p.can_admit(func) { p.headroom() } else { 0 });
        }
        while (moves.len() as u32) < max_moves {
            let donor = (0..online.len())
                .filter(|&j| gap[j] <= -1.0 && movable[j] > 0)
                .min_by(|&a, &b| gap[a].total_cmp(&gap[b]).then(online[a].0.cmp(&online[b].0)));
            let recv = (0..online.len())
                .filter(|&j| gap[j] >= 1.0 && headroom[j] > 0)
                .max_by(|&a, &b| gap[a].total_cmp(&gap[b]).then(online[b].0.cmp(&online[a].0)));
            let (Some(dj), Some(rj)) = (donor, recv) else {
                break;
            };
            if dj == rj {
                break;
            }
            moves.push(MigrationMove {
                from: online[dj].0,
                to: online[rj].0,
                func,
            });
            gap[dj] += 1.0;
            movable[dj] -= 1;
            gap[rj] -= 1.0;
            headroom[rj] -= 1;
        }
    }
    moves
}

/// Demand-agnostic planner: level warm stock across online nodes by
/// repeatedly moving the most-stocked node's coldest idle container to
/// the least-stocked node with headroom, while the difference exceeds
/// one (so a balanced fleet plans nothing). "Stock" counts idle *plus*
/// in-flight cold-starting containers — transfers and prewarms already
/// headed for a node are supply that has merely not landed yet, so a
/// replan inside the transfer-latency window (emergency replans fire on
/// arrival bursts) does not re-plan moves that are still in flight and
/// over-drain the donor. Only genuinely idle containers are movable.
pub fn plan_idle_spread(fleet: &Fleet, max_moves: u32) -> Vec<MigrationMove> {
    let mut moves = Vec::new();
    let online: Vec<NodeId> = fleet
        .nodes()
        .iter()
        .filter(|n| n.online)
        .map(|n| n.id)
        .collect();
    if online.len() < 2 {
        return moves;
    }
    let mut stock: Vec<u32> = online
        .iter()
        .map(|&id| {
            let p = &fleet.node(id).platform;
            p.idle_count() + p.cold_starting_count()
        })
        .collect();
    let mut movable: Vec<u32> = online
        .iter()
        .map(|&id| fleet.node(id).platform.idle_count())
        .collect();
    let mut headroom: Vec<u32> = online
        .iter()
        .map(|&id| fleet.node(id).platform.headroom())
        .collect();
    while (moves.len() as u32) < max_moves {
        let Some(dj) = (0..online.len())
            .filter(|&j| movable[j] > 0)
            .max_by(|&a, &b| stock[a].cmp(&stock[b]).then(online[b].cmp(&online[a])))
        else {
            break;
        };
        let Some(rj) = (0..online.len())
            .filter(|&j| j != dj && headroom[j] > 0)
            .min_by(|&a, &b| stock[a].cmp(&stock[b]).then(online[a].cmp(&online[b])))
        else {
            break;
        };
        if stock[dj] < stock[rj] + 2 {
            break; // moving would not strictly level the pools
        }
        // the victim is the donor's coldest (best-reclaim) idle container
        let Some(func) = fleet.node(online[dj]).platform.coldest_idle_function() else {
            break;
        };
        moves.push(MigrationMove {
            from: online[dj],
            to: online[rj],
            func,
        });
        stock[dj] -= 1;
        movable[dj] -= 1;
        stock[rj] += 1;
        headroom[rj] -= 1;
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FleetConfig, PlacementPolicy, PlatformConfig};

    fn fleet(nodes: u32) -> Fleet {
        let fc = FleetConfig {
            nodes,
            placement: PlacementPolicy::WarmFirst,
            ..Default::default()
        };
        let pc = PlatformConfig {
            latency_jitter: 0.0,
            ..Default::default()
        };
        Fleet::new(&fc, &pc, 7)
    }

    fn stock_idle(f: &mut Fleet, node: NodeId, count: usize, t0: u64) {
        for i in 0..count {
            let now = t0 + i as u64;
            let (cid, ready_at) = f.node_mut(node).platform.prewarm_one(now).unwrap();
            f.node_mut(node).platform.container_ready(cid, ready_at);
        }
    }

    #[test]
    fn off_plans_nothing() {
        let mut f = fleet(2);
        stock_idle(&mut f, 0, 3, 0);
        let cfg = MigrationConfig::default();
        assert!(plan(&cfg, &f, &[100.0]).is_empty());
    }

    #[test]
    fn demand_gap_moves_toward_predicted_demand() {
        let mut f = fleet(2);
        // all supply on node 0, demand worth 4 containers fleet-wide:
        // equal caps → target 2 per node, gaps (−1, +2) → exactly one move
        stock_idle(&mut f, 0, 3, 0);
        let moves = plan_demand_gap(&f, &[4.0], 8);
        assert_eq!(
            moves,
            vec![MigrationMove {
                from: 0,
                to: 1,
                func: 0
            }]
        );
        // a balanced fleet (|gap| < 1 everywhere) plans nothing
        stock_idle(&mut f, 1, 3, 100);
        assert!(plan_demand_gap(&f, &[12.0], 8).is_empty());
    }

    #[test]
    fn demand_gap_respects_move_budget_and_zero_demand() {
        let mut f = fleet(2);
        stock_idle(&mut f, 0, 8, 0);
        // demand 8 over equal caps → targets 4/4, gaps (−4, +4): four
        // moves would level it, but the per-pass budget caps at 2
        assert_eq!(plan_demand_gap(&f, &[8.0], 2).len(), 2);
        // no demand → nothing to rebalance toward
        assert!(plan_demand_gap(&f, &[0.0], 8).is_empty());
        assert!(plan_demand_gap(&f, &[-5.0], 8).is_empty());
    }

    #[test]
    fn demand_gap_skips_offline_nodes() {
        let mut f = fleet(3);
        stock_idle(&mut f, 0, 6, 0);
        f.fail_node(2, 1_000_000_000);
        let moves = plan_demand_gap(&f, &[8.0], 8);
        assert!(!moves.is_empty());
        assert!(moves.iter().all(|m| m.from != 2 && m.to != 2));
    }

    #[test]
    fn idle_spread_levels_pools() {
        let mut f = fleet(2);
        stock_idle(&mut f, 0, 4, 0);
        let moves = plan_idle_spread(&f, 8);
        // 4 vs 0 levels to 2 vs 2 in exactly two moves
        assert_eq!(moves.len(), 2);
        assert!(moves.iter().all(|m| m.from == 0 && m.to == 1));
        // an already-level fleet plans nothing
        stock_idle(&mut f, 1, 4, 100);
        assert!(plan_idle_spread(&f, 8).is_empty());
    }

    #[test]
    fn idle_spread_counts_inflight_transfers_as_receiver_stock() {
        // execute the planned moves, then replan while the transfers are
        // still in flight (cold-starting on the receiver): an emergency
        // replan inside the latency window must NOT move more containers
        let mut f = fleet(2);
        stock_idle(&mut f, 0, 4, 0);
        let moves = plan_idle_spread(&f, 8);
        assert_eq!(moves.len(), 2);
        for m in &moves {
            f.migrate(m.from, m.to, m.func, 1_000_000_000, 2_000_000)
                .expect("planned move must execute");
        }
        assert_eq!(f.node(1).platform.cold_starting_count(), 2);
        assert_eq!(f.node(1).platform.idle_count(), 0, "not landed yet");
        assert!(
            plan_idle_spread(&f, 8).is_empty(),
            "in-flight transfers re-planned as missing stock"
        );
    }

    #[test]
    fn single_online_node_never_migrates() {
        let mut f = fleet(1);
        stock_idle(&mut f, 0, 4, 0);
        assert!(plan_idle_spread(&f, 8).is_empty());
        assert!(plan_demand_gap(&f, &[10.0], 8).is_empty());
    }
}
