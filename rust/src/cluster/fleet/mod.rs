//! Multi-invoker fleet: N invoker nodes, each wrapping its own
//! [`Platform`] (per-node capacity, keep-alive, FCFS backlog), behind a
//! pluggable dispatch placement layer ([`placement`]).
//!
//! The paper's testbed is OpenWhisk on a Kubernetes cluster with several
//! invoker nodes; the fleet makes the cluster-scale effects visible that
//! a single 64-replica pool cannot show — placement skew, per-node
//! warm-pool fragmentation, node failures (the drain scenario), and
//! multi-tenant contention between functions sharing the cluster.
//!
//! # Math-to-code mapping
//!
//! The fleet is the actuation target of the paper's control loop:
//!
//! * **Dispatch** (Algorithm 1, `submitRequestAsync`) →
//!   [`Fleet::invoke_for`]: the placement layer picks a node for the
//!   request's *function* (warm-first becomes
//!   warm-for-this-function-first), then the node's platform applies
//!   OpenWhisk semantics.
//! * **Prewarm actuation** (Listing 1, Eq. 14's `x_k` budget) →
//!   [`Fleet::prewarm_for`]: one unbound cold container of a function on
//!   the node least provisioned *for that function*. The aggregate
//!   budget itself is fleet-scaled upstream: the planner's pool bound
//!   `w_max` tracks the fleet's **live** online capacity at every
//!   control step (`w_max × nodes` when everyone is healthy), so an
//!   8-node cluster is not capped at one node's 64 replicas — and a
//!   drained node's share drops out until it rejoins.
//! * **Reclaim** (Algorithm 2, Eq. 15's `r_k`) → [`Fleet::try_reclaim`]:
//!   each step drains the best-scoring log-safe idle candidate across
//!   all online nodes, preserving the algorithm's global ranking. With
//!   `PlatformConfig::reclaim_pressure_weight > 0` each node's best
//!   score carries a memory-pressure bias, so the cross-node pick
//!   prefers draining pressured nodes.
//! * **Elasticity** — the capacity lifecycle *healthy → draining →
//!   drained → rejoining* (docs/ARCHITECTURE.md "Fleet elasticity"):
//!   [`Fleet::fail_node`] drains a node, [`Fleet::restore_node`] brings
//!   it back cold (placement and capacity accounting see it
//!   immediately; the controller re-scales its budget to the live
//!   capacity at the next step), and [`Fleet::migrate`] moves idle warm
//!   containers between nodes under the [`migration`] planner's
//!   policies.
//! * **Telemetry** (the controller's Prometheus scrape) → the aggregate
//!   gauges ([`Fleet::warm_count`], [`Fleet::cold_ready_times`], …) and
//!   their per-function variants.
//!
//! Determinism guarantee: node 0 receives the caller's seed unchanged and
//! every placement decision is a pure function of platform state, so a
//! one-node fleet reproduces the legacy single-platform results
//! bit-for-bit (same seed → same metrics), keeping all existing figures
//! valid; a one-function registry likewise collapses every `*_for`
//! method to its legacy aggregate form.

pub mod migration;
pub mod placement;

use crate::cluster::chaos::{ChaosEngine, ExecFate};
use crate::cluster::container::ContainerId;
use crate::cluster::platform::{CompleteOutcome, InvokeOutcome, KeepAliveVerdict, Platform, ReadyOutcome};
use crate::cluster::telemetry::{Counters, FnCounterMap, GaugeSample};
use crate::cluster::RequestId;
use crate::config::{FleetConfig, Micros, PlacementPolicy, PlatformConfig};
use crate::workload::tenant::{FunctionId, FunctionRegistry};

/// Invoker-node identifier (index into the fleet, stable for a run).
pub type NodeId = u32;

/// Split `total` replica capacity across `nodes` as evenly as possible
/// with nothing lost to rounding: the first `total % nodes` nodes get one
/// extra replica. Returns None when the split is impossible (`nodes` is 0
/// or exceeds `total`, which would silently inflate capacity).
pub fn split_capacity(total: u32, nodes: u32) -> Option<Vec<u32>> {
    if nodes == 0 || nodes > total {
        return None;
    }
    let base = total / nodes;
    let rem = total % nodes;
    Some(
        (0..nodes)
            .map(|i| if i < rem { base + 1 } else { base })
            .collect(),
    )
}

/// One invoker: a platform plus its liveness flag. Offline nodes keep
/// their counters (the work happened) but hold no containers and are
/// skipped by placement and capacity accounting.
#[derive(Debug)]
pub struct InvokerNode {
    pub id: NodeId,
    pub platform: Platform,
    pub online: bool,
    /// Drain generation: how many times this node has failed. Nonzero
    /// means container events scheduled before a drain may still be in
    /// flight (referencing containers lost with the node), so
    /// unknown-container events are dropped after a rejoin instead of
    /// panicking. Ids are never reused, so post-rejoin events can't
    /// collide with lost ones.
    pub epoch: u32,
    /// Counter snapshot taken at the most recent drain. An offline node
    /// does no work, so `counters − counters_at_drain` is exactly the
    /// node's *post-rejoin* activity — the per-node report's evidence
    /// that a restored node reabsorbed load.
    pub counters_at_drain: Option<Counters>,
}

impl InvokerNode {
    /// In-flight work: executing + initializing containers + backlog.
    pub fn load(&self) -> u64 {
        (self.platform.busy_count() + self.platform.cold_starting_count()) as u64
            + self.platform.fcfs_len() as u64
    }

    // Node-scoped event handlers with the fleet's stale-event guards
    // (see the "node-scoped event handlers" section on [`Fleet`]): the
    // sequential loop reaches them through the `Fleet` wrappers, the
    // sharded workers directly through their `&mut InvokerNode` shard —
    // one implementation, so the two paths cannot drift.

    /// A cold start on this node finished initializing. None = stale
    /// event (node offline, or the container was lost to a drain or a
    /// chaos abort). The liveness check is unconditional — not gated on
    /// a nonzero drain epoch — because chaos spawn/exec aborts can
    /// orphan in-flight events on a node that never drained; ids are
    /// never reused, so a stale event can't collide with a live one.
    pub fn container_ready(&mut self, cid: ContainerId, now: Micros) -> Option<ReadyOutcome> {
        if !self.online || !self.platform.has_container(cid) {
            return None;
        }
        Some(self.platform.container_ready(cid, now))
    }

    /// An execution on this node completed. None = stale event (same
    /// unconditional liveness guard as [`Self::container_ready`]).
    pub fn exec_complete(&mut self, cid: ContainerId, now: Micros) -> Option<CompleteOutcome> {
        if !self.online || !self.platform.has_container(cid) {
            return None;
        }
        Some(self.platform.exec_complete(cid, now))
    }

    /// Keep-alive expiry check for a container on this node.
    pub fn keepalive_check(&mut self, cid: ContainerId, now: Micros) -> KeepAliveVerdict {
        if !self.online {
            return KeepAliveVerdict::NotApplicable;
        }
        self.platform.keepalive_check(cid, now)
    }

    /// Keep-alive window of a live container's function (None for
    /// unknown containers or an offline node).
    pub fn keepalive_of(&self, cid: ContainerId) -> Option<Micros> {
        if !self.online {
            return None;
        }
        self.platform.keepalive_of(cid)
    }
}

/// One node's slice of a run report: identity, liveness, live container
/// population, and the node-local monotonic counters (the per-node view
/// of the fleet's aggregate [`Counters`]).
#[derive(Debug, Clone, Copy)]
pub struct NodeReport {
    pub node: NodeId,
    pub online: bool,
    /// Replica capacity under the node's resource cap.
    pub capacity: u32,
    /// Containers live on the node when the snapshot was taken.
    pub containers: u32,
    pub counters: Counters,
    /// Counter snapshot at the node's most recent drain (None if it
    /// never failed). `counters − counters_at_drain` is the node's
    /// post-rejoin activity.
    pub counters_at_drain: Option<Counters>,
}

impl NodeReport {
    /// Activity since the node's most recent drain (None if it never
    /// drained). An offline node does no work, so nonzero
    /// invocations/prewarms here are exactly the rejoin evidence: the
    /// restored node reabsorbed load.
    pub fn post_restore(&self) -> Option<Counters> {
        let at = self.counters_at_drain?;
        let c = self.counters;
        // exhaustive construction: a new counter field must be diffed
        // here or this stops compiling
        Some(Counters {
            invocations: c.invocations - at.invocations,
            cold_starts: c.cold_starts - at.cold_starts,
            prewarms_started: c.prewarms_started - at.prewarms_started,
            prewarms_rejected: c.prewarms_rejected - at.prewarms_rejected,
            reclaims: c.reclaims - at.reclaims,
            keepalive_expiries: c.keepalive_expiries - at.keepalive_expiries,
            adaptive_expiries: c.adaptive_expiries - at.adaptive_expiries,
            capacity_queued: c.capacity_queued - at.capacity_queued,
            evictions: c.evictions - at.evictions,
            migrations_out: c.migrations_out - at.migrations_out,
            migrations_in: c.migrations_in - at.migrations_in,
            layer_hits: c.layer_hits - at.layer_hits,
            layer_misses: c.layer_misses - at.layer_misses,
            pull_mib: c.pull_mib - at.pull_mib,
            cold_cost_us: c.cold_cost_us - at.cold_cost_us,
            cold_charges: c.cold_charges - at.cold_charges,
            retries: c.retries - at.retries,
            timeouts: c.timeouts - at.timeouts,
            spawn_failures: c.spawn_failures - at.spawn_failures,
        })
    }
}

#[derive(Debug)]
pub struct Fleet {
    nodes: Vec<InvokerNode>,
    placement: PlacementPolicy,
    rr_cursor: usize,
    /// Invocation-level fault injector. None (the default, and always
    /// under `--chaos off`) means no chaos: none of the roll methods
    /// below touch any RNG, so the seed path is byte-identical.
    chaos: Option<ChaosEngine>,
}

impl Fleet {
    /// Build a single-tenant fleet (one-function registry from
    /// `platform_cfg`). See [`Fleet::with_registry`].
    pub fn new(fleet_cfg: &FleetConfig, platform_cfg: &PlatformConfig, seed: u64) -> Fleet {
        Self::with_registry(
            fleet_cfg,
            platform_cfg,
            &FunctionRegistry::single(platform_cfg),
            seed,
        )
    }

    /// Build a fleet of `fleet_cfg.nodes` invokers serving `registry`'s
    /// function set. Per-node capacity overrides come from
    /// `fleet_cfg.capacities` (cycled); node 0 keeps `seed` unchanged so
    /// a one-node fleet matches the legacy single-platform RNG stream
    /// exactly.
    pub fn with_registry(
        fleet_cfg: &FleetConfig,
        platform_cfg: &PlatformConfig,
        registry: &FunctionRegistry,
        seed: u64,
    ) -> Fleet {
        let n = fleet_cfg.nodes.max(1);
        let mut nodes = Vec::with_capacity(n as usize);
        for i in 0..n {
            let mut pc = platform_cfg.clone();
            if let Some(caps) = &fleet_cfg.capacities {
                if !caps.is_empty() {
                    let cap = caps[i as usize % caps.len()];
                    pc.max_containers = cap;
                    // the override is authoritative: lift the node's
                    // CPU/memory so the derived resource cap cannot bind
                    // below it (resource_cap() = min(cpu, mem, max))
                    pc.node_cpu_millis = pc.node_cpu_millis.max(cap * pc.container_cpu_millis);
                    pc.node_mem_mib = pc.node_mem_mib.max(cap * pc.container_mem_mib);
                }
            }
            let node_seed = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            nodes.push(InvokerNode {
                id: i,
                platform: Platform::with_registry(pc, registry.clone(), node_seed),
                online: true,
                epoch: 0,
                counters_at_drain: None,
            });
        }
        Fleet {
            nodes,
            placement: fleet_cfg.placement,
            rr_cursor: 0,
            chaos: None,
        }
    }

    // ---- topology -----------------------------------------------------------

    pub fn nodes(&self) -> &[InvokerNode] {
        &self.nodes
    }

    /// Mutable access to the node arena — the sharded executor splits
    /// this into disjoint contiguous shards (`chunks_mut`) so each
    /// worker thread owns its nodes' platforms for one batch window.
    /// Fleet-level state (placement cursor) is untouchable through it,
    /// which is exactly the isolation the deterministic merge relies on.
    pub fn nodes_mut(&mut self) -> &mut [InvokerNode] {
        &mut self.nodes
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn online_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.online).count()
    }

    pub fn node(&self, id: NodeId) -> &InvokerNode {
        &self.nodes[id as usize]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut InvokerNode {
        &mut self.nodes[id as usize]
    }

    fn online(&self) -> impl Iterator<Item = &InvokerNode> {
        self.nodes.iter().filter(|n| n.online)
    }

    // ---- aggregate gauges (the controller's cluster-level telemetry) --------

    pub fn total(&self) -> u32 {
        self.online().map(|n| n.platform.total()).sum()
    }

    pub fn idle_count(&self) -> u32 {
        self.online().map(|n| n.platform.idle_count()).sum()
    }

    pub fn busy_count(&self) -> u32 {
        self.online().map(|n| n.platform.busy_count()).sum()
    }

    pub fn warm_count(&self) -> u32 {
        self.online().map(|n| n.platform.warm_count()).sum()
    }

    pub fn cold_starting_count(&self) -> u32 {
        self.online().map(|n| n.platform.cold_starting_count()).sum()
    }

    pub fn fcfs_len(&self) -> usize {
        self.online().map(|n| n.platform.fcfs_len()).sum()
    }

    /// Total replica capacity across online nodes (the MPC's pool bound).
    pub fn resource_cap(&self) -> u32 {
        self.online().map(|n| n.platform.cfg.resource_cap()).sum()
    }

    /// Idle containers unused for at least `min_idle`, fleet-wide.
    pub fn idle_containers_older_than(&self, min_idle: Micros, now: Micros) -> u32 {
        self.online()
            .map(|n| n.platform.idle_containers_older_than(min_idle, now))
            .sum()
    }

    /// Fleet-wide idle warm pool of one function.
    pub fn idle_count_for(&self, func: FunctionId) -> u32 {
        self.online().map(|n| n.platform.idle_count_for(func)).sum()
    }

    /// Fleet-wide idle-container counts for every function in one pass
    /// (index = [`FunctionId`], length `nf`) — the dispatcher's drain
    /// snapshot.
    pub fn idle_by_function(&self, nf: usize) -> Vec<u32> {
        let mut out = vec![0u32; nf];
        self.idle_by_function_into(&mut out);
        out
    }

    /// Allocation-free [`Fleet::idle_by_function`]: zero `out` and
    /// accumulate each node's per-function idle counters into it — an
    /// O(nodes × functions) counter copy, no container scans.
    pub fn idle_by_function_into(&self, out: &mut [u32]) {
        out.fill(0);
        for n in self.online() {
            n.platform.idle_by_function_into(out);
        }
    }

    /// Fleet-wide warm (idle + busy) containers of one function.
    pub fn warm_count_for(&self, func: FunctionId) -> u32 {
        self.online().map(|n| n.platform.warm_count_for(func)).sum()
    }

    /// Fleet-wide in-flight cold starts of one function.
    pub fn cold_starting_for(&self, func: FunctionId) -> u32 {
        self.online()
            .map(|n| n.platform.cold_starting_for(func))
            .sum()
    }

    /// Ready times of in-flight cold starts of one function, fleet-wide.
    pub fn cold_ready_times_for(&self, func: FunctionId) -> Vec<Micros> {
        self.online()
            .flat_map(|n| n.platform.cold_ready_times_for(func))
            .collect()
    }

    /// Earliest ready time among in-flight cold starts of one function,
    /// fleet-wide — the force-dispatch guard's imminence probe, without
    /// materializing the ready-time vectors.
    pub fn next_cold_ready_for(&self, func: FunctionId) -> Option<Micros> {
        self.online()
            .filter_map(|n| n.platform.next_cold_ready_for(func))
            .min()
    }

    /// Keep-alive window of a live container's function (None for
    /// unknown containers or offline nodes).
    pub fn keepalive_of(&self, node: NodeId, cid: ContainerId) -> Option<Micros> {
        self.nodes.get(node as usize)?.keepalive_of(cid)
    }

    // ---- retention control (adaptive keep-alive) ----------------------------

    /// Install (or clear) the live keep-alive override for `func` on
    /// every node — offline nodes included, so a rejoiner serves new
    /// containers under the controller's current horizon immediately.
    pub fn set_keepalive_override(&mut self, func: FunctionId, horizon: Option<Micros>) {
        for n in &mut self.nodes {
            n.platform.set_keepalive_override(func, horizon);
        }
    }

    /// Expire idle containers of `func` already past `horizon` on every
    /// online node (the retention planner's sweep after shrinking a
    /// horizon). Returns how many expired fleet-wide.
    pub fn expire_idle_older_than(&mut self, func: FunctionId, horizon: Micros, now: Micros) -> u32 {
        self.nodes
            .iter_mut()
            .filter(|n| n.online)
            .map(|n| n.platform.expire_idle_older_than(func, horizon, now).len() as u32)
            .sum()
    }

    /// Total idle container-time saved by adaptive retention, fleet-wide
    /// (offline nodes keep their history).
    pub fn idle_saved(&self) -> Micros {
        self.nodes.iter().map(|n| n.platform.idle_saved()).sum()
    }

    /// Fleet memory-ledger pressure in `[0, 1]`: claimed MiB over node
    /// memory, summed across *online* capacity (the retention planner's
    /// budget-awareness input).
    pub fn mem_pressure(&self) -> f64 {
        let used: u64 = self.online().map(|n| n.platform.mem_used_mib() as u64).sum();
        let cap: u64 = self.online().map(|n| n.platform.cfg.node_mem_mib as u64).sum();
        used as f64 / cap.max(1) as f64
    }

    /// Profile of one function (every node clones the same registry, so
    /// node 0's copy is authoritative).
    pub fn profile(&self, func: FunctionId) -> &crate::workload::tenant::FunctionProfile {
        self.nodes[0].platform.profile(func)
    }

    /// Effective cold-start latency of `func` under the image-cache
    /// model: the worst case over online nodes (init + pull of whatever
    /// that node's layer store is missing). Conservative by design — the
    /// controller plans retention horizons and prewarm lead against the
    /// cost a request would pay if placement had to spill to the
    /// cache-coldest node. With `--image-cache off` every node reports
    /// the profile constant, so this degenerates to `profile.l_cold`.
    pub fn effective_l_cold(&self, func: FunctionId) -> Micros {
        self.online()
            .map(|n| n.platform.effective_l_cold(func))
            .max()
            .unwrap_or_else(|| self.profile(func).l_cold)
    }

    /// Ready times of in-flight cold starts across the fleet (readyCold).
    pub fn cold_ready_times(&self) -> Vec<Micros> {
        let mut out = Vec::new();
        self.cold_ready_times_into(&mut out);
        out
    }

    /// Allocation-free [`Fleet::cold_ready_times`]: append every online
    /// node's in-flight cold-start ready times to `out` (the controller's
    /// per-replan scratch buffer; the caller clears it).
    pub fn cold_ready_times_into(&self, out: &mut Vec<Micros>) {
        for n in self.online() {
            n.platform.cold_ready_times_into(out);
        }
    }

    /// Monotonic counters summed over every node, including offline ones
    /// (their history happened and stays in the books).
    pub fn counters(&self) -> Counters {
        let mut out = Counters::default();
        for n in &self.nodes {
            out.accumulate(&n.platform.counters);
        }
        out
    }

    /// Per-function activation counters aggregated over every node
    /// (offline included — their history happened).
    pub fn fn_counters(&self) -> FnCounterMap {
        let mut out = FnCounterMap::new();
        for n in &self.nodes {
            for (&f, c) in n.platform.fn_counters() {
                out.entry(f).or_default().accumulate(c);
            }
        }
        out
    }

    /// Containers ever created / removed, fleet-wide (conservation audit).
    pub fn spawned(&self) -> u64 {
        self.nodes.iter().map(|n| n.platform.spawned).sum()
    }

    pub fn removed(&self) -> u64 {
        self.nodes.iter().map(|n| n.platform.removed).sum()
    }

    pub fn gauge(&self, now: Micros, queue_len: u32) -> GaugeSample {
        GaugeSample {
            time: now,
            warm: self.warm_count(),
            idle: self.idle_count(),
            busy: self.busy_count(),
            cold_starting: self.cold_starting_count(),
            queue_len,
        }
    }

    /// Per-node load snapshot `(id, online, warm, load)` — the placement
    /// and prewarm-budget telemetry, also handy for reports.
    pub fn node_loads(&self) -> Vec<(NodeId, bool, u32, u64)> {
        self.nodes
            .iter()
            .map(|n| (n.id, n.online, n.platform.warm_count(), n.load()))
            .collect()
    }

    /// Per-node accounting snapshot (all nodes, offline included): which
    /// invoker did the work, and the elasticity counters showing capacity
    /// moving between nodes — the `RunReport.per_node` source.
    pub fn node_reports(&self) -> Vec<NodeReport> {
        self.nodes
            .iter()
            .map(|n| NodeReport {
                node: n.id,
                online: n.online,
                capacity: n.platform.cfg.resource_cap(),
                containers: n.platform.total(),
                counters: n.platform.counters,
                counters_at_drain: n.counters_at_drain,
            })
            .collect()
    }

    // ---- invocation path ----------------------------------------------------

    fn place_for(&mut self, func: FunctionId) -> usize {
        let picked = match self.placement {
            PlacementPolicy::RoundRobin => {
                let k = placement::round_robin(&self.nodes, self.rr_cursor);
                if k.is_some() {
                    self.rr_cursor = self.rr_cursor.wrapping_add(1);
                }
                k
            }
            PlacementPolicy::LeastLoaded => placement::least_loaded(&self.nodes),
            PlacementPolicy::WarmFirst => placement::warm_first_for(&self.nodes, func),
        };
        picked.expect("fleet has no online nodes")
    }

    /// Dispatch `req` (single-tenant shorthand for function 0).
    pub fn invoke(&mut self, req: RequestId, now: Micros) -> (NodeId, InvokeOutcome) {
        self.invoke_for(req, 0, now)
    }

    /// Dispatch `req` for `func`: the placement layer picks a node for
    /// the function (warm-first prefers nodes holding an idle container
    /// *of this function*), the node's platform applies OpenWhisk
    /// semantics (warm bind / cold start / eviction / FCFS backlog).
    pub fn invoke_for(
        &mut self,
        req: RequestId,
        func: FunctionId,
        now: Micros,
    ) -> (NodeId, InvokeOutcome) {
        let idx = self.place_for(func);
        let node = &mut self.nodes[idx];
        (node.id, node.platform.invoke_for(req, func, now))
    }

    /// Prewarm one container of function 0 (single-tenant shorthand).
    pub fn prewarm_one(&mut self, now: Micros) -> Option<(NodeId, ContainerId, Micros)> {
        self.prewarm_for(0, now)
    }

    /// Prewarm one container of `func` on the online node least
    /// provisioned *for that function* (with room for it) — this is how
    /// the MPC's fleet-scaled prewarm budget x_k lands on nodes from
    /// per-node, per-function telemetry. Ties on provisioning break
    /// toward the node that would pull the fewest image bytes (cache
    /// affinity; structurally 0 everywhere with `--image-cache off`, so
    /// the off path picks exactly as before). When no node can admit the
    /// function the least-provisioned node registers the rejection.
    pub fn prewarm_for(
        &mut self,
        func: FunctionId,
        now: Micros,
    ) -> Option<(NodeId, ContainerId, Micros)> {
        let pick = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.online && n.platform.can_admit(func))
            .min_by_key(|(i, n)| {
                (
                    n.platform.warm_count_for(func) + n.platform.cold_starting_for(func),
                    n.platform.pull_cost_mib(func),
                    *i,
                )
            })
            .map(|(i, _)| i);
        let idx = match pick {
            Some(i) => i,
            None => {
                self.nodes
                    .iter()
                    .enumerate()
                    .filter(|(_, n)| n.online)
                    .min_by_key(|(i, n)| (n.platform.total(), *i))
                    .map(|(i, _)| i)?
            }
        };
        let node = &mut self.nodes[idx];
        let id = node.id;
        node.platform
            .prewarm_for(func, now)
            .map(|(cid, ready_at)| (id, cid, ready_at))
    }

    /// Reclaim up to `n` idle containers fleet-wide, preserving
    /// Algorithm 2's global score ranking: each step drains the best
    /// candidate across all online nodes.
    pub fn try_reclaim(&mut self, n: u32, now: Micros) -> Vec<(NodeId, ContainerId)> {
        let mut out = Vec::new();
        if n == 0 {
            return out;
        }
        // single online node: defer to the platform's batch ranking
        // (bit-identical to the legacy single-platform path)
        let online: Vec<usize> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, nd)| nd.online)
            .map(|(i, _)| i)
            .collect();
        if online.len() == 1 {
            let node = &mut self.nodes[online[0]];
            let id = node.id;
            return node
                .platform
                .try_reclaim(n, now)
                .into_iter()
                .map(|cid| (id, cid))
                .collect();
        }
        for _ in 0..n {
            let best = self
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, nd)| nd.online)
                .filter_map(|(i, nd)| nd.platform.best_reclaim_score(now).map(|s| (s, i)))
                // total_cmp: a degenerate (NaN) score must not panic the
                // run mid-reclaim; ties break to the lower node index
                .max_by(|a, b| a.0.total_cmp(&b.0).then(b.1.cmp(&a.1)));
            let Some((_, idx)) = best else { break };
            let node = &mut self.nodes[idx];
            let id = node.id;
            let got = node.platform.try_reclaim(1, now);
            if got.is_empty() {
                // Unreachable today: best_reclaim_score ranks only
                // log-safe idle containers, and acks are synchronous with
                // exec_complete, so an idle container is always safe and
                // try_reclaim(1) on that node must succeed. If acks ever
                // become async (a true Loki analog), the platform's
                // rank-then-filter could pick an unsafe container and come
                // back empty here — revisit this break before that change,
                // or the remaining reclaim budget is dropped fleet-wide.
                break;
            }
            out.extend(got.into_iter().map(|cid| (id, cid)));
        }
        out
    }

    // ---- node-scoped event handlers -----------------------------------------
    //
    // Events carry (node, container); after a node failure its stale
    // Ready/Done/KeepAlive events keep arriving and must be dropped, so
    // these return None / NotApplicable for offline nodes. A *rejoined*
    // node (epoch > 0) additionally drops events for containers lost in
    // the drain — they can still be in flight when the node is back
    // online; on a never-drained node an unknown container stays the
    // hard logic error it always was.

    pub fn container_ready(
        &mut self,
        node: NodeId,
        cid: ContainerId,
        now: Micros,
    ) -> Option<ReadyOutcome> {
        self.nodes.get_mut(node as usize)?.container_ready(cid, now)
    }

    pub fn exec_complete(
        &mut self,
        node: NodeId,
        cid: ContainerId,
        now: Micros,
    ) -> Option<CompleteOutcome> {
        self.nodes.get_mut(node as usize)?.exec_complete(cid, now)
    }

    pub fn keepalive_check(&mut self, node: NodeId, cid: ContainerId, now: Micros) -> KeepAliveVerdict {
        match self.nodes.get_mut(node as usize) {
            Some(nd) => nd.keepalive_check(cid, now),
            None => KeepAliveVerdict::NotApplicable,
        }
    }

    // ---- failure / drain scenario -------------------------------------------

    /// Take `node` offline: its containers are lost and the requests they
    /// carried (executing, cold-start-bound, and FCFS backlog) are
    /// returned for redispatch through the placement layer. Refuses to
    /// drop the last online node — the fleet must keep serving.
    pub fn fail_node(&mut self, node: NodeId, now: Micros) -> Vec<RequestId> {
        if self.online_count() <= 1 {
            return Vec::new();
        }
        let Some(nd) = self.nodes.get_mut(node as usize) else {
            return Vec::new();
        };
        if !nd.online {
            return Vec::new();
        }
        nd.online = false;
        nd.epoch += 1;
        nd.counters_at_drain = Some(nd.platform.counters);
        nd.platform.fail_all(now)
    }

    /// Bring a drained node back online (the rejoin scenario): it
    /// re-enters placement and capacity accounting immediately, starting
    /// cold — no containers, no backlog, counters (history) intact. The
    /// controller's prewarm budget and `w_max` pick up the restored
    /// capacity at its next control step (live-capacity re-scaling).
    ///
    /// `cap` rebinds the node's replica capacity for the rest of the run
    /// (heterogeneous restore: the replacement machine need not match the
    /// one that failed). `None` keeps the pre-drain capacity. Returns
    /// whether the node actually transitioned offline → online.
    pub fn restore_node(&mut self, node: NodeId, _now: Micros, cap: Option<u32>) -> bool {
        match self.nodes.get_mut(node as usize) {
            Some(nd) if !nd.online => {
                if let Some(cap) = cap {
                    // the drained node holds no containers, so the
                    // override precondition (empty platform) holds
                    nd.platform.override_capacity(cap);
                }
                nd.online = true;
                true
            }
            _ => false,
        }
    }

    // ---- chaos (invocation-level fault injection) ---------------------------
    //
    // The fleet owns the engine so every RNG roll happens in determin-
    // istic event order on the single simulation stream. With no engine
    // installed (--chaos off) every wrapper is a constant: no RNG, no
    // counters, no behavior — the seed path cannot observe the feature.

    /// Install the fault injector for this run.
    pub fn set_chaos(&mut self, engine: ChaosEngine) {
        self.chaos = Some(engine);
    }

    pub fn chaos_enabled(&self) -> bool {
        self.chaos.is_some()
    }

    /// Roll a request-bound container spawn: does it fail before ready?
    pub fn chaos_spawn_fails(&mut self) -> bool {
        self.chaos.as_mut().is_some_and(|c| c.spawn_fails())
    }

    /// Roll a finished execution: does its result fail anyway?
    pub fn chaos_exec_fails(&mut self) -> bool {
        self.chaos.as_mut().is_some_and(|c| c.exec_fails())
    }

    /// Roll an execution's fate at dispatch (normal / straggler /
    /// timeout). Always [`ExecFate::Normal`] with chaos off.
    pub fn chaos_exec_fate(
        &mut self,
        func: FunctionId,
        start: Micros,
        done_at: Micros,
    ) -> ExecFate {
        match self.chaos.as_mut() {
            Some(c) => c.exec_fate(func, start, done_at),
            None => ExecFate::Normal,
        }
    }

    /// Charge one fault against `req`'s retry budget: `Some(backoff)`
    /// schedules the retry, `None` drops the request.
    pub fn chaos_retry_decision(&mut self, req: RequestId) -> Option<Micros> {
        self.chaos.as_mut()?.retry_decision(req)
    }

    /// Count a scheduled retry on the node where the fault happened.
    pub fn charge_retry(&mut self, node: NodeId) {
        if let Some(nd) = self.nodes.get_mut(node as usize) {
            nd.platform.counters.retries += 1;
        }
    }

    /// Chaos spawn failure on `node`: tear down a cold-starting
    /// container, returning the request it carried. None = stale (node
    /// offline or container already gone), a logged drop at the caller.
    pub fn abort_spawn(&mut self, node: NodeId, cid: ContainerId, now: Micros) -> Option<RequestId> {
        let nd = self.nodes.get_mut(node as usize)?;
        if !nd.online || !nd.platform.has_container(cid) {
            return None;
        }
        nd.platform.abort_spawn(cid, now)
    }

    /// Chaos execution timeout on `node`: kill a busy container at its
    /// deadline, returning the in-flight request. None = stale.
    pub fn abort_exec(&mut self, node: NodeId, cid: ContainerId, now: Micros) -> Option<RequestId> {
        let nd = self.nodes.get_mut(node as usize)?;
        if !nd.online || !nd.platform.has_container(cid) {
            return None;
        }
        nd.platform.abort_exec(cid, now)
    }

    /// Migration actuator: move one idle warm container of `func` from
    /// node `from` to node `to`. The source releases its LRU log-safe
    /// candidate (books it like a drain); the destination hosts the
    /// in-flight transfer — slot and memory claimed now, serviceable at
    /// the returned ready time (`now + latency`, jittered, with no cold
    /// start counted). Returns None with **no state change** when either
    /// side cannot participate (offline, no movable candidate, or the
    /// destination cannot admit the function — migrations never evict).
    pub fn migrate(
        &mut self,
        from: NodeId,
        to: NodeId,
        func: FunctionId,
        now: Micros,
        latency: Micros,
    ) -> Option<(ContainerId, Micros)> {
        if from == to {
            return None;
        }
        let src_ok = self.nodes.get(from as usize).is_some_and(|n| n.online);
        let dst_ok = self
            .nodes
            .get(to as usize)
            .is_some_and(|n| n.online && n.platform.can_admit(func));
        if !src_ok || !dst_ok {
            return None;
        }
        let cid = self.nodes[from as usize].platform.migrate_out_candidate(func)?;
        if !self.nodes[from as usize].platform.migrate_out(cid, now) {
            return None;
        }
        // admission was checked above and releasing a container on
        // another node cannot invalidate it
        self.nodes[to as usize].platform.migrate_in(func, now, latency)
    }

    /// End-of-run accounting across every node (offline nodes are already
    /// empty). Returns concatenated (keep-alive durations, idle totals).
    pub fn finalize(&mut self, now: Micros) -> (Vec<Micros>, Vec<Micros>) {
        let mut ka = Vec::new();
        let mut idle = Vec::new();
        for nd in &mut self.nodes {
            let (k, i) = nd.platform.finalize(now);
            ka.extend(k);
            idle.extend(i);
        }
        (ka, idle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FleetConfig;

    fn pcfg() -> PlatformConfig {
        PlatformConfig {
            latency_jitter: 0.0,
            ..Default::default()
        }
    }

    fn fleet(nodes: u32, placement: PlacementPolicy) -> Fleet {
        let fc = FleetConfig {
            nodes,
            placement,
            ..Default::default()
        };
        Fleet::new(&fc, &pcfg(), 11)
    }

    #[test]
    fn single_node_fleet_mirrors_bare_platform() {
        // same seed, same call sequence → identical outcomes and counters
        let mut f = fleet(1, PlacementPolicy::WarmFirst);
        let mut p = Platform::new(pcfg(), 11);
        for (req, t) in [(0u64, 0u64), (1, 1000), (2, 2000)] {
            let (node, a) = f.invoke(req, t);
            let b = p.invoke(req, t);
            assert_eq!(node, 0);
            assert_eq!(a, b);
        }
        assert_eq!(f.counters().cold_starts, p.counters.cold_starts);
        assert_eq!(f.cold_ready_times(), p.cold_ready_times());
        assert_eq!(f.resource_cap(), p.cfg.resource_cap());
    }

    #[test]
    fn round_robin_sprays_across_nodes() {
        let mut f = fleet(3, PlacementPolicy::RoundRobin);
        let mut seen = Vec::new();
        for req in 0..6 {
            let (node, _) = f.invoke(req, req * 1000);
            seen.push(node);
        }
        assert_eq!(seen, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(f.counters().cold_starts, 6); // every node cold-started
    }

    #[test]
    fn warm_first_reuses_warm_node() {
        let mut f = fleet(4, PlacementPolicy::WarmFirst);
        let (n0, out) = f.invoke(0, 0);
        let InvokeOutcome::ColdStart { cid, ready_at } = out else {
            panic!("{out:?}")
        };
        let ReadyOutcome::Started { done_at, .. } =
            f.container_ready(n0, cid, ready_at).unwrap()
        else {
            panic!()
        };
        f.exec_complete(n0, cid, done_at).unwrap();
        // next request must ride the idle warm container on the same node
        let (n1, out) = f.invoke(1, done_at + 1000);
        assert_eq!(n1, n0);
        assert!(matches!(out, InvokeOutcome::WarmStart { .. }), "{out:?}");
        assert_eq!(f.counters().cold_starts, 1);
    }

    #[test]
    fn least_loaded_balances_inflight_work() {
        let mut f = fleet(2, PlacementPolicy::LeastLoaded);
        let (a, _) = f.invoke(0, 0);
        let (b, _) = f.invoke(1, 10);
        assert_ne!(a, b); // second request avoids the loaded node
    }

    #[test]
    fn prewarm_budget_spreads_across_nodes() {
        let mut f = fleet(3, PlacementPolicy::WarmFirst);
        let mut targets = Vec::new();
        for _ in 0..6 {
            let (node, _cid, _ready) = f.prewarm_one(0).unwrap();
            targets.push(node);
        }
        // least-provisioned-first: each node gets every third prewarm
        assert_eq!(targets, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn heterogeneous_capacities_cycle() {
        let fc = FleetConfig {
            nodes: 3,
            capacities: Some(vec![1, 2]),
            placement: PlacementPolicy::LeastLoaded,
            ..Default::default()
        };
        let f = Fleet::new(&fc, &pcfg(), 1);
        assert_eq!(f.node(0).platform.cfg.resource_cap(), 1);
        assert_eq!(f.node(1).platform.cfg.resource_cap(), 2);
        assert_eq!(f.node(2).platform.cfg.resource_cap(), 1); // cycled
        assert_eq!(f.resource_cap(), 4);
    }

    #[test]
    fn capacity_override_beats_cpu_derived_cap() {
        // PlatformConfig's CPU budget caps at 64; an explicit per-node
        // override above that must still be honored
        let fc = FleetConfig {
            nodes: 1,
            capacities: Some(vec![128]),
            placement: PlacementPolicy::WarmFirst,
            ..Default::default()
        };
        let f = Fleet::new(&fc, &pcfg(), 1);
        assert_eq!(f.resource_cap(), 128);
    }

    #[test]
    fn split_capacity_conserves_total() {
        assert_eq!(split_capacity(64, 1), Some(vec![64]));
        assert_eq!(split_capacity(64, 3), Some(vec![22, 21, 21]));
        assert_eq!(
            split_capacity(64, 3).unwrap().iter().sum::<u32>(),
            64,
            "remainder must not be lost"
        );
        assert_eq!(split_capacity(64, 0), None);
        assert_eq!(split_capacity(4, 8), None, "must not inflate capacity");
    }

    #[test]
    fn fail_node_returns_lost_work_and_goes_dark() {
        let mut f = fleet(2, PlacementPolicy::RoundRobin);
        let (n0, _) = f.invoke(7, 0); // cold-start bound to req 7 on node 0
        assert_eq!(n0, 0);
        let lost = f.fail_node(0, 1000);
        assert_eq!(lost, vec![7]);
        assert_eq!(f.online_count(), 1);
        // stale events for the dead node are dropped, not panics
        assert!(f.container_ready(0, 1, 10_500_000).is_none());
        assert!(f.exec_complete(0, 1, 10_500_000).is_none());
        assert_eq!(
            f.keepalive_check(0, 1, 10_500_000),
            KeepAliveVerdict::NotApplicable
        );
        // counters survive the failure (the invocation happened)
        assert_eq!(f.counters().invocations, 1);
        // placement now only sees node 1
        let (n, _) = f.invoke(8, 2000);
        assert_eq!(n, 1);
    }

    #[test]
    fn fail_node_refuses_last_online() {
        let mut f = fleet(1, PlacementPolicy::WarmFirst);
        assert!(f.fail_node(0, 0).is_empty());
        assert_eq!(f.online_count(), 1);
    }

    #[test]
    fn restore_node_rejoins_cold_and_reabsorbs_work() {
        let mut f = fleet(2, PlacementPolicy::RoundRobin);
        let (n0, _) = f.invoke(1, 0);
        assert_eq!(n0, 0);
        f.fail_node(0, 1000);
        assert_eq!(f.online_count(), 1);
        // restoring an online node is a no-op, an offline one rejoins
        assert!(!f.restore_node(1, 2000, None));
        assert!(f.restore_node(0, 2000, None));
        assert!(!f.restore_node(0, 2001, None), "already online");
        assert_eq!(f.online_count(), 2);
        // the node rejoined cold: no containers, but capacity counts again
        assert_eq!(f.node(0).platform.total(), 0);
        assert_eq!(f.resource_cap(), 2 * f.node(1).platform.cfg.resource_cap());
        // placement routes to it again (round-robin resumes over both)...
        let mut seen = Vec::new();
        for req in 2..6 {
            seen.push(f.invoke(req, 3000 + req).0);
        }
        assert!(seen.contains(&0), "restored node got no dispatches: {seen:?}");
        // ...and the prewarm budget lands on the least-provisioned node,
        // which is now the cold rejoiner
        assert!(f.node(0).platform.counters.invocations >= 2);
    }

    #[test]
    fn stale_events_after_rejoin_are_dropped_not_panics() {
        let mut f = fleet(2, PlacementPolicy::RoundRobin);
        // a cold start in flight on node 0, lost when the node drains
        let (n0, out) = f.invoke(7, 0);
        let InvokeOutcome::ColdStart { cid, ready_at } = out else {
            panic!("{out:?}")
        };
        assert_eq!(n0, 0);
        f.fail_node(0, 1000);
        assert!(f.restore_node(0, 2000, None));
        // the pre-drain Ready event arrives at the now-online node: the
        // container died with the drain, so the event must be dropped
        assert!(f.container_ready(0, cid, ready_at).is_none());
        assert!(f.exec_complete(0, cid, ready_at).is_none());
        assert_eq!(
            f.keepalive_check(0, cid, ready_at),
            KeepAliveVerdict::NotApplicable
        );
        // fresh work on the rejoined node flows normally (new ids)
        let (cid2, r2) = f.node_mut(0).platform.prewarm_one(3000).unwrap();
        assert_ne!(cid2, cid, "container ids must never be reused");
        assert!(matches!(
            f.container_ready(0, cid2, r2),
            Some(ReadyOutcome::Idle)
        ));
    }

    #[test]
    fn migrate_moves_warm_state_without_cold_start() {
        let mut f = fleet(2, PlacementPolicy::WarmFirst);
        let (cid, r) = f.node_mut(0).platform.prewarm_one(0).unwrap();
        f.node_mut(0).platform.container_ready(cid, r);
        assert_eq!(f.idle_count(), 1);
        let (ncid, ready_at) = f
            .migrate(0, 1, 0, r + 1_000_000, 2_000_000)
            .expect("migration must proceed");
        assert_eq!(ready_at, r + 3_000_000);
        // source released, destination hosts the in-flight transfer
        assert_eq!(f.node(0).platform.total(), 0);
        assert_eq!(f.node(1).platform.cold_starting_count(), 1);
        let c = f.counters();
        assert_eq!(c.migrations_out, 1);
        assert_eq!(c.migrations_in, 1);
        assert_eq!(c.cold_starts, 0, "migration is not a cold start");
        // conservation holds across the move (one removed, one spawned)
        assert_eq!(f.spawned(), f.removed() + f.total() as u64);
        // the transfer lands and serves warm on the destination
        assert!(matches!(
            f.container_ready(1, ncid, ready_at),
            Some(ReadyOutcome::Idle)
        ));
        let (node, out) = f.invoke(1, ready_at + 10);
        assert_eq!(node, 1);
        assert!(matches!(out, InvokeOutcome::WarmStart { .. }), "{out:?}");
    }

    #[test]
    fn migrate_refuses_bad_endpoints() {
        let mut f = fleet(2, PlacementPolicy::WarmFirst);
        // nothing to move
        assert!(f.migrate(0, 1, 0, 0, 1000).is_none());
        let (cid, r) = f.node_mut(0).platform.prewarm_one(0).unwrap();
        f.node_mut(0).platform.container_ready(cid, r);
        // self-moves, unknown nodes, offline destinations
        assert!(f.migrate(0, 0, 0, r, 1000).is_none());
        assert!(f.migrate(0, 9, 0, r, 1000).is_none());
        f.fail_node(1, r + 1);
        assert!(f.migrate(0, 1, 0, r + 2, 1000).is_none());
        // no state was touched by the refusals
        assert_eq!(f.node(0).platform.total(), 1);
        assert_eq!(f.counters().migrations_out, 0);
    }

    #[test]
    fn node_reports_carry_per_node_counters() {
        let mut f = fleet(2, PlacementPolicy::RoundRobin);
        f.invoke(0, 0);
        f.invoke(1, 10);
        let reports = f.node_reports();
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.online && r.counters.invocations == 1));
        assert_eq!(reports[0].node, 0);
        assert_eq!(reports[1].containers, 1);
        // offline nodes keep their history in the report, and the drain
        // snapshot pins what happened before the outage
        f.fail_node(1, 100);
        let reports = f.node_reports();
        assert!(!reports[1].online);
        assert_eq!(reports[1].counters.invocations, 1);
        assert_eq!(reports[1].containers, 0);
        assert!(reports[0].post_restore().is_none(), "node 0 never drained");
        let pr = reports[1].post_restore().expect("drained node has snapshot");
        assert_eq!(pr.invocations, 0, "no post-rejoin work yet");
        // after a restore, new work shows up as post-restore activity
        assert!(f.restore_node(1, 200, None));
        f.invoke(2, 300); // round-robin continues on node 0 or 1
        f.invoke(3, 310);
        let pr = f.node_reports()[1].post_restore().unwrap();
        assert_eq!(pr.invocations, 1, "one of the two landed on node 1");
    }

    #[test]
    fn reclaim_follows_global_score_ranking() {
        let mut f = fleet(2, PlacementPolicy::WarmFirst);
        // idle container on each node; node 0's is older (higher score)
        let (c0, r0) = f.node_mut(0).platform.prewarm_one(0).unwrap();
        f.node_mut(0).platform.container_ready(c0, r0);
        let (c1, r1) = f.node_mut(1).platform.prewarm_one(5_000_000).unwrap();
        f.node_mut(1).platform.container_ready(c1, r1);
        let got = f.try_reclaim(1, r1 + 1_000_000);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 0, "longest-idle candidate lives on node 0");
        // the remaining idle container drains next
        let got2 = f.try_reclaim(5, r1 + 2_000_000);
        assert_eq!(got2.len(), 1);
        assert_eq!(got2[0].0, 1);
        assert_eq!(f.idle_count(), 0);
    }

    #[test]
    fn function_aware_warm_first_and_prewarm_split() {
        use crate::workload::tenant::{FunctionProfile, FunctionRegistry};
        let pc = pcfg();
        let mut p0 = FunctionRegistry::single(&pc).get(0).clone();
        p0.share = 0.5;
        let registry = FunctionRegistry::new(vec![
            p0,
            FunctionProfile {
                id: 1,
                name: "fn-1".into(),
                l_warm: 100_000,
                l_cold: 2_000_000,
                keep_alive: 60_000_000,
                mem_mib: 128,
                share: 0.5,
                idle_cost: None,
                cold_cost_weight: None,
            },
        ]);
        let fc = FleetConfig {
            nodes: 3,
            placement: PlacementPolicy::WarmFirst,
            ..Default::default()
        };
        let mut f = Fleet::with_registry(&fc, &pc, &registry, 11);
        // idle fn-0 container on node 2, idle fn-1 container on node 1
        let (c0, r0) = f.node_mut(2).platform.prewarm_for(0, 0).unwrap();
        f.node_mut(2).platform.container_ready(c0, r0);
        let (c1, r1) = f.node_mut(1).platform.prewarm_for(1, 5_000_000).unwrap();
        f.node_mut(1).platform.container_ready(c1, r1);
        // each function routes to ITS warm node, not the freshest overall
        let (n, out) = f.invoke_for(1, 0, r1 + 10);
        assert_eq!(n, 2);
        assert!(matches!(out, InvokeOutcome::WarmStart { .. }), "{out:?}");
        let (n, out) = f.invoke_for(2, 1, r1 + 20);
        assert_eq!(n, 1);
        assert!(matches!(out, InvokeOutcome::WarmStart { .. }), "{out:?}");
        // per-function prewarm provisioning counts only that function:
        // fn-1 is provisioned on node 1 (busy), so its next prewarms land
        // on nodes 0 and 2 first
        let (pn, _, _) = f.prewarm_for(1, r1 + 30).unwrap();
        assert_eq!(pn, 0);
        let (pn, _, _) = f.prewarm_for(1, r1 + 40).unwrap();
        assert_eq!(pn, 2);
        // per-function counters aggregate across nodes
        let fc_map = f.fn_counters();
        assert_eq!(fc_map[&0].warm_starts, 1);
        assert_eq!(fc_map[&1].warm_starts, 1);
        assert_eq!(f.warm_count_for(0), 1);
        assert_eq!(f.cold_starting_for(1), 2);
        assert_eq!(f.keepalive_of(1, c1), Some(60_000_000));
    }

    #[test]
    fn aggregates_sum_over_online_nodes() {
        let mut f = fleet(2, PlacementPolicy::RoundRobin);
        f.invoke(0, 0);
        f.invoke(1, 0);
        assert_eq!(f.cold_starting_count(), 2);
        assert_eq!(f.total(), 2);
        assert_eq!(f.spawned(), 2);
        let g = f.gauge(0, 0);
        assert_eq!(g.cold_starting, 2);
        assert_eq!(f.node_loads().len(), 2);
        // failing node 1 removes its container from the aggregates but
        // keeps conservation intact
        f.fail_node(1, 10);
        assert_eq!(f.cold_starting_count(), 1);
        assert_eq!(f.spawned(), 2);
        assert_eq!(f.removed(), 1);
    }

    // ---- image cache across the fleet ---------------------------------------

    fn cached_fleet(nodes: u32) -> Fleet {
        use crate::config::{ImageCacheConfig, ImageCacheMode};
        let pc = PlatformConfig {
            latency_jitter: 0.0,
            image: ImageCacheConfig {
                mode: ImageCacheMode::Lru,
                ..Default::default()
            },
            ..Default::default()
        };
        let fc = FleetConfig {
            nodes,
            placement: PlacementPolicy::WarmFirst,
            ..Default::default()
        };
        Fleet::new(&fc, &pc, 11)
    }

    #[test]
    fn effective_l_cold_is_worst_case_over_online_nodes() {
        let mut f = cached_fleet(2);
        // default single-function image: 64+192+256+16 = 528 MiB at
        // 100 MiB/s + 25% of the 10.5 s constant as init
        let cache_cold = 2_625_000 + 5_280_000;
        assert_eq!(f.effective_l_cold(0), cache_cold);
        // warming one node does not change the fleet's worst case...
        f.node_mut(0).platform.warm_image_for(0);
        assert_eq!(f.node(0).platform.effective_l_cold(0), 2_625_000);
        assert_eq!(f.effective_l_cold(0), cache_cold);
        // ...until the cache-cold node leaves the online set
        f.fail_node(1, 1000);
        assert_eq!(f.effective_l_cold(0), 2_625_000);
        // off mode reports the profile constant
        let off = fleet(2, PlacementPolicy::WarmFirst);
        assert_eq!(off.effective_l_cold(0), off.profile(0).l_cold);
    }

    #[test]
    fn prewarm_ties_break_toward_the_cache_warm_node() {
        let mut f = cached_fleet(3);
        // equal provisioning everywhere; only node 2 holds the image
        f.node_mut(2).platform.warm_image_for(0);
        let (n, _, _) = f.prewarm_for(0, 0).unwrap();
        assert_eq!(n, 2, "cache-affine node must win the tie");
        // with node 2 now provisioned, the remaining tie (nodes 0, 1)
        // falls back to the index order — both are equally cache-cold
        let (n, _, _) = f.prewarm_for(0, 10).unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn restore_with_capacity_override_rebinds_the_cap() {
        let mut f = fleet(2, PlacementPolicy::LeastLoaded);
        let base = f.node(0).platform.cfg.resource_cap();
        f.fail_node(0, 1000);
        assert!(f.restore_node(0, 2000, Some(3)));
        assert_eq!(f.node(0).platform.cfg.resource_cap(), 3);
        assert_ne!(f.node(0).platform.cfg.resource_cap(), base);
        assert_eq!(f.node(1).platform.cfg.resource_cap(), base, "peer untouched");
        assert_eq!(f.resource_cap(), base + 3);
        // the rebind is sticky: a later drain/rejoin keeps the new cap
        f.fail_node(0, 3000);
        assert!(f.restore_node(0, 4000, None));
        assert_eq!(f.node(0).platform.cfg.resource_cap(), 3);
    }
}
