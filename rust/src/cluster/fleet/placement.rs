//! Dispatch placement layer: picks the invoker node an invocation is
//! routed to. Policies are pure functions over the node array (plus the
//! round-robin cursor owned by the fleet), so placement decisions are
//! deterministic and never consume platform RNG state.
//!
//! Every per-node probe these policies make — `load()` (busy +
//! cold-starting + backlog), `mru_idle_recency_for`, `can_admit` — reads
//! the platform's incrementally-maintained indices, so placing one
//! request is O(nodes), independent of the container population (see
//! "State indices & hot-path complexity" in docs/ARCHITECTURE.md).

use crate::cluster::fleet::InvokerNode;
use crate::workload::tenant::FunctionId;

/// Rotate through online nodes: the `cursor`-th online node (mod count).
/// OpenWhisk's hash-spray analog — blind to warm-pool state, so it
/// maximizes placement skew and warm-pool fragmentation.
pub fn round_robin(nodes: &[InvokerNode], cursor: usize) -> Option<usize> {
    // allocation-free: this runs once per dispatch, the simulator's
    // hottest loop
    let online_count = nodes.iter().filter(|n| n.online).count();
    if online_count == 0 {
        return None;
    }
    nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| n.online)
        .nth(cursor % online_count)
        .map(|(i, _)| i)
}

/// Online node with the least in-flight work (busy + cold-starting +
/// backlog); ties break to the lower node index.
pub fn least_loaded(nodes: &[InvokerNode]) -> Option<usize> {
    nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| n.online)
        .min_by_key(|(i, n)| (n.load(), *i))
        .map(|(i, _)| i)
}

/// Single-tenant [`warm_first_for`] (function 0).
pub fn warm_first(nodes: &[InvokerNode]) -> Option<usize> {
    warm_first_for(nodes, 0)
}

/// Route to a node holding an idle warm container **of this function** —
/// most recently used first, preserving OpenWhisk's MRU reuse affinity
/// across the fleet. A foreign function's warm pool is useless to this
/// request, so it never attracts it. With no matching idle container
/// anywhere, spill to the least-loaded node that can still admit the
/// function, breaking load ties toward the node whose image cache would
/// pull the fewest bytes for it (cache affinity — the spill is a cold
/// start, so the missing layers are exactly its extra latency; the
/// probe is structurally 0 with `--image-cache off`, leaving the legacy
/// order untouched). With the whole fleet saturated, fall back to
/// least-loaded (the request joins that node's FCFS backlog or evicts a
/// foreign idle container there).
pub fn warm_first_for(nodes: &[InvokerNode], func: FunctionId) -> Option<usize> {
    let warmest = nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| n.online)
        .filter_map(|(i, n)| n.platform.mru_idle_recency_for(func).map(|r| (r, i)))
        .max_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
    if let Some((_, i)) = warmest {
        return Some(i);
    }
    let spill = nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| n.online && n.platform.can_admit(func))
        .min_by_key(|(i, n)| (n.load(), n.platform.pull_cost_mib(func), *i))
        .map(|(i, _)| i);
    if spill.is_some() {
        return spill;
    }
    least_loaded(nodes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::{Fleet, NodeId};
    use crate::config::{FleetConfig, PlacementPolicy, PlatformConfig};

    fn fleet(n: u32) -> Fleet {
        let fc = FleetConfig {
            nodes: n,
            placement: PlacementPolicy::WarmFirst,
            ..Default::default()
        };
        let pc = PlatformConfig {
            latency_jitter: 0.0,
            ..Default::default()
        };
        Fleet::new(&fc, &pc, 7)
    }

    fn prewarm_on(f: &mut Fleet, node: NodeId, now: u64) {
        let (cid, ready_at) = f.node_mut(node).platform.prewarm_one(now).unwrap();
        f.node_mut(node).platform.container_ready(cid, ready_at);
    }

    #[test]
    fn round_robin_cycles_online_nodes() {
        let f = fleet(3);
        assert_eq!(round_robin(f.nodes(), 0), Some(0));
        assert_eq!(round_robin(f.nodes(), 1), Some(1));
        assert_eq!(round_robin(f.nodes(), 2), Some(2));
        assert_eq!(round_robin(f.nodes(), 3), Some(0));
    }

    #[test]
    fn round_robin_skips_offline() {
        let mut f = fleet(3);
        f.fail_node(1, 0);
        assert_eq!(round_robin(f.nodes(), 0), Some(0));
        assert_eq!(round_robin(f.nodes(), 1), Some(2));
        assert_eq!(round_robin(f.nodes(), 2), Some(0));
    }

    #[test]
    fn least_loaded_prefers_empty_node() {
        let mut f = fleet(2);
        // put in-flight work on node 0
        f.node_mut(0).platform.invoke(1, 0);
        assert_eq!(least_loaded(f.nodes()), Some(1));
    }

    #[test]
    fn warm_first_routes_to_idle_then_spills() {
        let mut f = fleet(3);
        // no idle anywhere: spill to least-loaded with headroom (node 0)
        assert_eq!(warm_first(f.nodes()), Some(0));
        // idle container on node 2: route there despite node 0 being empty
        prewarm_on(&mut f, 2, 0);
        assert_eq!(warm_first(f.nodes()), Some(2));
        // MRU affinity: fresher idle container on node 1 wins
        prewarm_on(&mut f, 1, 5_000_000);
        assert_eq!(warm_first(f.nodes()), Some(1));
    }

    #[test]
    fn cold_spill_prefers_the_cache_warm_node() {
        use crate::config::{ImageCacheConfig, ImageCacheMode};
        let fc = FleetConfig {
            nodes: 3,
            placement: PlacementPolicy::WarmFirst,
            ..Default::default()
        };
        let pc = PlatformConfig {
            latency_jitter: 0.0,
            image: ImageCacheConfig {
                mode: ImageCacheMode::Lru,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut f = Fleet::new(&fc, &pc, 7);
        // no idle containers anywhere and equal load: the spill tie
        // breaks toward the node already holding the image layers
        f.node_mut(1).platform.warm_image_for(0);
        assert_eq!(warm_first(f.nodes()), Some(1));
        // a genuine idle warm container still dominates cache affinity
        prewarm_on(&mut f, 2, 0);
        assert_eq!(warm_first(f.nodes()), Some(2));
    }

    #[test]
    fn no_online_nodes_yields_none() {
        let mut f = fleet(1);
        // fail_node refuses to drop the last online node, so force the
        // flag directly to exercise the placement guard
        f.node_mut(0).online = false;
        assert_eq!(round_robin(f.nodes(), 0), None);
        assert_eq!(least_loaded(f.nodes()), None);
        assert_eq!(warm_first(f.nodes()), None);
    }
}
