//! Per-node content-addressed image/layer cache — the cold-start
//! fidelity model (ISSUE 6 tentpole).
//!
//! The paper charges every cold start a constant `L_cold(f)`, but the
//! cold-start taxonomy literature splits that latency into *image
//! distribution* (dominant, and a function of what the node's layer
//! store already holds) and *runtime init* (irreducible). This module
//! models the distribution half: each `FunctionProfile` maps to an
//! [`ImageManifest`] of content-addressed layers (base runtime layers
//! shared across functions, per-function app layers), and every node
//! carries an [`ImageCache`] — a capacity-bounded LRU layer store. A
//! cold start pulls exactly the layers the node is missing, so the
//! effective `L_cold(f, n)` is node-local state the controller can
//! *manage*: prewarms and migrations warm the destination cache,
//! placement prefers cache-affine nodes, and the retention/prewarm
//! rules consume the dynamic cost each control step.
//!
//! Determinism: the cache holds no RNG and iterates only over ordered
//! `BTreeMap`/`BTreeSet` state; recency is a monotone operation
//! sequence number (not simulation time), so identical operation
//! sequences reproduce identical eviction orders bit for bit.

use crate::config::ImageCacheConfig;

/// Content digest of one image layer (the content-addressed identity:
/// two functions naming the same `LayerId` share the bytes on disk).
pub type LayerId = u64;

/// One image layer: a content digest plus its size on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layer {
    pub id: LayerId,
    pub size_mib: u32,
}

/// A function's image: the ordered layer list its container is built
/// from. Order is cosmetic (pulls are charged by total missing bytes);
/// identity is per layer.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ImageManifest {
    pub layers: Vec<Layer>,
}

impl ImageManifest {
    pub fn new(layers: Vec<Layer>) -> Self {
        ImageManifest { layers }
    }

    /// Total image size in MiB (the pull cost against an empty cache).
    pub fn total_mib(&self) -> u64 {
        self.layers.iter().map(|l| l.size_mib as u64).sum()
    }
}

/// What one [`ImageCache::admit`] call did: per-layer hit/miss tallies
/// and the bytes actually pulled from the registry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmitOutcome {
    pub hits: u64,
    pub misses: u64,
    pub pulled_mib: u64,
}

/// A node's layer store: content-addressed, capacity-bounded, LRU.
///
/// The store outlives the node's containers — layers live on the node's
/// disk, not inside any container, so a drain (`fail_all`) that kills
/// every container leaves the cache intact; a rejoining node is
/// container-cold but image-warm, exactly like a restarted invoker.
#[derive(Debug, Clone)]
pub struct ImageCache {
    cfg: ImageCacheConfig,
    /// layer id → (size, recency sequence number of the last touch)
    cached: std::collections::BTreeMap<LayerId, (u32, u64)>,
    /// (recency seq, layer id) mirror of `cached`, ordered oldest-first
    /// so eviction pops the front deterministically.
    lru: std::collections::BTreeSet<(u64, LayerId)>,
    used_mib: u64,
    /// Monotone operation counter driving recency (never simulation
    /// time: two ops in the same microsecond must still order).
    seq: u64,
}

impl ImageCache {
    pub fn new(cfg: ImageCacheConfig) -> Self {
        ImageCache {
            cfg,
            cached: std::collections::BTreeMap::new(),
            lru: std::collections::BTreeSet::new(),
            used_mib: 0,
            seq: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    pub fn capacity_mib(&self) -> u64 {
        self.cfg.capacity_mib as u64
    }

    pub fn used_mib(&self) -> u64 {
        self.used_mib
    }

    pub fn len(&self) -> usize {
        self.cached.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cached.is_empty()
    }

    pub fn contains(&self, layer: LayerId) -> bool {
        self.cached.contains_key(&layer)
    }

    /// Bytes of `manifest` this node would have to pull right now — the
    /// read-only affinity probe placement and the controller use. Does
    /// not touch recency.
    pub fn missing_mib(&self, manifest: &ImageManifest) -> u64 {
        if !self.enabled() {
            return 0;
        }
        manifest
            .layers
            .iter()
            .filter(|l| !self.cached.contains_key(&l.id))
            .map(|l| l.size_mib as u64)
            .sum()
    }

    /// Admit `manifest` into the store: pull every missing layer, touch
    /// every layer (hit or pulled) to most-recently-used, then LRU-evict
    /// back under capacity. Layers of the image being admitted are
    /// touched *before* eviction runs, so an image larger than the whole
    /// store evicts everything else first and only then sheds its own
    /// oldest layers — deterministic, never panicking.
    pub fn admit(&mut self, manifest: &ImageManifest) -> AdmitOutcome {
        if !self.enabled() {
            return AdmitOutcome::default();
        }
        let mut out = AdmitOutcome::default();
        for l in &manifest.layers {
            self.seq += 1;
            match self.cached.insert(l.id, (l.size_mib, self.seq)) {
                Some((size, old_seq)) => {
                    out.hits += 1;
                    debug_assert_eq!(size, l.size_mib, "content-addressed: same id, same bytes");
                    self.lru.remove(&(old_seq, l.id));
                }
                None => {
                    out.misses += 1;
                    out.pulled_mib += l.size_mib as u64;
                    self.used_mib += l.size_mib as u64;
                }
            }
            self.lru.insert((self.seq, l.id));
        }
        while self.used_mib > self.capacity_mib() {
            let Some(&(seq, id)) = self.lru.iter().next() else {
                break;
            };
            self.lru.remove(&(seq, id));
            let (size, _) = self.cached.remove(&id).expect("lru mirrors cached");
            self.used_mib -= size as u64;
        }
        out
    }

    /// Ledger invariants, for `assert_matches_scan`-style property
    /// checks: the LRU mirror and the byte ledger must agree with the
    /// store exactly, and the store never sits over capacity.
    pub fn check_ledger(&self) -> Result<(), String> {
        if self.lru.len() != self.cached.len() {
            return Err(format!(
                "lru len {} != cached len {}",
                self.lru.len(),
                self.cached.len()
            ));
        }
        for &(seq, id) in &self.lru {
            match self.cached.get(&id) {
                Some(&(_, s)) if s == seq => {}
                other => return Err(format!("lru entry ({seq}, {id}) vs cached {other:?}")),
            }
        }
        let sum: u64 = self.cached.values().map(|&(size, _)| size as u64).sum();
        if sum != self.used_mib {
            return Err(format!("used_mib ledger {} != scan {}", self.used_mib, sum));
        }
        if self.enabled() && self.used_mib > self.capacity_mib() {
            return Err(format!(
                "over capacity: used {} > cap {}",
                self.used_mib,
                self.capacity_mib()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ImageCacheMode;

    fn lru(capacity_mib: u32) -> ImageCache {
        ImageCache::new(ImageCacheConfig {
            mode: ImageCacheMode::Lru,
            capacity_mib,
            ..Default::default()
        })
    }

    fn manifest(layers: &[(LayerId, u32)]) -> ImageManifest {
        ImageManifest::new(
            layers
                .iter()
                .map(|&(id, size_mib)| Layer { id, size_mib })
                .collect(),
        )
    }

    #[test]
    fn first_admit_pulls_everything_second_hits() {
        let mut c = lru(1024);
        let m = manifest(&[(1, 64), (2, 192), (10, 256)]);
        assert_eq!(c.missing_mib(&m), 512);
        let a = c.admit(&m);
        assert_eq!(a, AdmitOutcome { hits: 0, misses: 3, pulled_mib: 512 });
        assert_eq!(c.used_mib(), 512);
        assert_eq!(c.missing_mib(&m), 0);
        let b = c.admit(&m);
        assert_eq!(b, AdmitOutcome { hits: 3, misses: 0, pulled_mib: 0 });
        assert_eq!(c.used_mib(), 512);
        c.check_ledger().unwrap();
    }

    #[test]
    fn shared_layers_are_stored_once() {
        let mut c = lru(1024);
        c.admit(&manifest(&[(1, 64), (2, 192), (10, 100)]));
        let a = c.admit(&manifest(&[(1, 64), (2, 192), (11, 100)]));
        // the base layers hit, only the second function's app layer pulls
        assert_eq!(a, AdmitOutcome { hits: 2, misses: 1, pulled_mib: 100 });
        assert_eq!(c.used_mib(), 64 + 192 + 100 + 100);
        assert_eq!(c.len(), 4);
        c.check_ledger().unwrap();
    }

    #[test]
    fn eviction_is_lru_and_respects_touches() {
        let mut c = lru(300);
        c.admit(&manifest(&[(1, 100)]));
        c.admit(&manifest(&[(2, 100)]));
        c.admit(&manifest(&[(1, 100)])); // touch 1: now 2 is oldest
        c.admit(&manifest(&[(3, 200)])); // 400 > 300 → evict 2 then... still 400-100=300 ok
        assert!(c.contains(1));
        assert!(!c.contains(2), "layer 2 was least recently used");
        assert!(c.contains(3));
        assert_eq!(c.used_mib(), 300);
        c.check_ledger().unwrap();
    }

    #[test]
    fn oversized_image_sheds_its_own_oldest_layers_without_panic() {
        let mut c = lru(150);
        c.admit(&manifest(&[(9, 50)]));
        let a = c.admit(&manifest(&[(1, 100), (2, 100)]));
        assert_eq!(a.pulled_mib, 200);
        // 250 used > 150 cap: evicts 9 (oldest), then layer 1
        assert!(!c.contains(9));
        assert!(!c.contains(1));
        assert!(c.contains(2));
        assert_eq!(c.used_mib(), 100);
        c.check_ledger().unwrap();
    }

    #[test]
    fn off_mode_is_inert() {
        let mut c = ImageCache::new(ImageCacheConfig::default());
        assert!(!c.enabled());
        let m = manifest(&[(1, 64), (2, 9999)]);
        assert_eq!(c.missing_mib(&m), 0);
        assert_eq!(c.admit(&m), AdmitOutcome::default());
        assert!(c.is_empty());
        assert_eq!(c.used_mib(), 0);
        c.check_ledger().unwrap();
    }

    #[test]
    fn identical_op_sequences_reproduce_identical_state() {
        let script: &[&[(LayerId, u32)]] = &[
            &[(1, 64), (2, 192), (10, 128)],
            &[(1, 64), (2, 192), (11, 300)],
            &[(1, 64), (2, 192), (10, 128)],
            &[(12, 500)],
        ];
        let run = || {
            let mut c = lru(700);
            let mut log = Vec::new();
            for m in script {
                log.push(c.admit(&manifest(m)));
            }
            c.check_ledger().unwrap();
            (log, c.used_mib(), c.len())
        };
        assert_eq!(run(), run());
    }
}
