//! OpenWhisk/Kubernetes cluster substrate (DESIGN.md substitution table).
//!
//! - [`container`]: container lifecycle FSM
//! - [`platform`]: the per-node platform semantics (invoke / prewarm /
//!   reclaim / keep-alive / capacity)
//! - [`fleet`]: multi-invoker fleet with the pluggable dispatch placement
//!   layer and the node-failure/drain scenario
//! - [`activation_log`]: Grafana Loki analog (reclaim-safety protocol)
//! - [`telemetry`]: Prometheus analog (gauges + counters)
//! - [`image`]: per-node content-addressed image/layer cache (dynamic
//!   cold-start cost model)
//! - [`chaos`]: seeded fault injection (correlated node-fault schedules
//!   + invocation-level spawn/exec faults with retry/backoff/timeouts)

pub mod activation_log;
pub mod chaos;
pub mod container;
pub mod fleet;
pub mod image;
pub mod platform;
pub mod telemetry;

/// Request (activation) identifier, assigned by the workload in arrival order.
pub type RequestId = u64;

pub use chaos::{ChaosEngine, ExecFate};
pub use container::{Container, ContainerId, ContainerState};
pub use fleet::{Fleet, InvokerNode, NodeId, NodeReport};
pub use image::{AdmitOutcome, ImageCache, ImageManifest, Layer, LayerId};
pub use platform::{CompleteOutcome, InvokeOutcome, KeepAliveVerdict, Platform, ReadyOutcome};
pub use telemetry::{Counters, FnCounterMap, FnCounters, GaugeSample, Telemetry};
