//! OpenWhisk/Kubernetes cluster substrate (DESIGN.md substitution table).
//!
//! - [`container`]: container lifecycle FSM
//! - [`platform`]: the platform semantics (invoke / prewarm / reclaim /
//!   keep-alive / capacity)
//! - [`activation_log`]: Grafana Loki analog (reclaim-safety protocol)
//! - [`telemetry`]: Prometheus analog (gauges + counters)

pub mod activation_log;
pub mod container;
pub mod platform;
pub mod telemetry;

/// Request (activation) identifier, assigned by the workload in arrival order.
pub type RequestId = u64;

pub use container::{Container, ContainerId, ContainerState};
pub use platform::{CompleteOutcome, InvokeOutcome, KeepAliveVerdict, Platform, ReadyOutcome};
pub use telemetry::{Counters, GaugeSample, Telemetry};
