//! The serverless platform substrate — OpenWhisk-on-Kubernetes analog.
//!
//! Reproduces the scheduling semantics the paper's results depend on
//! (DESIGN.md substitution table): cold start on warm-miss, bounded replica
//! pool (64 = 32 vCPU / 0.5), FCFS backlog at capacity, keep-alive expiry,
//! and the reclaim-safety protocol of Algorithm 2 (activation-log check).
//!
//! **Multi-tenant semantics.** Every container is initialized for one
//! function and a warm container serves only that function. The platform
//! therefore keeps *per-function warm pools* inside one replica budget:
//! warm binding, the FCFS backlog, and keep-alive expiry all match on
//! the container's function, lifecycle latencies come from the
//! function's profile, and a per-function memory ledger bounds
//! heavyweight tenants. Cross-function contention appears as
//! *evictions*: at capacity, an idle container of another function is
//! drained (log-safe, Algorithm 2's ranking) to make room. With a
//! one-function registry all of this degenerates to the legacy
//! single-tenant behavior bit-for-bit.
//!
//! **Indexed state (hot-path complexity).** The controller's gauges fire
//! every control interval (Fig. 3) and the dispatcher consults the warm
//! pool on every invocation, so none of them may scan the container map:
//! the platform maintains per-function indices (`FnIndex`) updated at
//! every container state transition — an idle MRU set ordered by
//! `(last_used, id)`, busy/cold-starting tallies, the in-flight
//! cold-start ready times, and a per-function FCFS backlog queue — plus
//! aggregate idle/busy/cold counters and the memory ledger. Every gauge
//! is O(1) (aggregates, per-function counts, MRU recency via
//! `BTreeSet::last`) or O(functions); the brute-force scans survive only
//! as a `#[cfg(test)]` reference implementation that property tests
//! compare against bit-for-bit (see `assert_matches_scan`).
//!
//! **Indexed reclaim order.** Algorithm 2's per-container score
//! `idle_s − 0.1 × activations` equals `now_s − key` for the
//! now-independent key `last_used_s + 0.1 × activations`
//! ([`Container::reclaim_key`]), so descending-score enumeration is
//! ascending-key enumeration of a pre-sorted set. The platform keeps all
//! idle containers in one such `reclaim_order` set, making
//! [`Platform::try_reclaim`] O(n) in the *requested* count (output-
//! sensitive), eviction a first-match probe, and the fleet's
//! [`Platform::best_reclaim_score`] peek O(1) on the common path.
//!
//! **Pressure-aware reclaim.** With
//! `PlatformConfig::reclaim_pressure_weight > 0` the node's best reclaim
//! score is biased by its memory-ledger pressure
//! (`+ weight × mem_used / node_mem`), so the fleet's cross-node reclaim
//! ranking prefers draining pressured nodes. The term is node-constant,
//! so intra-node ranking is unchanged; at the default weight `0.0` the
//! scores are bit-identical to the container-only ranking.
//!
//! **Retention control (adaptive keep-alive).** Expiry consults a *live*
//! per-function horizon ([`Platform::effective_keepalive`]): the
//! registry's profile window unless the MPC's retention planner
//! installed an override ([`Platform::set_keepalive_override`]). Idle
//! containers always satisfy `since == last_used`, so the per-function
//! idle MRU set doubles as a sorted idle-age index — a shrunk horizon is
//! actuated by a prefix sweep ([`Platform::expire_idle_older_than`]),
//! O(matches), never a container scan. With no overrides (the default
//! `fixed` policy) every expiry path is bit-identical to the
//! profile-window code it replaced.
//!
//! **Elasticity hooks.** [`Platform::migrate_out`] /
//! [`Platform::migrate_in`] move an idle container's warm state between
//! nodes (the fleet's rebalancing pass): the source books it like a
//! drain, the destination hosts it as an in-flight transfer that
//! occupies a replica slot and memory (resource-time is conserved) and
//! re-enters service after the transfer latency — with no cold start
//! counted, which is the point of migrating instead of respawning.
//!
//! The platform is event-driven but owns no clock: methods take `now` and
//! return outcomes carrying future timestamps; the experiment runner turns
//! those into simulator events (or real timers in real-time mode).

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::cluster::activation_log::ActivationLog;
use crate::cluster::container::{Container, ContainerId, ContainerState};
use crate::cluster::image::{AdmitOutcome, ImageCache, ImageManifest};
use crate::cluster::telemetry::{Counters, FnCounters, GaugeSample};
use crate::cluster::RequestId;
use crate::config::{Micros, PlatformConfig};
use crate::util::rng::Rng;
use crate::workload::tenant::{FunctionId, FunctionProfile, FunctionRegistry};

/// Result of an invocation request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InvokeOutcome {
    /// Bound to an idle warm container; execution completes at `done_at`.
    WarmStart { cid: ContainerId, done_at: Micros },
    /// Triggered a cold start; container ready (and execution starts) at
    /// `ready_at`.
    ColdStart { cid: ContainerId, ready_at: Micros },
    /// Replica pool exhausted; queued in the platform's FCFS backlog.
    AtCapacity,
}

/// Result of a cold container finishing initialization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadyOutcome {
    /// Went idle (controller prewarm with no waiting work).
    Idle,
    /// Immediately started executing `request`; completes at `done_at`.
    Started { request: RequestId, done_at: Micros },
    /// Multi-tenant recycle: the container matched none of the backlog,
    /// so it was evicted and a fresh cold container `cid` (ready at
    /// `ready_at`) was spawned bound to waiting request `req` — which
    /// therefore pays a cold start.
    Respawned {
        req: RequestId,
        cid: ContainerId,
        ready_at: Micros,
    },
}

/// Result of an execution completing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompleteOutcome {
    pub completed: RequestId,
    /// FCFS backlog request (same function) that immediately reused the
    /// container.
    pub next: Option<(RequestId, Micros)>,
    /// Multi-tenant recycle: the idle container was evicted and a cold
    /// container spawned bound to the waiting foreign-function request
    /// `(req, cid, ready_at)` (None in any single-tenant run). The
    /// runner schedules a Ready event for it and marks `req` cold.
    pub respawn: Option<(RequestId, ContainerId, Micros)>,
}

/// Keep-alive check verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeepAliveVerdict {
    /// Container removed (idle past the keep-alive window).
    Expired,
    /// Container was reused since the check was scheduled; re-check then.
    Recheck(Micros),
    /// Container already gone or currently busy/cold-starting.
    NotApplicable,
}

/// Per-function incremental indices, maintained at every container state
/// transition (the invariants live in the four `index_*`/`deindex`
/// helpers on [`Platform`]).
///
/// * `idle` is ordered by `(last_used, id)`, so `.last()` is exactly the
///   MRU pick the dispatcher's scan used to compute
///   (`max_by_key(|c| (c.last_used, c.id))`) — idle containers always
///   satisfy `since == last_used`, which also makes the set a sorted
///   idle-age index for retention queries.
/// * `cold` maps in-flight cold starts to their ready times (the MPC's
///   readyCold input), keyed by container id.
/// * `backlog` carries `(global seq, request)` so cross-function FIFO
///   order is recoverable in O(functions) (oldest waiter = minimum head
///   seq among the per-function queues).
#[derive(Debug, Default)]
struct FnIndex {
    idle: BTreeSet<(Micros, ContainerId)>,
    busy: u32,
    cold: BTreeMap<ContainerId, Micros>,
    backlog: VecDeque<(u64, RequestId)>,
}

/// Reclaim-order set key: the container's now-independent reclaim key as
/// IEEE-754 bits. Keys are non-negative and finite (see
/// [`Container::reclaim_key`]), where the bit pattern of an `f64` orders
/// exactly like its value — so `BTreeSet<(bits, id)>` enumerates by
/// ascending key (descending Algorithm-2 score), ties to the lower
/// container id.
///
/// Relation to the scan-era comparator (`score total_cmp desc, id
/// asc`): `score = now_s − key` is weakly monotone in `key`, so the two
/// orders agree whenever scores differ, and exactly-equal keys tie by
/// id in both. The one divergence is the rounding edge where two
/// *distinct* keys (Δ of a few ulps) subtract to bitwise-equal scores
/// at large `now`: the scan broke that tie by id, the pre-sorted order
/// breaks it by key — both are valid rankings of candidates whose
/// scores are bitwise identical, and the canonical order is now the
/// key's.
fn reclaim_bits(key: f64) -> u64 {
    debug_assert!(
        key >= 0.0 && key.is_finite(),
        "reclaim key must be non-negative finite, got {key}"
    );
    key.to_bits()
}

#[derive(Debug)]
pub struct Platform {
    pub cfg: PlatformConfig,
    /// The deployed function set; profiles drive per-function lifecycle
    /// latencies, keep-alive windows, and memory footprints.
    registry: FunctionRegistry,
    containers: BTreeMap<ContainerId, Container>,
    next_cid: ContainerId,
    /// Per-function indices (idle MRU set, busy/cold tallies, backlog);
    /// one entry per registry function.
    fns: Vec<FnIndex>,
    /// All idle containers ordered by ascending reclaim key (descending
    /// Algorithm-2 score) — see [`reclaim_bits`]. Maintained in lock-step
    /// with the per-function idle sets.
    reclaim_order: BTreeSet<(u64, ContainerId)>,
    /// Aggregate tallies mirroring the per-function indices.
    idle_total: u32,
    busy_total: u32,
    cold_total: u32,
    /// Total backlogged requests across the per-function queues.
    fcfs_total: usize,
    /// Global arrival sequence for backlog entries (cross-function FIFO).
    fcfs_seq: u64,
    rng: Rng,
    pub counters: Counters,
    /// Per-function activation accounting (multi-tenant telemetry).
    fn_counters: BTreeMap<FunctionId, FnCounters>,
    pub log: ActivationLog,
    /// Live per-function keep-alive overrides set by the retention
    /// planner (None = the function's profile window). Every expiry
    /// check consults this at check time, so a horizon update takes
    /// effect for already-idle containers too — nothing is frozen into
    /// the container at creation.
    ka_overrides: Vec<Option<Micros>>,
    /// Idle container-time saved by adaptive retention: for every expiry
    /// that fired before the function's *profile* window would have, the
    /// span between the actual and the profile-scheduled removal.
    /// Structurally zero under the fixed policy.
    idle_saved: Micros,
    /// keep-alive durations (last activation → removal) of removed containers
    removed_keepalive: Vec<Micros>,
    /// total idle (warm-unused) time of removed containers
    removed_idle_total: Vec<Micros>,
    /// memory claimed by live containers (MiB), per-function footprints
    mem_used: u32,
    /// containers ever created (for conservation checks)
    pub spawned: u64,
    pub removed: u64,
    /// This node's image/layer store (the cold-start fidelity model;
    /// inert under `ImageCacheMode::Off`). Layers live on the node's
    /// disk, not in containers, so the store deliberately survives
    /// `fail_all`: a crashed invoker restarts container-cold but
    /// image-warm.
    image: ImageCache,
    /// Per-function image manifests, indexed by [`FunctionId`]. Empty
    /// when the cache is off (nothing ever reads them then).
    manifests: Vec<ImageManifest>,
}

impl Platform {
    /// Single-tenant platform: a one-function registry mirroring `cfg`.
    pub fn new(cfg: PlatformConfig, seed: u64) -> Self {
        let registry = FunctionRegistry::single(&cfg);
        Self::with_registry(cfg, registry, seed)
    }

    /// Multi-tenant platform serving `registry`'s function set.
    pub fn with_registry(cfg: PlatformConfig, registry: FunctionRegistry, seed: u64) -> Self {
        let fns = (0..registry.len()).map(|_| FnIndex::default()).collect();
        let ka_overrides = vec![None; registry.len()];
        let image = ImageCache::new(cfg.image);
        let manifests = if image.enabled() {
            registry.profiles().iter().map(|p| p.image()).collect()
        } else {
            Vec::new()
        };
        Platform {
            cfg,
            registry,
            containers: BTreeMap::new(),
            next_cid: 1,
            fns,
            reclaim_order: BTreeSet::new(),
            idle_total: 0,
            busy_total: 0,
            cold_total: 0,
            fcfs_total: 0,
            fcfs_seq: 0,
            rng: Rng::new(seed),
            counters: Counters::default(),
            fn_counters: BTreeMap::new(),
            log: ActivationLog::new(),
            ka_overrides,
            idle_saved: 0,
            removed_keepalive: Vec::new(),
            removed_idle_total: Vec::new(),
            mem_used: 0,
            spawned: 0,
            removed: 0,
            image,
            manifests,
        }
    }

    /// Profile of one function in the registry.
    pub fn profile(&self, func: FunctionId) -> &FunctionProfile {
        self.registry.get(func)
    }

    pub fn registry(&self) -> &FunctionRegistry {
        &self.registry
    }

    fn fn_counters_mut(&mut self, func: FunctionId) -> &mut FnCounters {
        self.fn_counters.entry(func).or_default()
    }

    /// Per-function activation counters observed so far.
    pub fn fn_counters(&self) -> &BTreeMap<FunctionId, FnCounters> {
        &self.fn_counters
    }

    fn jitter(&mut self, base: Micros) -> Micros {
        let j = self.cfg.latency_jitter;
        if j <= 0.0 {
            return base;
        }
        let f = self.rng.range_f64(1.0 - j, 1.0 + j);
        (base as f64 * f).round().max(1.0) as Micros
    }

    // ---- image/layer cache (cold-start fidelity) ----------------------------

    /// This node's layer store (read-only view).
    pub fn image_cache(&self) -> &ImageCache {
        &self.image
    }

    /// Replace the per-function manifests (property tests exercise the
    /// cache under randomized layer compositions; production manifests
    /// are derived from the profiles in the constructor). No-op with the
    /// cache off. Panics if the length does not match the registry.
    pub fn set_image_manifests(&mut self, manifests: Vec<ImageManifest>) {
        if !self.image.enabled() {
            return;
        }
        assert_eq!(manifests.len(), self.registry.len(), "one manifest per function");
        self.manifests = manifests;
    }

    /// MiB this node would pull to start `func` right now — the
    /// cache-affinity signal placement and prewarm tie-breaks consume.
    /// Exactly 0 with the cache off, so every off-mode comparison key is
    /// bit-identical to the pre-cache code.
    pub fn pull_cost_mib(&self, func: FunctionId) -> u64 {
        if !self.image.enabled() {
            return 0;
        }
        self.image.missing_mib(&self.manifests[func as usize])
    }

    /// Dynamic cold-start cost `L_cold(f, this node)` — `pull(missing) +
    /// init` against the current cache, the profile constant with the
    /// cache off. Read-only (no pull happens); the controller feeds this
    /// into the break-even retention rule and the prewarm lead window.
    pub fn effective_l_cold(&self, func: FunctionId) -> Micros {
        let base = self.profile(func).l_cold;
        if !self.image.enabled() {
            return base;
        }
        let missing = self.image.missing_mib(&self.manifests[func as usize]);
        self.cfg.image.effective_l_cold(base, missing)
    }

    /// Warm this node's layer store with `func`'s image (migrations and
    /// cold starts both land the layers on disk). Books the hit/miss and
    /// pull-byte telemetry; returns what was pulled. Inert when off.
    pub fn warm_image_for(&mut self, func: FunctionId) -> AdmitOutcome {
        if !self.image.enabled() {
            return AdmitOutcome::default();
        }
        let out = self.image.admit(&self.manifests[func as usize]);
        self.counters.layer_hits += out.hits;
        self.counters.layer_misses += out.misses;
        self.counters.pull_mib += out.pulled_mib;
        out
    }

    /// The cold-start charge for `func` on this node: pulls the missing
    /// layers into the cache and returns the effective init latency the
    /// spawn should pay. With the cache off this is *exactly*
    /// `profile.l_cold` with no counter traffic — the constant-cost seed
    /// path, bit for bit (the jitter draw downstream is base-independent,
    /// so the RNG stream is unchanged either way).
    fn charge_cold_start(&mut self, func: FunctionId) -> Micros {
        let base = self.profile(func).l_cold;
        if !self.image.enabled() {
            return base;
        }
        let pulled = self.warm_image_for(func).pulled_mib;
        let eff = self.cfg.image.effective_l_cold(base, pulled);
        self.counters.cold_cost_us += eff;
        self.counters.cold_charges += 1;
        eff
    }

    // ---- index transitions --------------------------------------------------
    //
    // Every container state change funnels through exactly one of these,
    // so the FnIndex structures and the aggregate tallies can never drift
    // from the container map (the property test audits this after every
    // operation).

    /// Container entered the idle pool at `t` (its new `last_used`).
    fn index_idle(&mut self, func: FunctionId, cid: ContainerId, t: Micros) {
        let inserted = self.fns[func as usize].idle.insert((t, cid));
        debug_assert!(inserted, "container {cid} already indexed idle");
        let key = reclaim_bits(self.containers[&cid].reclaim_key());
        let inserted = self.reclaim_order.insert((key, cid));
        debug_assert!(inserted, "container {cid} already in the reclaim order");
        self.idle_total += 1;
    }

    /// Idle → busy on `req` until `done_at`. Reads the idle key
    /// (`last_used`) off the container before transitioning it.
    fn begin_execution(
        &mut self,
        cid: ContainerId,
        func: FunctionId,
        req: RequestId,
        now: Micros,
        done_at: Micros,
    ) {
        let c = self
            .containers
            .get_mut(&cid)
            .expect("begin_execution on unknown container");
        let key = (c.last_used, cid);
        // the reclaim key reads last_used/activations, neither of which
        // start_execution changes — but take it before the transition for
        // symmetry with the insertion point
        let rkey = (reclaim_bits(c.reclaim_key()), cid);
        c.start_execution(req, now, done_at);
        let fi = &mut self.fns[func as usize];
        let removed = fi.idle.remove(&key);
        debug_assert!(removed, "idle index out of sync for container {cid}");
        let removed = self.reclaim_order.remove(&rkey);
        debug_assert!(removed, "reclaim order out of sync for container {cid}");
        self.idle_total -= 1;
        fi.busy += 1;
        self.busy_total += 1;
    }

    /// Drop a (live) container from whichever index matches its state.
    fn deindex(&mut self, c: &Container) {
        let fi = &mut self.fns[c.func as usize];
        match c.state {
            ContainerState::Idle { .. } => {
                let removed = fi.idle.remove(&(c.last_used, c.id));
                debug_assert!(removed, "idle index out of sync for container {}", c.id);
                let removed = self
                    .reclaim_order
                    .remove(&(reclaim_bits(c.reclaim_key()), c.id));
                debug_assert!(removed, "reclaim order out of sync for container {}", c.id);
                self.idle_total -= 1;
            }
            ContainerState::Busy { .. } => {
                fi.busy -= 1;
                self.busy_total -= 1;
            }
            ContainerState::ColdStarting { .. } => {
                let removed = fi.cold.remove(&c.id).is_some();
                debug_assert!(removed, "cold index out of sync for container {}", c.id);
                self.cold_total -= 1;
            }
        }
    }

    // ---- gauges (all O(1) or O(functions); no container scans) --------------

    pub fn total(&self) -> u32 {
        self.containers.len() as u32
    }
    pub fn idle_count(&self) -> u32 {
        self.idle_total
    }
    pub fn busy_count(&self) -> u32 {
        self.busy_total
    }
    pub fn warm_count(&self) -> u32 {
        self.idle_total + self.busy_total
    }
    pub fn cold_starting_count(&self) -> u32 {
        self.cold_total
    }
    pub fn fcfs_len(&self) -> usize {
        self.fcfs_total
    }

    /// Idle containers unused for at least `min_idle` (IceBreaker's
    /// retention-aware release eligibility). Idle containers always have
    /// `since == last_used`, so this is a sorted-prefix count on the
    /// per-function idle sets — O(functions + matches), not O(containers).
    ///
    /// `min_idle == 0` counts every *idle* container. (The old scan's
    /// `idle_for(now) >= 0` vacuously counted busy/cold containers too
    /// at 0 — a latent bug no caller could hit, since the only consumer
    /// passes IceBreaker's fixed 240 s retention window.)
    pub fn idle_containers_older_than(&self, min_idle: Micros, now: Micros) -> u32 {
        if min_idle == 0 {
            return self.idle_total;
        }
        let Some(cutoff) = now.checked_sub(min_idle) else {
            return 0;
        };
        self.fns
            .iter()
            .map(|fi| fi.idle.range(..=(cutoff, ContainerId::MAX)).count() as u32)
            .sum()
    }

    pub fn gauge(&self, now: Micros, queue_len: u32) -> GaugeSample {
        GaugeSample {
            time: now,
            warm: self.warm_count(),
            idle: self.idle_count(),
            busy: self.busy_count(),
            cold_starting: self.cold_starting_count(),
            queue_len,
        }
    }

    /// Remaining replica slots under the resource cap.
    pub fn headroom(&self) -> u32 {
        self.cfg.resource_cap().saturating_sub(self.total())
    }

    /// Memory claimed by live containers (MiB).
    pub fn mem_used_mib(&self) -> u32 {
        self.mem_used
    }

    /// Whether a new container of `func` fits the node right now: a free
    /// replica slot *and* room in the memory ledger for the function's
    /// footprint. With uniform paper-profile functions the memory term
    /// never binds (64 × 256 MiB ≪ 48 GiB), so this reduces to the
    /// legacy slot check.
    pub fn can_admit(&self, func: FunctionId) -> bool {
        self.total() < self.cfg.resource_cap()
            && self.mem_used + self.registry.get(func).mem_mib <= self.cfg.node_mem_mib
    }

    /// Idle warm containers of one function (the per-function warm pool).
    pub fn idle_count_for(&self, func: FunctionId) -> u32 {
        self.fns
            .get(func as usize)
            .map_or(0, |fi| fi.idle.len() as u32)
    }

    /// Accumulate idle-container counts per function into `out` (index =
    /// [`FunctionId`]; functions beyond `out.len()` are ignored) — an
    /// O(functions) counter copy for the dispatcher's drain snapshot.
    pub fn idle_by_function_into(&self, out: &mut [u32]) {
        for (f, fi) in self.fns.iter().enumerate() {
            if let Some(slot) = out.get_mut(f) {
                *slot += fi.idle.len() as u32;
            }
        }
    }

    /// Warm (idle + busy) containers of one function.
    pub fn warm_count_for(&self, func: FunctionId) -> u32 {
        self.fns
            .get(func as usize)
            .map_or(0, |fi| fi.idle.len() as u32 + fi.busy)
    }

    /// In-flight cold starts of one function.
    pub fn cold_starting_for(&self, func: FunctionId) -> u32 {
        self.fns
            .get(func as usize)
            .map_or(0, |fi| fi.cold.len() as u32)
    }

    /// Recency (last_used) of the most-recently-used idle container — the
    /// fleet's warm-first placement compares nodes on this.
    pub fn mru_idle_recency(&self) -> Option<Micros> {
        self.fns
            .iter()
            .filter_map(|fi| fi.idle.last())
            .map(|&(t, _)| t)
            .max()
    }

    /// Function-scoped [`Platform::mru_idle_recency`]: the fleet's
    /// warm-*for-this-function*-first placement compares nodes on this.
    pub fn mru_idle_recency_for(&self, func: FunctionId) -> Option<Micros> {
        self.fns
            .get(func as usize)
            .and_then(|fi| fi.idle.last())
            .map(|&(t, _)| t)
    }

    /// Node memory pressure in `[0, 1]`: ledger-claimed MiB over node
    /// capacity (the PR 2 memory ledger feeding the reclaim ranking).
    pub fn mem_pressure(&self) -> f64 {
        self.mem_used as f64 / self.cfg.node_mem_mib.max(1) as f64
    }

    /// Best (highest) reclaim score among idle, log-safe containers — the
    /// fleet ranks nodes on this to keep Algorithm 2's global ordering,
    /// so it carries the node's memory-pressure bias
    /// (`+ weight × mem_pressure`, skipped entirely at weight `0.0` so
    /// the default is bit-identical to the container-only score).
    ///
    /// The reclaim order is pre-sorted by descending score, so this is
    /// the first log-safe entry: O(1) on the common path (acks are
    /// synchronous with completion, so the head is log-safe), O(unsafe
    /// prefix) worst case — no longer O(idle).
    pub fn best_reclaim_score(&self, now: Micros) -> Option<f64> {
        let s = self
            .reclaim_order
            .iter()
            .find(|&&(_, cid)| self.log.all_completed(cid))
            .map(|&(_, cid)| self.containers[&cid].reclaim_score(now))?;
        let w = self.cfg.reclaim_pressure_weight;
        Some(if w > 0.0 { s + w * self.mem_pressure() } else { s })
    }

    /// Whether `cid` is live on this node. The fleet's stale-event guard
    /// after a node rejoin: Ready/Done events for containers lost in the
    /// drain may still be in flight when the node is back online.
    pub fn has_container(&self, cid: ContainerId) -> bool {
        self.containers.contains_key(&cid)
    }

    /// Ready times of in-flight cold starts (the MPC's readyCold input).
    pub fn cold_ready_times(&self) -> Vec<Micros> {
        let mut out = Vec::with_capacity(self.cold_total as usize);
        self.cold_ready_times_into(&mut out);
        out
    }

    /// Append every in-flight cold-start ready time to `out` (the
    /// allocation-free form the controller's scratch buffer uses).
    pub fn cold_ready_times_into(&self, out: &mut Vec<Micros>) {
        for fi in &self.fns {
            out.extend(fi.cold.values().copied());
        }
    }

    /// Ready times of in-flight cold starts of one function.
    pub fn cold_ready_times_for(&self, func: FunctionId) -> Vec<Micros> {
        self.fns
            .get(func as usize)
            .map_or_else(Vec::new, |fi| fi.cold.values().copied().collect())
    }

    /// Earliest ready time among in-flight cold starts of one function
    /// (the force-dispatch guard's imminence probe, without building a
    /// vector): O(cold starts of `func`).
    pub fn next_cold_ready_for(&self, func: FunctionId) -> Option<Micros> {
        self.fns
            .get(func as usize)
            .and_then(|fi| fi.cold.values().min())
            .copied()
    }

    // ---- invocation path ----------------------------------------------------

    /// Invoke `req` now (single-tenant shorthand for function 0).
    pub fn invoke(&mut self, req: RequestId, now: Micros) -> InvokeOutcome {
        self.invoke_for(req, 0, now)
    }

    /// Invoke `req` for `func` now. OpenWhisk semantics, function-aware:
    /// bind to an idle warm container *of this function* if any
    /// (most-recently-used first, matching OpenWhisk's reuse affinity),
    /// otherwise cold start; with the pool full of other functions'
    /// idle containers, evict the best log-safe candidate (Algorithm 2's
    /// ranking) to make room; otherwise FCFS-queue at capacity.
    pub fn invoke_for(&mut self, req: RequestId, func: FunctionId, now: Micros) -> InvokeOutcome {
        self.counters.invocations += 1;
        self.fn_counters_mut(func).invocations += 1;
        // MRU idle container of this function: OpenWhisk reuses the
        // warmest matching replica — `.last()` of the (last_used, id)
        // ordered idle set, O(log idle) instead of a container scan
        let pick = self
            .fns
            .get(func as usize)
            .and_then(|fi| fi.idle.last().copied());
        if let Some((_, cid)) = pick {
            let l_warm = self.profile(func).l_warm;
            let done_at = now + self.jitter(l_warm);
            self.begin_execution(cid, func, req, now, done_at);
            self.log.record_assignment(cid, req);
            self.fn_counters_mut(func).warm_starts += 1;
            return InvokeOutcome::WarmStart { cid, done_at };
        }
        if self.can_admit(func) || self.evict_for(func, now) {
            let l_cold = self.charge_cold_start(func);
            let ready_at = now + self.jitter(l_cold);
            let cid = self.spawn(func, now, ready_at, Some(req));
            self.counters.cold_starts += 1;
            self.fn_counters_mut(func).cold_starts += 1;
            return InvokeOutcome::ColdStart { cid, ready_at };
        }
        self.counters.capacity_queued += 1;
        self.fcfs_seq += 1;
        self.fns[func as usize].backlog.push_back((self.fcfs_seq, req));
        self.fcfs_total += 1;
        InvokeOutcome::AtCapacity
    }

    /// Evict idle containers of *other* functions (best reclaim score
    /// first, log-safe only) until a container of `func` fits. Returns
    /// whether room was made. Never fires in a single-tenant run: any
    /// idle container there would have warm-served the request instead.
    /// The victim is the first qualifying entry of the pre-sorted reclaim
    /// order (ascending key = descending score, ties to the lower id —
    /// the scan-era ranking), so each round is O(skipped candidates), not
    /// O(idle).
    fn evict_for(&mut self, func: FunctionId, now: Micros) -> bool {
        loop {
            if self.can_admit(func) {
                return true;
            }
            let victim = self
                .reclaim_order
                .iter()
                .map(|&(_, cid)| cid)
                .find(|&cid| {
                    self.containers[&cid].func != func && self.log.all_completed(cid)
                });
            let Some(vid) = victim else { return false };
            let vfunc = self.containers[&vid].func;
            self.remove(vid, now);
            self.counters.evictions += 1;
            self.fn_counters_mut(vfunc).evictions += 1;
        }
    }

    fn spawn(
        &mut self,
        func: FunctionId,
        now: Micros,
        ready_at: Micros,
        pending: Option<RequestId>,
    ) -> ContainerId {
        let cid = self.next_cid;
        self.next_cid += 1;
        self.spawned += 1;
        self.mem_used += self.registry.get(func).mem_mib;
        self.containers
            .insert(cid, Container::cold(cid, func, now, ready_at, pending));
        self.fns[func as usize].cold.insert(cid, ready_at);
        self.cold_total += 1;
        cid
    }

    /// Controller prewarm (Listing 1, forcePrewarm=true) for function 0.
    pub fn prewarm_one(&mut self, now: Micros) -> Option<(ContainerId, Micros)> {
        self.prewarm_for(0, now)
    }

    /// Controller prewarm for one function: start one unbound cold
    /// container of `func`. Returns None (and counts the rejection) when
    /// the function does not fit; prewarms never evict live warm state.
    pub fn prewarm_for(&mut self, func: FunctionId, now: Micros) -> Option<(ContainerId, Micros)> {
        if !self.can_admit(func) {
            self.counters.prewarms_rejected += 1;
            return None;
        }
        let l_cold = self.charge_cold_start(func);
        let ready_at = now + self.jitter(l_cold);
        let cid = self.spawn(func, now, ready_at, None);
        self.counters.prewarms_started += 1;
        Some((cid, ready_at))
    }

    /// Pop the oldest FCFS backlog request of `func` (FIFO within the
    /// function; foreign requests keep their positions). O(1) on the
    /// per-function queue — no positional scan of a shared deque.
    fn pop_fcfs(&mut self, func: FunctionId) -> Option<RequestId> {
        let (_, req) = self.fns.get_mut(func as usize)?.backlog.pop_front()?;
        self.fcfs_total -= 1;
        Some(req)
    }

    /// Cold init finished (ContainerReady event). Binds the triggering
    /// request, else the oldest same-function backlog request, else goes
    /// idle — unless the backlog holds only foreign functions, in which
    /// case the container is recycled for the oldest waiter (see
    /// [`ReadyOutcome::Respawned`]): without it a pool full of
    /// wrong-function prewarms could strand the backlog forever.
    pub fn container_ready(&mut self, cid: ContainerId, now: Micros) -> ReadyOutcome {
        let (pending, func) = {
            let c = self
                .containers
                .get_mut(&cid)
                .expect("ready event for unknown container");
            let f = c.func;
            (c.finish_cold_start(now), f)
        };
        // index: cold start landed → idle (possibly transiently, if it
        // starts executing in the same instant below)
        {
            let fi = &mut self.fns[func as usize];
            let removed = fi.cold.remove(&cid).is_some();
            debug_assert!(removed, "cold index out of sync for container {cid}");
            self.cold_total -= 1;
        }
        self.index_idle(func, cid, now);
        let next = pending.or_else(|| self.pop_fcfs(func));
        match next {
            Some(request) => {
                let l_warm = self.profile(func).l_warm;
                let done_at = now + self.jitter(l_warm);
                self.begin_execution(cid, func, request, now, done_at);
                self.log.record_assignment(cid, request);
                ReadyOutcome::Started { request, done_at }
            }
            None => {
                if self.fcfs_total > 0 {
                    if let Some((req, ncid, ready_at)) = self.respawn_for_backlog(cid, now) {
                        return ReadyOutcome::Respawned {
                            req,
                            cid: ncid,
                            ready_at,
                        };
                    }
                }
                ReadyOutcome::Idle
            }
        }
    }

    /// Execution finished (ExecDone event). Acks the activation and lets
    /// the oldest same-function backlog request immediately reuse the
    /// container; a backlog of only foreign functions recycles it.
    pub fn exec_complete(&mut self, cid: ContainerId, now: Micros) -> CompleteOutcome {
        let (completed, func) = {
            let c = self
                .containers
                .get_mut(&cid)
                .expect("completion for unknown container");
            let f = c.func;
            (c.finish_execution(now), f)
        };
        // index: busy → idle at `now` (the container's new last_used)
        self.fns[func as usize].busy -= 1;
        self.busy_total -= 1;
        self.index_idle(func, cid, now);
        self.log.record_ack(cid, completed, now);
        let next = self.pop_fcfs(func).map(|req| {
            let l_warm = self.profile(func).l_warm;
            let done_at = now + self.jitter(l_warm);
            self.begin_execution(cid, func, req, now, done_at);
            self.log.record_assignment(cid, req);
            (req, done_at)
        });
        let respawn = if next.is_none() && self.fcfs_total > 0 {
            self.respawn_for_backlog(cid, now)
        } else {
            None
        };
        CompleteOutcome {
            completed,
            next,
            respawn,
        }
    }

    /// The FCFS backlog holds only requests this idle container cannot
    /// serve (other functions). Evict it and cold-start a replacement
    /// bound to the oldest waiter whose swap fits the memory ledger
    /// (skipping an oversized head so it cannot starve feasible waiters
    /// behind it), provided the activation log clears the container for
    /// removal. Returns `(waiter, new container, ready time)`.
    ///
    /// Cross-function FIFO without a positional scan: the oldest feasible
    /// waiter is the minimum head sequence number among the per-function
    /// queues whose footprint fits — if any entry of a function fits, its
    /// queue head (older) fits too, so only heads need comparing.
    fn respawn_for_backlog(
        &mut self,
        cid: ContainerId,
        now: Micros,
    ) -> Option<(RequestId, ContainerId, Micros)> {
        let (vfunc, freed) = {
            let c = self.containers.get(&cid)?;
            if !c.is_idle() || !self.log.all_completed(cid) {
                return None;
            }
            (c.func, self.registry.get(c.func).mem_mib)
        };
        let budget = self.cfg.node_mem_mib;
        let after_evict = self.mem_used.saturating_sub(freed);
        let mut pick: Option<(u64, usize)> = None;
        for (fid, fi) in self.fns.iter().enumerate() {
            let Some(&(seq, _)) = fi.backlog.front() else {
                continue;
            };
            if after_evict + self.registry.get(fid as FunctionId).mem_mib > budget {
                continue;
            }
            let older = match pick {
                None => true,
                Some((s, _)) => seq < s,
            };
            if older {
                pick = Some((seq, fid));
            }
        }
        let (_, fidx) = pick?;
        self.remove(cid, now);
        self.counters.evictions += 1;
        self.fn_counters_mut(vfunc).evictions += 1;
        let (_, req) = self.fns[fidx].backlog.pop_front().expect("head checked above");
        self.fcfs_total -= 1;
        let func = fidx as FunctionId;
        let l_cold = self.charge_cold_start(func);
        let ready_at = now + self.jitter(l_cold);
        let ncid = self.spawn(func, now, ready_at, Some(req));
        self.counters.cold_starts += 1;
        self.fn_counters_mut(func).cold_starts += 1;
        Some((req, ncid, ready_at))
    }

    // ---- reclaim (Algorithm 2) ----------------------------------------------

    /// Reclaim up to `n` idle containers. Ranking by composite score
    /// (line 1), safety via the activation log (lines 5-6), then drain
    /// (lines 7-9). Returns the reclaimed ids.
    ///
    /// The reclaim order *is* the ranking (ascending now-independent key
    /// = descending score, equal keys tie to the lower id — the scan-era
    /// `select_nth_unstable` + sort order, except that candidates whose
    /// *distinct* keys round to bitwise-equal scores now tie by key
    /// instead of id; see [`reclaim_bits`]), so this is O(n log idle) in
    /// the **requested** count: output-sensitive, independent of how
    /// many idle containers exist. As before, an unsafe candidate inside
    /// the top-`n` slice consumes its budget slot (selection happens
    /// before the log filter).
    pub fn try_reclaim(&mut self, n: u32, now: Micros) -> Vec<ContainerId> {
        if n == 0 {
            return Vec::new();
        }
        // rankPods: the top-n prefix of the pre-sorted order
        let top: Vec<ContainerId> = self
            .reclaim_order
            .iter()
            .take(n as usize)
            .map(|&(_, cid)| cid)
            .collect();
        let mut reclaimed = Vec::new();
        for cid in top {
            // safety: the log must show completion for all assigned work
            if !self.log.all_completed(cid) {
                continue;
            }
            self.remove(cid, now);
            self.counters.reclaims += 1;
            reclaimed.push(cid);
        }
        reclaimed
    }

    // ---- cross-node migration (fleet elasticity) ----------------------------

    /// Source-side migration victim for `func`: the LRU (coldest) idle
    /// container of that function whose activation log is clear — the
    /// replica whose departure costs the least warm-reuse affinity
    /// (dispatch binds MRU-first, so the LRU end is the least likely to
    /// be reused next). O(1) typically (`.first()` of the per-function
    /// idle set), O(unsafe prefix) worst case.
    pub fn migrate_out_candidate(&self, func: FunctionId) -> Option<ContainerId> {
        self.fns
            .get(func as usize)?
            .idle
            .iter()
            .map(|&(_, cid)| cid)
            .find(|&cid| self.log.all_completed(cid))
    }

    /// Function of the node's overall coldest log-safe idle container
    /// (the head of the reclaim order) — the idle-spread planner's
    /// victim function. O(1) on the common path.
    pub fn coldest_idle_function(&self) -> Option<FunctionId> {
        self.reclaim_order
            .iter()
            .find(|&&(_, cid)| self.log.all_completed(cid))
            .map(|&(_, cid)| self.containers[&cid].func)
    }

    /// Migration-out: release an idle container so its warm state can
    /// move to another node. The source's books treat this like a drain —
    /// keep-alive and idle-time records close here (resource-time up to
    /// the departure is charged to this node). Returns false when the
    /// container is unknown, not idle, or log-unsafe.
    pub fn migrate_out(&mut self, cid: ContainerId, now: Micros) -> bool {
        match self.containers.get(&cid) {
            Some(c) if c.is_idle() && self.log.all_completed(cid) => {}
            _ => return false,
        }
        self.remove(cid, now);
        self.counters.migrations_out += 1;
        true
    }

    /// Migration-in: admit a container of `func` arriving from another
    /// node. It claims a replica slot and memory immediately (the
    /// in-flight transfer is counted in resource-time) and re-enters
    /// service after the jittered transfer `latency`, modeled as a
    /// cold-starting container whose "init" is the transfer — with **no**
    /// cold start counted: `latency ≪ L_cold(func)` is the reason to
    /// migrate warm state instead of respawning. Returns None when the
    /// node cannot admit the function (migrations never evict).
    pub fn migrate_in(
        &mut self,
        func: FunctionId,
        now: Micros,
        latency: Micros,
    ) -> Option<(ContainerId, Micros)> {
        if !self.can_admit(func) {
            return None;
        }
        // the transfer ships the container image too: the destination's
        // layer store warms, so later cold starts of `func` here pull less
        self.warm_image_for(func);
        let ready_at = now + self.jitter(latency);
        let cid = self.spawn(func, now, ready_at, None);
        self.counters.migrations_in += 1;
        Some((cid, ready_at))
    }

    // ---- retention control (adaptive keep-alive) ----------------------------

    /// Live keep-alive horizon of one function: the retention planner's
    /// override when set, the profile window otherwise. Every expiry
    /// path consults this at *check time* — the horizon is never frozen
    /// into a container.
    pub fn effective_keepalive(&self, func: FunctionId) -> Micros {
        self.ka_overrides
            .get(func as usize)
            .copied()
            .flatten()
            .unwrap_or_else(|| self.registry.get(func).keep_alive)
    }

    /// Install (or clear, with None) the live keep-alive override for
    /// `func`. Unknown functions are ignored. No container state moves
    /// here — already-idle containers past a shortened horizon expire at
    /// their next check or via [`Platform::expire_idle_older_than`].
    pub fn set_keepalive_override(&mut self, func: FunctionId, horizon: Option<Micros>) {
        if let Some(slot) = self.ka_overrides.get_mut(func as usize) {
            *slot = horizon;
        }
    }

    /// Idle container-time saved by earlier-than-profile expiries (the
    /// adaptive policy's resource win; structurally 0 under fixed).
    pub fn idle_saved(&self) -> Micros {
        self.idle_saved
    }

    /// Idle containers of `func` already past the live keep-alive
    /// horizon — exactly the set an expiry sweep at `now` would remove.
    /// Idle containers satisfy `since == last_used`, so this is a
    /// sorted-prefix count on the per-function idle set, O(log idle).
    pub fn idle_due_for(&self, func: FunctionId, now: Micros) -> u32 {
        let eff = self.effective_keepalive(func);
        let Some(cutoff) = now.checked_sub(eff) else {
            return 0;
        };
        self.fns
            .get(func as usize)
            .map_or(0, |fi| fi.idle.range(..=(cutoff, ContainerId::MAX)).count() as u32)
    }

    /// Expire every idle container of `func` idle for at least `horizon`
    /// at `now` — the retention planner's immediate sweep after it
    /// shrinks a horizon (scheduled KeepAlive events would only catch
    /// them at the old due times). Each removal is a keep-alive expiry;
    /// the prefix drain off the sorted idle set is O(matches log idle).
    /// Returns the expired ids.
    pub fn expire_idle_older_than(
        &mut self,
        func: FunctionId,
        horizon: Micros,
        now: Micros,
    ) -> Vec<ContainerId> {
        let Some(cutoff) = now.checked_sub(horizon) else {
            return Vec::new();
        };
        let Some(fi) = self.fns.get(func as usize) else {
            return Vec::new();
        };
        let victims: Vec<ContainerId> = fi
            .idle
            .range(..=(cutoff, ContainerId::MAX))
            .map(|&(_, cid)| cid)
            .collect();
        for &cid in &victims {
            self.expire(cid, now);
        }
        victims
    }

    /// Keep-alive window of a live container (its function's *live*
    /// horizon) — the runner's scheduling hint for the KeepAlive event.
    pub fn keepalive_of(&self, cid: ContainerId) -> Option<Micros> {
        self.containers
            .get(&cid)
            .map(|c| self.effective_keepalive(c.func))
    }

    /// Keep-alive check for one container, due at `last_used +` the
    /// function's live horizon (the profile window unless the retention
    /// planner overrode it — so a shrunk horizon expires the container
    /// at its next check, and a grown one reschedules it).
    pub fn keepalive_check(&mut self, cid: ContainerId, now: Micros) -> KeepAliveVerdict {
        let Some(c) = self.containers.get(&cid) else {
            return KeepAliveVerdict::NotApplicable;
        };
        if !c.is_idle() {
            return KeepAliveVerdict::NotApplicable;
        }
        let due = c.last_used + self.effective_keepalive(c.func);
        if now >= due {
            self.expire(cid, now);
            KeepAliveVerdict::Expired
        } else {
            KeepAliveVerdict::Recheck(due)
        }
    }

    /// Remove an idle container as a keep-alive expiry, crediting the
    /// idle time an earlier-than-profile horizon saved. Under the fixed
    /// policy every expiry fires at/after the profile due time, so the
    /// adaptive accounting is zero by construction.
    fn expire(&mut self, cid: ContainerId, now: Micros) {
        if let Some(c) = self.containers.get(&cid) {
            let profile_due = c.last_used + self.registry.get(c.func).keep_alive;
            if now < profile_due {
                self.idle_saved += profile_due - now;
                self.counters.adaptive_expiries += 1;
            }
        }
        self.remove(cid, now);
        self.counters.keepalive_expiries += 1;
    }

    fn remove(&mut self, cid: ContainerId, now: Micros) {
        if let Some(c) = self.containers.remove(&cid) {
            debug_assert!(c.is_idle(), "removing non-idle container {cid}");
            self.deindex(&c);
            // paper metric: duration from last activation to reclamation
            self.removed_keepalive.push(now.saturating_sub(c.last_used));
            self.removed_idle_total
                .push(c.idle_accum + c.idle_for(now));
            self.mem_used = self
                .mem_used
                .saturating_sub(self.registry.get(c.func).mem_mib);
            self.log.forget(cid);
            self.removed += 1;
        }
    }

    /// Re-cap this node's replica capacity (heterogeneous restore: the
    /// node rejoined after a hardware swap). Mirrors the fleet
    /// constructor's per-node override idiom — the derived CPU/memory
    /// floors are raised so the explicit cap is what binds. Only
    /// meaningful on a drained (empty) node; the memory ledger is not
    /// re-audited against live containers.
    pub fn override_capacity(&mut self, cap: u32) {
        debug_assert_eq!(self.total(), 0, "capacity override on a non-empty node");
        self.cfg.max_containers = cap;
        self.cfg.node_cpu_millis = self
            .cfg
            .node_cpu_millis
            .max(cap * self.cfg.container_cpu_millis);
        self.cfg.node_mem_mib = self.cfg.node_mem_mib.max(cap * self.cfg.container_mem_mib);
    }

    /// Node-crash semantics: every container is lost instantly; requests
    /// that were executing or waiting on a cold start, plus the FCFS
    /// backlog (in arrival order), are returned for redispatch elsewhere.
    /// Lost containers do not produce keep-alive records — the pod
    /// vanished, it was not drained gracefully.
    pub fn fail_all(&mut self, _now: Micros) -> Vec<RequestId> {
        let mut lost = Vec::new();
        for (cid, c) in std::mem::take(&mut self.containers) {
            match c.state {
                ContainerState::ColdStarting {
                    pending: Some(req), ..
                } => lost.push(req),
                ContainerState::Busy { request, .. } => lost.push(request),
                _ => {}
            }
            self.log.forget(cid);
            self.removed += 1;
        }
        self.mem_used = 0;
        // reset the indices wholesale; the backlog drains in global
        // arrival order (merge the per-function queues by sequence)
        let mut backlog: Vec<(u64, RequestId)> = Vec::with_capacity(self.fcfs_total);
        for fi in &mut self.fns {
            fi.idle.clear();
            fi.busy = 0;
            fi.cold.clear();
            backlog.extend(fi.backlog.drain(..));
        }
        self.reclaim_order.clear();
        backlog.sort_unstable_by_key(|&(seq, _)| seq);
        lost.extend(backlog.into_iter().map(|(_, req)| req));
        self.idle_total = 0;
        self.busy_total = 0;
        self.cold_total = 0;
        self.fcfs_total = 0;
        lost
    }

    /// Chaos spawn failure: a cold-starting container is torn down before
    /// it ever becomes ready. Crash semantics like [`fail_all`] — no
    /// keep-alive record, the activation log just forgets it — and the
    /// pending request (if any) is returned for retry. Returns `None`
    /// without touching anything when `cid` is not cold-starting (the
    /// stale-event case: the container was already lost to a drain).
    ///
    /// [`fail_all`]: Platform::fail_all
    pub fn abort_spawn(&mut self, cid: ContainerId, _now: Micros) -> Option<RequestId> {
        let pending = match self.containers.get(&cid).map(|c| &c.state) {
            Some(&ContainerState::ColdStarting { pending, .. }) => pending,
            _ => return None,
        };
        let c = self.containers.remove(&cid).expect("presence checked above");
        self.deindex(&c);
        self.mem_used = self
            .mem_used
            .saturating_sub(self.registry.get(c.func).mem_mib);
        self.log.forget(cid);
        self.removed += 1;
        self.counters.spawn_failures += 1;
        pending
    }

    /// Chaos execution timeout: a busy container is killed at its
    /// per-function deadline, its in-flight request returned for retry.
    /// Crash semantics (no keep-alive record); `None` without touching
    /// anything when `cid` is not busy (stale timeout after a drain or an
    /// earlier kill).
    pub fn abort_exec(&mut self, cid: ContainerId, _now: Micros) -> Option<RequestId> {
        let request = match self.containers.get(&cid).map(|c| &c.state) {
            Some(&ContainerState::Busy { request, .. }) => request,
            _ => return None,
        };
        let c = self.containers.remove(&cid).expect("presence checked above");
        self.deindex(&c);
        self.mem_used = self
            .mem_used
            .saturating_sub(self.registry.get(c.func).mem_mib);
        self.log.forget(cid);
        self.removed += 1;
        self.counters.timeouts += 1;
        Some(request)
    }

    /// End-of-run accounting: treat still-alive idle containers as kept
    /// warm until `now`. Returns (keepalive durations, total idle times).
    pub fn finalize(&mut self, now: Micros) -> (Vec<Micros>, Vec<Micros>) {
        let ids: Vec<ContainerId> = self.containers.keys().copied().collect();
        for cid in ids {
            let c = &self.containers[&cid];
            if c.is_idle() {
                self.remove(cid, now);
            }
        }
        (
            std::mem::take(&mut self.removed_keepalive),
            std::mem::take(&mut self.removed_idle_total),
        )
    }

    /// Direct read of accumulated keep-alive records (without finalize).
    pub fn keepalive_records(&self) -> &[Micros] {
        &self.removed_keepalive
    }
}

/// Brute-force reference implementation of every indexed query, kept as
/// the oracle the property tests compare the incremental indices against
/// after arbitrary operation sequences. This *is* the old pre-index code
/// path (full scans over the container map); it must never be used on a
/// hot path again, which is why it only compiles for tests.
#[cfg(test)]
impl Platform {
    /// Assert every indexed gauge equals its brute-force scan, returning
    /// Err with context so `prop_check` can report the replay seed.
    pub(crate) fn assert_matches_scan(&self, now: Micros) -> Result<(), String> {
        use crate::prop_assert;
        let scan = |pred: &dyn Fn(&Container) -> bool| -> u32 {
            self.containers.values().filter(|c| pred(c)).count() as u32
        };
        let idle = scan(&|c| c.is_idle());
        let busy = scan(&|c| c.is_busy());
        let cold = scan(&|c| c.is_cold_starting());
        prop_assert!(idle == self.idle_count(), "idle {} != {}", self.idle_count(), idle);
        prop_assert!(busy == self.busy_count(), "busy {} != {}", self.busy_count(), busy);
        prop_assert!(cold == self.cold_starting_count(), "cold {} != {}", self.cold_starting_count(), cold);
        prop_assert!(
            scan(&|c| c.is_warm()) == self.warm_count(),
            "warm mismatch at t={now}"
        );
        let mru = self
            .containers
            .values()
            .filter(|c| c.is_idle())
            .map(|c| c.last_used)
            .max();
        prop_assert!(
            mru == self.mru_idle_recency(),
            "mru {:?} != scan {:?}",
            self.mru_idle_recency(),
            mru
        );
        let best = self
            .containers
            .values()
            .filter(|c| c.is_idle() && self.log.all_completed(c.id))
            .map(|c| c.reclaim_score(now))
            .fold(None, |acc: Option<f64>, s| Some(acc.map_or(s, |a| a.max(s))))
            .map(|s| {
                let w = self.cfg.reclaim_pressure_weight;
                if w > 0.0 {
                    s + w * self.mem_pressure()
                } else {
                    s
                }
            });
        prop_assert!(
            best == self.best_reclaim_score(now),
            "best_reclaim {:?} != scan {:?}",
            self.best_reclaim_score(now),
            best
        );
        // the reclaim order must hold exactly the idle containers, keyed
        // by their (bit-encoded) now-independent reclaim keys
        let mut scan_order: Vec<(u64, ContainerId)> = self
            .containers
            .values()
            .filter(|c| c.is_idle())
            .map(|c| (c.reclaim_key().to_bits(), c.id))
            .collect();
        scan_order.sort_unstable();
        let idx_order: Vec<(u64, ContainerId)> = self.reclaim_order.iter().copied().collect();
        prop_assert!(
            idx_order == scan_order,
            "reclaim order mismatch at t={now}: {idx_order:?} != {scan_order:?}"
        );
        let mut scan_cold: Vec<Micros> = self
            .containers
            .values()
            .filter_map(|c| match c.state {
                ContainerState::ColdStarting { ready_at, .. } => Some(ready_at),
                _ => None,
            })
            .collect();
        scan_cold.sort_unstable();
        let mut idx_cold = self.cold_ready_times();
        idx_cold.sort_unstable();
        prop_assert!(idx_cold == scan_cold, "cold_ready_times mismatch at t={now}");
        for min_idle in [0, 1, 1_000_000, 600_000_000] {
            // is_idle() guard: busy/cold containers have idle_for == 0 and
            // must not be counted at min_idle == 0 (see the gauge's doc)
            let want = scan(&|c| c.is_idle() && c.idle_for(now) >= min_idle);
            let got = self.idle_containers_older_than(min_idle, now);
            prop_assert!(got == want, "older_than({min_idle}) {got} != {want}");
        }
        for f in 0..self.registry.len() as FunctionId {
            let idle_f = scan(&|c| c.is_idle() && c.func == f);
            let warm_f = scan(&|c| c.is_warm() && c.func == f);
            let cold_f = scan(&|c| c.is_cold_starting() && c.func == f);
            // retention audit: the expiry-due count under the *live*
            // per-function horizon must match a brute-force scan (the
            // set an expiry sweep at `now` would remove)
            let eff = self.effective_keepalive(f);
            let due = scan(&|c| c.is_idle() && c.func == f && c.idle_for(now) >= eff);
            prop_assert!(
                due == self.idle_due_for(f, now),
                "idle_due[{f}] {} != scan {due} (horizon {eff})",
                self.idle_due_for(f, now)
            );
            prop_assert!(idle_f == self.idle_count_for(f), "idle[{f}] mismatch");
            prop_assert!(warm_f == self.warm_count_for(f), "warm[{f}] mismatch");
            prop_assert!(cold_f == self.cold_starting_for(f), "cold[{f}] mismatch");
            let mru_f = self
                .containers
                .values()
                .filter(|c| c.is_idle() && c.func == f)
                .map(|c| c.last_used)
                .max();
            prop_assert!(mru_f == self.mru_idle_recency_for(f), "mru[{f}] mismatch");
            let mut scan_cold_f: Vec<Micros> = self
                .containers
                .values()
                .filter(|c| c.func == f)
                .filter_map(|c| match c.state {
                    ContainerState::ColdStarting { ready_at, .. } => Some(ready_at),
                    _ => None,
                })
                .collect();
            scan_cold_f.sort_unstable();
            let mut idx_cold_f = self.cold_ready_times_for(f);
            idx_cold_f.sort_unstable();
            prop_assert!(idx_cold_f == scan_cold_f, "cold_ready[{f}] mismatch");
            prop_assert!(
                self.next_cold_ready_for(f) == scan_cold_f.first().copied(),
                "next_cold_ready[{f}] mismatch"
            );
        }
        let mem: u32 = self
            .containers
            .values()
            .map(|c| self.registry.get(c.func).mem_mib)
            .sum();
        prop_assert!(mem == self.mem_used_mib(), "mem ledger {} != {mem}", self.mem_used_mib());
        let backlog_total: usize = self.fns.iter().map(|fi| fi.backlog.len()).sum();
        prop_assert!(backlog_total == self.fcfs_len(), "fcfs_len mismatch");
        // image-cache ledger: LRU mirror, byte ledger, and capacity bound
        // must agree with the layer store after every operation
        self.image.check_ledger()?;
        // the dynamic cold-start probe must equal its definition against
        // the scanned cache state (and collapse to the profile constant
        // when the cache is off)
        for f in 0..self.registry.len() as FunctionId {
            let base = self.registry.get(f).l_cold;
            let want = if self.image.enabled() {
                self.cfg
                    .image
                    .effective_l_cold(base, self.image.missing_mib(&self.manifests[f as usize]))
            } else {
                base
            };
            prop_assert!(
                self.effective_l_cold(f) == want,
                "effective_l_cold[{f}] {} != {want}",
                self.effective_l_cold(f)
            );
        }
        prop_assert!(
            self.spawned == self.removed + self.total() as u64,
            "conservation broken: spawned {} removed {} live {}",
            self.spawned,
            self.removed,
            self.total()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform() -> Platform {
        let cfg = PlatformConfig {
            latency_jitter: 0.0,
            ..Default::default()
        };
        Platform::new(cfg, 1)
    }

    #[test]
    fn cold_start_when_no_warm_container() {
        let mut p = platform();
        match p.invoke(1, 0) {
            InvokeOutcome::ColdStart { ready_at, .. } => assert_eq!(ready_at, 10_500_000),
            o => panic!("expected cold start, got {o:?}"),
        }
        assert_eq!(p.counters.cold_starts, 1);
        assert_eq!(p.cold_starting_count(), 1);
    }

    #[test]
    fn warm_reuse_after_completion() {
        let mut p = platform();
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke(1, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        assert_eq!(done_at, ready_at + 280_000);
        let out = p.exec_complete(cid, done_at);
        assert_eq!(out.completed, 1);
        // second request reuses the warm container
        match p.invoke(2, done_at + 1000) {
            InvokeOutcome::WarmStart { cid: c2, done_at: d2 } => {
                assert_eq!(c2, cid);
                assert_eq!(d2, done_at + 1000 + 280_000);
            }
            o => panic!("expected warm start, got {o:?}"),
        }
        assert_eq!(p.counters.cold_starts, 1);
    }

    #[test]
    fn capacity_bound_enforced_and_fcfs_drains() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        assert!(matches!(p.invoke(1, 0), InvokeOutcome::ColdStart { .. }));
        assert!(matches!(p.invoke(2, 0), InvokeOutcome::ColdStart { .. }));
        assert!(matches!(p.invoke(3, 0), InvokeOutcome::AtCapacity));
        assert_eq!(p.fcfs_len(), 1);
        // first container ready: serves its own bound request (req 1)
        let ReadyOutcome::Started { request, done_at } = p.container_ready(1, 10_500_000)
        else {
            panic!()
        };
        assert_eq!(request, 1);
        // completion hands the container to the FCFS backlog (req 3)
        let out = p.exec_complete(1, done_at);
        assert_eq!(out.completed, 1);
        assert_eq!(out.next.unwrap().0, 3);
        assert_eq!(p.fcfs_len(), 0);
    }

    #[test]
    fn prewarm_goes_idle_and_respects_capacity() {
        let cfg = PlatformConfig {
            max_containers: 1,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        assert!(p.prewarm_one(0).is_none());
        assert_eq!(p.counters.prewarms_rejected, 1);
        assert_eq!(p.container_ready(cid, ready_at), ReadyOutcome::Idle);
        assert_eq!(p.idle_count(), 1);
        // warm hit now
        assert!(matches!(
            p.invoke(1, ready_at + 10),
            InvokeOutcome::WarmStart { .. }
        ));
    }

    #[test]
    fn reclaim_only_idle_and_respects_log() {
        let mut p = platform();
        // two prewarmed idle containers + one busy
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        let InvokeOutcome::WarmStart { cid: busy, .. } = p.invoke(9, r2 + 1) else {
            panic!()
        };
        let got = p.try_reclaim(10, r2 + 2);
        assert_eq!(got.len(), 1); // only the remaining idle one
        assert!(!got.contains(&busy));
        assert_eq!(p.warm_count(), 1); // busy survives
    }

    #[test]
    fn keepalive_expiry_and_recheck() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        // too early: due at last_used + 600 s
        let due = ready_at + 600_000_000;
        match p.keepalive_check(cid, due - 5) {
            KeepAliveVerdict::Recheck(t) => assert_eq!(t, due),
            v => panic!("{v:?}"),
        }
        assert_eq!(p.keepalive_check(cid, due), KeepAliveVerdict::Expired);
        assert_eq!(p.total(), 0);
        assert_eq!(p.keepalive_check(cid, due), KeepAliveVerdict::NotApplicable);
    }

    #[test]
    fn keepalive_metric_records_last_use_to_removal() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        let reclaim_at = ready_at + 42_000_000;
        p.try_reclaim(1, reclaim_at);
        // last_used for a never-executed prewarm is its ready time
        assert_eq!(p.keepalive_records(), &[42_000_000]);
    }

    #[test]
    fn live_horizon_override_shortens_expiry_and_credits_saved_idle() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        assert_eq!(p.keepalive_of(cid), Some(600_000_000)); // profile window
        // the retention planner shrinks the live horizon to 60 s — the
        // already-idle container picks it up at its next check
        p.set_keepalive_override(0, Some(60_000_000));
        assert_eq!(p.effective_keepalive(0), 60_000_000);
        assert_eq!(p.keepalive_of(cid), Some(60_000_000));
        let due = ready_at + 60_000_000;
        match p.keepalive_check(cid, due - 1) {
            KeepAliveVerdict::Recheck(t) => assert_eq!(t, due),
            v => panic!("{v:?}"),
        }
        assert_eq!(p.keepalive_check(cid, due), KeepAliveVerdict::Expired);
        // early expiry credits the span to the profile-scheduled removal
        assert_eq!(p.counters.keepalive_expiries, 1);
        assert_eq!(p.counters.adaptive_expiries, 1);
        assert_eq!(p.idle_saved(), 600_000_000 - 60_000_000);
        // clearing the override restores the profile window
        p.set_keepalive_override(0, None);
        assert_eq!(p.effective_keepalive(0), 600_000_000);
        // out-of-range functions are ignored, not panics
        p.set_keepalive_override(99, Some(1));
    }

    #[test]
    fn expire_sweep_drains_exactly_the_idle_prefix() {
        let mut p = platform();
        // two idle containers with different ages + one busy
        let (c1, r1) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        let (c2, r2) = p.prewarm_one(r1 + 100_000_000).unwrap();
        p.container_ready(c2, r2);
        let (c3, r3) = p.prewarm_one(r2 + 1).unwrap();
        p.container_ready(c3, r3);
        let InvokeOutcome::WarmStart { cid: busy, .. } = p.invoke(1, r3 + 1) else {
            panic!()
        };
        assert_eq!(busy, c3); // MRU bind
        let now = r2 + 50_000_000;
        // live horizon 60 s: only c1 (idle ~160 s) qualifies; c2 (50 s)
        // and the busy c3 survive
        p.set_keepalive_override(0, Some(60_000_000));
        assert_eq!(p.idle_due_for(0, now), 1);
        let expired = p.expire_idle_older_than(0, 60_000_000, now);
        assert_eq!(expired, vec![c1]);
        assert_eq!(p.idle_due_for(0, now), 0);
        assert_eq!(p.counters.keepalive_expiries, 1);
        // the early removal is credited vs the 600 s profile window
        assert_eq!(p.counters.adaptive_expiries, 1);
        assert!(p.idle_saved() > 0);
        assert_eq!(p.warm_count(), 2);
        assert_eq!(p.spawned, p.removed + p.total() as u64);
        // an unknown function is a no-op, not a panic
        assert!(p.expire_idle_older_than(9, 1, now).is_empty());
    }

    #[test]
    fn fixed_policy_accrues_no_adaptive_accounting() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        // profile-window expiry (the fixed path): no adaptive credit
        let due = ready_at + 600_000_000;
        assert_eq!(p.keepalive_check(cid, due), KeepAliveVerdict::Expired);
        assert_eq!(p.counters.keepalive_expiries, 1);
        assert_eq!(p.counters.adaptive_expiries, 0);
        assert_eq!(p.idle_saved(), 0);
    }

    #[test]
    fn mru_reuse_prefers_warmest() {
        let mut p = platform();
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        // execute once on c2 so it is most recently used
        let InvokeOutcome::WarmStart { cid, done_at } = p.invoke(1, r2 + 1) else {
            panic!()
        };
        p.exec_complete(cid, done_at);
        let InvokeOutcome::WarmStart { cid: again, .. } = p.invoke(2, done_at + 5) else {
            panic!()
        };
        assert_eq!(again, cid);
        let _ = c1;
    }

    #[test]
    fn conservation_spawned_equals_removed_plus_live() {
        let mut p = platform();
        for i in 0..5 {
            let _ = p.invoke(i, i * 1000);
        }
        let ready: Vec<_> = p.cold_ready_times();
        assert_eq!(ready.len(), 5);
        assert_eq!(p.spawned, p.removed + p.total() as u64);
    }

    #[test]
    fn fail_all_returns_inflight_and_backlog() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        // req 1 cold-starting, req 2 executing, req 3 queued
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke(1, 0) else {
            panic!()
        };
        let InvokeOutcome::ColdStart { cid: c2, ready_at: r2 } = p.invoke(2, 0) else {
            panic!()
        };
        let _ = (cid, ready_at);
        p.container_ready(c2, r2);
        assert!(matches!(p.invoke(3, r2 + 1), InvokeOutcome::AtCapacity));
        let lost = p.fail_all(r2 + 2);
        assert_eq!(lost, vec![1, 2, 3]);
        assert_eq!(p.total(), 0);
        assert_eq!(p.fcfs_len(), 0);
        assert_eq!(p.spawned, p.removed); // conservation holds through a crash
        assert!(p.keepalive_records().is_empty()); // no graceful-drain records
    }

    #[test]
    fn mru_idle_recency_and_headroom() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        assert_eq!(p.headroom(), 2);
        assert_eq!(p.mru_idle_recency(), None);
        let (c1, r1) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        assert_eq!(p.headroom(), 1);
        assert_eq!(p.mru_idle_recency(), Some(r1));
        // an execution bumps recency
        let InvokeOutcome::WarmStart { cid, done_at } = p.invoke(1, r1 + 10) else {
            panic!()
        };
        assert_eq!(p.mru_idle_recency(), None); // busy, not idle
        p.exec_complete(cid, done_at);
        assert_eq!(p.mru_idle_recency(), Some(done_at));
    }

    #[test]
    fn best_reclaim_score_tracks_top_candidate() {
        let mut p = platform();
        assert!(p.best_reclaim_score(0).is_none());
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        let now = r2 + 5_000_000;
        // the peek equals the top candidate's score: c1 has been idle
        // longest (earlier ready), so it holds the max
        let peek = p.best_reclaim_score(now).unwrap();
        let expect = (now - r1) as f64 / 1e6;
        assert!((peek - expect).abs() < 1e-9, "peek {peek} vs {expect}");
        // busy containers are not candidates
        let InvokeOutcome::WarmStart { .. } = p.invoke(1, now) else {
            panic!()
        };
        let InvokeOutcome::WarmStart { .. } = p.invoke(2, now) else {
            panic!()
        };
        assert!(p.best_reclaim_score(now + 1).is_none());
        let _ = (c1, c2);
    }

    #[test]
    fn finalize_accounts_for_survivors() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        let (ka, idle) = p.finalize(ready_at + 1_000_000);
        assert_eq!(ka.len(), 1);
        assert_eq!(idle.len(), 1);
        assert_eq!(idle[0], 1_000_000);
        assert_eq!(p.total(), 0);
    }

    // ---- multi-tenant semantics ---------------------------------------------

    use crate::workload::tenant::{FunctionProfile, FunctionRegistry};

    /// Two-function registry: fn-0 = paper profile, fn-1 = a faster
    /// lightweight function with a short keep-alive.
    fn two_tenant_platform(max_containers: u32) -> Platform {
        let cfg = PlatformConfig {
            max_containers,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p0 = FunctionRegistry::single(&cfg).get(0).clone();
        p0.share = 0.7;
        let registry = FunctionRegistry::new(vec![
            p0,
            FunctionProfile {
                id: 1,
                name: "fn-1".into(),
                l_warm: 100_000,           // 100 ms
                l_cold: 2_000_000,         // 2 s
                keep_alive: 60_000_000,    // 1 min
                mem_mib: 128,
                share: 0.3,
                idle_cost: None,
                cold_cost_weight: None,
            },
        ]);
        Platform::with_registry(cfg, registry, 1)
    }

    #[test]
    fn warm_pools_are_per_function() {
        let mut p = two_tenant_platform(64);
        // warm up a fn-0 container
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke_for(1, 0, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        p.exec_complete(cid, done_at);
        assert_eq!(p.idle_count_for(0), 1);
        assert_eq!(p.idle_count_for(1), 0);
        // fn-1 cannot ride fn-0's warm container: it cold-starts with its
        // own (shorter) init latency
        match p.invoke_for(2, 1, done_at + 10) {
            InvokeOutcome::ColdStart { ready_at, .. } => {
                assert_eq!(ready_at, done_at + 10 + 2_000_000);
            }
            o => panic!("expected fn-1 cold start, got {o:?}"),
        }
        // fn-0 still warm-binds with its own exec latency
        match p.invoke_for(3, 0, done_at + 20) {
            InvokeOutcome::WarmStart { done_at: d, .. } => {
                assert_eq!(d, done_at + 20 + 280_000);
            }
            o => panic!("expected fn-0 warm start, got {o:?}"),
        }
        let fc = p.fn_counters();
        assert_eq!(fc[&0].cold_starts, 1);
        assert_eq!(fc[&0].warm_starts, 1);
        assert_eq!(fc[&1].cold_starts, 1);
    }

    #[test]
    fn eviction_makes_room_at_capacity() {
        let mut p = two_tenant_platform(1);
        // fill the single slot with an idle fn-0 container
        let (cid, ready_at) = p.prewarm_for(0, 0).unwrap();
        assert_eq!(p.container_ready(cid, ready_at), ReadyOutcome::Idle);
        // fn-1 arrives: the idle foreign container is evicted, not queued
        match p.invoke_for(7, 1, ready_at + 10) {
            InvokeOutcome::ColdStart { .. } => {}
            o => panic!("expected eviction + cold start, got {o:?}"),
        }
        assert_eq!(p.counters.evictions, 1);
        assert_eq!(p.fn_counters()[&0].evictions, 1);
        assert_eq!(p.total(), 1);
        assert_eq!(p.cold_starting_for(1), 1);
        assert_eq!(p.spawned, p.removed + p.total() as u64);
    }

    #[test]
    fn backlog_respawn_serves_foreign_function() {
        let mut p = two_tenant_platform(1);
        // fn-0 busy on the only slot; fn-1 queues at capacity
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke_for(1, 0, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        assert!(matches!(p.invoke_for(2, 1, ready_at + 1), InvokeOutcome::AtCapacity));
        assert_eq!(p.fcfs_len(), 1);
        // completion cannot warm-reuse (wrong function): the container is
        // recycled into a cold start bound to the fn-1 waiter
        let out = p.exec_complete(cid, done_at);
        assert_eq!(out.completed, 1);
        assert!(out.next.is_none());
        let (wreq, ncid, nready) = out.respawn.expect("respawn for the foreign waiter");
        assert_eq!(wreq, 2);
        assert_eq!(nready, done_at + 2_000_000);
        assert_eq!(p.fcfs_len(), 0);
        // the respawned container starts fn-1's request when ready
        let ReadyOutcome::Started { request, .. } = p.container_ready(ncid, nready) else {
            panic!()
        };
        assert_eq!(request, 2);
        assert_eq!(p.counters.evictions, 1);
    }

    #[test]
    fn ready_prewarm_recycles_for_stranded_foreign_backlog() {
        let mut p = two_tenant_platform(1);
        // an unbound fn-0 prewarm occupies the slot while fn-1 queues
        let (cid, ready_at) = p.prewarm_for(0, 0).unwrap();
        assert!(matches!(p.invoke_for(9, 1, 1), InvokeOutcome::AtCapacity));
        // when the prewarm lands there is no fn-0 work — without the
        // recycle the fn-1 request would be stranded forever
        let ReadyOutcome::Respawned { req, cid: ncid, ready_at: nready } =
            p.container_ready(cid, ready_at)
        else {
            panic!("expected recycle for the stranded waiter")
        };
        assert_eq!(req, 9);
        let ReadyOutcome::Started { request, .. } = p.container_ready(ncid, nready) else {
            panic!()
        };
        assert_eq!(request, 9);
        assert_eq!(p.fcfs_len(), 0);
    }

    #[test]
    fn fcfs_is_fifo_within_function() {
        let mut p = two_tenant_platform(1);
        // fn-0 busy; backlog = [fn-1 req 2, fn-0 req 3]
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke_for(1, 0, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        assert!(matches!(p.invoke_for(2, 1, ready_at + 1), InvokeOutcome::AtCapacity));
        assert!(matches!(p.invoke_for(3, 0, ready_at + 2), InvokeOutcome::AtCapacity));
        // the completing fn-0 container skips the older fn-1 waiter and
        // warm-serves its own function's request
        let out = p.exec_complete(cid, done_at);
        assert_eq!(out.next.unwrap().0, 3);
        assert!(out.respawn.is_none());
        assert_eq!(p.fcfs_len(), 1); // fn-1 still waiting
    }

    #[test]
    fn keepalive_follows_function_profile() {
        let mut p = two_tenant_platform(4);
        let (c1, r1) = p.prewarm_for(1, 0).unwrap();
        p.container_ready(c1, r1);
        assert_eq!(p.keepalive_of(c1), Some(60_000_000));
        // fn-1's 1-minute window, not the platform's 10-minute default
        let due = r1 + 60_000_000;
        match p.keepalive_check(c1, due - 1) {
            KeepAliveVerdict::Recheck(t) => assert_eq!(t, due),
            v => panic!("{v:?}"),
        }
        assert_eq!(p.keepalive_check(c1, due), KeepAliveVerdict::Expired);
    }

    #[test]
    fn memory_ledger_bounds_heavy_tenants() {
        // node with room for exactly one 384-MiB heavyweight
        let cfg = PlatformConfig {
            node_mem_mib: 512,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p0 = FunctionRegistry::single(&cfg).get(0).clone();
        p0.mem_mib = 384;
        let registry = FunctionRegistry::new(vec![p0]);
        let mut p = Platform::with_registry(cfg, registry, 1);
        assert!(p.can_admit(0));
        assert!(p.prewarm_for(0, 0).is_some());
        assert_eq!(p.mem_used_mib(), 384);
        // a second container would need 768 MiB total: rejected despite
        // free replica slots
        assert!(!p.can_admit(0));
        assert!(p.prewarm_for(0, 0).is_none());
        assert_eq!(p.counters.prewarms_rejected, 1);
    }

    // ---- elasticity: migration + pressure-aware, indexed reclaim ------------

    #[test]
    fn migrate_out_releases_idle_and_records_keepalive() {
        let mut p = platform();
        let (cid, r) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, r);
        assert_eq!(p.migrate_out_candidate(0), Some(cid));
        assert!(p.migrate_out(cid, r + 5_000_000));
        assert_eq!(p.total(), 0);
        assert_eq!(p.counters.migrations_out, 1);
        // the source charges the idle span up to the departure
        assert_eq!(p.keepalive_records(), &[5_000_000]);
        // gone: a repeated release is refused, and no candidate remains
        assert!(!p.migrate_out(cid, r + 6_000_000));
        assert!(p.migrate_out_candidate(0).is_none());
        assert_eq!(p.spawned, p.removed + p.total() as u64);
    }

    #[test]
    fn migrate_in_counts_no_cold_start_and_respects_capacity() {
        let cfg = PlatformConfig {
            max_containers: 1,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        let (cid, ready_at) = p.migrate_in(0, 0, 2_000_000).unwrap();
        assert_eq!(ready_at, 2_000_000);
        assert_eq!(p.cold_starting_count(), 1);
        assert_eq!(p.counters.migrations_in, 1);
        // a migration is neither a cold start nor a prewarm
        assert_eq!(p.counters.cold_starts, 0);
        assert_eq!(p.counters.prewarms_started, 0);
        // the in-flight transfer occupies the only replica slot
        assert!(p.migrate_in(0, 100, 2_000_000).is_none());
        // it lands idle and warm-serves like any warm container
        assert_eq!(p.container_ready(cid, ready_at), ReadyOutcome::Idle);
        assert!(matches!(
            p.invoke(1, ready_at + 10),
            InvokeOutcome::WarmStart { .. }
        ));
    }

    #[test]
    fn migrate_out_candidate_prefers_lru() {
        let mut p = platform();
        let (c1, r1) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        let (c2, r2) = p.prewarm_one(r1 + 1_000_000).unwrap();
        p.container_ready(c2, r2);
        // c1 has idled longest (coldest) → the migration victim; the MRU
        // c2 stays to serve the next dispatch
        assert_eq!(p.migrate_out_candidate(0), Some(c1));
        assert!(p.migrate_out(c1, r2 + 1));
        assert_eq!(p.migrate_out_candidate(0), Some(c2));
    }

    // ---- image/layer cache (cold-start fidelity) ----------------------------

    use crate::config::{ImageCacheConfig, ImageCacheMode};

    /// Single-tenant platform with the layer cache on. The paper-profile
    /// image is 64 + 192 (base) + 256 (deps = mem footprint) + 16 (code)
    /// = 528 MiB; at the default 100 MiB/s and init fraction 0.25 a
    /// cache-cold start costs 2.625 s init + 5.28 s pull.
    fn cached_platform(capacity_mib: u32) -> Platform {
        let cfg = PlatformConfig {
            latency_jitter: 0.0,
            image: ImageCacheConfig {
                mode: ImageCacheMode::Lru,
                capacity_mib,
                ..Default::default()
            },
            ..Default::default()
        };
        Platform::new(cfg, 1)
    }

    #[test]
    fn cold_start_charges_pull_plus_init_and_warms_the_cache() {
        let mut p = cached_platform(2048);
        assert_eq!(p.pull_cost_mib(0), 528);
        assert_eq!(p.effective_l_cold(0), 2_625_000 + 5_280_000);
        // first cold start pays the full pull
        let InvokeOutcome::ColdStart { ready_at, .. } = p.invoke(1, 0) else {
            panic!()
        };
        assert_eq!(ready_at, 7_905_000);
        assert_eq!(p.counters.pull_mib, 528);
        assert_eq!(p.counters.layer_misses, 4);
        assert_eq!(p.counters.layer_hits, 0);
        // the layers are on disk now: the next cold start is init-only
        assert_eq!(p.pull_cost_mib(0), 0);
        assert_eq!(p.effective_l_cold(0), 2_625_000);
        let (_, r2) = p.prewarm_one(0).unwrap();
        assert_eq!(r2, 2_625_000);
        assert_eq!(p.counters.layer_hits, 4);
        assert_eq!(p.counters.pull_mib, 528); // nothing new pulled
        // mean effective charge: (7.905 + 2.625) / 2 seconds
        assert_eq!(p.counters.cold_charges, 2);
        assert_eq!(p.counters.cold_cost_us, 7_905_000 + 2_625_000);
    }

    #[test]
    fn migrate_in_warms_the_destination_cache() {
        let mut p = cached_platform(2048);
        let (cid, ready_at) = p.migrate_in(0, 0, 2_000_000).unwrap();
        // the transfer shipped the image: cold starts here are cheap now
        assert_eq!(p.counters.pull_mib, 528);
        assert_eq!(p.effective_l_cold(0), 2_625_000);
        // a migration is still not a cold-start charge
        assert_eq!(p.counters.cold_charges, 0);
        assert_eq!(p.container_ready(cid, ready_at), ReadyOutcome::Idle);
    }

    #[test]
    fn image_cache_survives_a_node_crash() {
        let mut p = cached_platform(2048);
        p.warm_image_for(0);
        assert_eq!(p.effective_l_cold(0), 2_625_000);
        let lost = p.fail_all(1_000_000);
        assert!(lost.is_empty());
        assert_eq!(p.total(), 0);
        // layers live on the node's disk, not in containers: the restarted
        // invoker is container-cold but image-warm
        assert_eq!(p.pull_cost_mib(0), 0);
        assert_eq!(p.effective_l_cold(0), 2_625_000);
    }

    #[test]
    fn tiny_cache_re_pulls_evicted_layers() {
        // a store smaller than the image: every cold start re-pulls
        let mut p = cached_platform(100);
        let (_, r1) = p.prewarm_one(0).unwrap();
        assert_eq!(r1, 7_905_000);
        assert!(p.pull_cost_mib(0) > 0, "the store cannot hold the image");
        let before = p.counters.pull_mib;
        let (_, _r2) = p.prewarm_one(0).unwrap();
        assert!(p.counters.pull_mib > before, "second start re-pulled");
    }

    #[test]
    fn off_mode_charges_the_constant_and_stays_silent() {
        let mut p = platform(); // default: cache off
        let InvokeOutcome::ColdStart { ready_at, .. } = p.invoke(1, 0) else {
            panic!()
        };
        assert_eq!(ready_at, 10_500_000);
        assert_eq!(p.effective_l_cold(0), 10_500_000);
        assert_eq!(p.pull_cost_mib(0), 0);
        let c = p.counters;
        assert_eq!(c.layer_hits, 0);
        assert_eq!(c.layer_misses, 0);
        assert_eq!(c.pull_mib, 0);
        assert_eq!(c.cold_cost_us, 0);
        assert_eq!(c.cold_charges, 0);
        // warming is a no-op too
        assert_eq!(p.warm_image_for(0), AdmitOutcome::default());
    }

    #[test]
    fn override_capacity_rebinds_the_replica_cap() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        assert_eq!(p.headroom(), 2);
        p.override_capacity(4);
        assert_eq!(p.cfg.resource_cap(), 4);
        assert_eq!(p.headroom(), 4);
        p.override_capacity(1);
        assert_eq!(p.cfg.resource_cap(), 1);
        assert!(p.prewarm_one(0).is_some());
        assert!(p.prewarm_one(0).is_none(), "the shrunk cap binds");
    }

    #[test]
    fn pressure_bias_raises_best_reclaim_score() {
        // identical container state, different ledger weight/pressure
        let peek = |weight: f64, mem: u32| {
            let cfg = PlatformConfig {
                latency_jitter: 0.0,
                reclaim_pressure_weight: weight,
                node_mem_mib: 1024,
                container_mem_mib: mem,
                ..Default::default()
            };
            let mut p = Platform::new(cfg, 1);
            let (cid, r) = p.prewarm_one(0).unwrap();
            p.container_ready(cid, r);
            p.best_reclaim_score(r + 1_000_000).unwrap()
        };
        let unbiased = peek(0.0, 256);
        let light = peek(2.0, 256); // pressure 0.25 → +0.5
        let heavy = peek(2.0, 512); // pressure 0.50 → +1.0
        assert!((light - unbiased - 0.5).abs() < 1e-9, "{light} vs {unbiased}");
        assert!((heavy - unbiased - 1.0).abs() < 1e-9, "{heavy} vs {unbiased}");
    }

    /// The indexed reclaim order must reproduce the scan-era ranking:
    /// descending score at reclaim time, score ties broken by ascending
    /// key then id. (The old comparator broke score ties by id alone;
    /// bitwise-equal scores from *distinct* keys — a few-ulp rounding
    /// collapse — now canonically tie by key, so the reference ranking
    /// here includes the key as the middle tie-break. For equal keys
    /// the two rules coincide.)
    #[test]
    fn try_reclaim_matches_scan_era_ranking() {
        use crate::prop_assert;
        prop_check("indexed reclaim == scan-era ranking", 30, |g| {
            let cfg = PlatformConfig {
                latency_jitter: 0.0,
                ..Default::default()
            };
            let mut p = Platform::new(cfg, g.u64(0, 1 << 32));
            let m = g.usize(2, 12);
            let mut now = 0u64;
            for _ in 0..m {
                now += g.u64(1, 5_000_000);
                let (cid, ready_at) = p.prewarm_one(now).expect("capacity");
                now = ready_at;
                p.container_ready(cid, now);
                // vary activations/last_used via MRU-bound executions
                for req in 0..g.u64(0, 3) {
                    now += g.u64(1, 1_000_000);
                    let InvokeOutcome::WarmStart { cid: c, done_at } = p.invoke(req, now)
                    else {
                        return Err("expected warm start".into());
                    };
                    now = done_at;
                    p.exec_complete(c, now);
                }
            }
            now += g.u64(1, 10_000_000);
            let mut expect: Vec<(f64, f64, ContainerId)> = p
                .containers
                .values()
                .filter(|c| c.is_idle())
                .map(|c| (c.reclaim_score(now), c.reclaim_key(), c.id))
                .collect();
            expect.sort_by(|a, b| {
                b.0.total_cmp(&a.0)
                    .then(a.1.total_cmp(&b.1))
                    .then(a.2.cmp(&b.2))
            });
            let n = g.usize(1, m);
            let want: Vec<ContainerId> =
                expect.iter().take(n).map(|&(_, _, id)| id).collect();
            let got = p.try_reclaim(n as u32, now);
            prop_assert!(got == want, "reclaim picked {got:?}, scan ranking {want:?}");
            Ok(())
        });
    }

    // ---- index vs. reference-scan property ----------------------------------

    use crate::util::prop::prop_check;

    /// After an arbitrary interleaving of invoke / prewarm / ready /
    /// complete / keep-alive / reclaim / migrate operations — and, since
    /// the retention-control PR, random per-step keep-alive horizon
    /// updates with immediate expiry sweeps, and since the chaos PR,
    /// random spawn aborts, execution kills, and whole-node crashes —
    /// every indexed counter and
    /// MRU/recency/ready-time/reclaim-order/expiry-due query must equal
    /// the brute-force scan over the container map (see
    /// [`Platform::assert_matches_scan`]).
    #[test]
    fn indices_match_reference_scan_after_random_ops() {
        use crate::prop_assert;
        prop_check("platform index == reference scan", 40, |g| {
            use crate::cluster::image::{ImageManifest, Layer};
            use crate::config::{ImageCacheConfig, ImageCacheMode};
            let nf = g.usize(1, 4) as u32;
            let cfg = PlatformConfig {
                max_containers: g.usize(1, 10) as u32,
                // small ledger so eviction/respawn paths actually fire
                node_mem_mib: g.usize(256, 2048) as u32,
                latency_jitter: 0.0,
                // sometimes bias the reclaim peek with node pressure so
                // the scan-vs-index equality covers that path too
                reclaim_pressure_weight: if g.bool(0.5) { g.f64(0.1, 4.0) } else { 0.0 },
                // sometimes run with the layer cache on, small enough
                // that admissions evict (the interesting ledger paths)
                image: if g.bool(0.5) {
                    ImageCacheConfig {
                        mode: ImageCacheMode::Lru,
                        capacity_mib: g.usize(64, 1024) as u32,
                        ..Default::default()
                    }
                } else {
                    ImageCacheConfig::default()
                },
                ..Default::default()
            };
            let registry = FunctionRegistry::synthesize(nf, 1.1, &cfg, g.u64(0, 1 << 32));
            let mut p = Platform::with_registry(cfg, registry, g.u64(0, 1 << 32));
            if p.image_cache().enabled() && g.bool(0.5) {
                // randomized layer manifests: arbitrary sharing patterns
                // (including repeated ids) over a small id space; sizes
                // derive from the id so content-addressing holds — the
                // same digest always names the same bytes
                let manifests = (0..nf)
                    .map(|_| {
                        let n = g.usize(1, 5);
                        ImageManifest::new(
                            (0..n)
                                .map(|_| {
                                    let id = g.u64(1, 12);
                                    Layer {
                                        id,
                                        size_mib: (id * 97 % 600 + 1) as u32,
                                    }
                                })
                                .collect(),
                        )
                    })
                    .collect();
                p.set_image_manifests(manifests);
            }
            let mut now: Micros = 0;
            let mut req: RequestId = 0;
            let mut pending_ready: Vec<(ContainerId, Micros)> = Vec::new();
            let mut pending_done: Vec<(ContainerId, Micros)> = Vec::new();
            let steps = g.usize(20, 150);
            for _ in 0..steps {
                now += g.u64(1, 2_000_000);
                let func = g.u64(0, (nf - 1) as u64) as FunctionId;
                match g.usize(0, 14) {
                    0 => {
                        req += 1;
                        match p.invoke_for(req, func, now) {
                            InvokeOutcome::ColdStart { cid, ready_at } => {
                                pending_ready.push((cid, ready_at))
                            }
                            InvokeOutcome::WarmStart { cid, done_at } => {
                                pending_done.push((cid, done_at))
                            }
                            InvokeOutcome::AtCapacity => {}
                        }
                    }
                    1 => {
                        if let Some((cid, ready_at)) = p.prewarm_for(func, now) {
                            pending_ready.push((cid, ready_at));
                        }
                    }
                    2 => {
                        if !pending_ready.is_empty() {
                            let i = g.usize(0, pending_ready.len() - 1);
                            let (cid, t) = pending_ready.swap_remove(i);
                            now = now.max(t);
                            match p.container_ready(cid, now) {
                                ReadyOutcome::Started { done_at, .. } => {
                                    pending_done.push((cid, done_at))
                                }
                                ReadyOutcome::Respawned {
                                    cid: ncid, ready_at, ..
                                } => pending_ready.push((ncid, ready_at)),
                                ReadyOutcome::Idle => {}
                            }
                        }
                    }
                    3 => {
                        if !pending_done.is_empty() {
                            let i = g.usize(0, pending_done.len() - 1);
                            let (cid, t) = pending_done.swap_remove(i);
                            now = now.max(t);
                            let out = p.exec_complete(cid, now);
                            if let Some((_, done_at)) = out.next {
                                pending_done.push((cid, done_at));
                            }
                            if let Some((_, ncid, ready_at)) = out.respawn {
                                pending_ready.push((ncid, ready_at));
                            }
                        }
                    }
                    4 => {
                        p.try_reclaim(g.usize(0, 3) as u32, now);
                    }
                    5 => {
                        // migration-out: the function's LRU idle candidate
                        // leaves for a (phantom) peer node
                        if let Some(cid) = p.migrate_out_candidate(func) {
                            assert!(p.migrate_out(cid, now));
                        }
                    }
                    6 => {
                        // migration-in from a phantom peer: occupies a slot
                        // now, serviceable after the transfer latency
                        if let Some((cid, ready_at)) =
                            p.migrate_in(func, now, g.u64(1, 3_000_000))
                        {
                            pending_ready.push((cid, ready_at));
                        }
                    }
                    7 => {
                        // retention planner: install or clear a random live
                        // horizon; every later expiry check must consult it
                        let horizon =
                            (!g.bool(0.3)).then(|| g.u64(1, 900_000_000));
                        p.set_keepalive_override(func, horizon);
                    }
                    8 => {
                        // retention sweep under a random horizon: the
                        // expired set must equal the brute-force scan of
                        // idle containers of the function past that age
                        let h = g.u64(1, 600_000_000);
                        let mut want: Vec<ContainerId> = p
                            .containers
                            .values()
                            .filter(|c| {
                                c.is_idle() && c.func == func && c.idle_for(now) >= h
                            })
                            .map(|c| c.id)
                            .collect();
                        want.sort_unstable();
                        let mut got = p.expire_idle_older_than(func, h, now);
                        got.sort_unstable();
                        prop_assert!(
                            got == want,
                            "expiry sweep {got:?} != scan {want:?} (h={h})"
                        );
                    }
                    9 => {
                        // image-cache warm (registry prefetch / migration
                        // landing): admits, touches, and possibly evicts —
                        // the ledger audit below must survive all of it
                        p.warm_image_for(func);
                    }
                    10 => {
                        // chaos spawn failure: kill a random in-flight cold
                        // start (crash semantics), or probe an id that is
                        // not cold-starting — which must be a no-op
                        if !pending_ready.is_empty() && g.bool(0.7) {
                            let i = g.usize(0, pending_ready.len() - 1);
                            let (cid, _) = pending_ready.swap_remove(i);
                            p.abort_spawn(cid, now);
                        } else {
                            let cid = g.u64(1, p.spawned.max(1));
                            if !pending_ready.iter().any(|&(c, _)| c == cid)
                                && !pending_done.iter().any(|&(c, _)| c == cid)
                            {
                                prop_assert!(
                                    p.abort_spawn(cid, now).is_none(),
                                    "abort_spawn({cid}) acted on a non-cold container"
                                );
                            }
                        }
                    }
                    11 => {
                        // chaos execution timeout: kill a random in-flight
                        // execution; stale/idle ids must be a no-op
                        if !pending_done.is_empty() && g.bool(0.7) {
                            let i = g.usize(0, pending_done.len() - 1);
                            let (cid, _) = pending_done.swap_remove(i);
                            p.abort_exec(cid, now);
                        } else {
                            let cid = g.u64(1, p.spawned.max(1));
                            if !pending_ready.iter().any(|&(c, _)| c == cid)
                                && !pending_done.iter().any(|&(c, _)| c == cid)
                            {
                                prop_assert!(
                                    p.abort_exec(cid, now).is_none(),
                                    "abort_exec({cid}) acted on a non-busy container"
                                );
                            }
                        }
                    }
                    12 => {
                        // node crash (storm member): everything is lost at
                        // once and the in-flight events go stale — the
                        // coordinator drops those via the fleet's liveness
                        // guard, so the test just forgets them here
                        if g.bool(0.2) {
                            p.fail_all(now);
                            pending_ready.clear();
                            pending_done.clear();
                            if g.bool(0.5) {
                                // heterogeneous restore on the empty node
                                p.override_capacity(g.usize(1, 10) as u32);
                            }
                        }
                    }
                    13 => {
                        // survival release (the slot-survival policy's
                        // actuation shape): install a short live horizon,
                        // then immediately sweep — the expired set must
                        // equal the brute-force scan under that horizon,
                        // and later keep-alive checks must consult it
                        let h = g.u64(1, 30_000_000);
                        p.set_keepalive_override(func, Some(h));
                        let mut want: Vec<ContainerId> = p
                            .containers
                            .values()
                            .filter(|c| {
                                c.is_idle() && c.func == func && c.idle_for(now) >= h
                            })
                            .map(|c| c.id)
                            .collect();
                        want.sort_unstable();
                        let mut got = p.expire_idle_older_than(func, h, now);
                        got.sort_unstable();
                        prop_assert!(
                            got == want,
                            "survival release {got:?} != scan {want:?} (h={h})"
                        );
                    }
                    _ => {
                        // keep-alive probe on an arbitrary (possibly gone)
                        // container id; expiry removes only idle ones —
                        // under the container's *live* horizon
                        let cid = g.u64(1, p.spawned.max(1));
                        let _ = p.keepalive_check(cid, now + 600_000_000 * u64::from(g.bool(0.5)));
                    }
                }
                p.assert_matches_scan(now)?;
            }
            Ok(())
        });
    }

    /// The sharded runner's isolation premise, at the platform layer: a
    /// random op sequence tagged by node and applied to N platforms gives
    /// the same result whether the ops are interleaved on one thread (the
    /// sequential loop) or partitioned per node and run on worker threads
    /// (the shard workers) — platforms share no hidden state, and each
    /// node's RNG stream depends only on its own call order. Both copies
    /// must also still satisfy the index-vs-scan audit.
    #[test]
    fn shard_partitioned_ops_match_sequential_interleaving() {
        use crate::prop_assert;

        #[derive(Clone)]
        struct ShardOp {
            node: usize,
            kind: usize,
            func: FunctionId,
            dt: Micros,
            pick: usize,
        }

        #[derive(Default)]
        struct NodeState {
            now: Micros,
            req: RequestId,
            pending_ready: Vec<(ContainerId, Micros)>,
            pending_done: Vec<(ContainerId, Micros)>,
        }

        // deterministic given (platform, state, op) — no ambient input,
        // so sequential and partitioned application can only diverge if
        // the platforms leak state into each other
        fn apply(p: &mut Platform, st: &mut NodeState, op: &ShardOp) {
            st.now += op.dt;
            match op.kind {
                0 => {
                    st.req += 1;
                    match p.invoke_for(st.req, op.func, st.now) {
                        InvokeOutcome::ColdStart { cid, ready_at } => {
                            st.pending_ready.push((cid, ready_at))
                        }
                        InvokeOutcome::WarmStart { cid, done_at } => {
                            st.pending_done.push((cid, done_at))
                        }
                        InvokeOutcome::AtCapacity => {}
                    }
                }
                1 => {
                    if let Some((cid, ready_at)) = p.prewarm_for(op.func, st.now) {
                        st.pending_ready.push((cid, ready_at));
                    }
                }
                2 => {
                    if !st.pending_ready.is_empty() {
                        let i = op.pick % st.pending_ready.len();
                        let (cid, t) = st.pending_ready.swap_remove(i);
                        st.now = st.now.max(t);
                        match p.container_ready(cid, st.now) {
                            ReadyOutcome::Started { done_at, .. } => {
                                st.pending_done.push((cid, done_at))
                            }
                            ReadyOutcome::Respawned {
                                cid: ncid, ready_at, ..
                            } => st.pending_ready.push((ncid, ready_at)),
                            ReadyOutcome::Idle => {}
                        }
                    }
                }
                3 => {
                    if !st.pending_done.is_empty() {
                        let i = op.pick % st.pending_done.len();
                        let (cid, t) = st.pending_done.swap_remove(i);
                        st.now = st.now.max(t);
                        let out = p.exec_complete(cid, st.now);
                        if let Some((_, done_at)) = out.next {
                            st.pending_done.push((cid, done_at));
                        }
                        if let Some((_, ncid, ready_at)) = out.respawn {
                            st.pending_ready.push((ncid, ready_at));
                        }
                    }
                }
                4 => {
                    p.try_reclaim((op.pick % 3) as u32, st.now);
                }
                _ => {
                    let cid = (op.pick as u64 % p.spawned.max(1)) + 1;
                    let _ = p.keepalive_check(cid, st.now);
                }
            }
        }

        prop_check("shard-partitioned == interleaved", 25, |g| {
            let nodes = g.usize(2, 4);
            let nf = g.usize(1, 3) as u32;
            let seed = g.u64(0, 1 << 32);
            let mk = |i: usize| {
                // default latency_jitter stays on: identical RNG streams
                // under per-node call order are part of the contract
                let cfg = PlatformConfig {
                    max_containers: 6,
                    ..Default::default()
                };
                let registry = FunctionRegistry::synthesize(nf, 1.1, &cfg, seed);
                Platform::with_registry(
                    cfg,
                    registry,
                    seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                )
            };
            let ops: Vec<ShardOp> = (0..g.usize(30, 120))
                .map(|_| ShardOp {
                    node: g.usize(0, nodes - 1),
                    kind: g.usize(0, 5),
                    func: g.u64(0, (nf - 1) as u64) as FunctionId,
                    dt: g.u64(1, 2_000_000),
                    pick: g.usize(0, 1_000),
                })
                .collect();

            // sequential reference: one thread, ops in global order
            let mut seq_p: Vec<Platform> = (0..nodes).map(mk).collect();
            let mut seq_st: Vec<NodeState> = (0..nodes).map(|_| NodeState::default()).collect();
            for op in &ops {
                apply(&mut seq_p[op.node], &mut seq_st[op.node], op);
            }

            // sharded: partition by node, one worker thread per node
            let mut per_node: Vec<Vec<ShardOp>> = (0..nodes).map(|_| Vec::new()).collect();
            for op in &ops {
                per_node[op.node].push(op.clone());
            }
            let mut par_p: Vec<Platform> = (0..nodes).map(mk).collect();
            let mut par_st: Vec<NodeState> = (0..nodes).map(|_| NodeState::default()).collect();
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for ((p, st), ops) in par_p.iter_mut().zip(par_st.iter_mut()).zip(&per_node) {
                    handles.push(s.spawn(move || {
                        for op in ops {
                            apply(p, st, op);
                        }
                    }));
                }
                for h in handles {
                    h.join().expect("shard worker panicked");
                }
            });

            for i in 0..nodes {
                par_p[i].assert_matches_scan(par_st[i].now)?;
                prop_assert!(
                    par_p[i].counters == seq_p[i].counters,
                    "node {i} counters diverged: {:?} vs {:?}",
                    par_p[i].counters,
                    seq_p[i].counters
                );
                prop_assert!(par_p[i].idle_count() == seq_p[i].idle_count(), "node {i} idle");
                prop_assert!(par_p[i].busy_count() == seq_p[i].busy_count(), "node {i} busy");
                prop_assert!(par_p[i].spawned == seq_p[i].spawned, "node {i} spawn counter");
                prop_assert!(
                    par_st[i].pending_ready == seq_st[i].pending_ready
                        && par_st[i].pending_done == seq_st[i].pending_done,
                    "node {i} in-flight outcomes diverged"
                );
            }
            Ok(())
        });
    }
}
