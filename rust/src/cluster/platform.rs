//! The serverless platform substrate — OpenWhisk-on-Kubernetes analog.
//!
//! Reproduces the scheduling semantics the paper's results depend on
//! (DESIGN.md substitution table): cold start on warm-miss, bounded replica
//! pool (64 = 32 vCPU / 0.5), FCFS backlog at capacity, keep-alive expiry,
//! and the reclaim-safety protocol of Algorithm 2 (activation-log check).
//!
//! The platform is event-driven but owns no clock: methods take `now` and
//! return outcomes carrying future timestamps; the experiment runner turns
//! those into simulator events (or real timers in real-time mode).

use std::collections::{BTreeMap, VecDeque};

use crate::cluster::activation_log::ActivationLog;
use crate::cluster::container::{Container, ContainerId};
use crate::cluster::telemetry::{Counters, GaugeSample};
use crate::cluster::RequestId;
use crate::config::{Micros, PlatformConfig};
use crate::util::rng::Rng;

/// Result of an invocation request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InvokeOutcome {
    /// Bound to an idle warm container; execution completes at `done_at`.
    WarmStart { cid: ContainerId, done_at: Micros },
    /// Triggered a cold start; container ready (and execution starts) at
    /// `ready_at`.
    ColdStart { cid: ContainerId, ready_at: Micros },
    /// Replica pool exhausted; queued in the platform's FCFS backlog.
    AtCapacity,
}

/// Result of a cold container finishing initialization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadyOutcome {
    /// Went idle (controller prewarm with no waiting work).
    Idle,
    /// Immediately started executing `request`; completes at `done_at`.
    Started { request: RequestId, done_at: Micros },
}

/// Result of an execution completing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompleteOutcome {
    pub completed: RequestId,
    /// FCFS backlog request that immediately reused the container.
    pub next: Option<(RequestId, Micros)>,
}

/// Keep-alive check verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeepAliveVerdict {
    /// Container removed (idle past the keep-alive window).
    Expired,
    /// Container was reused since the check was scheduled; re-check then.
    Recheck(Micros),
    /// Container already gone or currently busy/cold-starting.
    NotApplicable,
}

#[derive(Debug)]
pub struct Platform {
    pub cfg: PlatformConfig,
    containers: BTreeMap<ContainerId, Container>,
    next_cid: ContainerId,
    fcfs: VecDeque<RequestId>,
    rng: Rng,
    pub counters: Counters,
    pub log: ActivationLog,
    /// keep-alive durations (last activation → removal) of removed containers
    removed_keepalive: Vec<Micros>,
    /// total idle (warm-unused) time of removed containers
    removed_idle_total: Vec<Micros>,
    /// containers ever created (for conservation checks)
    pub spawned: u64,
    pub removed: u64,
}

impl Platform {
    pub fn new(cfg: PlatformConfig, seed: u64) -> Self {
        Platform {
            cfg,
            containers: BTreeMap::new(),
            next_cid: 1,
            fcfs: VecDeque::new(),
            rng: Rng::new(seed),
            counters: Counters::default(),
            log: ActivationLog::new(),
            removed_keepalive: Vec::new(),
            removed_idle_total: Vec::new(),
            spawned: 0,
            removed: 0,
        }
    }

    fn jitter(&mut self, base: Micros) -> Micros {
        let j = self.cfg.latency_jitter;
        if j <= 0.0 {
            return base;
        }
        let f = self.rng.range_f64(1.0 - j, 1.0 + j);
        (base as f64 * f).round().max(1.0) as Micros
    }

    // ---- gauges -------------------------------------------------------------

    pub fn total(&self) -> u32 {
        self.containers.len() as u32
    }
    pub fn idle_count(&self) -> u32 {
        self.containers.values().filter(|c| c.is_idle()).count() as u32
    }
    pub fn busy_count(&self) -> u32 {
        self.containers.values().filter(|c| c.is_busy()).count() as u32
    }
    pub fn warm_count(&self) -> u32 {
        self.containers.values().filter(|c| c.is_warm()).count() as u32
    }
    pub fn cold_starting_count(&self) -> u32 {
        self.containers.values().filter(|c| c.is_cold_starting()).count() as u32
    }
    pub fn fcfs_len(&self) -> usize {
        self.fcfs.len()
    }

    /// Idle containers unused for at least `min_idle` (IceBreaker's
    /// retention-aware release eligibility).
    pub fn idle_containers_older_than(&self, min_idle: Micros, now: Micros) -> u32 {
        self.containers
            .values()
            .filter(|c| c.idle_for(now) >= min_idle)
            .count() as u32
    }

    pub fn gauge(&self, now: Micros, queue_len: u32) -> GaugeSample {
        GaugeSample {
            time: now,
            warm: self.warm_count(),
            idle: self.idle_count(),
            busy: self.busy_count(),
            cold_starting: self.cold_starting_count(),
            queue_len,
        }
    }

    /// Remaining replica slots under the resource cap.
    pub fn headroom(&self) -> u32 {
        self.cfg.resource_cap().saturating_sub(self.total())
    }

    /// Recency (last_used) of the most-recently-used idle container — the
    /// fleet's warm-first placement compares nodes on this.
    pub fn mru_idle_recency(&self) -> Option<Micros> {
        self.containers
            .values()
            .filter(|c| c.is_idle())
            .map(|c| c.last_used)
            .max()
    }

    /// Best (highest) reclaim score among idle, log-safe containers — the
    /// fleet ranks nodes on this to keep Algorithm 2's global ordering.
    pub fn best_reclaim_score(&self, now: Micros) -> Option<f64> {
        self.containers
            .values()
            .filter(|c| c.is_idle() && self.log.all_completed(c.id))
            .map(|c| c.reclaim_score(now))
            .fold(None, |acc, s| Some(acc.map_or(s, |a: f64| a.max(s))))
    }

    /// Ready times of in-flight cold starts (the MPC's readyCold input).
    pub fn cold_ready_times(&self) -> Vec<Micros> {
        self.containers
            .values()
            .filter_map(|c| match c.state {
                crate::cluster::container::ContainerState::ColdStarting { ready_at, .. } => {
                    Some(ready_at)
                }
                _ => None,
            })
            .collect()
    }

    // ---- invocation path ----------------------------------------------------

    /// Invoke `req` now. OpenWhisk semantics: bind to a warm idle container
    /// if any (most-recently-used first, matching OpenWhisk's reuse
    /// affinity), otherwise cold start, otherwise FCFS-queue at capacity.
    pub fn invoke(&mut self, req: RequestId, now: Micros) -> InvokeOutcome {
        self.counters.invocations += 1;
        // MRU idle container: OpenWhisk reuses the warmest replica
        let pick = self
            .containers
            .values()
            .filter(|c| c.is_idle())
            .max_by_key(|c| (c.last_used, c.id))
            .map(|c| c.id);
        if let Some(cid) = pick {
            let done_at = now + self.jitter(self.cfg.l_warm);
            let c = self.containers.get_mut(&cid).unwrap();
            c.start_execution(req, now, done_at);
            self.log.record_assignment(cid, req);
            return InvokeOutcome::WarmStart { cid, done_at };
        }
        if self.total() < self.cfg.resource_cap() {
            let ready_at = now + self.jitter(self.cfg.l_cold);
            let cid = self.spawn(now, ready_at, Some(req));
            self.counters.cold_starts += 1;
            return InvokeOutcome::ColdStart { cid, ready_at };
        }
        self.counters.capacity_queued += 1;
        self.fcfs.push_back(req);
        InvokeOutcome::AtCapacity
    }

    fn spawn(&mut self, now: Micros, ready_at: Micros, pending: Option<RequestId>) -> ContainerId {
        let cid = self.next_cid;
        self.next_cid += 1;
        self.spawned += 1;
        self.containers
            .insert(cid, Container::cold(cid, now, ready_at, pending));
        cid
    }

    /// Controller prewarm (Listing 1, forcePrewarm=true): start one unbound
    /// cold container. Returns None (and counts the rejection) at capacity.
    pub fn prewarm_one(&mut self, now: Micros) -> Option<(ContainerId, Micros)> {
        if self.total() >= self.cfg.resource_cap() {
            self.counters.prewarms_rejected += 1;
            return None;
        }
        let ready_at = now + self.jitter(self.cfg.l_cold);
        let cid = self.spawn(now, ready_at, None);
        self.counters.prewarms_started += 1;
        Some((cid, ready_at))
    }

    /// Cold init finished (ContainerReady event). Binds the triggering
    /// request, else the FCFS backlog head, else goes idle.
    pub fn container_ready(&mut self, cid: ContainerId, now: Micros) -> ReadyOutcome {
        let pending = {
            let c = self
                .containers
                .get_mut(&cid)
                .expect("ready event for unknown container");
            c.finish_cold_start(now)
        };
        let next = pending.or_else(|| self.fcfs.pop_front());
        match next {
            Some(request) => {
                let done_at = now + self.jitter(self.cfg.l_warm);
                let c = self.containers.get_mut(&cid).unwrap();
                c.start_execution(request, now, done_at);
                self.log.record_assignment(cid, request);
                ReadyOutcome::Started { request, done_at }
            }
            None => ReadyOutcome::Idle,
        }
    }

    /// Execution finished (ExecDone event). Acks the activation and lets the
    /// FCFS backlog immediately reuse the now-idle container.
    pub fn exec_complete(&mut self, cid: ContainerId, now: Micros) -> CompleteOutcome {
        let completed = {
            let c = self
                .containers
                .get_mut(&cid)
                .expect("completion for unknown container");
            c.finish_execution(now)
        };
        self.log.record_ack(cid, completed, now);
        let next = self.fcfs.pop_front().map(|req| {
            let done_at = now + self.jitter(self.cfg.l_warm);
            let c = self.containers.get_mut(&cid).unwrap();
            c.start_execution(req, now, done_at);
            self.log.record_assignment(cid, req);
            (req, done_at)
        });
        CompleteOutcome { completed, next }
    }

    // ---- reclaim (Algorithm 2) ----------------------------------------------

    /// Reclaim up to `n` idle containers. Ranking by composite score
    /// (line 1), safety via the activation log (lines 5-6), then drain
    /// (lines 7-9). Returns the reclaimed ids.
    pub fn try_reclaim(&mut self, n: u32, now: Micros) -> Vec<ContainerId> {
        if n == 0 {
            return Vec::new();
        }
        // rankPods: idle candidates by descending reclaim score
        let mut candidates: Vec<(f64, ContainerId)> = self
            .containers
            .values()
            .filter(|c| c.is_idle())
            .map(|c| (c.reclaim_score(now), c.id))
            .collect();
        candidates.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let mut reclaimed = Vec::new();
        for (_, cid) in candidates.into_iter().take(n as usize) {
            // safety: the log must show completion for all assigned work
            if !self.log.all_completed(cid) {
                continue;
            }
            self.remove(cid, now);
            self.counters.reclaims += 1;
            reclaimed.push(cid);
        }
        reclaimed
    }

    /// Keep-alive check for one container (scheduled at last_used+keep_alive).
    pub fn keepalive_check(&mut self, cid: ContainerId, now: Micros) -> KeepAliveVerdict {
        let Some(c) = self.containers.get(&cid) else {
            return KeepAliveVerdict::NotApplicable;
        };
        if !c.is_idle() {
            return KeepAliveVerdict::NotApplicable;
        }
        let due = c.last_used + self.cfg.keep_alive;
        if now >= due {
            self.remove(cid, now);
            self.counters.keepalive_expiries += 1;
            KeepAliveVerdict::Expired
        } else {
            KeepAliveVerdict::Recheck(due)
        }
    }

    fn remove(&mut self, cid: ContainerId, now: Micros) {
        if let Some(c) = self.containers.remove(&cid) {
            debug_assert!(c.is_idle(), "removing non-idle container {cid}");
            // paper metric: duration from last activation to reclamation
            self.removed_keepalive.push(now.saturating_sub(c.last_used));
            self.removed_idle_total
                .push(c.idle_accum + c.idle_for(now));
            self.log.forget(cid);
            self.removed += 1;
        }
    }

    /// Node-crash semantics: every container is lost instantly; requests
    /// that were executing or waiting on a cold start, plus the FCFS
    /// backlog, are returned for redispatch elsewhere. Lost containers do
    /// not produce keep-alive records — the pod vanished, it was not
    /// drained gracefully.
    pub fn fail_all(&mut self, _now: Micros) -> Vec<RequestId> {
        let mut lost = Vec::new();
        for (cid, c) in std::mem::take(&mut self.containers) {
            match c.state {
                crate::cluster::container::ContainerState::ColdStarting {
                    pending: Some(req),
                    ..
                } => lost.push(req),
                crate::cluster::container::ContainerState::Busy { request, .. } => {
                    lost.push(request)
                }
                _ => {}
            }
            self.log.forget(cid);
            self.removed += 1;
        }
        lost.extend(self.fcfs.drain(..));
        lost
    }

    /// End-of-run accounting: treat still-alive idle containers as kept
    /// warm until `now`. Returns (keepalive durations, total idle times).
    pub fn finalize(&mut self, now: Micros) -> (Vec<Micros>, Vec<Micros>) {
        let ids: Vec<ContainerId> = self.containers.keys().copied().collect();
        for cid in ids {
            let c = &self.containers[&cid];
            if c.is_idle() {
                self.remove(cid, now);
            }
        }
        (
            std::mem::take(&mut self.removed_keepalive),
            std::mem::take(&mut self.removed_idle_total),
        )
    }

    /// Direct read of accumulated keep-alive records (without finalize).
    pub fn keepalive_records(&self) -> &[Micros] {
        &self.removed_keepalive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform() -> Platform {
        let cfg = PlatformConfig {
            latency_jitter: 0.0,
            ..Default::default()
        };
        Platform::new(cfg, 1)
    }

    #[test]
    fn cold_start_when_no_warm_container() {
        let mut p = platform();
        match p.invoke(1, 0) {
            InvokeOutcome::ColdStart { ready_at, .. } => assert_eq!(ready_at, 10_500_000),
            o => panic!("expected cold start, got {o:?}"),
        }
        assert_eq!(p.counters.cold_starts, 1);
        assert_eq!(p.cold_starting_count(), 1);
    }

    #[test]
    fn warm_reuse_after_completion() {
        let mut p = platform();
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke(1, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        assert_eq!(done_at, ready_at + 280_000);
        let out = p.exec_complete(cid, done_at);
        assert_eq!(out.completed, 1);
        // second request reuses the warm container
        match p.invoke(2, done_at + 1000) {
            InvokeOutcome::WarmStart { cid: c2, done_at: d2 } => {
                assert_eq!(c2, cid);
                assert_eq!(d2, done_at + 1000 + 280_000);
            }
            o => panic!("expected warm start, got {o:?}"),
        }
        assert_eq!(p.counters.cold_starts, 1);
    }

    #[test]
    fn capacity_bound_enforced_and_fcfs_drains() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        assert!(matches!(p.invoke(1, 0), InvokeOutcome::ColdStart { .. }));
        assert!(matches!(p.invoke(2, 0), InvokeOutcome::ColdStart { .. }));
        assert!(matches!(p.invoke(3, 0), InvokeOutcome::AtCapacity));
        assert_eq!(p.fcfs_len(), 1);
        // first container ready: serves its own bound request (req 1)
        let ReadyOutcome::Started { request, done_at } = p.container_ready(1, 10_500_000)
        else {
            panic!()
        };
        assert_eq!(request, 1);
        // completion hands the container to the FCFS backlog (req 3)
        let out = p.exec_complete(1, done_at);
        assert_eq!(out.completed, 1);
        assert_eq!(out.next.unwrap().0, 3);
        assert_eq!(p.fcfs_len(), 0);
    }

    #[test]
    fn prewarm_goes_idle_and_respects_capacity() {
        let cfg = PlatformConfig {
            max_containers: 1,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        assert!(p.prewarm_one(0).is_none());
        assert_eq!(p.counters.prewarms_rejected, 1);
        assert_eq!(p.container_ready(cid, ready_at), ReadyOutcome::Idle);
        assert_eq!(p.idle_count(), 1);
        // warm hit now
        assert!(matches!(
            p.invoke(1, ready_at + 10),
            InvokeOutcome::WarmStart { .. }
        ));
    }

    #[test]
    fn reclaim_only_idle_and_respects_log() {
        let mut p = platform();
        // two prewarmed idle containers + one busy
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        let InvokeOutcome::WarmStart { cid: busy, .. } = p.invoke(9, r2 + 1) else {
            panic!()
        };
        let got = p.try_reclaim(10, r2 + 2);
        assert_eq!(got.len(), 1); // only the remaining idle one
        assert!(!got.contains(&busy));
        assert_eq!(p.warm_count(), 1); // busy survives
    }

    #[test]
    fn keepalive_expiry_and_recheck() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        // too early: due at last_used + 600 s
        let due = ready_at + 600_000_000;
        match p.keepalive_check(cid, due - 5) {
            KeepAliveVerdict::Recheck(t) => assert_eq!(t, due),
            v => panic!("{v:?}"),
        }
        assert_eq!(p.keepalive_check(cid, due), KeepAliveVerdict::Expired);
        assert_eq!(p.total(), 0);
        assert_eq!(p.keepalive_check(cid, due), KeepAliveVerdict::NotApplicable);
    }

    #[test]
    fn keepalive_metric_records_last_use_to_removal() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        let reclaim_at = ready_at + 42_000_000;
        p.try_reclaim(1, reclaim_at);
        // last_used for a never-executed prewarm is its ready time
        assert_eq!(p.keepalive_records(), &[42_000_000]);
    }

    #[test]
    fn mru_reuse_prefers_warmest() {
        let mut p = platform();
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        // execute once on c2 so it is most recently used
        let InvokeOutcome::WarmStart { cid, done_at } = p.invoke(1, r2 + 1) else {
            panic!()
        };
        p.exec_complete(cid, done_at);
        let InvokeOutcome::WarmStart { cid: again, .. } = p.invoke(2, done_at + 5) else {
            panic!()
        };
        assert_eq!(again, cid);
        let _ = c1;
    }

    #[test]
    fn conservation_spawned_equals_removed_plus_live() {
        let mut p = platform();
        for i in 0..5 {
            let _ = p.invoke(i, i * 1000);
        }
        let ready: Vec<_> = p.cold_ready_times();
        assert_eq!(ready.len(), 5);
        assert_eq!(p.spawned, p.removed + p.total() as u64);
    }

    #[test]
    fn fail_all_returns_inflight_and_backlog() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        // req 1 cold-starting, req 2 executing, req 3 queued
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke(1, 0) else {
            panic!()
        };
        let InvokeOutcome::ColdStart { cid: c2, ready_at: r2 } = p.invoke(2, 0) else {
            panic!()
        };
        let _ = (cid, ready_at);
        p.container_ready(c2, r2);
        assert!(matches!(p.invoke(3, r2 + 1), InvokeOutcome::AtCapacity));
        let lost = p.fail_all(r2 + 2);
        assert_eq!(lost, vec![1, 2, 3]);
        assert_eq!(p.total(), 0);
        assert_eq!(p.fcfs_len(), 0);
        assert_eq!(p.spawned, p.removed); // conservation holds through a crash
        assert!(p.keepalive_records().is_empty()); // no graceful-drain records
    }

    #[test]
    fn mru_idle_recency_and_headroom() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        assert_eq!(p.headroom(), 2);
        assert_eq!(p.mru_idle_recency(), None);
        let (c1, r1) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        assert_eq!(p.headroom(), 1);
        assert_eq!(p.mru_idle_recency(), Some(r1));
        // an execution bumps recency
        let InvokeOutcome::WarmStart { cid, done_at } = p.invoke(1, r1 + 10) else {
            panic!()
        };
        assert_eq!(p.mru_idle_recency(), None); // busy, not idle
        p.exec_complete(cid, done_at);
        assert_eq!(p.mru_idle_recency(), Some(done_at));
    }

    #[test]
    fn best_reclaim_score_tracks_top_candidate() {
        let mut p = platform();
        assert!(p.best_reclaim_score(0).is_none());
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        let now = r2 + 5_000_000;
        // the peek equals the top candidate's score: c1 has been idle
        // longest (earlier ready), so it holds the max
        let peek = p.best_reclaim_score(now).unwrap();
        let expect = (now - r1) as f64 / 1e6;
        assert!((peek - expect).abs() < 1e-9, "peek {peek} vs {expect}");
        // busy containers are not candidates
        let InvokeOutcome::WarmStart { .. } = p.invoke(1, now) else {
            panic!()
        };
        let InvokeOutcome::WarmStart { .. } = p.invoke(2, now) else {
            panic!()
        };
        assert!(p.best_reclaim_score(now + 1).is_none());
        let _ = (c1, c2);
    }

    #[test]
    fn finalize_accounts_for_survivors() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        let (ka, idle) = p.finalize(ready_at + 1_000_000);
        assert_eq!(ka.len(), 1);
        assert_eq!(idle.len(), 1);
        assert_eq!(idle[0], 1_000_000);
        assert_eq!(p.total(), 0);
    }
}
