//! The serverless platform substrate — OpenWhisk-on-Kubernetes analog.
//!
//! Reproduces the scheduling semantics the paper's results depend on
//! (DESIGN.md substitution table): cold start on warm-miss, bounded replica
//! pool (64 = 32 vCPU / 0.5), FCFS backlog at capacity, keep-alive expiry,
//! and the reclaim-safety protocol of Algorithm 2 (activation-log check).
//!
//! **Multi-tenant semantics.** Every container is initialized for one
//! function and a warm container serves only that function. The platform
//! therefore keeps *per-function warm pools* inside one replica budget:
//! warm binding, the FCFS backlog, and keep-alive expiry all match on
//! the container's function, lifecycle latencies come from the
//! function's profile, and a per-function memory ledger bounds
//! heavyweight tenants. Cross-function contention appears as
//! *evictions*: at capacity, an idle container of another function is
//! drained (log-safe, Algorithm 2's ranking) to make room. With a
//! one-function registry all of this degenerates to the legacy
//! single-tenant behavior bit-for-bit.
//!
//! The platform is event-driven but owns no clock: methods take `now` and
//! return outcomes carrying future timestamps; the experiment runner turns
//! those into simulator events (or real timers in real-time mode).

use std::collections::{BTreeMap, VecDeque};

use crate::cluster::activation_log::ActivationLog;
use crate::cluster::container::{Container, ContainerId};
use crate::cluster::telemetry::{Counters, FnCounters, GaugeSample};
use crate::cluster::RequestId;
use crate::config::{Micros, PlatformConfig};
use crate::util::rng::Rng;
use crate::workload::tenant::{FunctionId, FunctionProfile, FunctionRegistry};

/// Result of an invocation request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InvokeOutcome {
    /// Bound to an idle warm container; execution completes at `done_at`.
    WarmStart { cid: ContainerId, done_at: Micros },
    /// Triggered a cold start; container ready (and execution starts) at
    /// `ready_at`.
    ColdStart { cid: ContainerId, ready_at: Micros },
    /// Replica pool exhausted; queued in the platform's FCFS backlog.
    AtCapacity,
}

/// Result of a cold container finishing initialization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadyOutcome {
    /// Went idle (controller prewarm with no waiting work).
    Idle,
    /// Immediately started executing `request`; completes at `done_at`.
    Started { request: RequestId, done_at: Micros },
    /// Multi-tenant recycle: the container matched none of the backlog,
    /// so it was evicted and a fresh cold container `cid` (ready at
    /// `ready_at`) was spawned bound to waiting request `req` — which
    /// therefore pays a cold start.
    Respawned {
        req: RequestId,
        cid: ContainerId,
        ready_at: Micros,
    },
}

/// Result of an execution completing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompleteOutcome {
    pub completed: RequestId,
    /// FCFS backlog request (same function) that immediately reused the
    /// container.
    pub next: Option<(RequestId, Micros)>,
    /// Multi-tenant recycle: the idle container was evicted and a cold
    /// container spawned bound to the waiting foreign-function request
    /// `(req, cid, ready_at)` (None in any single-tenant run). The
    /// runner schedules a Ready event for it and marks `req` cold.
    pub respawn: Option<(RequestId, ContainerId, Micros)>,
}

/// Keep-alive check verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeepAliveVerdict {
    /// Container removed (idle past the keep-alive window).
    Expired,
    /// Container was reused since the check was scheduled; re-check then.
    Recheck(Micros),
    /// Container already gone or currently busy/cold-starting.
    NotApplicable,
}

#[derive(Debug)]
pub struct Platform {
    pub cfg: PlatformConfig,
    /// The deployed function set; profiles drive per-function lifecycle
    /// latencies, keep-alive windows, and memory footprints.
    registry: FunctionRegistry,
    containers: BTreeMap<ContainerId, Container>,
    next_cid: ContainerId,
    fcfs: VecDeque<(RequestId, FunctionId)>,
    rng: Rng,
    pub counters: Counters,
    /// Per-function activation accounting (multi-tenant telemetry).
    fn_counters: BTreeMap<FunctionId, FnCounters>,
    pub log: ActivationLog,
    /// keep-alive durations (last activation → removal) of removed containers
    removed_keepalive: Vec<Micros>,
    /// total idle (warm-unused) time of removed containers
    removed_idle_total: Vec<Micros>,
    /// memory claimed by live containers (MiB), per-function footprints
    mem_used: u32,
    /// containers ever created (for conservation checks)
    pub spawned: u64,
    pub removed: u64,
}

impl Platform {
    /// Single-tenant platform: a one-function registry mirroring `cfg`.
    pub fn new(cfg: PlatformConfig, seed: u64) -> Self {
        let registry = FunctionRegistry::single(&cfg);
        Self::with_registry(cfg, registry, seed)
    }

    /// Multi-tenant platform serving `registry`'s function set.
    pub fn with_registry(cfg: PlatformConfig, registry: FunctionRegistry, seed: u64) -> Self {
        Platform {
            cfg,
            registry,
            containers: BTreeMap::new(),
            next_cid: 1,
            fcfs: VecDeque::new(),
            rng: Rng::new(seed),
            counters: Counters::default(),
            fn_counters: BTreeMap::new(),
            log: ActivationLog::new(),
            removed_keepalive: Vec::new(),
            removed_idle_total: Vec::new(),
            mem_used: 0,
            spawned: 0,
            removed: 0,
        }
    }

    /// Profile of one function in the registry.
    pub fn profile(&self, func: FunctionId) -> &FunctionProfile {
        self.registry.get(func)
    }

    pub fn registry(&self) -> &FunctionRegistry {
        &self.registry
    }

    fn fn_counters_mut(&mut self, func: FunctionId) -> &mut FnCounters {
        self.fn_counters.entry(func).or_default()
    }

    /// Per-function activation counters observed so far.
    pub fn fn_counters(&self) -> &BTreeMap<FunctionId, FnCounters> {
        &self.fn_counters
    }

    fn jitter(&mut self, base: Micros) -> Micros {
        let j = self.cfg.latency_jitter;
        if j <= 0.0 {
            return base;
        }
        let f = self.rng.range_f64(1.0 - j, 1.0 + j);
        (base as f64 * f).round().max(1.0) as Micros
    }

    // ---- gauges -------------------------------------------------------------

    pub fn total(&self) -> u32 {
        self.containers.len() as u32
    }
    pub fn idle_count(&self) -> u32 {
        self.containers.values().filter(|c| c.is_idle()).count() as u32
    }
    pub fn busy_count(&self) -> u32 {
        self.containers.values().filter(|c| c.is_busy()).count() as u32
    }
    pub fn warm_count(&self) -> u32 {
        self.containers.values().filter(|c| c.is_warm()).count() as u32
    }
    pub fn cold_starting_count(&self) -> u32 {
        self.containers.values().filter(|c| c.is_cold_starting()).count() as u32
    }
    pub fn fcfs_len(&self) -> usize {
        self.fcfs.len()
    }

    /// Idle containers unused for at least `min_idle` (IceBreaker's
    /// retention-aware release eligibility).
    pub fn idle_containers_older_than(&self, min_idle: Micros, now: Micros) -> u32 {
        self.containers
            .values()
            .filter(|c| c.idle_for(now) >= min_idle)
            .count() as u32
    }

    pub fn gauge(&self, now: Micros, queue_len: u32) -> GaugeSample {
        GaugeSample {
            time: now,
            warm: self.warm_count(),
            idle: self.idle_count(),
            busy: self.busy_count(),
            cold_starting: self.cold_starting_count(),
            queue_len,
        }
    }

    /// Remaining replica slots under the resource cap.
    pub fn headroom(&self) -> u32 {
        self.cfg.resource_cap().saturating_sub(self.total())
    }

    /// Memory claimed by live containers (MiB).
    pub fn mem_used_mib(&self) -> u32 {
        self.mem_used
    }

    /// Whether a new container of `func` fits the node right now: a free
    /// replica slot *and* room in the memory ledger for the function's
    /// footprint. With uniform paper-profile functions the memory term
    /// never binds (64 × 256 MiB ≪ 48 GiB), so this reduces to the
    /// legacy slot check.
    pub fn can_admit(&self, func: FunctionId) -> bool {
        self.total() < self.cfg.resource_cap()
            && self.mem_used + self.registry.get(func).mem_mib <= self.cfg.node_mem_mib
    }

    /// Idle warm containers of one function (the per-function warm pool).
    pub fn idle_count_for(&self, func: FunctionId) -> u32 {
        self.containers
            .values()
            .filter(|c| c.is_idle() && c.func == func)
            .count() as u32
    }

    /// Accumulate idle-container counts per function into `out` (index =
    /// [`FunctionId`]; functions beyond `out.len()` are ignored) — one
    /// container pass instead of one per function for the dispatcher's
    /// drain snapshot.
    pub fn idle_by_function_into(&self, out: &mut [u32]) {
        for c in self.containers.values() {
            if c.is_idle() {
                if let Some(slot) = out.get_mut(c.func as usize) {
                    *slot += 1;
                }
            }
        }
    }

    /// Warm (idle + busy) containers of one function.
    pub fn warm_count_for(&self, func: FunctionId) -> u32 {
        self.containers
            .values()
            .filter(|c| c.is_warm() && c.func == func)
            .count() as u32
    }

    /// In-flight cold starts of one function.
    pub fn cold_starting_for(&self, func: FunctionId) -> u32 {
        self.containers
            .values()
            .filter(|c| c.is_cold_starting() && c.func == func)
            .count() as u32
    }

    /// Recency (last_used) of the most-recently-used idle container — the
    /// fleet's warm-first placement compares nodes on this.
    pub fn mru_idle_recency(&self) -> Option<Micros> {
        self.containers
            .values()
            .filter(|c| c.is_idle())
            .map(|c| c.last_used)
            .max()
    }

    /// Function-scoped [`Platform::mru_idle_recency`]: the fleet's
    /// warm-*for-this-function*-first placement compares nodes on this.
    pub fn mru_idle_recency_for(&self, func: FunctionId) -> Option<Micros> {
        self.containers
            .values()
            .filter(|c| c.is_idle() && c.func == func)
            .map(|c| c.last_used)
            .max()
    }

    /// Best (highest) reclaim score among idle, log-safe containers — the
    /// fleet ranks nodes on this to keep Algorithm 2's global ordering.
    pub fn best_reclaim_score(&self, now: Micros) -> Option<f64> {
        self.containers
            .values()
            .filter(|c| c.is_idle() && self.log.all_completed(c.id))
            .map(|c| c.reclaim_score(now))
            .fold(None, |acc, s| Some(acc.map_or(s, |a: f64| a.max(s))))
    }

    /// Ready times of in-flight cold starts (the MPC's readyCold input).
    pub fn cold_ready_times(&self) -> Vec<Micros> {
        self.containers
            .values()
            .filter_map(|c| match c.state {
                crate::cluster::container::ContainerState::ColdStarting { ready_at, .. } => {
                    Some(ready_at)
                }
                _ => None,
            })
            .collect()
    }

    /// Ready times of in-flight cold starts of one function.
    pub fn cold_ready_times_for(&self, func: FunctionId) -> Vec<Micros> {
        self.containers
            .values()
            .filter(|c| c.func == func)
            .filter_map(|c| match c.state {
                crate::cluster::container::ContainerState::ColdStarting { ready_at, .. } => {
                    Some(ready_at)
                }
                _ => None,
            })
            .collect()
    }

    // ---- invocation path ----------------------------------------------------

    /// Invoke `req` now (single-tenant shorthand for function 0).
    pub fn invoke(&mut self, req: RequestId, now: Micros) -> InvokeOutcome {
        self.invoke_for(req, 0, now)
    }

    /// Invoke `req` for `func` now. OpenWhisk semantics, function-aware:
    /// bind to an idle warm container *of this function* if any
    /// (most-recently-used first, matching OpenWhisk's reuse affinity),
    /// otherwise cold start; with the pool full of other functions'
    /// idle containers, evict the best log-safe candidate (Algorithm 2's
    /// ranking) to make room; otherwise FCFS-queue at capacity.
    pub fn invoke_for(&mut self, req: RequestId, func: FunctionId, now: Micros) -> InvokeOutcome {
        self.counters.invocations += 1;
        self.fn_counters_mut(func).invocations += 1;
        // MRU idle container of this function: OpenWhisk reuses the
        // warmest matching replica
        let pick = self
            .containers
            .values()
            .filter(|c| c.is_idle() && c.func == func)
            .max_by_key(|c| (c.last_used, c.id))
            .map(|c| c.id);
        if let Some(cid) = pick {
            let l_warm = self.profile(func).l_warm;
            let done_at = now + self.jitter(l_warm);
            let c = self.containers.get_mut(&cid).unwrap();
            c.start_execution(req, now, done_at);
            self.log.record_assignment(cid, req);
            self.fn_counters_mut(func).warm_starts += 1;
            return InvokeOutcome::WarmStart { cid, done_at };
        }
        if self.can_admit(func) || self.evict_for(func, now) {
            let l_cold = self.profile(func).l_cold;
            let ready_at = now + self.jitter(l_cold);
            let cid = self.spawn(func, now, ready_at, Some(req));
            self.counters.cold_starts += 1;
            self.fn_counters_mut(func).cold_starts += 1;
            return InvokeOutcome::ColdStart { cid, ready_at };
        }
        self.counters.capacity_queued += 1;
        self.fcfs.push_back((req, func));
        InvokeOutcome::AtCapacity
    }

    /// Evict idle containers of *other* functions (best reclaim score
    /// first, log-safe only) until a container of `func` fits. Returns
    /// whether room was made. Never fires in a single-tenant run: any
    /// idle container there would have warm-served the request instead.
    fn evict_for(&mut self, func: FunctionId, now: Micros) -> bool {
        loop {
            if self.can_admit(func) {
                return true;
            }
            let victim = self
                .containers
                .values()
                .filter(|c| c.is_idle() && c.func != func && self.log.all_completed(c.id))
                .map(|c| (c.reclaim_score(now), c.id))
                .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(b.1.cmp(&a.1)))
                .map(|(_, id)| id);
            let Some(vid) = victim else { return false };
            let vfunc = self.containers[&vid].func;
            self.remove(vid, now);
            self.counters.evictions += 1;
            self.fn_counters_mut(vfunc).evictions += 1;
        }
    }

    fn spawn(
        &mut self,
        func: FunctionId,
        now: Micros,
        ready_at: Micros,
        pending: Option<RequestId>,
    ) -> ContainerId {
        let cid = self.next_cid;
        self.next_cid += 1;
        self.spawned += 1;
        self.mem_used += self.registry.get(func).mem_mib;
        self.containers
            .insert(cid, Container::cold(cid, func, now, ready_at, pending));
        cid
    }

    /// Controller prewarm (Listing 1, forcePrewarm=true) for function 0.
    pub fn prewarm_one(&mut self, now: Micros) -> Option<(ContainerId, Micros)> {
        self.prewarm_for(0, now)
    }

    /// Controller prewarm for one function: start one unbound cold
    /// container of `func`. Returns None (and counts the rejection) when
    /// the function does not fit; prewarms never evict live warm state.
    pub fn prewarm_for(&mut self, func: FunctionId, now: Micros) -> Option<(ContainerId, Micros)> {
        if !self.can_admit(func) {
            self.counters.prewarms_rejected += 1;
            return None;
        }
        let l_cold = self.profile(func).l_cold;
        let ready_at = now + self.jitter(l_cold);
        let cid = self.spawn(func, now, ready_at, None);
        self.counters.prewarms_started += 1;
        Some((cid, ready_at))
    }

    /// Pop the oldest FCFS backlog request of `func` (FIFO within the
    /// function; foreign requests keep their positions).
    fn pop_fcfs(&mut self, func: FunctionId) -> Option<RequestId> {
        let idx = self.fcfs.iter().position(|&(_, f)| f == func)?;
        self.fcfs.remove(idx).map(|(req, _)| req)
    }

    /// Cold init finished (ContainerReady event). Binds the triggering
    /// request, else the oldest same-function backlog request, else goes
    /// idle — unless the backlog holds only foreign functions, in which
    /// case the container is recycled for the oldest waiter (see
    /// [`ReadyOutcome::Respawned`]): without it a pool full of
    /// wrong-function prewarms could strand the backlog forever.
    pub fn container_ready(&mut self, cid: ContainerId, now: Micros) -> ReadyOutcome {
        let (pending, func) = {
            let c = self
                .containers
                .get_mut(&cid)
                .expect("ready event for unknown container");
            let f = c.func;
            (c.finish_cold_start(now), f)
        };
        let next = pending.or_else(|| self.pop_fcfs(func));
        match next {
            Some(request) => {
                let l_warm = self.profile(func).l_warm;
                let done_at = now + self.jitter(l_warm);
                let c = self.containers.get_mut(&cid).unwrap();
                c.start_execution(request, now, done_at);
                self.log.record_assignment(cid, request);
                ReadyOutcome::Started { request, done_at }
            }
            None => {
                if !self.fcfs.is_empty() {
                    if let Some((req, ncid, ready_at)) = self.respawn_for_backlog(cid, now) {
                        return ReadyOutcome::Respawned {
                            req,
                            cid: ncid,
                            ready_at,
                        };
                    }
                }
                ReadyOutcome::Idle
            }
        }
    }

    /// Execution finished (ExecDone event). Acks the activation and lets
    /// the oldest same-function backlog request immediately reuse the
    /// container; a backlog of only foreign functions recycles it.
    pub fn exec_complete(&mut self, cid: ContainerId, now: Micros) -> CompleteOutcome {
        let (completed, func) = {
            let c = self
                .containers
                .get_mut(&cid)
                .expect("completion for unknown container");
            let f = c.func;
            (c.finish_execution(now), f)
        };
        self.log.record_ack(cid, completed, now);
        let next = self.pop_fcfs(func).map(|req| {
            let l_warm = self.profile(func).l_warm;
            let done_at = now + self.jitter(l_warm);
            let c = self.containers.get_mut(&cid).unwrap();
            c.start_execution(req, now, done_at);
            self.log.record_assignment(cid, req);
            (req, done_at)
        });
        let respawn = if next.is_none() && !self.fcfs.is_empty() {
            self.respawn_for_backlog(cid, now)
        } else {
            None
        };
        CompleteOutcome {
            completed,
            next,
            respawn,
        }
    }

    /// The FCFS backlog holds only requests this idle container cannot
    /// serve (other functions). Evict it and cold-start a replacement
    /// bound to the oldest waiter whose swap fits the memory ledger
    /// (skipping an oversized head so it cannot starve feasible waiters
    /// behind it), provided the activation log clears the container for
    /// removal. Returns `(waiter, new container, ready time)`.
    fn respawn_for_backlog(
        &mut self,
        cid: ContainerId,
        now: Micros,
    ) -> Option<(RequestId, ContainerId, Micros)> {
        let (vfunc, freed) = {
            let c = self.containers.get(&cid)?;
            if !c.is_idle() || !self.log.all_completed(cid) {
                return None;
            }
            (c.func, self.registry.get(c.func).mem_mib)
        };
        let budget = self.cfg.node_mem_mib;
        let after_evict = self.mem_used.saturating_sub(freed);
        let idx = self
            .fcfs
            .iter()
            .position(|&(_, f)| after_evict + self.registry.get(f).mem_mib <= budget)?;
        let (req, func) = self.fcfs[idx];
        self.remove(cid, now);
        self.counters.evictions += 1;
        self.fn_counters_mut(vfunc).evictions += 1;
        self.fcfs.remove(idx);
        let l_cold = self.profile(func).l_cold;
        let ready_at = now + self.jitter(l_cold);
        let ncid = self.spawn(func, now, ready_at, Some(req));
        self.counters.cold_starts += 1;
        self.fn_counters_mut(func).cold_starts += 1;
        Some((req, ncid, ready_at))
    }

    // ---- reclaim (Algorithm 2) ----------------------------------------------

    /// Reclaim up to `n` idle containers. Ranking by composite score
    /// (line 1), safety via the activation log (lines 5-6), then drain
    /// (lines 7-9). Returns the reclaimed ids.
    pub fn try_reclaim(&mut self, n: u32, now: Micros) -> Vec<ContainerId> {
        if n == 0 {
            return Vec::new();
        }
        // rankPods: idle candidates by descending reclaim score
        let mut candidates: Vec<(f64, ContainerId)> = self
            .containers
            .values()
            .filter(|c| c.is_idle())
            .map(|c| (c.reclaim_score(now), c.id))
            .collect();
        candidates.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let mut reclaimed = Vec::new();
        for (_, cid) in candidates.into_iter().take(n as usize) {
            // safety: the log must show completion for all assigned work
            if !self.log.all_completed(cid) {
                continue;
            }
            self.remove(cid, now);
            self.counters.reclaims += 1;
            reclaimed.push(cid);
        }
        reclaimed
    }

    /// Keep-alive window of a live container (its function's profile) —
    /// the runner's scheduling hint for the KeepAlive event.
    pub fn keepalive_of(&self, cid: ContainerId) -> Option<Micros> {
        self.containers
            .get(&cid)
            .map(|c| self.registry.get(c.func).keep_alive)
    }

    /// Keep-alive check for one container, scheduled at `last_used +
    /// keep_alive` of the container's function.
    pub fn keepalive_check(&mut self, cid: ContainerId, now: Micros) -> KeepAliveVerdict {
        let Some(c) = self.containers.get(&cid) else {
            return KeepAliveVerdict::NotApplicable;
        };
        if !c.is_idle() {
            return KeepAliveVerdict::NotApplicable;
        }
        let due = c.last_used + self.registry.get(c.func).keep_alive;
        if now >= due {
            self.remove(cid, now);
            self.counters.keepalive_expiries += 1;
            KeepAliveVerdict::Expired
        } else {
            KeepAliveVerdict::Recheck(due)
        }
    }

    fn remove(&mut self, cid: ContainerId, now: Micros) {
        if let Some(c) = self.containers.remove(&cid) {
            debug_assert!(c.is_idle(), "removing non-idle container {cid}");
            // paper metric: duration from last activation to reclamation
            self.removed_keepalive.push(now.saturating_sub(c.last_used));
            self.removed_idle_total
                .push(c.idle_accum + c.idle_for(now));
            self.mem_used = self
                .mem_used
                .saturating_sub(self.registry.get(c.func).mem_mib);
            self.log.forget(cid);
            self.removed += 1;
        }
    }

    /// Node-crash semantics: every container is lost instantly; requests
    /// that were executing or waiting on a cold start, plus the FCFS
    /// backlog, are returned for redispatch elsewhere. Lost containers do
    /// not produce keep-alive records — the pod vanished, it was not
    /// drained gracefully.
    pub fn fail_all(&mut self, _now: Micros) -> Vec<RequestId> {
        let mut lost = Vec::new();
        for (cid, c) in std::mem::take(&mut self.containers) {
            match c.state {
                crate::cluster::container::ContainerState::ColdStarting {
                    pending: Some(req),
                    ..
                } => lost.push(req),
                crate::cluster::container::ContainerState::Busy { request, .. } => {
                    lost.push(request)
                }
                _ => {}
            }
            self.log.forget(cid);
            self.removed += 1;
        }
        self.mem_used = 0;
        lost.extend(self.fcfs.drain(..).map(|(req, _)| req));
        lost
    }

    /// End-of-run accounting: treat still-alive idle containers as kept
    /// warm until `now`. Returns (keepalive durations, total idle times).
    pub fn finalize(&mut self, now: Micros) -> (Vec<Micros>, Vec<Micros>) {
        let ids: Vec<ContainerId> = self.containers.keys().copied().collect();
        for cid in ids {
            let c = &self.containers[&cid];
            if c.is_idle() {
                self.remove(cid, now);
            }
        }
        (
            std::mem::take(&mut self.removed_keepalive),
            std::mem::take(&mut self.removed_idle_total),
        )
    }

    /// Direct read of accumulated keep-alive records (without finalize).
    pub fn keepalive_records(&self) -> &[Micros] {
        &self.removed_keepalive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform() -> Platform {
        let cfg = PlatformConfig {
            latency_jitter: 0.0,
            ..Default::default()
        };
        Platform::new(cfg, 1)
    }

    #[test]
    fn cold_start_when_no_warm_container() {
        let mut p = platform();
        match p.invoke(1, 0) {
            InvokeOutcome::ColdStart { ready_at, .. } => assert_eq!(ready_at, 10_500_000),
            o => panic!("expected cold start, got {o:?}"),
        }
        assert_eq!(p.counters.cold_starts, 1);
        assert_eq!(p.cold_starting_count(), 1);
    }

    #[test]
    fn warm_reuse_after_completion() {
        let mut p = platform();
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke(1, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        assert_eq!(done_at, ready_at + 280_000);
        let out = p.exec_complete(cid, done_at);
        assert_eq!(out.completed, 1);
        // second request reuses the warm container
        match p.invoke(2, done_at + 1000) {
            InvokeOutcome::WarmStart { cid: c2, done_at: d2 } => {
                assert_eq!(c2, cid);
                assert_eq!(d2, done_at + 1000 + 280_000);
            }
            o => panic!("expected warm start, got {o:?}"),
        }
        assert_eq!(p.counters.cold_starts, 1);
    }

    #[test]
    fn capacity_bound_enforced_and_fcfs_drains() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        assert!(matches!(p.invoke(1, 0), InvokeOutcome::ColdStart { .. }));
        assert!(matches!(p.invoke(2, 0), InvokeOutcome::ColdStart { .. }));
        assert!(matches!(p.invoke(3, 0), InvokeOutcome::AtCapacity));
        assert_eq!(p.fcfs_len(), 1);
        // first container ready: serves its own bound request (req 1)
        let ReadyOutcome::Started { request, done_at } = p.container_ready(1, 10_500_000)
        else {
            panic!()
        };
        assert_eq!(request, 1);
        // completion hands the container to the FCFS backlog (req 3)
        let out = p.exec_complete(1, done_at);
        assert_eq!(out.completed, 1);
        assert_eq!(out.next.unwrap().0, 3);
        assert_eq!(p.fcfs_len(), 0);
    }

    #[test]
    fn prewarm_goes_idle_and_respects_capacity() {
        let cfg = PlatformConfig {
            max_containers: 1,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        assert!(p.prewarm_one(0).is_none());
        assert_eq!(p.counters.prewarms_rejected, 1);
        assert_eq!(p.container_ready(cid, ready_at), ReadyOutcome::Idle);
        assert_eq!(p.idle_count(), 1);
        // warm hit now
        assert!(matches!(
            p.invoke(1, ready_at + 10),
            InvokeOutcome::WarmStart { .. }
        ));
    }

    #[test]
    fn reclaim_only_idle_and_respects_log() {
        let mut p = platform();
        // two prewarmed idle containers + one busy
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        let InvokeOutcome::WarmStart { cid: busy, .. } = p.invoke(9, r2 + 1) else {
            panic!()
        };
        let got = p.try_reclaim(10, r2 + 2);
        assert_eq!(got.len(), 1); // only the remaining idle one
        assert!(!got.contains(&busy));
        assert_eq!(p.warm_count(), 1); // busy survives
    }

    #[test]
    fn keepalive_expiry_and_recheck() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        // too early: due at last_used + 600 s
        let due = ready_at + 600_000_000;
        match p.keepalive_check(cid, due - 5) {
            KeepAliveVerdict::Recheck(t) => assert_eq!(t, due),
            v => panic!("{v:?}"),
        }
        assert_eq!(p.keepalive_check(cid, due), KeepAliveVerdict::Expired);
        assert_eq!(p.total(), 0);
        assert_eq!(p.keepalive_check(cid, due), KeepAliveVerdict::NotApplicable);
    }

    #[test]
    fn keepalive_metric_records_last_use_to_removal() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        let reclaim_at = ready_at + 42_000_000;
        p.try_reclaim(1, reclaim_at);
        // last_used for a never-executed prewarm is its ready time
        assert_eq!(p.keepalive_records(), &[42_000_000]);
    }

    #[test]
    fn mru_reuse_prefers_warmest() {
        let mut p = platform();
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        // execute once on c2 so it is most recently used
        let InvokeOutcome::WarmStart { cid, done_at } = p.invoke(1, r2 + 1) else {
            panic!()
        };
        p.exec_complete(cid, done_at);
        let InvokeOutcome::WarmStart { cid: again, .. } = p.invoke(2, done_at + 5) else {
            panic!()
        };
        assert_eq!(again, cid);
        let _ = c1;
    }

    #[test]
    fn conservation_spawned_equals_removed_plus_live() {
        let mut p = platform();
        for i in 0..5 {
            let _ = p.invoke(i, i * 1000);
        }
        let ready: Vec<_> = p.cold_ready_times();
        assert_eq!(ready.len(), 5);
        assert_eq!(p.spawned, p.removed + p.total() as u64);
    }

    #[test]
    fn fail_all_returns_inflight_and_backlog() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        // req 1 cold-starting, req 2 executing, req 3 queued
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke(1, 0) else {
            panic!()
        };
        let InvokeOutcome::ColdStart { cid: c2, ready_at: r2 } = p.invoke(2, 0) else {
            panic!()
        };
        let _ = (cid, ready_at);
        p.container_ready(c2, r2);
        assert!(matches!(p.invoke(3, r2 + 1), InvokeOutcome::AtCapacity));
        let lost = p.fail_all(r2 + 2);
        assert_eq!(lost, vec![1, 2, 3]);
        assert_eq!(p.total(), 0);
        assert_eq!(p.fcfs_len(), 0);
        assert_eq!(p.spawned, p.removed); // conservation holds through a crash
        assert!(p.keepalive_records().is_empty()); // no graceful-drain records
    }

    #[test]
    fn mru_idle_recency_and_headroom() {
        let cfg = PlatformConfig {
            max_containers: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p = Platform::new(cfg, 1);
        assert_eq!(p.headroom(), 2);
        assert_eq!(p.mru_idle_recency(), None);
        let (c1, r1) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        assert_eq!(p.headroom(), 1);
        assert_eq!(p.mru_idle_recency(), Some(r1));
        // an execution bumps recency
        let InvokeOutcome::WarmStart { cid, done_at } = p.invoke(1, r1 + 10) else {
            panic!()
        };
        assert_eq!(p.mru_idle_recency(), None); // busy, not idle
        p.exec_complete(cid, done_at);
        assert_eq!(p.mru_idle_recency(), Some(done_at));
    }

    #[test]
    fn best_reclaim_score_tracks_top_candidate() {
        let mut p = platform();
        assert!(p.best_reclaim_score(0).is_none());
        let (c1, r1) = p.prewarm_one(0).unwrap();
        let (c2, r2) = p.prewarm_one(0).unwrap();
        p.container_ready(c1, r1);
        p.container_ready(c2, r2);
        let now = r2 + 5_000_000;
        // the peek equals the top candidate's score: c1 has been idle
        // longest (earlier ready), so it holds the max
        let peek = p.best_reclaim_score(now).unwrap();
        let expect = (now - r1) as f64 / 1e6;
        assert!((peek - expect).abs() < 1e-9, "peek {peek} vs {expect}");
        // busy containers are not candidates
        let InvokeOutcome::WarmStart { .. } = p.invoke(1, now) else {
            panic!()
        };
        let InvokeOutcome::WarmStart { .. } = p.invoke(2, now) else {
            panic!()
        };
        assert!(p.best_reclaim_score(now + 1).is_none());
        let _ = (c1, c2);
    }

    #[test]
    fn finalize_accounts_for_survivors() {
        let mut p = platform();
        let (cid, ready_at) = p.prewarm_one(0).unwrap();
        p.container_ready(cid, ready_at);
        let (ka, idle) = p.finalize(ready_at + 1_000_000);
        assert_eq!(ka.len(), 1);
        assert_eq!(idle.len(), 1);
        assert_eq!(idle[0], 1_000_000);
        assert_eq!(p.total(), 0);
    }

    // ---- multi-tenant semantics ---------------------------------------------

    use crate::workload::tenant::{FunctionProfile, FunctionRegistry};

    /// Two-function registry: fn-0 = paper profile, fn-1 = a faster
    /// lightweight function with a short keep-alive.
    fn two_tenant_platform(max_containers: u32) -> Platform {
        let cfg = PlatformConfig {
            max_containers,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p0 = FunctionRegistry::single(&cfg).get(0).clone();
        p0.share = 0.7;
        let registry = FunctionRegistry::new(vec![
            p0,
            FunctionProfile {
                id: 1,
                name: "fn-1".into(),
                l_warm: 100_000,           // 100 ms
                l_cold: 2_000_000,         // 2 s
                keep_alive: 60_000_000,    // 1 min
                mem_mib: 128,
                share: 0.3,
            },
        ]);
        Platform::with_registry(cfg, registry, 1)
    }

    #[test]
    fn warm_pools_are_per_function() {
        let mut p = two_tenant_platform(64);
        // warm up a fn-0 container
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke_for(1, 0, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        p.exec_complete(cid, done_at);
        assert_eq!(p.idle_count_for(0), 1);
        assert_eq!(p.idle_count_for(1), 0);
        // fn-1 cannot ride fn-0's warm container: it cold-starts with its
        // own (shorter) init latency
        match p.invoke_for(2, 1, done_at + 10) {
            InvokeOutcome::ColdStart { ready_at, .. } => {
                assert_eq!(ready_at, done_at + 10 + 2_000_000);
            }
            o => panic!("expected fn-1 cold start, got {o:?}"),
        }
        // fn-0 still warm-binds with its own exec latency
        match p.invoke_for(3, 0, done_at + 20) {
            InvokeOutcome::WarmStart { done_at: d, .. } => {
                assert_eq!(d, done_at + 20 + 280_000);
            }
            o => panic!("expected fn-0 warm start, got {o:?}"),
        }
        let fc = p.fn_counters();
        assert_eq!(fc[&0].cold_starts, 1);
        assert_eq!(fc[&0].warm_starts, 1);
        assert_eq!(fc[&1].cold_starts, 1);
    }

    #[test]
    fn eviction_makes_room_at_capacity() {
        let mut p = two_tenant_platform(1);
        // fill the single slot with an idle fn-0 container
        let (cid, ready_at) = p.prewarm_for(0, 0).unwrap();
        assert_eq!(p.container_ready(cid, ready_at), ReadyOutcome::Idle);
        // fn-1 arrives: the idle foreign container is evicted, not queued
        match p.invoke_for(7, 1, ready_at + 10) {
            InvokeOutcome::ColdStart { .. } => {}
            o => panic!("expected eviction + cold start, got {o:?}"),
        }
        assert_eq!(p.counters.evictions, 1);
        assert_eq!(p.fn_counters()[&0].evictions, 1);
        assert_eq!(p.total(), 1);
        assert_eq!(p.cold_starting_for(1), 1);
        assert_eq!(p.spawned, p.removed + p.total() as u64);
    }

    #[test]
    fn backlog_respawn_serves_foreign_function() {
        let mut p = two_tenant_platform(1);
        // fn-0 busy on the only slot; fn-1 queues at capacity
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke_for(1, 0, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        assert!(matches!(p.invoke_for(2, 1, ready_at + 1), InvokeOutcome::AtCapacity));
        assert_eq!(p.fcfs_len(), 1);
        // completion cannot warm-reuse (wrong function): the container is
        // recycled into a cold start bound to the fn-1 waiter
        let out = p.exec_complete(cid, done_at);
        assert_eq!(out.completed, 1);
        assert!(out.next.is_none());
        let (wreq, ncid, nready) = out.respawn.expect("respawn for the foreign waiter");
        assert_eq!(wreq, 2);
        assert_eq!(nready, done_at + 2_000_000);
        assert_eq!(p.fcfs_len(), 0);
        // the respawned container starts fn-1's request when ready
        let ReadyOutcome::Started { request, .. } = p.container_ready(ncid, nready) else {
            panic!()
        };
        assert_eq!(request, 2);
        assert_eq!(p.counters.evictions, 1);
    }

    #[test]
    fn ready_prewarm_recycles_for_stranded_foreign_backlog() {
        let mut p = two_tenant_platform(1);
        // an unbound fn-0 prewarm occupies the slot while fn-1 queues
        let (cid, ready_at) = p.prewarm_for(0, 0).unwrap();
        assert!(matches!(p.invoke_for(9, 1, 1), InvokeOutcome::AtCapacity));
        // when the prewarm lands there is no fn-0 work — without the
        // recycle the fn-1 request would be stranded forever
        let ReadyOutcome::Respawned { req, cid: ncid, ready_at: nready } =
            p.container_ready(cid, ready_at)
        else {
            panic!("expected recycle for the stranded waiter")
        };
        assert_eq!(req, 9);
        let ReadyOutcome::Started { request, .. } = p.container_ready(ncid, nready) else {
            panic!()
        };
        assert_eq!(request, 9);
        assert_eq!(p.fcfs_len(), 0);
    }

    #[test]
    fn fcfs_is_fifo_within_function() {
        let mut p = two_tenant_platform(1);
        // fn-0 busy; backlog = [fn-1 req 2, fn-0 req 3]
        let InvokeOutcome::ColdStart { cid, ready_at } = p.invoke_for(1, 0, 0) else {
            panic!()
        };
        let ReadyOutcome::Started { done_at, .. } = p.container_ready(cid, ready_at) else {
            panic!()
        };
        assert!(matches!(p.invoke_for(2, 1, ready_at + 1), InvokeOutcome::AtCapacity));
        assert!(matches!(p.invoke_for(3, 0, ready_at + 2), InvokeOutcome::AtCapacity));
        // the completing fn-0 container skips the older fn-1 waiter and
        // warm-serves its own function's request
        let out = p.exec_complete(cid, done_at);
        assert_eq!(out.next.unwrap().0, 3);
        assert!(out.respawn.is_none());
        assert_eq!(p.fcfs_len(), 1); // fn-1 still waiting
    }

    #[test]
    fn keepalive_follows_function_profile() {
        let mut p = two_tenant_platform(4);
        let (c1, r1) = p.prewarm_for(1, 0).unwrap();
        p.container_ready(c1, r1);
        assert_eq!(p.keepalive_of(c1), Some(60_000_000));
        // fn-1's 1-minute window, not the platform's 10-minute default
        let due = r1 + 60_000_000;
        match p.keepalive_check(c1, due - 1) {
            KeepAliveVerdict::Recheck(t) => assert_eq!(t, due),
            v => panic!("{v:?}"),
        }
        assert_eq!(p.keepalive_check(c1, due), KeepAliveVerdict::Expired);
    }

    #[test]
    fn memory_ledger_bounds_heavy_tenants() {
        // node with room for exactly one 384-MiB heavyweight
        let cfg = PlatformConfig {
            node_mem_mib: 512,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let mut p0 = FunctionRegistry::single(&cfg).get(0).clone();
        p0.mem_mib = 384;
        let registry = FunctionRegistry::new(vec![p0]);
        let mut p = Platform::with_registry(cfg, registry, 1);
        assert!(p.can_admit(0));
        assert!(p.prewarm_for(0, 0).is_some());
        assert_eq!(p.mem_used_mib(), 384);
        // a second container would need 768 MiB total: rejected despite
        // free replica slots
        assert!(!p.can_admit(0));
        assert!(p.prewarm_for(0, 0).is_none());
        assert_eq!(p.counters.prewarms_rejected, 1);
    }
}
