//! Metrics registry — the Prometheus analog.
//!
//! The controller reads exactly what the paper scrapes from Prometheus:
//! the per-interval invocation rate (forecast history) and the warm /
//! cold-starting container gauges. Counters accumulate platform totals
//! for the experiment reports.

use crate::config::Micros;
use crate::workload::tenant::FunctionId;

/// Monotonic platform counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Counters {
    pub invocations: u64,
    pub cold_starts: u64,
    pub prewarms_started: u64,
    pub prewarms_rejected: u64,
    pub reclaims: u64,
    pub keepalive_expiries: u64,
    /// Keep-alive expiries that fired *before* the function's profile
    /// window would have — the adaptive retention planner's early
    /// removals (a subset of `keepalive_expiries`; structurally 0 under
    /// the fixed policy).
    pub adaptive_expiries: u64,
    pub capacity_queued: u64,
    /// Idle containers of one function removed to make room for another
    /// (multi-tenant contention; always 0 in a single-tenant run).
    pub evictions: u64,
    /// Idle containers released to migrate to another node (counted on
    /// the source; always 0 with `MigrationPolicy::Off`).
    pub migrations_out: u64,
    /// Containers admitted from another node's migration (counted on the
    /// destination; fleet-wide `migrations_in == migrations_out`).
    pub migrations_in: u64,
    /// Image layers found already cached when an image was admitted
    /// (always 0 with `--image-cache off`).
    pub layer_hits: u64,
    /// Image layers that had to be pulled from the registry.
    pub layer_misses: u64,
    /// Total MiB pulled from the registry (the image-distribution bytes
    /// the cache failed to absorb).
    pub pull_mib: u64,
    /// Sum of effective cold-start charges (µs) under the image-cache
    /// model, and how many charges contributed — together they yield the
    /// mean effective `L_cold`. Only accumulated when the cache is
    /// enabled, so the off path stays structurally silent.
    pub cold_cost_us: u64,
    pub cold_charges: u64,
    /// Invocation retries scheduled by the chaos engine after a spawn
    /// failure, execution failure, or timeout (structurally 0 with
    /// `--chaos off` — the engine is never constructed then).
    pub retries: u64,
    /// Executions killed at their per-function chaos timeout.
    pub timeouts: u64,
    /// Request-bound container spawns the chaos engine failed before
    /// the container became ready.
    pub spawn_failures: u64,
}

impl Counters {
    /// Fold another counter set in (fleet-level aggregation over nodes).
    pub fn accumulate(&mut self, o: &Counters) {
        // exhaustive destructure (no `..`): adding a counter field without
        // aggregating it here becomes a compile error, not a silent zero
        let Counters {
            invocations,
            cold_starts,
            prewarms_started,
            prewarms_rejected,
            reclaims,
            keepalive_expiries,
            adaptive_expiries,
            capacity_queued,
            evictions,
            migrations_out,
            migrations_in,
            layer_hits,
            layer_misses,
            pull_mib,
            cold_cost_us,
            cold_charges,
            retries,
            timeouts,
            spawn_failures,
        } = *o;
        self.invocations += invocations;
        self.cold_starts += cold_starts;
        self.prewarms_started += prewarms_started;
        self.prewarms_rejected += prewarms_rejected;
        self.reclaims += reclaims;
        self.keepalive_expiries += keepalive_expiries;
        self.adaptive_expiries += adaptive_expiries;
        self.capacity_queued += capacity_queued;
        self.evictions += evictions;
        self.migrations_out += migrations_out;
        self.migrations_in += migrations_in;
        self.layer_hits += layer_hits;
        self.layer_misses += layer_misses;
        self.pull_mib += pull_mib;
        self.cold_cost_us += cold_cost_us;
        self.cold_charges += cold_charges;
        self.retries += retries;
        self.timeouts += timeouts;
        self.spawn_failures += spawn_failures;
    }

    /// Mean effective cold-start charge in seconds under the image-cache
    /// model (0 when the cache never charged anything — i.e. off, or no
    /// cold starts).
    pub fn mean_effective_l_cold_s(&self) -> f64 {
        if self.cold_charges == 0 {
            return 0.0;
        }
        self.cold_cost_us as f64 / self.cold_charges as f64 / 1e6
    }
}

/// Per-function activation counters (the multi-tenant accounting the
/// tenant experiments report alongside the aggregate [`Counters`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FnCounters {
    pub invocations: u64,
    /// Invocations served directly by an idle warm container.
    pub warm_starts: u64,
    /// Invocations (or backlog respawns) that paid this function's cold
    /// start.
    pub cold_starts: u64,
    /// Containers of this function evicted to make room for another.
    pub evictions: u64,
}

impl FnCounters {
    /// Fold another per-function counter set in (fleet aggregation).
    pub fn accumulate(&mut self, o: &FnCounters) {
        let FnCounters {
            invocations,
            warm_starts,
            cold_starts,
            evictions,
        } = *o;
        self.invocations += invocations;
        self.warm_starts += warm_starts;
        self.cold_starts += cold_starts;
        self.evictions += evictions;
    }
}

/// Convenience alias for fleet-level per-function aggregation results.
pub type FnCounterMap = std::collections::BTreeMap<FunctionId, FnCounters>;

/// One gauge sample (scrape).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaugeSample {
    pub time: Micros,
    pub warm: u32,
    pub idle: u32,
    pub busy: u32,
    pub cold_starting: u32,
    pub queue_len: u32,
}

/// Time-series store for gauge scrapes + counters.
#[derive(Debug, Default)]
pub struct Telemetry {
    pub counters: Counters,
    samples: Vec<GaugeSample>,
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn scrape(&mut self, sample: GaugeSample) {
        self.samples.push(sample);
    }

    pub fn samples(&self) -> &[GaugeSample] {
        &self.samples
    }

    /// Mean warm-container gauge over all scrapes (Fig. 6's quantity).
    pub fn mean_warm(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|s| s.warm as f64).sum::<f64>() / self.samples.len() as f64
    }

    /// Warm-container series (time, count) at the scrape cadence.
    pub fn warm_series(&self) -> Vec<(Micros, u32)> {
        self.samples.iter().map(|s| (s.time, s.warm)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(time: Micros, warm: u32) -> GaugeSample {
        GaugeSample {
            time,
            warm,
            idle: 0,
            busy: warm,
            cold_starting: 0,
            queue_len: 0,
        }
    }

    #[test]
    fn mean_warm_over_scrapes() {
        let mut t = Telemetry::new();
        t.scrape(sample(0, 2));
        t.scrape(sample(60, 4));
        t.scrape(sample(120, 6));
        assert_eq!(t.mean_warm(), 4.0);
        assert_eq!(t.warm_series(), vec![(0, 2), (60, 4), (120, 6)]);
    }

    #[test]
    fn empty_telemetry_is_zero() {
        let t = Telemetry::new();
        assert_eq!(t.mean_warm(), 0.0);
        assert!(t.samples().is_empty());
    }

    #[test]
    fn cache_counters_accumulate_and_average() {
        let mut a = Counters {
            layer_hits: 2,
            layer_misses: 3,
            pull_mib: 100,
            cold_cost_us: 4_000_000,
            cold_charges: 2,
            ..Default::default()
        };
        let b = Counters {
            layer_hits: 1,
            pull_mib: 50,
            cold_cost_us: 2_000_000,
            cold_charges: 1,
            ..Default::default()
        };
        a.accumulate(&b);
        assert_eq!(a.layer_hits, 3);
        assert_eq!(a.layer_misses, 3);
        assert_eq!(a.pull_mib, 150);
        // 6 s of charges over 3 cold charges → mean 2 s
        assert_eq!(a.mean_effective_l_cold_s(), 2.0);
        assert_eq!(Counters::default().mean_effective_l_cold_s(), 0.0);
    }

    #[test]
    fn chaos_counters_accumulate_and_default_to_zero() {
        let d = Counters::default();
        assert_eq!((d.retries, d.timeouts, d.spawn_failures), (0, 0, 0));
        let mut a = Counters {
            retries: 2,
            timeouts: 1,
            ..Default::default()
        };
        let b = Counters {
            retries: 3,
            spawn_failures: 4,
            ..Default::default()
        };
        a.accumulate(&b);
        assert_eq!(a.retries, 5);
        assert_eq!(a.timeouts, 1);
        assert_eq!(a.spawn_failures, 4);
    }
}
