//! Typed configuration for the platform, the controller, and experiments.
//!
//! Defaults mirror the paper's testbed (Sec. IV) and the artifact constants
//! baked by `python/compile/constants.py` (cross-checked at runtime against
//! `artifacts/meta.json` by `runtime::artifacts`).

use crate::util::json::Json;

/// Microseconds — the simulator's native time unit.
pub type Micros = u64;

pub const MICROS_PER_SEC: u64 = 1_000_000;

pub fn secs(s: f64) -> Micros {
    (s * MICROS_PER_SEC as f64).round() as Micros
}

pub fn to_secs(us: Micros) -> f64 {
    us as f64 / MICROS_PER_SEC as f64
}

/// Serverless platform substrate parameters (OpenWhisk-on-k3s analog).
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Warm execution latency L_warm (paper: 280 ms for EfficientDet).
    pub l_warm: Micros,
    /// Cold start initialization latency L_cold (paper: 10.5 s).
    pub l_cold: Micros,
    /// Max concurrent replicas (paper: 64, CPU-bound: 32 vCPU / 0.5 each).
    pub max_containers: u32,
    /// Node CPU capacity in milli-vCPU (paper: 32 vCPU).
    pub node_cpu_millis: u32,
    /// Node memory capacity in MiB (paper: 48 GB).
    pub node_mem_mib: u32,
    /// Per-container CPU request in milli-vCPU (paper: 0.5 vCPU).
    pub container_cpu_millis: u32,
    /// Per-container memory limit in MiB (paper: 256 MB).
    pub container_mem_mib: u32,
    /// Default keep-alive for idle containers (OpenWhisk: 10 minutes).
    pub keep_alive: Micros,
    /// Jitter fraction applied to execution/init latencies (0 = exact).
    pub latency_jitter: f64,
    /// Weight of the node's memory-pressure term in the fleet-level
    /// reclaim ranking (Algorithm 2 extension): a node's best reclaim
    /// candidate scores `container score + weight × mem_used/node_mem`,
    /// so draining prefers pressured nodes. `0.0` (the default) disables
    /// the term entirely — the ranking is then bit-identical to the
    /// container-only score.
    pub reclaim_pressure_weight: f64,
    /// Per-node image/layer cache model (cold-start fidelity). `Off` (the
    /// default) charges the constant profile `l_cold` — the paper's model,
    /// bit for bit; `Lru` makes a cold start cost
    /// `pull(missing layers) + init` against the node's cache state.
    pub image: ImageCacheConfig,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            l_warm: secs(0.280),
            l_cold: secs(10.5),
            max_containers: 64,
            node_cpu_millis: 32_000,
            node_mem_mib: 48 * 1024,
            container_cpu_millis: 500,
            container_mem_mib: 256,
            keep_alive: secs(600.0),
            latency_jitter: 0.05,
            reclaim_pressure_weight: 0.0,
            image: ImageCacheConfig::default(),
        }
    }
}

impl PlatformConfig {
    /// Replica cap implied by node resources (the binding constraint is CPU
    /// in the paper's testbed: 32 vCPU / 0.5 = 64).
    pub fn resource_cap(&self) -> u32 {
        let by_cpu = self.node_cpu_millis / self.container_cpu_millis.max(1);
        let by_mem = self.node_mem_mib / self.container_mem_mib.max(1);
        by_cpu.min(by_mem).min(self.max_containers)
    }
}

/// Image/layer cache mode for the per-node cold-start model (see
/// `cluster::image`). `Off` (the default) is the paper's constant-`l_cold`
/// world, bit for bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImageCacheMode {
    /// No cache model: every cold start charges the profile `l_cold`.
    Off,
    /// Content-addressed per-node layer cache with LRU eviction: a cold
    /// start charges `pull(missing layers) + init`.
    Lru,
}

impl ImageCacheMode {
    pub fn name(&self) -> &'static str {
        match self {
            ImageCacheMode::Off => "off",
            ImageCacheMode::Lru => "lru",
        }
    }

    pub fn parse(s: &str) -> Option<ImageCacheMode> {
        match s {
            "off" | "none" => Some(ImageCacheMode::Off),
            "lru" | "on" => Some(ImageCacheMode::Lru),
            _ => None,
        }
    }

    pub const ALL: [ImageCacheMode; 2] = [ImageCacheMode::Off, ImageCacheMode::Lru];
}

/// Per-node image/layer cache parameters. With the cache enabled, a cold
/// start of function `f` on node `n` charges
/// `init_fraction × l_cold(f) + missing_mib(f, n) / bandwidth_mibps`
/// instead of the constant `l_cold(f)` — the split the cold-start
/// taxonomy literature measures (image distribution dominates; runtime
/// init is the remainder). All knobs are inert under `Off`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ImageCacheConfig {
    pub mode: ImageCacheMode,
    /// Per-node layer-store capacity in MiB (LRU-evicted beyond this).
    pub capacity_mib: u32,
    /// Registry pull bandwidth in MiB/s (shared fleet registry).
    pub bandwidth_mibps: f64,
    /// Fraction of the profile `l_cold` attributed to runtime init (the
    /// part a warm layer cache cannot remove), in `[0, 1]`.
    pub init_fraction: f64,
}

impl Default for ImageCacheConfig {
    fn default() -> Self {
        ImageCacheConfig {
            mode: ImageCacheMode::Off,
            capacity_mib: 2048,
            bandwidth_mibps: 100.0,
            init_fraction: 0.25,
        }
    }
}

impl ImageCacheConfig {
    pub fn enabled(&self) -> bool {
        self.mode != ImageCacheMode::Off
    }

    /// The dynamic cold-start cost formula: `init + pull(missing)`. Under
    /// `Off` this is exactly the profile `l_cold` (the caller never
    /// consults the cache then, but the identity keeps the coupling sites
    /// honest). The pull term is deliberately uncapped — a cache-cold node
    /// behind a slow registry can cost *more* than the paper's constant,
    /// which is what drives the controller to prewarm it earlier.
    pub fn effective_l_cold(&self, l_cold: Micros, missing_mib: u64) -> Micros {
        if !self.enabled() {
            return l_cold;
        }
        let init = (l_cold as f64 * self.init_fraction.clamp(0.0, 1.0)).round() as Micros;
        let pull = if self.bandwidth_mibps.is_finite() && self.bandwidth_mibps > 0.0 {
            secs(missing_mib as f64 / self.bandwidth_mibps)
        } else {
            0 // degenerate bandwidth: charge init only, never overflow
        };
        init.saturating_add(pull)
    }
}

/// Chaos/fault-injection mode (see `cluster::chaos`). `Off` (the
/// default) runs none of the chaos machinery — no RNG stream, no event
/// interception — and is byte-identical to the seed path. The named
/// presets compose a correlated fault schedule on top of the
/// invocation-level faults that `Faults` enables alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosMode {
    /// No fault injection (the seed path, bit for bit).
    Off,
    /// Invocation-level faults only: spawn failures, execution failures,
    /// stragglers/timeouts — no scheduled node events.
    Faults,
    /// Failure storm: several overlapping node drains in a window, each
    /// restored later, plus the invocation-level faults.
    FailureStorm,
    /// Rolling restart: staggered non-overlapping drain→restore waves
    /// across the fleet, plus the invocation-level faults.
    RollingRestart,
    /// Flash crowd: the workload's Zipf popularity inverts mid-run
    /// (head and tail functions swap), plus the invocation-level faults.
    FlashCrowd,
}

impl ChaosMode {
    pub fn name(&self) -> &'static str {
        match self {
            ChaosMode::Off => "off",
            ChaosMode::Faults => "faults",
            ChaosMode::FailureStorm => "failure-storm",
            ChaosMode::RollingRestart => "rolling-restart",
            ChaosMode::FlashCrowd => "flash-crowd",
        }
    }

    pub fn parse(s: &str) -> Option<ChaosMode> {
        match s {
            "off" | "none" => Some(ChaosMode::Off),
            "faults" | "on" => Some(ChaosMode::Faults),
            "failure-storm" | "storm" => Some(ChaosMode::FailureStorm),
            "rolling-restart" | "rolling" => Some(ChaosMode::RollingRestart),
            "flash-crowd" | "flash" => Some(ChaosMode::FlashCrowd),
        _ => None,
        }
    }

    pub const ALL: [ChaosMode; 5] = [
        ChaosMode::Off,
        ChaosMode::Faults,
        ChaosMode::FailureStorm,
        ChaosMode::RollingRestart,
        ChaosMode::FlashCrowd,
    ];

    /// The named scenario presets (everything but `Off`/`Faults`).
    pub const PRESETS: [ChaosMode; 3] = [
        ChaosMode::FailureStorm,
        ChaosMode::RollingRestart,
        ChaosMode::FlashCrowd,
    ];

    /// Whether the mode generates its own correlated node-drain schedule
    /// (and so refuses to merge with hand-written `--fail-node` flags).
    pub fn has_node_schedule(&self) -> bool {
        matches!(self, ChaosMode::FailureStorm | ChaosMode::RollingRestart)
    }
}

/// Chaos-engine parameters: the invocation-level fault probabilities and
/// the retry/backoff/timeout policy bounding them. All knobs are inert
/// under `ChaosMode::Off` — the engine is never even constructed then,
/// so no RNG stream moves and no counter can tick.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    pub mode: ChaosMode,
    /// Probability a request-bound container spawn fails (the cold start
    /// is torn down before the container ever becomes ready). Prewarms
    /// are exempt: a failed prewarm is indistinguishable from a smaller
    /// budget, so only request-bound spawns are interesting to fault.
    pub spawn_fail_p: f64,
    /// Probability an execution that ran to completion still fails (the
    /// container worked, the result did not) — charged in resource-time
    /// but not recorded as a completion; the request retries.
    pub exec_fail_p: f64,
    /// Probability an execution straggles: its duration stretches by
    /// `straggler_factor`, bounded by the per-function timeout.
    pub straggler_p: f64,
    /// Multiplier applied to a straggling execution's duration.
    pub straggler_factor: f64,
    /// Max retries per request across all fault kinds; a request that
    /// exhausts them is dropped (surfaces in `RunReport.dropped`).
    pub max_retries: u32,
    /// Base retry backoff; attempt `n` waits `backoff × 2^(n−1)`.
    pub retry_backoff: Micros,
    /// Per-function execution timeout as a multiple of `l_warm(f)`: an
    /// execution still running at `start + factor × l_warm(f)` is killed
    /// and retried.
    pub timeout_factor: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            mode: ChaosMode::Off,
            spawn_fail_p: 0.05,
            exec_fail_p: 0.05,
            straggler_p: 0.02,
            straggler_factor: 12.0,
            max_retries: 3,
            retry_backoff: secs(1.0),
            timeout_factor: 8.0,
        }
    }
}

impl ChaosConfig {
    pub fn enabled(&self) -> bool {
        self.mode != ChaosMode::Off
    }
}

/// Placement policy used by the fleet's dispatch layer to pick an invoker
/// node for each request (see `cluster::fleet::placement`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Rotate through online nodes regardless of their warm-pool state
    /// (OpenWhisk's default hash-spray analog; maximizes placement skew).
    RoundRobin,
    /// Route to the online node with the least in-flight work.
    LeastLoaded,
    /// Route to a node holding an idle warm container (most recently used
    /// first, preserving OpenWhisk reuse affinity across the fleet); spill
    /// to the least-loaded node with capacity headroom otherwise.
    WarmFirst,
}

impl PlacementPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::WarmFirst => "warm-first",
        }
    }

    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s {
            "round-robin" | "rr" => Some(PlacementPolicy::RoundRobin),
            "least-loaded" | "ll" => Some(PlacementPolicy::LeastLoaded),
            "warm-first" | "wf" => Some(PlacementPolicy::WarmFirst),
            _ => None,
        }
    }

    pub const ALL: [PlacementPolicy; 3] = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::LeastLoaded,
        PlacementPolicy::WarmFirst,
    ];
}

/// A scheduled node outage (the drain scenario): `node` goes offline at
/// `at`; its in-flight work and backlog redistribute to the survivors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeFailure {
    pub node: u32,
    pub at: Micros,
}

/// A scheduled node restore (the rejoin scenario): the previously drained
/// `node` re-enters the fleet at `at`, starting cold (no containers, no
/// backlog). Placement sees it immediately; the MPC's prewarm budget and
/// `w_max` re-scale to the restored live capacity at the next control
/// step (see `coordinator::controller`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeRestore {
    pub node: u32,
    pub at: Micros,
    /// Optional replica-cap override for the rejoined node (heterogeneous
    /// restore: hardware swapped or partially degraded while offline).
    /// None = the node keeps the capacity it drained with.
    pub cap: Option<u32>,
}

/// Parse a CLI restore spec `<node>@<seconds>[:cap]` (e.g. `1@900`,
/// `1@900:32` for a rejoin at a different replica cap).
pub fn parse_restore_spec(s: &str) -> Option<NodeRestore> {
    let (node, rest) = s.split_once('@')?;
    let node: u32 = node.trim().parse().ok()?;
    let (at, cap) = match rest.split_once(':') {
        Some((at, cap)) => {
            let cap: u32 = cap.trim().parse().ok()?;
            if cap == 0 {
                return None;
            }
            (at, Some(cap))
        }
        None => (rest, None),
    };
    let at_s: f64 = at.trim().parse().ok()?;
    (at_s.is_finite() && at_s >= 0.0).then(|| NodeRestore {
        node,
        at: secs(at_s),
        cap,
    })
}

/// Parse a CLI failure spec `<node>@<seconds>` (e.g. `1@600`).
pub fn parse_failure_spec(s: &str) -> Option<NodeFailure> {
    let (node, at) = s.split_once('@')?;
    let node: u32 = node.trim().parse().ok()?;
    let at_s: f64 = at.trim().parse().ok()?;
    (at_s.is_finite() && at_s >= 0.0).then(|| NodeFailure {
        node,
        at: secs(at_s),
    })
}

/// Cross-validate a fault schedule against the fleet shape. Rejects:
/// out-of-range node ids, any drain on a single-node fleet, events at or
/// past `duration`, a restore with no preceding drain of the same node,
/// two drains of one node without a restore in between (duplicate /
/// overlapping windows), non-increasing event times on one node, and any
/// instant where every node would be offline at once (the fleet refuses
/// to drain its last survivor, so such a schedule could never execute).
pub fn validate_fault_schedule(
    failures: &[NodeFailure],
    restores: &[NodeRestore],
    nodes: u32,
    duration: Micros,
) -> Result<(), String> {
    if !failures.is_empty() && nodes < 2 {
        return Err("--fail-node requires --nodes >= 2 (a drain must leave a survivor)".into());
    }
    for f in failures {
        if f.node >= nodes {
            return Err(format!("--fail-node {}: node id out of range (nodes = {nodes})", f.node));
        }
        if f.at >= duration {
            return Err(format!("--fail-node {}: time is at or past the run duration", f.node));
        }
    }
    for r in restores {
        if r.node >= nodes {
            return Err(format!("--restore-node {}: node id out of range (nodes = {nodes})", r.node));
        }
        if r.at >= duration {
            return Err(format!("--restore-node {}: time is at or past the run duration", r.node));
        }
    }
    // Per-node timeline: events must strictly alternate drain → restore →
    // drain …, starting with a drain, at strictly increasing times.
    // (+1 = drain, -1 = restore; sort is stable so same-time conflicts on
    // one node surface as a non-increasing step.)
    let mut timeline: Vec<(Micros, u32, i32)> = failures
        .iter()
        .map(|f| (f.at, f.node, 1))
        .chain(restores.iter().map(|r| (r.at, r.node, -1)))
        .collect();
    timeline.sort_by_key(|&(at, node, _)| (at, node));
    let mut state = vec![0i32; nodes as usize];
    let mut last_at = vec![None::<Micros>; nodes as usize];
    let mut offline = 0u32;
    for &(at, node, delta) in &timeline {
        let n = node as usize;
        if let Some(prev) = last_at[n] {
            if at <= prev {
                return Err(format!(
                    "node {node}: fault events at {:.1}s and {:.1}s must be strictly ordered",
                    prev as f64 / 1e6,
                    at as f64 / 1e6
                ));
            }
        }
        last_at[n] = Some(at);
        match (state[n], delta) {
            (0, 1) => {
                state[n] = 1;
                offline += 1;
            }
            (1, -1) => {
                state[n] = 0;
                offline -= 1;
            }
            (1, 1) => {
                return Err(format!(
                    "node {node}: drained twice without a restore in between (overlapping windows)"
                ));
            }
            _ => {
                return Err(format!(
                    "node {node}: restore at {:.1}s has no preceding drain",
                    at as f64 / 1e6
                ));
            }
        }
        if offline >= nodes {
            return Err(format!(
                "at {:.1}s every node would be offline at once; leave at least one survivor",
                at as f64 / 1e6
            ));
        }
    }
    Ok(())
}

/// Cross-node container migration policy used by the fleet's rebalancing
/// pass (see `cluster::fleet::migration`). `Off` (the default) skips the
/// pass entirely, keeping runs bit-identical to the pre-elasticity code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationPolicy {
    /// No migrations — the legacy fixed-placement fleet.
    Off,
    /// Forecast-driven rebalancing: move idle warm containers toward
    /// nodes whose capacity-proportional share of the per-function
    /// demand forecast exceeds their provisioned supply.
    DemandGap,
    /// Demand-agnostic rebalancing: level the total idle-container count
    /// across online nodes (move from the most- to the least-stocked).
    IdleSpread,
}

impl MigrationPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            MigrationPolicy::Off => "off",
            MigrationPolicy::DemandGap => "demand-gap",
            MigrationPolicy::IdleSpread => "idle-spread",
        }
    }

    pub fn parse(s: &str) -> Option<MigrationPolicy> {
        match s {
            "off" | "none" => Some(MigrationPolicy::Off),
            "demand-gap" | "dg" => Some(MigrationPolicy::DemandGap),
            "idle-spread" | "is" => Some(MigrationPolicy::IdleSpread),
            _ => None,
        }
    }

    pub const ALL: [MigrationPolicy; 3] = [
        MigrationPolicy::Off,
        MigrationPolicy::DemandGap,
        MigrationPolicy::IdleSpread,
    ];
}

/// Cross-node migration parameters. A migrated container is off-pool on
/// the source immediately and re-enters service on the destination after
/// `latency` (it occupies a replica slot and memory there while in
/// flight, so migration time is counted in resource-time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationConfig {
    pub policy: MigrationPolicy,
    /// Warm-state transfer latency (checkpoint/restore — far below a cold
    /// start, which is the point of migrating instead of respawning).
    pub latency: Micros,
    /// Cap on moves per rebalancing pass (one pass per control step).
    pub max_moves_per_step: u32,
}

impl Default for MigrationConfig {
    fn default() -> Self {
        MigrationConfig {
            policy: MigrationPolicy::Off,
            latency: secs(2.0),
            max_moves_per_step: 4,
        }
    }
}

/// Invoker-fleet shape: how many nodes, their capacities, and the
/// dispatch placement policy. With `nodes == 1` the fleet reproduces the
/// single-platform results bit-for-bit (same seed → same metrics).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of invoker nodes (≥ 1).
    pub nodes: u32,
    /// Optional per-node `max_containers` overrides (cycled if shorter
    /// than `nodes`); None = every node uses `PlatformConfig`'s cap.
    pub capacities: Option<Vec<u32>>,
    pub placement: PlacementPolicy,
    /// Scheduled mid-run node outages (empty = no drains). Repeatable:
    /// the single-failure scenario of earlier PRs is a one-element vec.
    pub failures: Vec<NodeFailure>,
    /// Scheduled node restores/rejoins (each pairs with an earlier drain
    /// of the same node — see `validate_fault_schedule`).
    pub restores: Vec<NodeRestore>,
    /// Cross-node container migration (rebalancing) parameters.
    pub migration: MigrationConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            nodes: 1,
            capacities: None,
            placement: PlacementPolicy::WarmFirst,
            failures: Vec::new(),
            restores: Vec::new(),
            migration: MigrationConfig::default(),
        }
    }
}

impl FleetConfig {
    /// Total replica capacity across the fleet (per-node overrides cycled
    /// exactly as `Fleet::new` applies them). This is what the MPC's pool
    /// bound `w_max` scales with — the ROADMAP follow-up from the fleet
    /// PR: a single node's 64-replica bound must not cap an 8-node
    /// cluster's prewarm plan.
    pub fn total_capacity(&self, pc: &PlatformConfig) -> u32 {
        let n = self.nodes.max(1);
        match &self.capacities {
            Some(caps) if !caps.is_empty() => {
                (0..n).map(|i| caps[i as usize % caps.len()]).sum()
            }
            _ => pc.resource_cap() * n,
        }
    }
}

/// Multi-tenant workload shape: how many functions share the fleet and
/// how skewed their popularity is. The default (one function) is the
/// legacy single-tenant system, bit-identical to the pre-tenancy code.
#[derive(Debug, Clone)]
pub struct TenantConfig {
    /// Number of distinct functions (1 = legacy single-tenant).
    pub functions: u32,
    /// Zipf popularity exponent `s` (0 = uniform shares).
    pub zipf_s: f64,
}

impl Default for TenantConfig {
    fn default() -> Self {
        TenantConfig {
            functions: 1,
            zipf_s: 1.1,
        }
    }
}

/// Container-retention (keep-alive) policy for the control loop.
/// `Fixed` (the default) keeps the per-function profile windows the
/// registry ships — the pre-retention-control system, bit for bit.
/// `Adaptive` lets the MPC's retention planner re-derive every
/// function's keep-alive horizon each control step from its forecast
/// (see `coordinator::keepalive`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeepAlivePolicy {
    /// Static per-function profile keep-alive windows (legacy).
    Fixed,
    /// Forecast-driven per-function horizons, clamped to
    /// `[min, profile keep-alive]` by the break-even rule.
    Adaptive,
}

impl KeepAlivePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            KeepAlivePolicy::Fixed => "fixed",
            KeepAlivePolicy::Adaptive => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Option<KeepAlivePolicy> {
        match s {
            "fixed" | "profile" => Some(KeepAlivePolicy::Fixed),
            "adaptive" | "spes" => Some(KeepAlivePolicy::Adaptive),
            _ => None,
        }
    }

    pub const ALL: [KeepAlivePolicy; 2] = [KeepAlivePolicy::Fixed, KeepAlivePolicy::Adaptive];
}

/// Retention-planner parameters (the break-even knobs). Holding an idle
/// container one more second costs `idle_cost_per_s`; an arrival that
/// would otherwise cold-start saves `cold_cost_weight × L_cold(f)`
/// seconds of weighted user delay — so retention pays while the
/// forecast arrival rate stays above
/// `idle_cost_per_s / (cold_cost_weight × L_cold(f))`. All knobs are
/// inert under `KeepAlivePolicy::Fixed`.
#[derive(Debug, Clone, Copy)]
pub struct KeepAliveConfig {
    pub policy: KeepAlivePolicy,
    /// Floor on any adaptive horizon (never evict faster than this).
    pub min: Micros,
    /// Cost rate of keeping an idle container (per container-second).
    pub idle_cost_per_s: f64,
    /// Cold-start cost weight: one avoided cold start is worth
    /// `weight × L_cold(f)` idle-seconds (default mirrors the MPC's
    /// cold-delay aversion `alpha`).
    pub cold_cost_weight: f64,
    /// Memory-pressure shrink weight: the planned horizon scales by
    /// `1 − weight × mem_pressure` (floored at `min`); `0` disables.
    pub pressure_weight: f64,
}

impl Default for KeepAliveConfig {
    fn default() -> Self {
        KeepAliveConfig {
            policy: KeepAlivePolicy::Fixed,
            min: secs(30.0),
            idle_cost_per_s: 1.0,
            cold_cost_weight: 16.0,
            pressure_weight: 0.0,
        }
    }
}

/// Forecast backend for the MPC's demand predictions (`--forecast`).
/// `Fourier` (the default) is the paper's predictor and reproduces the
/// pre-zoo system bit for bit; the other fixed backends swap the model
/// behind the same `Forecaster` trait; `Auto` selects per function
/// online by rolling WAPE (see `forecast::selector`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForecastBackend {
    /// Harmonic regression + statistical clipping (Eq. 1-2, legacy).
    Fourier,
    /// ARIMA(2,1,2) via Hannan-Rissanen (the Fig. 4 baseline).
    Arima,
    /// SPES-style trailing-window quantile (non-parametric).
    Histogram,
    /// Attention-inspired episode matching (softmax over past windows).
    Attn,
    /// Online per-function selection over the whole zoo.
    Auto,
}

impl ForecastBackend {
    pub fn name(&self) -> &'static str {
        match self {
            ForecastBackend::Fourier => "fourier",
            ForecastBackend::Arima => "arima",
            ForecastBackend::Histogram => "histogram",
            ForecastBackend::Attn => "attn",
            ForecastBackend::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<ForecastBackend> {
        match s {
            "fourier" | "harmonic" => Some(ForecastBackend::Fourier),
            "arima" => Some(ForecastBackend::Arima),
            "histogram" | "hist" => Some(ForecastBackend::Histogram),
            "attn" | "attention" => Some(ForecastBackend::Attn),
            "auto" | "zoo" | "selector" => Some(ForecastBackend::Auto),
            _ => None,
        }
    }

    pub const ALL: [ForecastBackend; 5] = [
        ForecastBackend::Fourier,
        ForecastBackend::Arima,
        ForecastBackend::Histogram,
        ForecastBackend::Attn,
        ForecastBackend::Auto,
    ];
}

/// Forecast-zoo parameters: which backend, plus the online selector's
/// scoring knobs. The knobs are inert under any fixed backend.
#[derive(Debug, Clone, Copy)]
pub struct ForecastConfig {
    pub backend: ForecastBackend,
    /// Scored bins kept in each backend's rolling WAPE window.
    pub score_window: usize,
    /// Relative margin a challenger must beat the incumbent's rolling
    /// WAPE by before selection moves (anti-thrash).
    pub hysteresis: f64,
    /// Scored bins required before the first switch may happen.
    pub warmup_bins: usize,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        ForecastConfig {
            backend: ForecastBackend::Fourier,
            score_window: 16,
            hysteresis: 0.1,
            warmup_bins: 8,
        }
    }
}

/// Slot-survival estimator parameters (`--policy survival`; the
/// per-container lifecycle rival from arXiv:2604.05465). The estimator
/// keeps a sliding window of each function's observed inter-arrival
/// gaps and releases an idle container once the empirical probability
/// that its function arrives again within the break-even window —
/// `cold_cost_weight × L_cold(f) / idle_cost_per_s` seconds, the same
/// economics the retention planner uses — drops below `threshold`.
/// All knobs are inert under every other policy.
#[derive(Debug, Clone, Copy)]
pub struct SurvivalConfig {
    /// Trailing inter-arrival gaps kept per function (the sliding-window
    /// empirical survival distribution).
    pub window: usize,
    /// Release once the conditional reuse probability over the next
    /// break-even window drops below this (in `[0, 1]`; `0` never
    /// releases early, `> 1` always releases at the floor).
    pub threshold: f64,
    /// Gap samples required before the estimator overrides a function's
    /// profile keep-alive (cold history ⇒ keep the platform default).
    pub min_samples: usize,
}

impl Default for SurvivalConfig {
    fn default() -> Self {
        SurvivalConfig {
            window: 64,
            threshold: 0.5,
            min_samples: 8,
        }
    }
}

/// MPC controller parameters (Sec. III; Table I weights).
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Control interval Δt.
    pub dt: Micros,
    /// Forecast window W (samples of length Δt).
    pub window: usize,
    /// Prediction horizon H (steps).
    pub horizon: usize,
    /// Cold start delay in steps D = ceil(L_cold / Δt).
    pub cold_steps: usize,
    /// Statistical-clipping confidence γ (Eq. 2).
    pub gamma_clip: f64,
    /// Cost weights in PARAM_NAMES order (alpha..grad_clip).
    pub weights: Weights,
    /// PGD iterations (must match the artifact when using the HLO solver).
    pub pgd_iters: u32,
    /// Force-dispatch guard: max time a request may be shaped/queued before
    /// it is dispatched unconditionally (even onto a cold container).
    pub max_shaping_delay: Micros,
    /// Container-retention policy + break-even knobs (the keep-alive leg
    /// of the prewarm → dispatch → retain control triangle).
    pub keepalive: KeepAliveConfig,
    /// Forecast backend + online-selector knobs (`--forecast`).
    pub forecast: ForecastConfig,
    /// Slot-survival estimator knobs (`--policy survival`); inert under
    /// every other policy.
    pub survival: SurvivalConfig,
}

/// MPC objective weights (Table I). Layout mirrors
/// `python/compile/constants.PARAM_NAMES`.
#[derive(Debug, Clone, Copy)]
pub struct Weights {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub delta: f64,
    pub eta: f64,
    pub rho1: f64,
    pub rho2: f64,
    pub rho_me: f64,
    pub kappa: f64,
    pub mu: f64,
    pub l_cold: f64,
    pub l_warm: f64,
    pub w_max: f64,
    pub lr: f64,
    pub momentum: f64,
    pub grad_clip: f64,
}

impl Default for Weights {
    fn default() -> Self {
        Weights {
            // tuned on the bursty workload (EXPERIMENTS.md §Tuning):
            // strong cold-delay aversion, slow reclaim (rho1) so the pool
            // decays gradually between bursts rather than collapsing
            alpha: 16.0,
            // waiting one step costs ~dt user-seconds: beta * l_warm ~= dt
            beta: 107.0,
            gamma: 0.0002,
            delta: 2.0,
            eta: 0.005,
            rho1: 0.2,
            rho2: 0.02,
            rho_me: 2.0,
            kappa: 0.5,
            // planning-model service rate: per-container budget per step
            // sized for a 1.5 s drain target (DESIGN.md §Timescale), keeping
            // sub-step queueing delay visible to the step-granular planner
            mu: 1.5 / 0.280,
            l_cold: 10.5,
            l_warm: 0.280,
            w_max: 64.0,
            lr: 0.5,
            momentum: 0.9, // Adam beta1
            grad_clip: 5000.0,
        }
    }
}

impl Weights {
    pub fn to_params_vec(&self) -> [f32; 16] {
        [
            self.alpha as f32,
            self.beta as f32,
            self.gamma as f32,
            self.delta as f32,
            self.eta as f32,
            self.rho1 as f32,
            self.rho2 as f32,
            self.rho_me as f32,
            self.kappa as f32,
            self.mu as f32,
            self.l_cold as f32,
            self.l_warm as f32,
            self.w_max as f32,
            self.lr as f32,
            self.momentum as f32,
            self.grad_clip as f32,
        ]
    }
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            // coarse enough that H * dt spans the inter-burst gaps the
            // predictor must anticipate (DESIGN.md §Timescale)
            dt: secs(30.0),
            window: 120,
            horizon: 24,
            cold_steps: 1,
            gamma_clip: 6.0,
            weights: Weights::default(),
            pgd_iters: 300,
            // force-dispatch guard: a request never shapes longer than
            // slightly over L_cold — beyond that a cold start wins anyway
            max_shaping_delay: secs(12.0),
            keepalive: KeepAliveConfig::default(),
            forecast: ForecastConfig::default(),
            survival: SurvivalConfig::default(),
        }
    }
}

/// Which scheduling policy an experiment runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// OpenWhisk default: reactive cold starts + fixed keep-alive.
    OpenWhisk,
    /// IceBreaker adapted to a homogeneous single node.
    IceBreaker,
    /// This paper's MPC scheduler.
    Mpc,
    /// Slot-survival lifecycle control (arXiv:2604.05465): reactive
    /// dispatch plus per-container retention/release driven by empirical
    /// inter-arrival survival probabilities.
    Survival,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::OpenWhisk => "openwhisk",
            Policy::IceBreaker => "icebreaker",
            Policy::Mpc => "mpc",
            Policy::Survival => "survival",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "openwhisk" | "default" => Some(Policy::OpenWhisk),
            "icebreaker" => Some(Policy::IceBreaker),
            "mpc" | "mpc-scheduler" => Some(Policy::Mpc),
            "survival" | "slot-survival" => Some(Policy::Survival),
            _ => None,
        }
    }

    pub const ALL: [Policy; 4] = [
        Policy::OpenWhisk,
        Policy::IceBreaker,
        Policy::Mpc,
        Policy::Survival,
    ];
}

/// Workload selection for experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Azure-Functions-like steady periodic trace (Sec. IV "Workload").
    AzureLike,
    /// Synthetic bursty trace (bursts 1-5 s at 5-300 req/s, idle 50-800 s).
    SyntheticBursty,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::AzureLike => "azure",
            TraceKind::SyntheticBursty => "synthetic",
        }
    }

    pub fn parse(s: &str) -> Option<TraceKind> {
        match s {
            "azure" | "azure-like" => Some(TraceKind::AzureLike),
            "synthetic" | "bursty" => Some(TraceKind::SyntheticBursty),
            _ => None,
        }
    }
}

/// A full experiment description (policy x workload x duration).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub platform: PlatformConfig,
    pub fleet: FleetConfig,
    pub controller: ControllerConfig,
    pub trace: TraceKind,
    /// Multi-tenant workload shape (1 function = legacy single-tenant).
    pub tenancy: TenantConfig,
    pub duration: Micros,
    pub seed: u64,
    /// Sampling interval for container-usage metrics (paper: 1 minute).
    pub sample_interval: Micros,
    /// Worker threads for the sharded event loop (`--threads`). `1` (the
    /// default) runs the sequential seed-path loop; `N > 1` shards
    /// node-local event windows across `N` workers with a deterministic
    /// `(time, seq)` merge — results are bit-identical either way (see
    /// `experiments::sharded`). Must be at least 1.
    pub threads: u32,
    /// Chaos/fault-injection parameters (`--chaos`). `Off` (the default)
    /// constructs none of the machinery and is byte-identical to the
    /// seed path (see `cluster::chaos`).
    pub chaos: ChaosConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            platform: PlatformConfig::default(),
            fleet: FleetConfig::default(),
            controller: ControllerConfig::default(),
            trace: TraceKind::AzureLike,
            tenancy: TenantConfig::default(),
            duration: secs(3600.0), // paper: 60-minute runs
            seed: 42,
            sample_interval: secs(60.0),
            threads: 1,
            chaos: ChaosConfig::default(),
        }
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace", Json::Str(self.trace.name().into())),
            ("duration_s", Json::Num(to_secs(self.duration))),
            ("seed", Json::Num(self.seed as f64)),
            ("nodes", Json::Num(self.fleet.nodes as f64)),
            ("placement", Json::Str(self.fleet.placement.name().into())),
            ("functions", Json::Num(self.tenancy.functions as f64)),
            ("zipf_s", Json::Num(self.tenancy.zipf_s)),
            ("dt_s", Json::Num(to_secs(self.controller.dt))),
            ("horizon", Json::Num(self.controller.horizon as f64)),
            ("window", Json::Num(self.controller.window as f64)),
            ("l_warm_s", Json::Num(to_secs(self.platform.l_warm))),
            ("l_cold_s", Json::Num(to_secs(self.platform.l_cold))),
            ("max_containers", Json::Num(self.platform.max_containers as f64)),
            ("keep_alive_s", Json::Num(to_secs(self.platform.keep_alive))),
            ("threads", Json::Num(self.threads as f64)),
            ("chaos", Json::Str(self.chaos.mode.name().into())),
            ("forecast", Json::Str(self.controller.forecast.backend.name().into())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants() {
        let p = PlatformConfig::default();
        assert_eq!(p.l_warm, 280_000);
        assert_eq!(p.l_cold, 10_500_000);
        assert_eq!(p.resource_cap(), 64); // 32 vCPU / 0.5
        let c = ControllerConfig::default();
        assert_eq!(c.cold_steps, 1); // ceil(10.5 / 30.0)
        assert_eq!(c.dt, secs(30.0));
        assert_eq!(c.horizon, 24);
    }

    #[test]
    fn threads_default_is_sequential_and_surfaces_in_json() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.threads, 1, "default must be the sequential seed path");
        let j = cfg.to_json();
        assert_eq!(j.path("threads").unwrap().as_f64(), Some(1.0));
        let sharded = ExperimentConfig {
            threads: 8,
            ..Default::default()
        };
        assert_eq!(
            sharded.to_json().path("threads").unwrap().as_f64(),
            Some(8.0)
        );
    }

    #[test]
    fn resource_cap_respects_memory() {
        let p = PlatformConfig {
            node_mem_mib: 1024,
            container_mem_mib: 256,
            ..Default::default()
        };
        assert_eq!(p.resource_cap(), 4);
    }

    #[test]
    fn weights_vec_layout_matches_meta_order() {
        let w = Weights::default();
        let v = w.to_params_vec();
        assert_eq!(v[0], 16.0); // alpha
        assert_eq!(v[9], (1.5f64 / 0.280) as f32); // mu (1.5 s drain target)
        assert_eq!(v[12], 64.0); // w_max
        assert_eq!(v[15], 5000.0); // grad_clip
    }

    #[test]
    fn policy_and_trace_parse() {
        assert_eq!(Policy::parse("mpc"), Some(Policy::Mpc));
        assert_eq!(Policy::parse("default"), Some(Policy::OpenWhisk));
        assert_eq!(Policy::parse("nope"), None);
        assert_eq!(TraceKind::parse("bursty"), Some(TraceKind::SyntheticBursty));
    }

    #[test]
    fn policy_parse_and_names_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("slot-survival"), Some(Policy::Survival));
    }

    #[test]
    fn survival_defaults_are_inert_shaped() {
        let sv = ControllerConfig::default().survival;
        assert_eq!(sv.window, 64);
        assert_eq!(sv.threshold, 0.5);
        assert_eq!(sv.min_samples, 8);
    }

    #[test]
    fn placement_parse_and_names_roundtrip() {
        for p in PlacementPolicy::ALL {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("wf"), Some(PlacementPolicy::WarmFirst));
        assert_eq!(PlacementPolicy::parse("nope"), None);
    }

    #[test]
    fn fleet_defaults_to_single_node() {
        let f = FleetConfig::default();
        assert_eq!(f.nodes, 1);
        assert!(f.capacities.is_none());
        assert_eq!(f.placement, PlacementPolicy::WarmFirst);
        assert!(f.failures.is_empty());
        // elasticity is opt-in: no restores, no migration, no pressure term
        assert!(f.restores.is_empty());
        assert_eq!(f.migration.policy, MigrationPolicy::Off);
        assert_eq!(f.migration.latency, secs(2.0));
        assert_eq!(f.migration.max_moves_per_step, 4);
        assert_eq!(PlatformConfig::default().reclaim_pressure_weight, 0.0);
    }

    #[test]
    fn migration_policy_parse_and_names_roundtrip() {
        for p in MigrationPolicy::ALL {
            assert_eq!(MigrationPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(MigrationPolicy::parse("dg"), Some(MigrationPolicy::DemandGap));
        assert_eq!(MigrationPolicy::parse("none"), Some(MigrationPolicy::Off));
        assert_eq!(MigrationPolicy::parse("nope"), None);
    }

    #[test]
    fn restore_spec_parses_id_at_seconds() {
        assert_eq!(
            parse_restore_spec("1@900"),
            Some(NodeRestore {
                node: 1,
                at: secs(900.0),
                cap: None
            })
        );
        assert_eq!(
            parse_restore_spec("0@0.5"),
            Some(NodeRestore {
                node: 0,
                at: secs(0.5),
                cap: None
            })
        );
        assert_eq!(parse_restore_spec("1"), None);
        assert_eq!(parse_restore_spec("x@900"), None);
        assert_eq!(parse_restore_spec("1@-5"), None);
        assert_eq!(parse_restore_spec("1@abc"), None);
    }

    #[test]
    fn restore_spec_parses_optional_capacity() {
        assert_eq!(
            parse_restore_spec("1@900:32"),
            Some(NodeRestore {
                node: 1,
                at: secs(900.0),
                cap: Some(32)
            })
        );
        assert_eq!(
            parse_restore_spec("2@1200.5:1"),
            Some(NodeRestore {
                node: 2,
                at: secs(1200.5),
                cap: Some(1)
            })
        );
        // a zero cap would be a permanently useless node, not a restore
        assert_eq!(parse_restore_spec("1@900:0"), None);
        assert_eq!(parse_restore_spec("1@900:"), None);
        assert_eq!(parse_restore_spec("1@900:abc"), None);
        assert_eq!(parse_restore_spec("1@900:-4"), None);
    }

    #[test]
    fn failure_spec_parses_id_at_seconds() {
        assert_eq!(
            parse_failure_spec("1@600"),
            Some(NodeFailure {
                node: 1,
                at: secs(600.0)
            })
        );
        assert_eq!(parse_failure_spec("2"), None);
        assert_eq!(parse_failure_spec("x@600"), None);
        assert_eq!(parse_failure_spec("1@-5"), None);
        assert_eq!(parse_failure_spec("1@nan"), None);
    }

    #[test]
    fn chaos_mode_parse_and_names_roundtrip() {
        for m in ChaosMode::ALL {
            assert_eq!(ChaosMode::parse(m.name()), Some(m));
        }
        assert_eq!(ChaosMode::parse("on"), Some(ChaosMode::Faults));
        assert_eq!(ChaosMode::parse("storm"), Some(ChaosMode::FailureStorm));
        assert_eq!(ChaosMode::parse("none"), Some(ChaosMode::Off));
        assert_eq!(ChaosMode::parse("nope"), None);
        for p in ChaosMode::PRESETS {
            assert_ne!(p, ChaosMode::Off);
            assert_ne!(p, ChaosMode::Faults);
        }
    }

    #[test]
    fn chaos_defaults_are_off_and_inert() {
        let ch = ExperimentConfig::default().chaos;
        assert_eq!(ch.mode, ChaosMode::Off);
        assert!(!ch.enabled());
        assert_eq!(ch.spawn_fail_p, 0.05);
        assert_eq!(ch.exec_fail_p, 0.05);
        assert_eq!(ch.straggler_p, 0.02);
        assert_eq!(ch.straggler_factor, 12.0);
        assert_eq!(ch.max_retries, 3);
        assert_eq!(ch.retry_backoff, secs(1.0));
        assert_eq!(ch.timeout_factor, 8.0);
        // the mode surfaces in the config JSON as a stable field
        let j = ExperimentConfig::default().to_json();
        assert_eq!(j.path("chaos").unwrap().as_str(), Some("off"));
    }

    #[test]
    fn fault_schedule_validation_accepts_legal_timelines() {
        let dur = secs(3600.0);
        // empty schedule is always fine
        assert!(validate_fault_schedule(&[], &[], 1, dur).is_ok());
        // the legacy single drain + restore pair
        let f = [NodeFailure { node: 1, at: secs(600.0) }];
        let r = [NodeRestore { node: 1, at: secs(900.0), cap: None }];
        assert!(validate_fault_schedule(&f, &r, 2, dur).is_ok());
        // drain without restore (permanent outage) is fine
        assert!(validate_fault_schedule(&f, &[], 2, dur).is_ok());
        // overlapping drains of *different* nodes with a survivor are fine
        let storm = [
            NodeFailure { node: 1, at: secs(100.0) },
            NodeFailure { node: 2, at: secs(110.0) },
        ];
        let back = [
            NodeRestore { node: 1, at: secs(400.0), cap: None },
            NodeRestore { node: 2, at: secs(410.0), cap: Some(32) },
        ];
        assert!(validate_fault_schedule(&storm, &back, 4, dur).is_ok());
        // re-drain after a restore (rolling restart revisits a node)
        let roll = [
            NodeFailure { node: 1, at: secs(100.0) },
            NodeFailure { node: 1, at: secs(500.0) },
        ];
        let up = [NodeRestore { node: 1, at: secs(200.0), cap: None }];
        assert!(validate_fault_schedule(&roll, &up, 2, dur).is_ok());
    }

    #[test]
    fn fault_schedule_validation_rejects_malformed_timelines() {
        let dur = secs(3600.0);
        let f1 = [NodeFailure { node: 1, at: secs(600.0) }];
        // single-node fleet cannot drain its only node
        assert!(validate_fault_schedule(&f1, &[], 1, dur).is_err());
        // out-of-range ids
        assert!(validate_fault_schedule(
            &[NodeFailure { node: 9, at: secs(10.0) }],
            &[],
            2,
            dur
        )
        .is_err());
        assert!(validate_fault_schedule(
            &[],
            &[NodeRestore { node: 9, at: secs(10.0), cap: None }],
            2,
            dur
        )
        .is_err());
        // events at or past the run end never fire
        assert!(validate_fault_schedule(
            &[NodeFailure { node: 1, at: dur }],
            &[],
            2,
            dur
        )
        .is_err());
        // restore before (or without) a drain
        assert!(validate_fault_schedule(
            &[],
            &[NodeRestore { node: 1, at: secs(10.0), cap: None }],
            2,
            dur
        )
        .is_err());
        assert!(validate_fault_schedule(
            &f1,
            &[NodeRestore { node: 1, at: secs(100.0), cap: None }],
            2,
            dur
        )
        .is_err());
        // duplicate drain of one node without a restore in between
        let dup = [
            NodeFailure { node: 1, at: secs(100.0) },
            NodeFailure { node: 1, at: secs(200.0) },
        ];
        assert!(validate_fault_schedule(&dup, &[], 2, dur).is_err());
        // same-instant drain + restore on one node is ambiguous
        let f = [NodeFailure { node: 1, at: secs(100.0) }];
        let r = [NodeRestore { node: 1, at: secs(100.0), cap: None }];
        assert!(validate_fault_schedule(&f, &r, 2, dur).is_err());
        // both nodes of a 2-node fleet offline at once
        let both = [
            NodeFailure { node: 0, at: secs(100.0) },
            NodeFailure { node: 1, at: secs(150.0) },
        ];
        assert!(validate_fault_schedule(&both, &[], 2, dur).is_err());
    }

    #[test]
    fn image_cache_mode_parse_and_names_roundtrip() {
        for m in ImageCacheMode::ALL {
            assert_eq!(ImageCacheMode::parse(m.name()), Some(m));
        }
        assert_eq!(ImageCacheMode::parse("on"), Some(ImageCacheMode::Lru));
        assert_eq!(ImageCacheMode::parse("none"), Some(ImageCacheMode::Off));
        assert_eq!(ImageCacheMode::parse("nope"), None);
    }

    #[test]
    fn image_cache_defaults_are_off_and_inert() {
        let ic = PlatformConfig::default().image;
        assert_eq!(ic.mode, ImageCacheMode::Off);
        assert!(!ic.enabled());
        assert_eq!(ic.capacity_mib, 2048);
        assert_eq!(ic.bandwidth_mibps, 100.0);
        assert_eq!(ic.init_fraction, 0.25);
        // Off charges the constant profile l_cold, whatever the cache state
        assert_eq!(ic.effective_l_cold(secs(10.5), 0), secs(10.5));
        assert_eq!(ic.effective_l_cold(secs(10.5), 9999), secs(10.5));
    }

    #[test]
    fn effective_l_cold_is_init_plus_pull() {
        let ic = ImageCacheConfig {
            mode: ImageCacheMode::Lru,
            ..Default::default()
        };
        // fully cached: init only (0.25 × 10.5 s)
        assert_eq!(ic.effective_l_cold(secs(10.5), 0), secs(2.625));
        // 512 MiB missing at 100 MiB/s: +5.12 s of pull
        assert_eq!(ic.effective_l_cold(secs(10.5), 512), secs(2.625) + secs(5.12));
        // cache-cold behind a slow registry exceeds the paper constant
        let slow = ImageCacheConfig {
            bandwidth_mibps: 10.0,
            ..ic
        };
        assert!(slow.effective_l_cold(secs(10.5), 2048) > secs(10.5));
        // degenerate knobs never panic or overflow
        let weird = ImageCacheConfig {
            bandwidth_mibps: 0.0,
            init_fraction: 7.0,
            ..ic
        };
        assert_eq!(weird.effective_l_cold(secs(10.5), u64::MAX), secs(10.5));
        let nan = ImageCacheConfig {
            bandwidth_mibps: f64::NAN,
            ..ic
        };
        assert_eq!(nan.effective_l_cold(secs(10.5), 100), secs(2.625));
    }

    #[test]
    fn keepalive_policy_parse_and_names_roundtrip() {
        for p in KeepAlivePolicy::ALL {
            assert_eq!(KeepAlivePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(KeepAlivePolicy::parse("profile"), Some(KeepAlivePolicy::Fixed));
        assert_eq!(KeepAlivePolicy::parse("spes"), Some(KeepAlivePolicy::Adaptive));
        assert_eq!(KeepAlivePolicy::parse("nope"), None);
        assert_eq!(KeepAlivePolicy::parse(""), None);
    }

    #[test]
    fn keepalive_defaults_are_fixed_and_inert() {
        let ka = ControllerConfig::default().keepalive;
        assert_eq!(ka.policy, KeepAlivePolicy::Fixed);
        assert_eq!(ka.min, secs(30.0));
        assert_eq!(ka.idle_cost_per_s, 1.0);
        assert_eq!(ka.cold_cost_weight, 16.0);
        assert_eq!(ka.pressure_weight, 0.0);
    }

    #[test]
    fn forecast_backend_parse_and_names_roundtrip() {
        for b in ForecastBackend::ALL {
            assert_eq!(ForecastBackend::parse(b.name()), Some(b));
        }
        assert_eq!(ForecastBackend::parse("hist"), Some(ForecastBackend::Histogram));
        assert_eq!(ForecastBackend::parse("attention"), Some(ForecastBackend::Attn));
        assert_eq!(ForecastBackend::parse("zoo"), Some(ForecastBackend::Auto));
        assert_eq!(ForecastBackend::parse("harmonic"), Some(ForecastBackend::Fourier));
        assert_eq!(ForecastBackend::parse("lstm"), None);
        assert_eq!(ForecastBackend::parse(""), None);
    }

    #[test]
    fn forecast_defaults_are_fourier_and_inert() {
        let fc = ControllerConfig::default().forecast;
        assert_eq!(fc.backend, ForecastBackend::Fourier);
        assert_eq!(fc.score_window, 16);
        assert_eq!(fc.hysteresis, 0.1);
        assert_eq!(fc.warmup_bins, 8);
        // the backend name rides in the config JSON envelope
        let j = ExperimentConfig::default().to_json();
        assert_eq!(j.path("forecast").unwrap().as_str(), Some("fourier"));
    }

    #[test]
    fn tenancy_defaults_to_single_function() {
        let t = TenantConfig::default();
        assert_eq!(t.functions, 1);
        assert!((t.zipf_s - 1.1).abs() < 1e-12);
    }

    #[test]
    fn fleet_total_capacity_scales_and_cycles() {
        let pc = PlatformConfig::default();
        assert_eq!(FleetConfig::default().total_capacity(&pc), 64);
        let f = FleetConfig {
            nodes: 8,
            ..Default::default()
        };
        assert_eq!(f.total_capacity(&pc), 512);
        // explicit per-node overrides (cycled) win over the derived cap
        let f = FleetConfig {
            nodes: 3,
            capacities: Some(vec![1, 2]),
            ..Default::default()
        };
        assert_eq!(f.total_capacity(&pc), 4); // 1 + 2 + 1
    }

    #[test]
    fn time_conversions_roundtrip() {
        assert_eq!(secs(1.0), MICROS_PER_SEC);
        assert_eq!(secs(0.280), 280_000);
        assert!((to_secs(secs(123.456)) - 123.456).abs() < 1e-6);
    }

    #[test]
    fn experiment_json_has_core_fields() {
        let e = ExperimentConfig::default();
        let j = e.to_json();
        assert_eq!(j.path("trace").unwrap().as_str(), Some("azure"));
        assert_eq!(j.path("duration_s").unwrap().as_f64(), Some(3600.0));
    }
}
