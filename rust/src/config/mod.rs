//! Typed configuration for the platform, the controller, and experiments.
//!
//! Defaults mirror the paper's testbed (Sec. IV) and the artifact constants
//! baked by `python/compile/constants.py` (cross-checked at runtime against
//! `artifacts/meta.json` by `runtime::artifacts`).

use crate::util::json::Json;

/// Microseconds — the simulator's native time unit.
pub type Micros = u64;

pub const MICROS_PER_SEC: u64 = 1_000_000;

pub fn secs(s: f64) -> Micros {
    (s * MICROS_PER_SEC as f64).round() as Micros
}

pub fn to_secs(us: Micros) -> f64 {
    us as f64 / MICROS_PER_SEC as f64
}

/// Serverless platform substrate parameters (OpenWhisk-on-k3s analog).
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Warm execution latency L_warm (paper: 280 ms for EfficientDet).
    pub l_warm: Micros,
    /// Cold start initialization latency L_cold (paper: 10.5 s).
    pub l_cold: Micros,
    /// Max concurrent replicas (paper: 64, CPU-bound: 32 vCPU / 0.5 each).
    pub max_containers: u32,
    /// Node CPU capacity in milli-vCPU (paper: 32 vCPU).
    pub node_cpu_millis: u32,
    /// Node memory capacity in MiB (paper: 48 GB).
    pub node_mem_mib: u32,
    /// Per-container CPU request in milli-vCPU (paper: 0.5 vCPU).
    pub container_cpu_millis: u32,
    /// Per-container memory limit in MiB (paper: 256 MB).
    pub container_mem_mib: u32,
    /// Default keep-alive for idle containers (OpenWhisk: 10 minutes).
    pub keep_alive: Micros,
    /// Jitter fraction applied to execution/init latencies (0 = exact).
    pub latency_jitter: f64,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            l_warm: secs(0.280),
            l_cold: secs(10.5),
            max_containers: 64,
            node_cpu_millis: 32_000,
            node_mem_mib: 48 * 1024,
            container_cpu_millis: 500,
            container_mem_mib: 256,
            keep_alive: secs(600.0),
            latency_jitter: 0.05,
        }
    }
}

impl PlatformConfig {
    /// Replica cap implied by node resources (the binding constraint is CPU
    /// in the paper's testbed: 32 vCPU / 0.5 = 64).
    pub fn resource_cap(&self) -> u32 {
        let by_cpu = self.node_cpu_millis / self.container_cpu_millis.max(1);
        let by_mem = self.node_mem_mib / self.container_mem_mib.max(1);
        by_cpu.min(by_mem).min(self.max_containers)
    }
}

/// Placement policy used by the fleet's dispatch layer to pick an invoker
/// node for each request (see `cluster::fleet::placement`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Rotate through online nodes regardless of their warm-pool state
    /// (OpenWhisk's default hash-spray analog; maximizes placement skew).
    RoundRobin,
    /// Route to the online node with the least in-flight work.
    LeastLoaded,
    /// Route to a node holding an idle warm container (most recently used
    /// first, preserving OpenWhisk reuse affinity across the fleet); spill
    /// to the least-loaded node with capacity headroom otherwise.
    WarmFirst,
}

impl PlacementPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::WarmFirst => "warm-first",
        }
    }

    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s {
            "round-robin" | "rr" => Some(PlacementPolicy::RoundRobin),
            "least-loaded" | "ll" => Some(PlacementPolicy::LeastLoaded),
            "warm-first" | "wf" => Some(PlacementPolicy::WarmFirst),
            _ => None,
        }
    }

    pub const ALL: [PlacementPolicy; 3] = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::LeastLoaded,
        PlacementPolicy::WarmFirst,
    ];
}

/// A scheduled node outage (the drain scenario): `node` goes offline at
/// `at`; its in-flight work and backlog redistribute to the survivors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeFailure {
    pub node: u32,
    pub at: Micros,
}

/// Invoker-fleet shape: how many nodes, their capacities, and the
/// dispatch placement policy. With `nodes == 1` the fleet reproduces the
/// single-platform results bit-for-bit (same seed → same metrics).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of invoker nodes (≥ 1).
    pub nodes: u32,
    /// Optional per-node `max_containers` overrides (cycled if shorter
    /// than `nodes`); None = every node uses `PlatformConfig`'s cap.
    pub capacities: Option<Vec<u32>>,
    pub placement: PlacementPolicy,
    /// Optional mid-run node outage scenario.
    pub failure: Option<NodeFailure>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            nodes: 1,
            capacities: None,
            placement: PlacementPolicy::WarmFirst,
            failure: None,
        }
    }
}

impl FleetConfig {
    /// Total replica capacity across the fleet (per-node overrides cycled
    /// exactly as `Fleet::new` applies them). This is what the MPC's pool
    /// bound `w_max` scales with — the ROADMAP follow-up from the fleet
    /// PR: a single node's 64-replica bound must not cap an 8-node
    /// cluster's prewarm plan.
    pub fn total_capacity(&self, pc: &PlatformConfig) -> u32 {
        let n = self.nodes.max(1);
        match &self.capacities {
            Some(caps) if !caps.is_empty() => {
                (0..n).map(|i| caps[i as usize % caps.len()]).sum()
            }
            _ => pc.resource_cap() * n,
        }
    }
}

/// Multi-tenant workload shape: how many functions share the fleet and
/// how skewed their popularity is. The default (one function) is the
/// legacy single-tenant system, bit-identical to the pre-tenancy code.
#[derive(Debug, Clone)]
pub struct TenantConfig {
    /// Number of distinct functions (1 = legacy single-tenant).
    pub functions: u32,
    /// Zipf popularity exponent `s` (0 = uniform shares).
    pub zipf_s: f64,
}

impl Default for TenantConfig {
    fn default() -> Self {
        TenantConfig {
            functions: 1,
            zipf_s: 1.1,
        }
    }
}

/// MPC controller parameters (Sec. III; Table I weights).
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Control interval Δt.
    pub dt: Micros,
    /// Forecast window W (samples of length Δt).
    pub window: usize,
    /// Prediction horizon H (steps).
    pub horizon: usize,
    /// Cold start delay in steps D = ceil(L_cold / Δt).
    pub cold_steps: usize,
    /// Statistical-clipping confidence γ (Eq. 2).
    pub gamma_clip: f64,
    /// Cost weights in PARAM_NAMES order (alpha..grad_clip).
    pub weights: Weights,
    /// PGD iterations (must match the artifact when using the HLO solver).
    pub pgd_iters: u32,
    /// Force-dispatch guard: max time a request may be shaped/queued before
    /// it is dispatched unconditionally (even onto a cold container).
    pub max_shaping_delay: Micros,
}

/// MPC objective weights (Table I). Layout mirrors
/// `python/compile/constants.PARAM_NAMES`.
#[derive(Debug, Clone, Copy)]
pub struct Weights {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub delta: f64,
    pub eta: f64,
    pub rho1: f64,
    pub rho2: f64,
    pub rho_me: f64,
    pub kappa: f64,
    pub mu: f64,
    pub l_cold: f64,
    pub l_warm: f64,
    pub w_max: f64,
    pub lr: f64,
    pub momentum: f64,
    pub grad_clip: f64,
}

impl Default for Weights {
    fn default() -> Self {
        Weights {
            // tuned on the bursty workload (EXPERIMENTS.md §Tuning):
            // strong cold-delay aversion, slow reclaim (rho1) so the pool
            // decays gradually between bursts rather than collapsing
            alpha: 16.0,
            // waiting one step costs ~dt user-seconds: beta * l_warm ~= dt
            beta: 107.0,
            gamma: 0.0002,
            delta: 2.0,
            eta: 0.005,
            rho1: 0.2,
            rho2: 0.02,
            rho_me: 2.0,
            kappa: 0.5,
            // planning-model service rate: per-container budget per step
            // sized for a 1.5 s drain target (DESIGN.md §Timescale), keeping
            // sub-step queueing delay visible to the step-granular planner
            mu: 1.5 / 0.280,
            l_cold: 10.5,
            l_warm: 0.280,
            w_max: 64.0,
            lr: 0.5,
            momentum: 0.9, // Adam beta1
            grad_clip: 5000.0,
        }
    }
}

impl Weights {
    pub fn to_params_vec(&self) -> [f32; 16] {
        [
            self.alpha as f32,
            self.beta as f32,
            self.gamma as f32,
            self.delta as f32,
            self.eta as f32,
            self.rho1 as f32,
            self.rho2 as f32,
            self.rho_me as f32,
            self.kappa as f32,
            self.mu as f32,
            self.l_cold as f32,
            self.l_warm as f32,
            self.w_max as f32,
            self.lr as f32,
            self.momentum as f32,
            self.grad_clip as f32,
        ]
    }
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            // coarse enough that H * dt spans the inter-burst gaps the
            // predictor must anticipate (DESIGN.md §Timescale)
            dt: secs(30.0),
            window: 120,
            horizon: 24,
            cold_steps: 1,
            gamma_clip: 6.0,
            weights: Weights::default(),
            pgd_iters: 300,
            // force-dispatch guard: a request never shapes longer than
            // slightly over L_cold — beyond that a cold start wins anyway
            max_shaping_delay: secs(12.0),
        }
    }
}

/// Which scheduling policy an experiment runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// OpenWhisk default: reactive cold starts + fixed keep-alive.
    OpenWhisk,
    /// IceBreaker adapted to a homogeneous single node.
    IceBreaker,
    /// This paper's MPC scheduler.
    Mpc,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::OpenWhisk => "openwhisk",
            Policy::IceBreaker => "icebreaker",
            Policy::Mpc => "mpc",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "openwhisk" | "default" => Some(Policy::OpenWhisk),
            "icebreaker" => Some(Policy::IceBreaker),
            "mpc" | "mpc-scheduler" => Some(Policy::Mpc),
            _ => None,
        }
    }
}

/// Workload selection for experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Azure-Functions-like steady periodic trace (Sec. IV "Workload").
    AzureLike,
    /// Synthetic bursty trace (bursts 1-5 s at 5-300 req/s, idle 50-800 s).
    SyntheticBursty,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::AzureLike => "azure",
            TraceKind::SyntheticBursty => "synthetic",
        }
    }

    pub fn parse(s: &str) -> Option<TraceKind> {
        match s {
            "azure" | "azure-like" => Some(TraceKind::AzureLike),
            "synthetic" | "bursty" => Some(TraceKind::SyntheticBursty),
            _ => None,
        }
    }
}

/// A full experiment description (policy x workload x duration).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub platform: PlatformConfig,
    pub fleet: FleetConfig,
    pub controller: ControllerConfig,
    pub trace: TraceKind,
    /// Multi-tenant workload shape (1 function = legacy single-tenant).
    pub tenancy: TenantConfig,
    pub duration: Micros,
    pub seed: u64,
    /// Sampling interval for container-usage metrics (paper: 1 minute).
    pub sample_interval: Micros,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            platform: PlatformConfig::default(),
            fleet: FleetConfig::default(),
            controller: ControllerConfig::default(),
            trace: TraceKind::AzureLike,
            tenancy: TenantConfig::default(),
            duration: secs(3600.0), // paper: 60-minute runs
            seed: 42,
            sample_interval: secs(60.0),
        }
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace", Json::Str(self.trace.name().into())),
            ("duration_s", Json::Num(to_secs(self.duration))),
            ("seed", Json::Num(self.seed as f64)),
            ("nodes", Json::Num(self.fleet.nodes as f64)),
            ("placement", Json::Str(self.fleet.placement.name().into())),
            ("functions", Json::Num(self.tenancy.functions as f64)),
            ("zipf_s", Json::Num(self.tenancy.zipf_s)),
            ("dt_s", Json::Num(to_secs(self.controller.dt))),
            ("horizon", Json::Num(self.controller.horizon as f64)),
            ("window", Json::Num(self.controller.window as f64)),
            ("l_warm_s", Json::Num(to_secs(self.platform.l_warm))),
            ("l_cold_s", Json::Num(to_secs(self.platform.l_cold))),
            ("max_containers", Json::Num(self.platform.max_containers as f64)),
            ("keep_alive_s", Json::Num(to_secs(self.platform.keep_alive))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants() {
        let p = PlatformConfig::default();
        assert_eq!(p.l_warm, 280_000);
        assert_eq!(p.l_cold, 10_500_000);
        assert_eq!(p.resource_cap(), 64); // 32 vCPU / 0.5
        let c = ControllerConfig::default();
        assert_eq!(c.cold_steps, 1); // ceil(10.5 / 30.0)
        assert_eq!(c.dt, secs(30.0));
        assert_eq!(c.horizon, 24);
    }

    #[test]
    fn resource_cap_respects_memory() {
        let p = PlatformConfig {
            node_mem_mib: 1024,
            container_mem_mib: 256,
            ..Default::default()
        };
        assert_eq!(p.resource_cap(), 4);
    }

    #[test]
    fn weights_vec_layout_matches_meta_order() {
        let w = Weights::default();
        let v = w.to_params_vec();
        assert_eq!(v[0], 16.0); // alpha
        assert_eq!(v[9], (1.5f64 / 0.280) as f32); // mu (1.5 s drain target)
        assert_eq!(v[12], 64.0); // w_max
        assert_eq!(v[15], 5000.0); // grad_clip
    }

    #[test]
    fn policy_and_trace_parse() {
        assert_eq!(Policy::parse("mpc"), Some(Policy::Mpc));
        assert_eq!(Policy::parse("default"), Some(Policy::OpenWhisk));
        assert_eq!(Policy::parse("nope"), None);
        assert_eq!(TraceKind::parse("bursty"), Some(TraceKind::SyntheticBursty));
    }

    #[test]
    fn placement_parse_and_names_roundtrip() {
        for p in PlacementPolicy::ALL {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("wf"), Some(PlacementPolicy::WarmFirst));
        assert_eq!(PlacementPolicy::parse("nope"), None);
    }

    #[test]
    fn fleet_defaults_to_single_node() {
        let f = FleetConfig::default();
        assert_eq!(f.nodes, 1);
        assert!(f.capacities.is_none());
        assert_eq!(f.placement, PlacementPolicy::WarmFirst);
        assert!(f.failure.is_none());
    }

    #[test]
    fn tenancy_defaults_to_single_function() {
        let t = TenantConfig::default();
        assert_eq!(t.functions, 1);
        assert!((t.zipf_s - 1.1).abs() < 1e-12);
    }

    #[test]
    fn fleet_total_capacity_scales_and_cycles() {
        let pc = PlatformConfig::default();
        assert_eq!(FleetConfig::default().total_capacity(&pc), 64);
        let f = FleetConfig {
            nodes: 8,
            ..Default::default()
        };
        assert_eq!(f.total_capacity(&pc), 512);
        // explicit per-node overrides (cycled) win over the derived cap
        let f = FleetConfig {
            nodes: 3,
            capacities: Some(vec![1, 2]),
            ..Default::default()
        };
        assert_eq!(f.total_capacity(&pc), 4); // 1 + 2 + 1
    }

    #[test]
    fn time_conversions_roundtrip() {
        assert_eq!(secs(1.0), MICROS_PER_SEC);
        assert_eq!(secs(0.280), 280_000);
        assert!((to_secs(secs(123.456)) - 123.456).abs() < 1e-6);
    }

    #[test]
    fn experiment_json_has_core_fields() {
        let e = ExperimentConfig::default();
        let j = e.to_json();
        assert_eq!(j.path("trace").unwrap().as_str(), Some("azure"));
        assert_eq!(j.path("duration_s").unwrap().as_f64(), Some(3600.0));
    }
}
