//! The paper's MPC scheduler (Sec. III): forecast → optimize → actuate at
//! every control interval, with predictive request shaping.
//!
//! Requests are *not* forwarded on arrival: they enter the request queue
//! (Redis analog) and the dispatch actuator releases them in warm-capacity
//! batches (Algorithm 1), guided by the optimized plan. A force-dispatch
//! guard bounds worst-case shaping delay so a mispredicted lull can never
//! strand requests.
//!
//! **Multi-tenant control.** The horizon problem stays aggregate (one
//! queue/pool state, Eq. 3-18), but the scheduler additionally tracks a
//! per-function arrival history and runs a per-function forecast at
//! each control step through a pluggable registry slot — the paper's
//! Fourier predictor by default, any zoo backend or the online selector
//! under `--forecast` (see [`MpcScheduler::with_forecast`]). The plan's first-step prewarm budget `x_0` —
//! already fleet-scaled through `w_max` — is then split across functions
//! proportionally to their predicted demand over the cold-start lead
//! window, and the dispatcher releases queued requests against *their
//! function's* idle warm pool. With one function all of this collapses
//! to the single-tenant controller bit-for-bit.
//!
//! **Elasticity (live-capacity control).** The planning pool bound is
//! re-scaled to the fleet's *live* online capacity at every control
//! step, not once at startup:
//!
//! ```text
//! w_max(t) = w_max^node × C_live(t) / C_node
//! ```
//!
//! where `C_node` is one node's replica cap and `C_live(t)` sums the
//! caps of currently-online nodes ([`crate::cluster::Fleet::resource_cap`]).
//! The expression is the startup scaling evaluated with live capacity,
//! so a fully-online fleet reproduces the startup bound bit-for-bit; a
//! drain shrinks the prewarm plan immediately and a rejoin grows it
//! back (the repair stage's hard pool cap tracks the same live sum).
//! After actuation the controller runs the fleet's migration
//! rebalancing pass ([`Ctx::migrate_rebalance`]), feeding it the same
//! per-function lead-window demand the prewarm split uses — a no-op
//! under the default `MigrationPolicy::Off`.

use std::time::Instant;

use crate::cluster::platform::InvokeOutcome;
use crate::cluster::RequestId;
use crate::config::{
    ControllerConfig, ForecastBackend, ForecastConfig, KeepAliveConfig, KeepAlivePolicy, Micros,
    MigrationPolicy,
};
use crate::coordinator::keepalive;
use crate::coordinator::queue::RequestQueue;
use crate::coordinator::{Ctx, ForecastTelemetry, Scheduler};
use crate::forecast::selector::{make_backend, AutoSelector};
use crate::forecast::{Forecaster, FourierForecaster};
use crate::mpc::{repair, MpcInput, MpcSolver, Plan};
use crate::util::timeseries::RingBuffer;
use crate::workload::tenant::{split_budget, FunctionId};

/// Prewarm lead window in control steps: how far ahead of predicted
/// demand a prewarm must launch so the container is warm when the
/// demand lands. The constant-cost path uses the configured
/// `cold_steps` plus two slack steps (the legacy margin). Under the
/// image-cache model (`dynamic`) the effective cold start of a
/// cache-cold node can exceed the paper constant — registry pull on top
/// of init — so the window stretches to cover `l_cold_eff`; it never
/// shrinks below the configured `cold_steps`, and with `dynamic` false
/// it is exactly the legacy expression, keeping `--image-cache off`
/// byte-identical.
pub fn lead_steps(cold_steps: usize, dt: Micros, l_cold_eff: Micros, dynamic: bool) -> usize {
    if !dynamic || dt == 0 {
        return cold_steps + 2;
    }
    let eff_steps = (l_cold_eff / dt + (l_cold_eff % dt != 0) as u64) as usize;
    cold_steps.max(eff_steps) + 2
}

/// One slot of the per-function forecaster registry: either a fixed
/// backend (any zoo model behind the [`Forecaster`] trait) or the
/// online selector routing through its current best. The controller
/// never matches on this outside the enum's own methods, so every
/// forecast-consuming decision — prewarm split, lead-window demand,
/// adaptive retention horizon — flows through whichever model the slot
/// currently resolves to.
enum FnForecaster {
    Fixed(Box<dyn Forecaster>),
    Auto(Box<AutoSelector>),
}

impl FnForecaster {
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64> {
        match self {
            FnForecaster::Fixed(f) => f.forecast(history, horizon),
            FnForecaster::Auto(s) => s.forecast(history, horizon),
        }
    }

    /// Selector bookkeeping at bin close (score pendings, maybe switch,
    /// stage the next one-step predictions); a no-op for fixed backends,
    /// which is what keeps the seed path byte-identical.
    fn observe(&mut self, history: &[f64], realized: f64) {
        if let FnForecaster::Auto(s) = self {
            s.observe(history, realized);
        }
    }

    fn switches(&self) -> u64 {
        match self {
            FnForecaster::Fixed(_) => 0,
            FnForecaster::Auto(s) => s.switches(),
        }
    }

    /// The model currently answering forecasts; `fixed_name` is the
    /// configured backend name (a fixed slot always answers with it).
    fn model_name(&self, fixed_name: &'static str) -> &'static str {
        match self {
            FnForecaster::Fixed(_) => fixed_name,
            FnForecaster::Auto(s) => s.current_name(),
        }
    }

    /// Rolling selector accuracy; structurally zero for fixed backends
    /// (no scoring loop runs).
    fn rolling_accuracy_pct(&self) -> f64 {
        match self {
            FnForecaster::Fixed(_) => 0.0,
            FnForecaster::Auto(s) => s.rolling_accuracy_pct(),
        }
    }
}

/// Build the registry slot a [`ForecastConfig`] asks for.
fn new_fn_forecaster(fc: &ForecastConfig, gamma_clip: f64) -> FnForecaster {
    if fc.backend == ForecastBackend::Auto {
        FnForecaster::Auto(Box::new(AutoSelector::new(fc, gamma_clip)))
    } else {
        FnForecaster::Fixed(make_backend(fc.backend, gamma_clip))
    }
}

/// Per-function demand tracker driving the multi-tenant prewarm split.
struct TenantDemand {
    history: RingBuffer,
    arrivals_this_interval: u32,
    forecaster: FnForecaster,
}

pub struct MpcScheduler {
    cc: ControllerConfig,
    queue: RequestQueue,
    history: RingBuffer,
    arrivals_this_interval: u32,
    forecaster: FnForecaster,
    solver: Box<dyn MpcSolver>,
    warm_start: Vec<f64>,
    x_prev: f64,
    /// Per-function demand trackers; empty in a single-tenant run (the
    /// aggregate machinery is then the whole controller).
    tenants: Vec<TenantDemand>,
    /// Adaptive keep-alive knobs (Some = the retention planner runs each
    /// control step; None = fixed profile windows, the bit-identical
    /// default). See [`crate::coordinator::keepalive`].
    retention: Option<KeepAliveConfig>,
    /// Live-capacity scaling `(C_node, w_max^node)`: when set, the
    /// planning pool bound is recomputed as
    /// `w_max^node × C_live / C_node` at every replan (see the module
    /// doc). None = the startup-scaled bound stays fixed (the HLO path,
    /// and direct constructions that predate elasticity).
    live_capacity: Option<(u32, f64)>,
    /// Graceful degradation under fault injection (chaos runs only):
    /// floors the live-capacity `w_max` re-scaling at one replica-slot
    /// (a storm that drains most of the fleet must clamp the plan, not
    /// drive the solver into an infeasible zero-capacity corner), and
    /// discounts per-function forecasts whose window-long history has
    /// diverged from the recent regime (a flash crowd inverts popularity
    /// faster than the Fourier window can forget). False (the default)
    /// leaves every expression byte-identical to the seed path.
    degradation: bool,
    /// Scratch: per-function idle snapshot for the dispatcher's drain
    /// (reused every call instead of allocating per arrival).
    idle_scratch: Vec<u32>,
    /// Scratch: readyCold bucket vector, recycled across replans (it is
    /// lent to [`MpcInput`] for the solve and taken back afterwards).
    rdy_scratch: Vec<f64>,
    /// Scratch: raw cold-start ready times gathered from the fleet.
    cold_scratch: Vec<Micros>,
    /// Last optimized plan (observability / tests).
    pub last_plan: Option<Plan>,
    /// Total force-dispatches (guard activations).
    pub forced_dispatches: u64,
    /// Event-triggered replans (unforecasted load spikes).
    pub emergency_replans: u64,
    /// Stale-forecast discounts applied (degradation mode only): one per
    /// (function, replan) whose window-long history was overridden by
    /// the recent-regime mean.
    pub stale_discounts: u64,
    last_solve_at: Option<Micros>,
    /// Backend + selector knobs the registry was configured with (the
    /// default when constructed directly: Fourier, knobs inert) —
    /// reported through [`Scheduler::forecast_telemetry`].
    fcfg: ForecastConfig,
}

impl MpcScheduler {
    pub fn new(
        cc: ControllerConfig,
        forecaster: Box<dyn Forecaster>,
        solver: Box<dyn MpcSolver>,
    ) -> Self {
        let window = cc.window;
        let horizon = cc.horizon;
        MpcScheduler {
            cc,
            queue: RequestQueue::new(),
            history: RingBuffer::new(window),
            arrivals_this_interval: 0,
            forecaster: FnForecaster::Fixed(forecaster),
            solver,
            warm_start: vec![0.0; 3 * horizon],
            x_prev: 0.0,
            tenants: Vec::new(),
            retention: None,
            live_capacity: None,
            degradation: false,
            idle_scratch: Vec::new(),
            rdy_scratch: Vec::new(),
            cold_scratch: Vec::new(),
            last_plan: None,
            forced_dispatches: 0,
            emergency_replans: 0,
            stale_discounts: 0,
            last_solve_at: None,
            fcfg: ForecastConfig::default(),
        }
    }

    /// Enable live-capacity re-scaling of the planning pool bound:
    /// `node_cap` is one node's replica cap `C_node` and `base_w_max`
    /// the *unscaled* per-node bound `w_max^node`. At every replan the
    /// effective bound becomes `base_w_max × C_live / C_node` — exactly
    /// the startup scaling when the whole fleet is online (bit-identical
    /// f64 expression), smaller during a drain, restored on rejoin.
    pub fn with_live_capacity(mut self, node_cap: u32, base_w_max: f64) -> Self {
        self.live_capacity = Some((node_cap.max(1), base_w_max));
        self
    }

    /// Enable graceful degradation for chaos runs (see the field doc):
    /// the `w_max` clamp and the stale-forecast discount. A no-op with
    /// `on == false`, keeping the `--chaos off` path byte-identical.
    pub fn with_degradation(mut self, on: bool) -> Self {
        self.degradation = on;
        self
    }

    /// Enable the adaptive retention planner: every control step the
    /// per-function keep-alive horizons are re-derived from the same
    /// forecasts the prewarm split consumes and actuated fleet-wide
    /// ([`Ctx::apply_keepalive`]). A no-op under
    /// [`KeepAlivePolicy::Fixed`], keeping the seed path bit-identical.
    pub fn with_keepalive(mut self, ka: KeepAliveConfig) -> Self {
        if ka.policy == KeepAlivePolicy::Adaptive {
            self.retention = Some(ka);
        }
        self
    }

    /// Enable per-function demand tracking for an `n`-function workload.
    /// With `n <= 1` this is a no-op and the controller stays bit-identical
    /// to the single-tenant form.
    pub fn with_functions(mut self, n: usize) -> Self {
        if n > 1 {
            self.tenants = (0..n)
                .map(|_| TenantDemand {
                    history: RingBuffer::new(self.cc.window),
                    arrivals_this_interval: 0,
                    forecaster: FnForecaster::Fixed(Box::new(FourierForecaster {
                        gamma_clip: self.cc.gamma_clip,
                        ..Default::default()
                    })),
                })
                .collect();
        }
        self
    }

    /// Select the forecast backend for the aggregate horizon problem
    /// and every slot of the per-function registry (`--forecast`).
    /// Call *after* [`MpcScheduler::with_functions`] so the registry is
    /// populated. `Fourier` (the default) keeps the
    /// constructor-provided forecasters untouched — the seed path, byte
    /// for byte; `Auto` installs one online selector per slot.
    pub fn with_forecast(mut self, fc: &ForecastConfig) -> Self {
        self.fcfg = *fc;
        if fc.backend == ForecastBackend::Fourier {
            return self;
        }
        let gamma = self.cc.gamma_clip;
        self.forecaster = new_fn_forecaster(fc, gamma);
        for t in &mut self.tenants {
            t.forecaster = new_fn_forecaster(fc, gamma);
        }
        self
    }

    /// Bucket in-flight cold-start ready times into readyCold[k] (k < H).
    /// Allocation-free on the steady state: the ready times land in
    /// `cold_scratch` (the fleet's indexed cold maps, no container scan)
    /// and the bucket vector is the recycled `rdy_scratch`, which
    /// `replan` hands back to the scratch slot after the solve.
    fn ready_schedule(&mut self, ctx: &Ctx) -> Vec<f64> {
        self.cold_scratch.clear();
        ctx.fleet.cold_ready_times_into(&mut self.cold_scratch);
        let mut rdy = std::mem::take(&mut self.rdy_scratch);
        rdy.clear();
        rdy.resize(self.cc.horizon, 0.0);
        for &ready_at in &self.cold_scratch {
            let delta = ready_at.saturating_sub(ctx.now);
            let k = (delta / self.cc.dt) as usize;
            if k < rdy.len() {
                rdy[k] += 1.0;
            }
        }
        rdy
    }

    /// Algorithm 1, work-conserving form: release queued requests in
    /// batches bounded by idle warm capacity. Holding a request while a
    /// warm container sits idle is never optimal under the paper's
    /// objective (WaitCost and OverProvision are both positive), so the
    /// dispatcher drains whenever warm capacity frees up; the plan's s_k
    /// shapes *cold-start avoidance*, not warm serving.
    ///
    /// Multi-tenant form: a request is released only against *its
    /// function's* idle pool (FIFO within each function), so a
    /// head-of-line function with no warm capacity cannot block another
    /// function's drain. The per-function idle counts are snapshotted
    /// once into a reused scratch buffer (an O(nodes × functions)
    /// counter copy off the platform indices — no container scan, no
    /// allocation) and decremented as warm capacity is consumed. With
    /// one function this is exactly the legacy head pop.
    fn try_dispatch(&mut self, ctx: &mut Ctx) {
        if self.tenants.len() <= 1 {
            // legacy single-tenant drain, bit-identical to the pre-tenancy
            // controller
            while !self.queue.is_empty() && ctx.fleet.idle_count() > 0 {
                let (req, _) = self.queue.pop().unwrap();
                if !matches!(ctx.dispatch(req), InvokeOutcome::WarmStart { .. }) {
                    // a non-warm-first placement routed past the idle pool
                    // (round-robin/least-loaded can); stop draining —
                    // further releases would only add cold starts the
                    // shaping queue exists to avoid
                    break;
                }
            }
            return;
        }
        let nf = self.tenants.len();
        self.idle_scratch.resize(nf, 0);
        ctx.fleet.idle_by_function_into(&mut self.idle_scratch);
        let idle = &mut self.idle_scratch;
        loop {
            if self.queue.is_empty() || idle.iter().all(|&c| c == 0) {
                break;
            }
            let Some((req, _)) = self.queue.pop_matching(|r, _| {
                let f = ctx.func_of(r) as usize;
                f < idle.len() && idle[f] > 0
            }) else {
                // queued work exists but none of it has a matching warm
                // container — releasing would only add cold starts
                break;
            };
            let f = ctx.func_of(req) as usize;
            if matches!(ctx.dispatch(req), InvokeOutcome::WarmStart { .. }) {
                idle[f] -= 1;
            } else {
                // placement routed past the function's idle pool; stop
                // draining rather than manufacture cold starts
                break;
            }
        }
    }

    /// Unforecasted load spike: the queue exceeds what the provisioned pool
    /// (warm + in-flight cold) can absorb within one interval. Re-plan
    /// immediately instead of waiting for the next tick (rate-limited).
    fn needs_emergency_replan(&self, ctx: &Ctx) -> bool {
        let capacity_per_step = (ctx.fleet.warm_count()
            + ctx.fleet.cold_starting_count()) as f64
            * self.cc.weights.mu;
        // re-plans are cheap (sub-ms solve); during a burst the demand
        // estimate must escalate faster than the burst itself
        let recent = self
            .last_solve_at
            .is_some_and(|t| ctx.now.saturating_sub(t) < crate::config::secs(1.0));
        self.queue.len() as f64 > capacity_per_step && !recent
    }

    /// Force-dispatch guard: requests older than `max_shaping_delay` go out
    /// unconditionally (a cold start now beats unbounded queueing) — unless
    /// an in-flight prewarm *of that request's function* is about to land,
    /// in which case waiting the last couple of seconds strictly dominates
    /// starting a fresh cold container (which would take the full L_cold
    /// again). The imminence check is per request, so one function's
    /// landing prewarm neither holds back nor releases another function's
    /// stale work.
    fn force_stale(&mut self, ctx: &mut Ctx) {
        let now = ctx.now;
        let guard = self.cc.max_shaping_delay;
        // fast path: shaping rarely exceeds the guard, and stale requests
        // form a FIFO prefix — if the head is fresh, everything is
        if !self
            .queue
            .oldest_age(now)
            .is_some_and(|age| age > guard)
        {
            return;
        }
        // per-function imminence, computed once per call (as the legacy
        // single-tenant guard did): a cold start launched by a forced
        // dispatch below lands a full L_cold away, far outside the 3 s
        // window, so the verdicts cannot change mid-drain
        let nf = self.tenants.len().max(1);
        let imminent: Vec<bool> = (0..nf)
            .map(|f| {
                ctx.fleet
                    .next_cold_ready_for(f as FunctionId)
                    .is_some_and(|t| t.saturating_sub(now) < crate::config::secs(3.0))
            })
            .collect();
        loop {
            let popped = self.queue.pop_matching(|req, arrival| {
                now.saturating_sub(arrival) > guard
                    && !imminent
                        .get(ctx.func_of(req) as usize)
                        .copied()
                        .unwrap_or(false)
            });
            let Some((req, _)) = popped else { break };
            self.forced_dispatches += 1;
            ctx.dispatch(req);
        }
    }

    /// The control cycle (Fig. 3): forecast → optimize → actuate step 0.
    fn replan(&mut self, ctx: &mut Ctx) {
        self.last_solve_at = Some(ctx.now);
        // 0. elasticity: re-scale the planning pool bound to the live
        // online capacity (the module doc's w_max(t) formula — the same
        // f64 expression as the startup scaling, so a fully-online fleet
        // reproduces the startup bound bit-for-bit)
        if let Some((node_cap, base)) = self.live_capacity {
            let mut w = base * (ctx.fleet.resource_cap() as f64 / node_cap as f64);
            if self.degradation {
                // a failure storm can drop the live capacity to a sliver
                // of the planning pool; floor the bound at one slot so
                // the solver clamps and replans on the survivors instead
                // of chasing an infeasible zero-capacity plan
                w = w.max(1.0);
            }
            self.cc.weights.w_max = w;
            self.solver.set_w_max(w);
        }
        // 1. forecast over the horizon (aggregate + per-function demand
        // shares + the adaptive retention horizons, all inside the
        // reported forecast overhead — each per-function forecast is
        // computed once and feeds both the prewarm split and retention)
        let pad = self.history.recent_mean(self.cc.window);
        let hist = self.history.to_padded_vec(pad);
        let t0 = Instant::now();
        let mut lam = self.forecaster.forecast(&hist, self.cc.horizon);
        // the open interval's arrivals are demand the closed-bin history
        // cannot see yet — fold them into the first forecast step
        lam[0] += self.arrivals_this_interval as f64;
        let mut ka_horizons: Option<Vec<Micros>> = None;
        let shares = if self.tenants.len() > 1 {
            Some(if self.retention.is_some() {
                let (sh, hz) = self.tenant_shares_and_horizons(ctx);
                ka_horizons = Some(hz);
                sh
            } else {
                self.tenant_shares(ctx)
            })
        } else {
            // single-tenant retention planning rides the aggregate
            // forecast (function 0 *is* the workload); the break-even
            // rule charges the fleet's live effective cold cost, which
            // is exactly the profile constant with the cache off
            if let Some(ka) = self.retention {
                ka_horizons = Some(vec![keepalive::plan_horizon_dynamic(
                    &lam,
                    self.cc.dt,
                    ctx.fleet.profile(0),
                    &ka,
                    ctx.fleet.mem_pressure(),
                    ctx.fleet.effective_l_cold(0),
                )]);
            }
            None
        };
        // migration demand: the same per-function lead-window forecast
        // the prewarm split uses (a one-element aggregate when
        // single-tenant). Only materialized when a migration policy is
        // active, so the default path allocates nothing extra.
        let mig_demand: Option<Vec<f64>> =
            if ctx.cfg.fleet.migration.policy != MigrationPolicy::Off {
                Some(match &shares {
                    Some(sh) => sh.clone(),
                    None => {
                        let lead = lead_steps(
                            self.cc.cold_steps,
                            self.cc.dt,
                            ctx.fleet.effective_l_cold(0),
                            ctx.cfg.platform.image.enabled(),
                        );
                        vec![lam.iter().take(lead).sum::<f64>().max(0.0)]
                    }
                })
            } else {
                None
            };
        let forecast_ns = t0.elapsed().as_nanos() as f64;

        // 2. optimize
        let input = MpcInput {
            lam,
            rdy: self.ready_schedule(ctx),
            q0: self.queue.len() as f64,
            w0: ctx.fleet.warm_count() as f64,
            x_prev: self.x_prev,
        };
        let t1 = Instant::now();
        let (z, _cost) = self.solver.solve(&self.warm_start, &input);
        let solve_ns = t1.elapsed().as_nanos() as f64;
        ctx.recorder.on_control_overhead(forecast_ns, solve_ns);

        let plan = repair(
            &z,
            &input,
            &self.cc.weights,
            self.cc.cold_steps,
            ctx.fleet.resource_cap(),
            ctx.fleet.cold_starting_count(),
        );
        // hand the readyCold buffer back to the scratch slot so the next
        // replan reuses it instead of allocating
        self.rdy_scratch = input.rdy;
        let (x0, r0, _s0) = plan.first();
        self.warm_start = plan.shifted_warm_start();
        self.x_prev = x0 as f64;

        // 3. actuate only the first step (receding horizon); the prewarm
        // budget lands per-function in a multi-tenant run
        if x0 > 0 {
            match &shares {
                Some(sh) => {
                    for (f, n) in split_budget(sh, x0).into_iter().enumerate() {
                        if n > 0 {
                            ctx.prewarm_for(f as FunctionId, n);
                        }
                    }
                }
                None => {
                    ctx.prewarm(x0);
                }
            }
        } else if r0 > 0 {
            ctx.reclaim(r0);
        }
        self.last_plan = Some(plan);

        self.try_dispatch(ctx);
        // 3b. retention actuation: install the planned horizons as the
        // fleet's live keep-alive windows and expire idle containers
        // already past them — after the drain, so queued work binds warm
        // capacity before retention releases any of it (None under the
        // fixed policy: the block never runs)
        if let Some(horizons) = ka_horizons {
            for (f, h) in horizons.into_iter().enumerate() {
                ctx.apply_keepalive(f as FunctionId, h);
            }
        }
        // 4. elasticity: rebalance idle warm capacity across nodes under
        // the configured migration policy (no-op when Off). Runs after
        // the dispatch drain so queued work binds warm capacity before
        // any of it moves; an in-flight transfer then counts as an
        // imminent cold-ready for the force-dispatch guard below.
        if let Some(demand) = mig_demand {
            ctx.migrate_rebalance(&demand);
        }
        self.force_stale(ctx);
    }

    /// The adaptive-retention twin of [`MpcScheduler::tenant_shares`]:
    /// one forecast per function (through its registry slot), feeding *both* the prewarm
    /// split share (identical arithmetic to `tenant_shares`) and the
    /// retention horizon (break-even rule over the same forecast, with
    /// the open interval's arrivals folded into the first step exactly
    /// as the aggregate path does). One forecast per function per
    /// replan — never two. Only called under the adaptive policy.
    fn tenant_shares_and_horizons(&mut self, ctx: &Ctx) -> (Vec<f64>, Vec<Micros>) {
        let ka = self.retention.expect("called only under the adaptive policy");
        let dynamic = ctx.cfg.platform.image.enabled();
        let horizon = self.cc.horizon;
        let window = self.cc.window;
        let dt = self.cc.dt;
        let pressure = ctx.fleet.mem_pressure();
        let degradation = self.degradation;
        let mut stale = 0u64;
        let mut shares = Vec::with_capacity(self.tenants.len());
        let mut horizons = Vec::with_capacity(self.tenants.len());
        for (f, t) in self.tenants.iter_mut().enumerate() {
            // the function's live effective cold cost feeds both control
            // rules: the lead window stretches so prewarms launched now
            // land before the demand they cover, and the break-even rule
            // charges what a cold start would actually cost this step
            let eff = ctx.fleet.effective_l_cold(f as FunctionId);
            let lead = lead_steps(self.cc.cold_steps, dt, eff, dynamic);
            let pad = t.history.recent_mean(window);
            let hist = t.history.to_padded_vec(pad);
            let mut lam_f = t.forecaster.forecast(&hist, horizon);
            let mut demand: f64 =
                lam_f.iter().take(lead).sum::<f64>() + t.arrivals_this_interval as f64;
            if degradation {
                let recent = t.history.recent_mean(STALE_RECENT_BINS);
                if forecast_is_stale(recent, pad) {
                    stale += 1;
                    demand = recent * lead as f64 + t.arrivals_this_interval as f64;
                }
            }
            shares.push(demand.max(0.0));
            lam_f[0] += t.arrivals_this_interval as f64;
            let profile = ctx.fleet.profile(f as FunctionId);
            horizons.push(keepalive::plan_horizon_dynamic(
                &lam_f, dt, profile, &ka, pressure, eff,
            ));
        }
        self.stale_discounts += stale;
        (shares, horizons)
    }

    /// Per-function demand over the cold-start lead window (one
    /// forecast per function through its registry slot, same lead as
    /// IceBreaker's sizing) — the
    /// shares the plan's first-step prewarm budget `x_0` is split by,
    /// via the largest-remainder method so the budget is conserved
    /// exactly.
    fn tenant_shares(&mut self, ctx: &Ctx) -> Vec<f64> {
        let dynamic = ctx.cfg.platform.image.enabled();
        let horizon = self.cc.horizon;
        let window = self.cc.window;
        let dt = self.cc.dt;
        let cold_steps = self.cc.cold_steps;
        let degradation = self.degradation;
        let mut stale = 0u64;
        let shares = self
            .tenants
            .iter_mut()
            .enumerate()
            .map(|(f, t)| {
                let lead = lead_steps(
                    cold_steps,
                    dt,
                    ctx.fleet.effective_l_cold(f as FunctionId),
                    dynamic,
                );
                let pad = t.history.recent_mean(window);
                let hist = t.history.to_padded_vec(pad);
                let lam = t.forecaster.forecast(&hist, horizon);
                let mut demand: f64 =
                    lam.iter().take(lead).sum::<f64>() + t.arrivals_this_interval as f64;
                if degradation {
                    let recent = t.history.recent_mean(STALE_RECENT_BINS);
                    let full = t.history.recent_mean(window);
                    if forecast_is_stale(recent, full) {
                        stale += 1;
                        demand = recent * lead as f64 + t.arrivals_this_interval as f64;
                    }
                }
                demand.max(0.0)
            })
            .collect();
        self.stale_discounts += stale;
        shares
    }
}

/// Bins of the short "recent regime" window the stale-forecast guard
/// compares against the full history window.
const STALE_RECENT_BINS: usize = 4;

/// Divergence factor between the recent-regime mean and the window-long
/// mean beyond which the full-window Fourier forecast is considered
/// stale (degradation mode only).
const STALE_DIVERGENCE: f64 = 4.0;

/// True when one mean dwarfs the other by [`STALE_DIVERGENCE`] — the
/// signature of an abrupt popularity shift (a flash crowd inverting the
/// Zipf head/tail) that the window-long history cannot reflect yet. The
/// `> 1.0` floor keeps near-zero-rate noise from triggering it.
fn forecast_is_stale(recent: f64, full: f64) -> bool {
    let hi = recent.max(full);
    let lo = recent.min(full);
    hi.is_finite() && hi > 1.0 && hi > lo * STALE_DIVERGENCE
}

impl Scheduler for MpcScheduler {
    fn on_arrival(&mut self, req: RequestId, ctx: &mut Ctx) {
        self.arrivals_this_interval += 1;
        if !self.tenants.is_empty() {
            let f = ctx.func_of(req) as usize;
            if let Some(t) = self.tenants.get_mut(f) {
                t.arrivals_this_interval += 1;
            }
        }
        self.queue.push(req, ctx.now);
        // serve immediately if a warm container is free — shaping never
        // delays needlessly
        self.try_dispatch(ctx);
        if self.needs_emergency_replan(ctx) {
            self.emergency_replans += 1;
            self.replan(ctx);
        }
    }

    fn on_control_tick(&mut self, ctx: &mut Ctx) {
        // close the interval's arrival bin, then run the control cycle.
        // The selector scores only here — emergency replans re-solve on
        // the same open bin and must not double-count it — and sees the
        // same padded window the routed forecast consumes.
        let realized = self.arrivals_this_interval as f64;
        self.history.push(realized);
        self.arrivals_this_interval = 0;
        if matches!(self.forecaster, FnForecaster::Auto(_)) {
            let pad = self.history.recent_mean(self.cc.window);
            let hist = self.history.to_padded_vec(pad);
            self.forecaster.observe(&hist, realized);
        }
        for t in &mut self.tenants {
            let realized = t.arrivals_this_interval as f64;
            t.history.push(realized);
            t.arrivals_this_interval = 0;
            if matches!(t.forecaster, FnForecaster::Auto(_)) {
                let pad = t.history.recent_mean(self.cc.window);
                let hist = t.history.to_padded_vec(pad);
                t.forecaster.observe(&hist, realized);
            }
        }
        self.replan(ctx);
    }
    fn on_idle_capacity(&mut self, ctx: &mut Ctx) {
        self.try_dispatch(ctx);
    }

    fn tick_interval(&self) -> Option<Micros> {
        Some(self.cc.dt)
    }

    fn queue_len(&self) -> u32 {
        self.queue.len() as u32
    }

    fn forecast_telemetry(&self) -> Option<ForecastTelemetry> {
        let fixed = self.fcfg.backend.name();
        let per_function = if self.tenants.is_empty() {
            vec![(
                0,
                self.forecaster.model_name(fixed),
                self.forecaster.rolling_accuracy_pct(),
            )]
        } else {
            self.tenants
                .iter()
                .enumerate()
                .map(|(f, t)| {
                    (
                        f as FunctionId,
                        t.forecaster.model_name(fixed),
                        t.forecaster.rolling_accuracy_pct(),
                    )
                })
                .collect()
        };
        let selector_switches = self.forecaster.switches()
            + self.tenants.iter().map(|t| t.forecaster.switches()).sum::<u64>();
        Some(ForecastTelemetry {
            backend: fixed,
            selector_switches,
            per_function,
        })
    }

    fn name(&self) -> &'static str {
        "mpc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fleet;
    use crate::config::{ExperimentConfig, Weights};
    use crate::coordinator::Ev;
    use crate::forecast::FourierForecaster;
    use crate::metrics::Recorder;
    use crate::mpc::RustSolver;
    use crate::simulator::EventQueue;

    fn make() -> (MpcScheduler, Fleet, EventQueue<Ev>, Recorder, ExperimentConfig) {
        let cfg = ExperimentConfig::default();
        let cc = cfg.controller.clone();
        let sched = MpcScheduler::new(
            cc.clone(),
            Box::new(FourierForecaster::default()),
            Box::new(RustSolver::new(Weights::default(), 60, cc.cold_steps)),
        );
        let fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
        (sched, fleet, EventQueue::new(), Recorder::new(64), cfg)
    }

    #[test]
    fn arrivals_are_queued_not_forwarded_when_cold() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        let mut ctx = Ctx {
            now: 0,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        rec_arrival(&mut ctx, &mut sched, 0);
        // shaped, not forwarded: no cold start bound to the request —
        // the emergency replan may prewarm (unbound) containers instead
        assert_eq!(sched.queue_len(), 1);
        assert_eq!(ctx.fleet.counters().cold_starts, 0);
        assert!(sched.emergency_replans <= 1);
    }

    fn rec_arrival(ctx: &mut Ctx, sched: &mut MpcScheduler, req: RequestId) {
        ctx.recorder.on_arrival(req, ctx.now);
        sched.on_arrival(req, ctx);
    }

    #[test]
    fn control_tick_produces_feasible_actions() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        // queue a burst then tick
        {
            let mut ctx = Ctx {
                now: 0,
                fleet: &mut fleet,
                events: &mut events,
                recorder: &mut rec,
                cfg: &cfg,
            };
            for req in 0..20 {
                rec_arrival(&mut ctx, &mut sched, req);
            }
        }
        let mut ctx = Ctx {
            now: 30_000_000,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        sched.on_control_tick(&mut ctx);
        // standing queue + zero warm pool must have triggered prewarming
        // (either via the arrival-time emergency replan or this tick)
        assert!(ctx.fleet.cold_starting_count() > 0);
        // overhead recorded for every solve
        assert!(!rec.forecast_ns.is_empty());
        assert_eq!(rec.forecast_ns.len(), rec.solve_ns.len());
    }

    #[test]
    fn force_dispatch_guard_fires() {
        // a fleet that cannot host containers at all: prewarms fail, so
        // the shaped request has nothing to wait for and must be forced
        let mut cfg = ExperimentConfig::default();
        cfg.platform.max_containers = 0;
        let cc = cfg.controller.clone();
        let mut sched = MpcScheduler::new(
            cc.clone(),
            Box::new(FourierForecaster::default()),
            Box::new(RustSolver::new(Weights::default(), 60, cc.cold_steps)),
        );
        let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
        let mut events = EventQueue::new();
        let mut rec = Recorder::new(4);
        {
            let mut ctx = Ctx {
                now: 0,
                fleet: &mut fleet,
                events: &mut events,
                recorder: &mut rec,
                cfg: &cfg,
            };
            rec_arrival(&mut ctx, &mut sched, 0);
        }
        // long after max_shaping_delay, a tick must force it out
        let mut ctx = Ctx {
            now: cfg.controller.max_shaping_delay + 2_000_000,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        sched.on_control_tick(&mut ctx);
        assert_eq!(sched.queue_len(), 0);
        assert!(sched.forced_dispatches >= 1);
        assert_eq!(ctx.fleet.counters().invocations, 1);
    }

    #[test]
    fn w_max_tracks_live_capacity_across_drain_and_rejoin() {
        let mut cfg = ExperimentConfig::default();
        cfg.fleet.nodes = 4;
        let cc = cfg.controller.clone();
        let base = cc.weights.w_max;
        let node_cap = cfg.platform.resource_cap();
        let mut sched = MpcScheduler::new(
            cc.clone(),
            Box::new(FourierForecaster::default()),
            Box::new(RustSolver::new(Weights::default(), 20, cc.cold_steps)),
        )
        .with_live_capacity(node_cap, base);
        let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
        let mut events = EventQueue::new();
        let mut rec = Recorder::new(4);
        {
            let mut ctx = Ctx {
                now: 30_000_000,
                fleet: &mut fleet,
                events: &mut events,
                recorder: &mut rec,
                cfg: &cfg,
            };
            sched.on_control_tick(&mut ctx);
        }
        assert_eq!(sched.cc.weights.w_max, base * 4.0);
        // a drain shrinks the planning bound at the next step...
        fleet.fail_node(2, 31_000_000);
        {
            let mut ctx = Ctx {
                now: 60_000_000,
                fleet: &mut fleet,
                events: &mut events,
                recorder: &mut rec,
                cfg: &cfg,
            };
            sched.on_control_tick(&mut ctx);
        }
        assert_eq!(sched.cc.weights.w_max, base * 3.0);
        // ...and the rejoin restores it (bit-identical to startup)
        fleet.restore_node(2, 61_000_000, None);
        {
            let mut ctx = Ctx {
                now: 90_000_000,
                fleet: &mut fleet,
                events: &mut events,
                recorder: &mut rec,
                cfg: &cfg,
            };
            sched.on_control_tick(&mut ctx);
        }
        assert_eq!(sched.cc.weights.w_max, base * 4.0);
    }

    #[test]
    fn degradation_floors_the_live_capacity_bound() {
        let mut cfg = ExperimentConfig::default();
        cfg.fleet.nodes = 4;
        let cc = cfg.controller.clone();
        let node_cap = cfg.platform.resource_cap();
        // base chosen so a 3-node storm drives the re-scaled bound well
        // below one slot: 0.3 × 1 node online = 0.3
        for (on, expect) in [(false, 0.3), (true, 1.0)] {
            let mut sched = MpcScheduler::new(
                cc.clone(),
                Box::new(FourierForecaster::default()),
                Box::new(RustSolver::new(Weights::default(), 20, cc.cold_steps)),
            )
            .with_live_capacity(node_cap, 0.3)
            .with_degradation(on);
            let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
            fleet.fail_node(1, 1_000_000);
            fleet.fail_node(2, 1_000_000);
            fleet.fail_node(3, 1_000_000);
            let mut events = EventQueue::new();
            let mut rec = Recorder::new(4);
            let mut ctx = Ctx {
                now: 30_000_000,
                fleet: &mut fleet,
                events: &mut events,
                recorder: &mut rec,
                cfg: &cfg,
            };
            sched.on_control_tick(&mut ctx);
            assert_eq!(sched.cc.weights.w_max, expect);
        }
    }

    #[test]
    fn stale_forecast_detection_requires_large_divergence() {
        assert!(forecast_is_stale(8.0, 1.0)); // surging flash head
        assert!(forecast_is_stale(0.0, 5.0)); // collapsed flash tail
        assert!(!forecast_is_stale(3.0, 2.0)); // ordinary drift
        assert!(!forecast_is_stale(0.9, 0.1)); // near-zero noise floor
        assert!(!forecast_is_stale(0.0, 0.0));
        assert!(!forecast_is_stale(f64::NAN, 1.0));
    }

    #[test]
    fn stale_histories_are_discounted_to_the_recent_regime() {
        let cfg = ExperimentConfig::default();
        let cc = cfg.controller.clone();
        let window = cc.window;
        let mut sched = MpcScheduler::new(
            cc.clone(),
            Box::new(FourierForecaster::default()),
            Box::new(RustSolver::new(Weights::default(), 60, cc.cold_steps)),
        )
        .with_functions(2)
        .with_degradation(true);
        let registry = crate::workload::FunctionRegistry::synthesize(2, 1.1, &cfg.platform, 7);
        let mut fleet = Fleet::with_registry(&cfg.fleet, &cfg.platform, &registry, 7);
        // function 0: long-quiet history, sudden surge (a new flash head)
        for _ in 0..window.saturating_sub(STALE_RECENT_BINS) {
            sched.tenants[0].history.push(0.0);
        }
        for _ in 0..STALE_RECENT_BINS {
            sched.tenants[0].history.push(20.0);
        }
        // function 1: steady traffic — its forecast stays authoritative
        for _ in 0..window {
            sched.tenants[1].history.push(5.0);
        }
        let mut events = EventQueue::new();
        let mut rec = Recorder::new(4);
        let ctx = Ctx {
            now: 0,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        let shares = sched.tenant_shares(&ctx);
        assert_eq!(sched.stale_discounts, 1, "exactly the surged function discounts");
        // the discounted share tracks the recent 20 req/interval regime,
        // not the near-zero window mean the Fourier fit would produce
        assert!(shares[0] > shares[1], "the flash head must out-demand steady traffic");
    }

    #[test]
    fn adaptive_retention_shrinks_horizon_and_expires_idle() {
        let mut cfg = ExperimentConfig::default();
        cfg.platform.latency_jitter = 0.0;
        cfg.controller.keepalive.policy = KeepAlivePolicy::Adaptive;
        let cc = cfg.controller.clone();
        let mut sched = MpcScheduler::new(
            cc.clone(),
            Box::new(FourierForecaster::default()),
            Box::new(RustSolver::new(Weights::default(), 60, cc.cold_steps)),
        )
        .with_keepalive(cc.keepalive);
        let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
        // an idle container that has sat well past the 30 s floor
        let (cid, r) = fleet.node_mut(0).platform.prewarm_one(0).unwrap();
        fleet.node_mut(0).platform.container_ready(cid, r);
        let mut events = EventQueue::new();
        let mut rec = Recorder::new(4);
        let mut ctx = Ctx {
            now: r + 100_000_000,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        sched.on_control_tick(&mut ctx);
        // a dead forecast clamps the horizon to the floor fleet-wide...
        assert_eq!(
            ctx.fleet.node(0).platform.effective_keepalive(0),
            cc.keepalive.min
        );
        // ...and the long-idle container is drained (retention sweep, or
        // the plan's own reclaim if it got there first)
        assert_eq!(ctx.fleet.idle_count(), 0);
        let c = ctx.fleet.counters();
        assert!(c.keepalive_expiries + c.reclaims >= 1, "{c:?}");
        // the horizon trajectory is recorded for the report
        assert_eq!(rec.horizon_samples.len(), 1);
        assert_eq!(rec.horizon_samples[0].1, 0);
        assert_eq!(rec.horizon_samples[0].2, cc.keepalive.min);
    }

    #[test]
    fn fixed_keepalive_policy_is_inert_in_the_controller() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        // with_keepalive under Fixed must be a no-op
        sched = sched.with_keepalive(cfg.controller.keepalive);
        let (cid, r) = fleet.node_mut(0).platform.prewarm_one(0).unwrap();
        fleet.node_mut(0).platform.container_ready(cid, r);
        let mut ctx = Ctx {
            now: r + 100_000_000,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        sched.on_control_tick(&mut ctx);
        // no override installed, no horizon samples, profile window live
        assert_eq!(
            ctx.fleet.node(0).platform.effective_keepalive(0),
            cfg.platform.keep_alive
        );
        assert!(rec.horizon_samples.is_empty());
        assert_eq!(ctx.fleet.counters().adaptive_expiries, 0);
    }

    #[test]
    fn idle_capacity_callback_is_a_noop_while_the_queue_is_empty() {
        // the sharded engine's batch precondition (experiments::sharded):
        // while the shaping queue is empty, skipping on_idle_capacity
        // must be observationally safe — no event pushed, no container
        // touched, no metric recorded. An idle warm container makes the
        // dispatch path *available*, proving the no-op is the empty
        // queue, not missing capacity.
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        let (cid, r) = fleet.node_mut(0).platform.prewarm_one(0).unwrap();
        fleet.node_mut(0).platform.container_ready(cid, r);
        let idle_before = fleet.idle_count();
        let counters_before = fleet.counters();
        let mut ctx = Ctx {
            now: r + 1_000_000,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        assert_eq!(sched.queue_len(), 0);
        sched.on_idle_capacity(&mut ctx);
        assert_eq!(ctx.events.len(), 0, "no event may be scheduled");
        assert_eq!(ctx.events.processed(), 0);
        assert_eq!(ctx.fleet.idle_count(), idle_before);
        assert_eq!(ctx.fleet.counters(), counters_before);
    }

    #[test]
    fn migration_pass_rebalances_on_tick_when_enabled() {
        use crate::config::{MigrationConfig, MigrationPolicy};
        let mut cfg = ExperimentConfig::default();
        cfg.fleet.nodes = 2;
        cfg.fleet.migration = MigrationConfig {
            policy: MigrationPolicy::IdleSpread,
            ..Default::default()
        };
        cfg.platform.latency_jitter = 0.0;
        let cc = cfg.controller.clone();
        let mut sched = MpcScheduler::new(
            cc.clone(),
            Box::new(FourierForecaster::default()),
            Box::new(RustSolver::new(Weights::default(), 20, cc.cold_steps)),
        );
        let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
        // all idle capacity piled on node 0
        for i in 0..4u64 {
            let (cid, r) = fleet.node_mut(0).platform.prewarm_one(i).unwrap();
            fleet.node_mut(0).platform.container_ready(cid, r);
        }
        // prime demand history so the plan sustains (not reclaims) the pool
        for _ in 0..10 {
            sched.history.push(50.0);
        }
        let mut events = EventQueue::new();
        let mut rec = Recorder::new(4);
        let mut ctx = Ctx {
            now: 30_000_000,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        sched.on_control_tick(&mut ctx);
        let c = ctx.fleet.counters();
        assert!(c.migrations_out >= 1, "no rebalancing happened: {c:?}");
        assert_eq!(c.migrations_out, c.migrations_in);
    }

    #[test]
    fn lead_window_stretches_with_the_effective_cold_cost() {
        use crate::config::secs;
        let dt = secs(30.0);
        // cache off: the legacy margin, whatever the probe says
        assert_eq!(lead_steps(1, dt, secs(10.5), false), 3);
        assert_eq!(lead_steps(1, dt, secs(1000.0), false), 3);
        // dynamic: a cache-warm cold start never shrinks the window
        // below the configured cold_steps...
        assert_eq!(lead_steps(1, dt, secs(2.625), true), 3);
        // ...the paper constant rounds up to the same window...
        assert_eq!(lead_steps(1, dt, secs(10.5), true), 3);
        // ...and a slow-registry pull (108.2 s effective) stretches it
        // to cover the pull: ceil(108.2/30) = 4 steps + 2 slack
        assert_eq!(lead_steps(1, dt, secs(108.225), true), 6);
        assert_eq!(lead_steps(1, 0, secs(10.5), true), 3, "degenerate dt");
    }

    #[test]
    fn retention_charges_the_live_effective_cold_cost() {
        use crate::config::{to_secs, ImageCacheConfig, ImageCacheMode};
        // slow registry: a cache-cold node pays 2.625 s init + 528 MiB
        // at 5 MiB/s = 108.225 s per cold start — an order of magnitude
        // above the 10.5 s constant
        let mut cfg = ExperimentConfig::default();
        cfg.platform.latency_jitter = 0.0;
        cfg.platform.image = ImageCacheConfig {
            mode: ImageCacheMode::Lru,
            bandwidth_mibps: 5.0,
            ..Default::default()
        };
        cfg.controller.keepalive.policy = KeepAlivePolicy::Adaptive;
        let ka = cfg.controller.keepalive;
        // demand between the two break-even rates: too sparse to retain
        // against the constant, worth retaining against the slow pull
        let be_const = ka.idle_cost_per_s / (ka.cold_cost_weight * 10.5);
        let be_eff = ka.idle_cost_per_s / (ka.cold_cost_weight * 108.225);
        let per_step = (be_const + be_eff) / 2.0 * to_secs(cfg.controller.dt);
        let run = |cfg: &ExperimentConfig| {
            let cc = cfg.controller.clone();
            let mut sched = MpcScheduler::new(
                cc.clone(),
                Box::new(FourierForecaster::default()),
                Box::new(RustSolver::new(Weights::default(), 60, cc.cold_steps)),
            )
            .with_keepalive(cc.keepalive);
            for _ in 0..10 {
                sched.history.push(per_step);
            }
            let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
            let mut events = EventQueue::new();
            let mut rec = Recorder::new(4);
            let mut ctx = Ctx {
                now: 30_000_000,
                fleet: &mut fleet,
                events: &mut events,
                recorder: &mut rec,
                cfg,
            };
            sched.on_control_tick(&mut ctx);
            fleet.node(0).platform.effective_keepalive(0)
        };
        // cache-cold fleet: the same sparse demand clears the (much
        // lower) dynamic break-even → retained past the floor
        assert!(run(&cfg) > ka.min, "dynamic cost must extend retention");
        // constant-cost control (cache off): below break-even → floor
        cfg.platform.image = ImageCacheConfig::default();
        assert_eq!(run(&cfg), ka.min);
    }

    #[test]
    fn fourier_forecast_config_is_inert_and_reports_structural_zero() {
        use crate::config::ForecastConfig;
        let (sched, ..) = make();
        // aggressive selector knobs under the fourier backend: the
        // builder must not touch the registry (seed path, byte for byte)
        let sched = sched.with_forecast(&ForecastConfig {
            score_window: 2,
            hysteresis: 0.0,
            warmup_bins: 1,
            ..Default::default()
        });
        assert!(matches!(sched.forecaster, FnForecaster::Fixed(_)));
        let t = sched.forecast_telemetry().unwrap();
        assert_eq!(t.backend, "fourier");
        assert_eq!(t.selector_switches, 0);
        assert_eq!(t.per_function, vec![(0, "fourier", 0.0)]);
    }

    #[test]
    fn auto_installs_a_selector_per_registry_slot() {
        use crate::config::{ForecastBackend, ForecastConfig};
        let cfg = ExperimentConfig::default();
        let cc = cfg.controller.clone();
        let fc = ForecastConfig {
            backend: ForecastBackend::Auto,
            ..Default::default()
        };
        let sched = MpcScheduler::new(
            cc.clone(),
            Box::new(FourierForecaster::default()),
            Box::new(RustSolver::new(Weights::default(), 20, cc.cold_steps)),
        )
        .with_functions(3)
        .with_forecast(&fc);
        assert!(matches!(sched.forecaster, FnForecaster::Auto(_)));
        assert_eq!(sched.tenants.len(), 3);
        for t in &sched.tenants {
            assert!(matches!(t.forecaster, FnForecaster::Auto(_)));
        }
        let tel = sched.forecast_telemetry().unwrap();
        assert_eq!(tel.backend, "auto");
        assert_eq!(tel.selector_switches, 0);
        assert_eq!(tel.per_function.len(), 3);
        // the selector starts every slot on the zoo's first backend
        assert!(tel.per_function.iter().all(|&(_, m, _)| m == "fourier"));
    }

    #[test]
    fn fixed_nonfourier_backend_swaps_every_slot() {
        use crate::config::{ForecastBackend, ForecastConfig};
        let cfg = ExperimentConfig::default();
        let cc = cfg.controller.clone();
        let fc = ForecastConfig {
            backend: ForecastBackend::Histogram,
            ..Default::default()
        };
        let sched = MpcScheduler::new(
            cc.clone(),
            Box::new(FourierForecaster::default()),
            Box::new(RustSolver::new(Weights::default(), 20, cc.cold_steps)),
        )
        .with_functions(2)
        .with_forecast(&fc);
        let tel = sched.forecast_telemetry().unwrap();
        assert_eq!(tel.backend, "histogram");
        assert_eq!(tel.selector_switches, 0, "fixed backends never switch");
        assert_eq!(
            tel.per_function,
            vec![(0, "histogram", 0.0), (1, "histogram", 0.0)]
        );
    }

    #[test]
    fn auto_controller_ticks_deterministically() {
        use crate::config::{ForecastBackend, ForecastConfig};
        let run = || {
            let cfg = ExperimentConfig::default();
            let cc = cfg.controller.clone();
            let mut sched = MpcScheduler::new(
                cc.clone(),
                Box::new(FourierForecaster::default()),
                Box::new(RustSolver::new(Weights::default(), 20, cc.cold_steps)),
            )
            .with_forecast(&ForecastConfig {
                backend: ForecastBackend::Auto,
                warmup_bins: 2,
                score_window: 4,
                ..Default::default()
            });
            let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
            let mut events = EventQueue::new();
            let mut rec = Recorder::new(16);
            for step in 0u64..12 {
                let mut ctx = Ctx {
                    now: (step + 1) * 30_000_000,
                    fleet: &mut fleet,
                    events: &mut events,
                    recorder: &mut rec,
                    cfg: &cfg,
                };
                // a square-wave demand bin so the selector has signal
                sched.arrivals_this_interval = if step % 4 < 2 { 12 } else { 0 };
                sched.on_control_tick(&mut ctx);
            }
            let tel = sched.forecast_telemetry().unwrap();
            (
                tel.per_function[0].1,
                tel.selector_switches,
                fleet.counters().cold_starts,
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn prewarm_budget_lands_on_least_provisioned_nodes() {
        // 3-node fleet: the controller's aggregate prewarm budget must be
        // split across nodes by per-node telemetry, not dumped on one
        let mut cfg = ExperimentConfig::default();
        cfg.fleet.nodes = 3;
        let mut fleet = Fleet::new(&cfg.fleet, &cfg.platform, 7);
        let mut events = EventQueue::new();
        let mut rec = Recorder::new(4);
        let mut ctx = Ctx {
            now: 0,
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        assert_eq!(ctx.prewarm(6), 6);
        for (_, online, _, load) in ctx.fleet.node_loads() {
            assert!(online);
            assert_eq!(load, 2, "budget skewed: {:?}", ctx.fleet.node_loads());
        }
    }
}
