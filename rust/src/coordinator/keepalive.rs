//! Adaptive per-function keep-alive (retention control) — the third leg
//! of the control triangle the paper's MPC closes: prewarm (Eq. 14's
//! `x_k`), dispatch (Algorithm 1), and now **retain**.
//!
//! The workload registry ships a static per-function keep-alive window
//! (OpenWhisk's 10-minute default); SPES (arXiv:2403.17574) shows that
//! most of the performance/resource trade-off lives in adapting that
//! horizon to each function's predicted inter-arrival pattern. This
//! module derives the horizon each control step from the *same*
//! lead-window forecasts the prewarm split already consumes — whatever
//! backend the model zoo routed that function through (Fourier by
//! default; ARIMA, histogram, attention, or the online `auto` selector
//! under `--forecast`, see [`crate::forecast::selector`]):
//!
//! ```text
//! keep a warm container of f alive at forecast step k only while
//!     λ_f(k) / Δt   ≥   idle_cost_per_s / (cold_cost_weight × L_cold(f))
//!     └─ rate ──┘       └──────────── break-even rate ───────────────┘
//! ```
//!
//! The left side is the forecast arrival rate (arrivals/second) at step
//! `k`; the right side is the rate at which an idle container's holding
//! cost is exactly repaid by the cold starts it is expected to absorb.
//! The horizon is the span of *leading* forecast steps that pass the
//! test, clamped to `[min, profile keep-alive]` (the planner may only
//! shrink retention, never extend it past the profile) and optionally
//! scaled down under memory pressure (`pressure_weight`).
//!
//! Degenerate inputs must never panic the control loop (cf. the
//! `f64::total_cmp` NaN satellite of the indexed-platform PR): a
//! non-finite or non-positive cold saving makes the break-even rate
//! unbeatable (horizon clamps to `min`), a non-positive idle cost makes
//! it free (horizon clamps to the profile window), and NaN forecast
//! steps terminate the horizon instead of poisoning the comparison.
//!
//! Actuation lives in the controller ([`crate::coordinator::Ctx::apply_keepalive`]):
//! the planned horizon becomes the fleet-wide *live* override consulted
//! by every future expiry check, and idle containers already past it
//! are expired immediately via the platform's indexed sweep
//! (`Platform::expire_idle_older_than`). Under
//! [`KeepAlivePolicy::Fixed`](crate::config::KeepAlivePolicy) none of
//! this runs and the system is bit-identical to the pre-retention code.

use crate::config::{to_secs, KeepAliveConfig, Micros};
use crate::workload::tenant::FunctionProfile;

/// Break-even arrival rate (arrivals per second): retention pays while
/// the forecast rate is at least `idle_cost_per_s / cold_save_s`.
///
/// Guards (never panic, never produce NaN-poisoned comparisons):
/// a non-positive or non-finite saving can never repay holding cost —
/// the rate is `+∞` (nothing is retained past the floor); a
/// non-positive or non-finite idle cost makes retention free — the rate
/// is `0` (everything is retained to the profile window).
pub fn break_even_rate(idle_cost_per_s: f64, cold_save_s: f64) -> f64 {
    // NaN falls into the !is_finite arm, so no negated float comparison
    // is ever evaluated on it
    if !cold_save_s.is_finite() || cold_save_s <= 0.0 {
        return f64::INFINITY;
    }
    if !idle_cost_per_s.is_finite() || idle_cost_per_s <= 0.0 {
        return 0.0;
    }
    idle_cost_per_s / cold_save_s
}

/// Horizon from a per-step forecast: the span of leading steps of `lam`
/// (arrivals per `dt` interval) whose per-second rate beats `be_rate`,
/// clamped to `[min, max]`. A NaN forecast step fails the comparison
/// and terminates the horizon (no panic, no `NaN >=` surprises).
pub fn horizon_from_forecast(
    lam: &[f64],
    dt: Micros,
    be_rate: f64,
    min: Micros,
    max: Micros,
) -> Micros {
    let lo = min.min(max);
    let dt_s = to_secs(dt);
    if dt_s <= 0.0 {
        return lo;
    }
    let mut span: Micros = 0;
    for &l in lam {
        let rate = l / dt_s;
        // NaN rate or NaN threshold both fail this test, ending the
        // horizon — the conservative outcome
        let keeps_paying = rate.is_finite() && rate >= be_rate;
        if !keeps_paying {
            break;
        }
        span = span.saturating_add(dt);
    }
    span.clamp(lo, max)
}

/// Shrink a planned horizon under memory pressure: scale by
/// `1 − weight × pressure`, floored at `min` (and never above the
/// unscaled horizon). Inert at `weight <= 0` or non-finite inputs.
pub fn pressure_scaled(horizon: Micros, min: Micros, pressure: f64, weight: f64) -> Micros {
    if !weight.is_finite() || weight <= 0.0 || !pressure.is_finite() {
        return horizon;
    }
    let lo = min.min(horizon);
    // weight and pressure are finite here, so the scale is a number
    let scale = 1.0 - weight * pressure.max(0.0);
    if scale <= 0.0 {
        return lo;
    }
    let scaled = (horizon as f64 * scale.min(1.0)).round() as Micros;
    scaled.clamp(lo, horizon)
}

/// One function's keep-alive horizon for this control step: break-even
/// rule over its forecast, clamped to `[cfg.min, profile keep-alive]`,
/// pressure-scaled. This is the whole retention planner — it is pure,
/// so the controller can call it per function with whatever forecast
/// vector drives that function (the aggregate λ single-tenant, the
/// per-function Fourier forecast multi-tenant).
///
/// Charges the profile's *constant* `L_cold` — the paper's model. Under
/// the image-cache cold-start model the controller calls
/// [`plan_horizon_dynamic`] with the fleet's live effective cost
/// instead.
pub fn plan_horizon(
    lam: &[f64],
    dt: Micros,
    profile: &FunctionProfile,
    cfg: &KeepAliveConfig,
    pressure: f64,
) -> Micros {
    plan_horizon_dynamic(lam, dt, profile, cfg, pressure, profile.l_cold)
}

/// [`plan_horizon`] with the cold-start cost supplied by the caller —
/// the image-cache coupling point. `l_cold_eff` is the fleet's live
/// effective `L_cold(f)` (init + worst-case pull) this control step, so
/// a cache-cold fleet (big saving per absorbed cold start) lowers the
/// break-even rate and retains longer, while a cache-warm fleet (cold
/// starts are cheap anyway) retains less.
///
/// Per-function deployment knobs: a profile may override the global
/// `idle_cost_per_s` / `cold_cost_weight` economics
/// ([`FunctionProfile::idle_cost`] / [`FunctionProfile::cold_cost_weight`]);
/// `None` falls back to the config's globals, so registries that never
/// set them plan exactly as before.
pub fn plan_horizon_dynamic(
    lam: &[f64],
    dt: Micros,
    profile: &FunctionProfile,
    cfg: &KeepAliveConfig,
    pressure: f64,
    l_cold_eff: Micros,
) -> Micros {
    let max = profile.keep_alive;
    let min = cfg.min.min(max);
    let idle_cost = profile.idle_cost.unwrap_or(cfg.idle_cost_per_s);
    let weight = profile.cold_cost_weight.unwrap_or(cfg.cold_cost_weight);
    let be = break_even_rate(idle_cost, weight * to_secs(l_cold_eff));
    let h = horizon_from_forecast(lam, dt, be, min, max);
    pressure_scaled(h, min, pressure, cfg.pressure_weight)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{secs, KeepAlivePolicy, PlatformConfig};
    use crate::workload::tenant::FunctionRegistry;

    fn profile() -> FunctionProfile {
        // the paper profile: L_cold 10.5 s, keep-alive 600 s
        FunctionRegistry::single(&PlatformConfig::default()).get(0).clone()
    }

    fn cfg() -> KeepAliveConfig {
        KeepAliveConfig {
            policy: KeepAlivePolicy::Adaptive,
            ..Default::default()
        }
    }

    #[test]
    fn zero_forecast_clamps_to_min() {
        let p = profile();
        let lam = vec![0.0; 24];
        assert_eq!(plan_horizon(&lam, secs(30.0), &p, &cfg(), 0.0), cfg().min);
        // an empty forecast behaves the same
        assert_eq!(plan_horizon(&[], secs(30.0), &p, &cfg(), 0.0), cfg().min);
    }

    #[test]
    fn forecast_above_break_even_everywhere_clamps_to_profile() {
        let p = profile();
        // 24 steps × 30 s = 720 s of qualifying demand > the 600 s window
        let lam = vec![100.0; 24];
        assert_eq!(plan_horizon(&lam, secs(30.0), &p, &cfg(), 0.0), p.keep_alive);
    }

    #[test]
    fn horizon_tracks_the_leading_qualifying_span() {
        let be = break_even_rate(1.0, 16.0 * 10.5); // ≈ 0.00595 arrivals/s
        // per-step count that exactly beats / misses the threshold
        let hot = be * 30.0 + 1.0;
        let lam = vec![hot, hot, hot, 0.0, hot];
        // 3 leading qualifying steps → 90 s; the post-gap demand is the
        // prewarm planner's problem, not retention's
        let h = horizon_from_forecast(&lam, secs(30.0), be, secs(30.0), secs(600.0));
        assert_eq!(h, secs(90.0));
    }

    #[test]
    fn degenerate_costs_never_panic() {
        let p = profile();
        let lam = vec![1000.0; 24];
        let dt = secs(30.0);
        // zero / negative / NaN / infinite cold saving: a non-finite or
        // non-positive saving never beats the break-even → the floor
        for w in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let ka = KeepAliveConfig {
                cold_cost_weight: w,
                ..cfg()
            };
            assert_eq!(plan_horizon(&lam, dt, &p, &ka, 0.0), ka.min, "w={w}");
        }
        // zero / NaN idle cost: retention is free → profile window
        for c in [0.0, -3.0, f64::NAN] {
            let ka = KeepAliveConfig {
                idle_cost_per_s: c,
                ..cfg()
            };
            assert_eq!(plan_horizon(&lam, dt, &p, &ka, 0.0), p.keep_alive, "c={c}");
        }
        // NaN forecast steps terminate the horizon instead of poisoning it
        let poisoned = vec![f64::NAN; 24];
        assert_eq!(plan_horizon(&poisoned, dt, &p, &cfg(), 0.0), cfg().min);
    }

    #[test]
    fn min_above_profile_caps_at_profile() {
        let p = profile();
        let ka = KeepAliveConfig {
            min: secs(9_000.0),
            ..cfg()
        };
        // the planner may never extend retention past the profile window
        assert_eq!(plan_horizon(&[1e6; 24], secs(30.0), &p, &ka, 0.0), p.keep_alive);
        assert_eq!(plan_horizon(&[0.0; 24], secs(30.0), &p, &ka, 0.0), p.keep_alive);
    }

    #[test]
    fn pressure_scaling_shrinks_but_respects_the_floor() {
        let min = secs(30.0);
        let h = secs(600.0);
        // weight 0 (the default) is inert
        assert_eq!(pressure_scaled(h, min, 0.9, 0.0), h);
        // halving pressure × unit weight halves the horizon
        assert_eq!(pressure_scaled(h, min, 0.5, 1.0), secs(300.0));
        // saturated pressure clamps at the floor, never below
        assert_eq!(pressure_scaled(h, min, 1.0, 1.0), min);
        assert_eq!(pressure_scaled(h, min, 5.0, 2.0), min);
        // degenerate inputs are inert, not panics
        assert_eq!(pressure_scaled(h, min, f64::NAN, 1.0), h);
        assert_eq!(pressure_scaled(h, min, 0.5, f64::NAN), h);
        // negative pressure never extends the horizon
        assert_eq!(pressure_scaled(h, min, -3.0, 1.0), h);
    }

    #[test]
    fn dynamic_cold_cost_moves_the_retention_horizon() {
        let p = profile();
        let ka = cfg();
        let dt = secs(30.0);
        // an arrival rate that beats break-even at the paper constant
        // (10.5 s) but not when the fleet is cache-warm and a cold start
        // costs only the init slice (2.625 s)
        let be_const = break_even_rate(ka.idle_cost_per_s, ka.cold_cost_weight * 10.5);
        let be_warm = break_even_rate(ka.idle_cost_per_s, ka.cold_cost_weight * 2.625);
        let rate = (be_const + be_warm) / 2.0;
        let lam = vec![rate * 30.0; 4];
        assert_eq!(
            plan_horizon_dynamic(&lam, dt, &p, &ka, 0.0, secs(10.5)),
            secs(120.0),
            "cache-cold cost retains through the whole forecast"
        );
        assert_eq!(
            plan_horizon_dynamic(&lam, dt, &p, &ka, 0.0, secs(2.625)),
            ka.min,
            "cache-warm cost drops the same demand to the floor"
        );
        // the static entry point is the dynamic one at the constant
        assert_eq!(
            plan_horizon(&lam, dt, &p, &ka, 0.0),
            plan_horizon_dynamic(&lam, dt, &p, &ka, 0.0, p.l_cold)
        );
        // monotone: a costlier cold start never shortens retention
        let mut prev = 0;
        for eff in [1.0, 2.625, 5.0, 7.905, 10.5, 20.0] {
            let h = plan_horizon_dynamic(&lam, dt, &p, &ka, 0.0, secs(eff));
            assert!(h >= prev, "horizon shrank as L_cold grew to {eff}s");
            prev = h;
        }
    }

    #[test]
    fn per_function_knobs_override_the_global_economics() {
        let ka = cfg();
        let dt = secs(30.0);
        let base = profile();
        assert_eq!(base.idle_cost, None);
        assert_eq!(base.cold_cost_weight, None);
        // demand comfortably above the global break-even: full retention
        let be = break_even_rate(ka.idle_cost_per_s, ka.cold_cost_weight * to_secs(base.l_cold));
        let lam = vec![be * 30.0 * 1.5; 24];
        assert_eq!(plan_horizon(&lam, dt, &base, &ka, 0.0), base.keep_alive);
        // a 10× idle-cost premium pushes the same demand under break-even
        let pricey = FunctionProfile {
            idle_cost: Some(ka.idle_cost_per_s * 10.0),
            ..base.clone()
        };
        assert_eq!(plan_horizon(&lam, dt, &pricey, &ka, 0.0), ka.min);
        // a near-zero cold-cost weight (cold starts barely hurt) too
        let tolerant = FunctionProfile {
            cold_cost_weight: Some(ka.cold_cost_weight / 100.0),
            ..base.clone()
        };
        assert_eq!(plan_horizon(&lam, dt, &tolerant, &ka, 0.0), ka.min);
        // overrides compose with the dynamic cost path unchanged
        assert_eq!(
            plan_horizon_dynamic(&lam, dt, &pricey, &ka, 0.0, base.l_cold),
            ka.min
        );
    }

    #[test]
    fn break_even_rate_edges() {
        assert_eq!(break_even_rate(1.0, 168.0), 1.0 / 168.0);
        assert_eq!(break_even_rate(1.0, 0.0), f64::INFINITY);
        assert_eq!(break_even_rate(1.0, f64::NAN), f64::INFINITY);
        assert_eq!(break_even_rate(1.0, f64::INFINITY), f64::INFINITY);
        assert_eq!(break_even_rate(0.0, 10.0), 0.0);
        assert_eq!(break_even_rate(f64::NAN, 10.0), 0.0);
    }
}
