//! The scheduling coordinator: the `Scheduler` policy interface, the
//! actuation context shared by all policies, and the paper's MPC
//! controller ([`controller::MpcScheduler`]).
//!
//! Actuation targets the invoker [`Fleet`]: the dispatch actuator routes
//! through the placement layer, the prewarm actuator splits the budget
//! across nodes from per-node telemetry, and reclaim drains the globally
//! best candidates. With a one-node fleet all of it degenerates to the
//! legacy single-platform behavior.

pub mod controller;
pub mod keepalive;
pub mod queue;
pub mod survival;

use crate::cluster::container::ContainerId;
use crate::cluster::fleet::{Fleet, NodeId};
use crate::cluster::platform::InvokeOutcome;
use crate::cluster::RequestId;
use crate::config::{ExperimentConfig, Micros};
use crate::metrics::Recorder;
use crate::simulator::EventQueue;
use crate::workload::tenant::FunctionId;

/// Simulation events shared by the runner and the policies. Container
/// events carry the node they live on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ev {
    /// A request arrives from the workload.
    Arrival(RequestId),
    /// A cold-starting container finishes initialization.
    Ready(NodeId, ContainerId),
    /// An execution completes on a container.
    Done(NodeId, ContainerId),
    /// Policy control tick (every Δt for MPC / IceBreaker).
    Control,
    /// Telemetry scrape (paper: 1-minute cadence).
    Sample,
    /// Keep-alive expiry check for a container.
    KeepAlive(NodeId, ContainerId),
    /// Invoker node goes offline (drain scenario).
    NodeFail(NodeId),
    /// A drained invoker node rejoins the fleet, cold (restore
    /// scenario). Carries the optional replica-cap override so multiple
    /// scheduled restores need no side-channel config lookup.
    NodeRestore(NodeId, Option<u32>),
    /// A chaos-faulted request's backoff elapsed: redispatch it.
    ChaosRetry(RequestId),
    /// A chaos-straggling execution hit its per-function deadline: kill
    /// the container and retry the request.
    ChaosTimeout(NodeId, ContainerId),
}

/// Everything a policy may touch while handling an event. Provides the
/// actuator primitives (dispatch / prewarm / reclaim) so policies cannot
/// bypass metrics or event bookkeeping.
pub struct Ctx<'a> {
    pub now: Micros,
    pub fleet: &'a mut Fleet,
    pub events: &'a mut EventQueue<Ev>,
    pub recorder: &'a mut Recorder,
    pub cfg: &'a ExperimentConfig,
}

impl Ctx<'_> {
    /// Function of a request, as recorded at arrival (function 0 when the
    /// workload is single-tenant).
    pub fn func_of(&self, req: RequestId) -> FunctionId {
        self.recorder.func_of(req)
    }

    /// Dispatch actuator: submit `req` to the fleet (Algorithm 1's
    /// `submitRequestAsync`) under the function recorded at arrival; the
    /// placement layer picks the node for that function. Schedules the
    /// follow-up events and records dispatch/cold metadata. Returns the
    /// outcome so shaping policies can see whether placement actually
    /// consumed warm capacity.
    pub fn dispatch(&mut self, req: RequestId) -> InvokeOutcome {
        self.recorder.on_dispatch(req, self.now);
        let func = self.recorder.func_of(req);
        let (node, outcome) = self.fleet.invoke_for(req, func, self.now);
        match outcome {
            InvokeOutcome::WarmStart { cid, done_at } => {
                self.push_exec(node, cid, req, done_at);
            }
            InvokeOutcome::ColdStart { cid, ready_at } => {
                if self.fleet.chaos_spawn_fails() {
                    // the spawn was attempted (the platform already
                    // counted the cold start and consumed its jitter
                    // roll) but the container dies before ready; the
                    // request's cold flag reflects its eventual
                    // successful attempt, so on_cold is skipped here
                    self.fleet.abort_spawn(node, cid, self.now);
                    self.chaos_retry_or_drop(req, node);
                } else {
                    self.recorder.on_cold(req);
                    self.events.push(ready_at, Ev::Ready(node, cid));
                }
            }
            InvokeOutcome::AtCapacity => {
                // node-local FCFS backlog; completion events flow from the
                // container that eventually picks it up
            }
        }
        outcome
    }

    /// Schedule the completion of an execution that just started on
    /// `(node, cid)`, letting the chaos engine stretch it (straggler) or
    /// bound it at the per-function timeout. With chaos off this is
    /// exactly `events.push(done_at, Done(node, cid))`.
    pub fn push_exec(&mut self, node: NodeId, cid: ContainerId, req: RequestId, done_at: Micros) {
        use crate::cluster::chaos::ExecFate;
        let func = self.recorder.func_of(req);
        match self.fleet.chaos_exec_fate(func, self.now, done_at) {
            ExecFate::Normal => self.events.push(done_at, Ev::Done(node, cid)),
            ExecFate::Stretched(late) => self.events.push(late, Ev::Done(node, cid)),
            ExecFate::TimedOut(deadline) => {
                self.events.push(deadline, Ev::ChaosTimeout(node, cid))
            }
        }
    }

    /// A chaos fault hit `req` on `node`: schedule its retry after the
    /// policy backoff, or drop it when the budget is exhausted (the
    /// request then never completes and surfaces in `RunReport.dropped`).
    pub fn chaos_retry_or_drop(&mut self, req: RequestId, node: NodeId) {
        let Some(backoff) = self.fleet.chaos_retry_decision(req) else {
            return;
        };
        self.fleet.charge_retry(node);
        self.events
            .push(self.now + backoff, Ev::ChaosRetry(req));
    }

    /// Prewarm actuator (Listing 1) for function 0 — the single-tenant
    /// form every pre-tenancy policy used.
    pub fn prewarm(&mut self, n: u32) -> u32 {
        self.prewarm_for(0, n)
    }

    /// Prewarm actuator for one function: launch up to `n` unbound cold
    /// containers of `func`, each on the node least provisioned for it;
    /// returns how many actually started.
    pub fn prewarm_for(&mut self, func: FunctionId, n: u32) -> u32 {
        let mut started = 0;
        for _ in 0..n {
            match self.fleet.prewarm_for(func, self.now) {
                Some((node, cid, ready_at)) => {
                    self.events.push(ready_at, Ev::Ready(node, cid));
                    started += 1;
                }
                None => break,
            }
        }
        started
    }

    /// Reclaim actuator (Algorithm 2): drain up to `n` idle containers
    /// fleet-wide, honoring the activation-log safety check. Returns the
    /// count.
    pub fn reclaim(&mut self, n: u32) -> u32 {
        self.fleet.try_reclaim(n, self.now).len() as u32
    }

    /// Migration actuator (fleet elasticity): run one rebalancing pass
    /// under the configured [`crate::config::MigrationPolicy`], moving
    /// idle warm containers toward nodes whose capacity-proportional
    /// share of `demand` (the caller's per-function forecast over the
    /// cold-start lead window) exceeds their provisioned supply. Each
    /// landed transfer schedules its Ready event at the migration
    /// latency. Returns the number of moves executed. A no-op (zero
    /// fleet probes) with the default `MigrationPolicy::Off`.
    pub fn migrate_rebalance(&mut self, demand: &[f64]) -> u32 {
        let mc = &self.cfg.fleet.migration;
        if mc.policy == crate::config::MigrationPolicy::Off {
            return 0;
        }
        let moves = crate::cluster::fleet::migration::plan(mc, &*self.fleet, demand);
        let mut moved = 0;
        for m in moves {
            // the plan is a heuristic over a snapshot; migrate()
            // re-validates and refuses rather than forcing a stale move
            if let Some((cid, ready_at)) =
                self.fleet.migrate(m.from, m.to, m.func, self.now, mc.latency)
            {
                self.events.push(ready_at, Ev::Ready(m.to, cid));
                moved += 1;
            }
        }
        moved
    }

    /// Retention actuator (adaptive keep-alive): install `horizon` as
    /// the fleet-wide live keep-alive window for `func` — every future
    /// expiry check consults it — and immediately expire idle containers
    /// already past it (scheduled KeepAlive events would only catch them
    /// at the old due times). Records the horizon sample for the
    /// `RunReport` trajectory. Returns how many containers expired.
    /// Never called under `KeepAlivePolicy::Fixed`, which is what keeps
    /// the default path bit-identical.
    pub fn apply_keepalive(&mut self, func: FunctionId, horizon: Micros) -> u32 {
        self.recorder.on_keepalive_horizon(self.now, func, horizon);
        self.fleet.set_keepalive_override(func, Some(horizon));
        self.fleet.expire_idle_older_than(func, horizon, self.now)
    }

    /// Schedule the keep-alive check for a container that just went idle,
    /// at its function's keep-alive window (the platform default when the
    /// container is already gone).
    pub fn schedule_keepalive(&mut self, node: NodeId, cid: ContainerId) {
        let ka = self
            .fleet
            .keepalive_of(node, cid)
            .unwrap_or(self.cfg.platform.keep_alive);
        self.events.push(self.now + ka, Ev::KeepAlive(node, cid));
    }
}

/// Forecast-zoo telemetry a policy may expose for the run report: the
/// configured backend, how often the online selector moved, and the
/// per-function `(function, current model, rolling accuracy %)` rows.
/// Under a fixed backend the selector columns are structurally zero
/// (zero switches, zero accuracy, every row naming the fixed backend).
#[derive(Debug, Clone, PartialEq)]
pub struct ForecastTelemetry {
    pub backend: &'static str,
    pub selector_switches: u64,
    pub per_function: Vec<(FunctionId, &'static str, f64)>,
}

/// Slot-survival telemetry a policy may expose for the run report:
/// containers released early by the survival rule, decisions that kept
/// the full profile window, and the mean at-age-zero reuse probability
/// across decisions. Structurally zero for every other policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SurvivalTelemetry {
    pub releases: u64,
    pub retained: u64,
    pub mean_survival: f64,
}

/// A scheduling policy (OpenWhisk default, IceBreaker, MPC, survival).
pub trait Scheduler {
    /// A request arrived.
    fn on_arrival(&mut self, req: RequestId, ctx: &mut Ctx);

    /// Control tick (only delivered if `tick_interval` is Some).
    fn on_control_tick(&mut self, _ctx: &mut Ctx) {}

    /// A container just became idle (execution finished or prewarm ready
    /// with no backlog) — a dispatch opportunity for shaping policies.
    fn on_idle_capacity(&mut self, _ctx: &mut Ctx) {}

    /// Δt for control ticks; None = purely reactive policy.
    fn tick_interval(&self) -> Option<Micros> {
        None
    }

    /// Requests currently shaped/held by the policy (not yet dispatched).
    fn queue_len(&self) -> u32 {
        0
    }

    /// Forecast-zoo telemetry for the run report; None for policies
    /// without a forecast registry (the runner then keeps the report's
    /// structural-zero defaults).
    fn forecast_telemetry(&self) -> Option<ForecastTelemetry> {
        None
    }

    /// Slot-survival telemetry for the run report; None for policies
    /// without a survival estimator (the runner then keeps the report's
    /// structural-zero defaults).
    fn survival_telemetry(&self) -> Option<SurvivalTelemetry> {
        None
    }

    /// Policy name for reports.
    fn name(&self) -> &'static str;
}
