//! Request queue — the Redis-queue analog (Algorithm 1's source).
//!
//! FIFO of pending requests with arrival timestamps, supporting the batch
//! pop of the dispatch actuator and the age query of the force-dispatch
//! guard.

use std::collections::VecDeque;

use crate::cluster::RequestId;
use crate::config::Micros;

#[derive(Debug, Default)]
pub struct RequestQueue {
    q: VecDeque<(RequestId, Micros)>,
    /// Total requests ever enqueued (for conservation checks).
    pub enqueued: u64,
    /// Total requests ever popped.
    pub popped: u64,
}

impl RequestQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, req: RequestId, arrival: Micros) {
        self.q.push_back((req, arrival));
        self.enqueued += 1;
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Pop the oldest request (Algorithm 1, line 3).
    pub fn pop(&mut self) -> Option<(RequestId, Micros)> {
        let item = self.q.pop_front();
        if item.is_some() {
            self.popped += 1;
        }
        item
    }

    /// Pop the oldest request satisfying `pred(request, arrival)` (FIFO
    /// within the matching subset) — the multi-tenant dispatcher
    /// releases the oldest request whose *function* has idle warm
    /// capacity, so a head-of-line function without capacity cannot
    /// block the others; the force-dispatch guard uses the arrival to
    /// select only stale requests.
    pub fn pop_matching<F: Fn(RequestId, Micros) -> bool>(
        &mut self,
        pred: F,
    ) -> Option<(RequestId, Micros)> {
        let idx = self.q.iter().position(|&(req, at)| pred(req, at))?;
        let item = self.q.remove(idx);
        if item.is_some() {
            self.popped += 1;
        }
        item
    }

    /// Pop up to `n` oldest requests.
    pub fn pop_batch(&mut self, n: usize) -> Vec<(RequestId, Micros)> {
        let take = n.min(self.q.len());
        (0..take).filter_map(|_| self.pop()).collect()
    }

    /// Age of the oldest queued request.
    pub fn oldest_age(&self, now: Micros) -> Option<Micros> {
        self.q.front().map(|&(_, a)| now.saturating_sub(a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = RequestQueue::new();
        q.push(1, 10);
        q.push(2, 20);
        q.push(3, 30);
        assert_eq!(q.pop(), Some((1, 10)));
        assert_eq!(q.pop_batch(5), vec![(2, 20), (3, 30)]);
        assert!(q.is_empty());
    }

    #[test]
    fn conservation_counters() {
        let mut q = RequestQueue::new();
        for i in 0..10 {
            q.push(i, i);
        }
        q.pop_batch(4);
        assert_eq!(q.enqueued, 10);
        assert_eq!(q.popped, 4);
        assert_eq!(q.len(), 6);
        assert_eq!(q.enqueued - q.popped, q.len() as u64);
    }

    #[test]
    fn pop_matching_keeps_fifo_within_subset() {
        let mut q = RequestQueue::new();
        for (req, t) in [(10, 1), (11, 2), (12, 3), (13, 4)] {
            q.push(req, t);
        }
        // pop the oldest even request, then the next
        assert_eq!(q.pop_matching(|r, _| r % 2 == 0), Some((10, 1)));
        assert_eq!(q.pop_matching(|r, _| r % 2 == 0), Some((12, 3)));
        assert_eq!(q.pop_matching(|r, _| r % 2 == 0), None);
        // the arrival timestamp is visible to the predicate
        assert_eq!(q.pop_matching(|_, at| at >= 4), Some((13, 4)));
        // the skipped-over request kept its place
        assert_eq!(q.pop(), Some((11, 2)));
        assert!(q.is_empty());
        assert_eq!(q.popped, 4);
    }

    #[test]
    fn oldest_age() {
        let mut q = RequestQueue::new();
        assert_eq!(q.oldest_age(100), None);
        q.push(1, 40);
        q.push(2, 90);
        assert_eq!(q.oldest_age(100), Some(60));
        q.pop();
        assert_eq!(q.oldest_age(100), Some(10));
    }
}
