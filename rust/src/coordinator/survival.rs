//! Slot-survival lifecycle control (arXiv:2604.05465) — the third
//! policy family next to the MPC plan and the reactive baselines.
//!
//! Where the MPC plans the *fleet* (a prewarm/dispatch/retain program
//! over a forecast horizon) and IceBreaker sizes a warm pool against a
//! point forecast, slot-survival prediction asks a per-container
//! question: *given that this container has already sat idle for `a`
//! seconds, how likely is its function to arrive again before holding
//! it stops paying?* The estimator is the empirical survival function
//! of each function's inter-arrival gaps — the same sliding-window
//! machinery as the SPES histogram backend
//! ([`crate::forecast::histogram`], arXiv:2403.17574), but over gap
//! durations instead of per-interval rates:
//!
//! ```text
//! P(reuse | idle a) = |{g : a < g ≤ a + T_be}| / |{g : g > a}|
//! T_be(f)           = cold_cost_weight × L_cold(f) / idle_cost_per_s
//! ```
//!
//! `T_be` is the break-even window from the retention planner's
//! economics ([`crate::coordinator::keepalive`]): holding an idle
//! container for `T_be` seconds costs exactly one avoided cold start,
//! so a reuse probability below `threshold` over that window means the
//! container is (probabilistically) no longer worth its rent. The
//! **release rule** walks idle age upward through the observed gaps and
//! releases at the first age where the conditional reuse probability
//! drops below the threshold — conditioning is what makes this survival
//! analysis rather than a static timeout: surviving past the intra-burst
//! gap mass *lowers* the reuse odds on bursty workloads (the remaining
//! mass is the long inter-burst tail), which is exactly when a fixed
//! keep-alive idles pointlessly.
//!
//! Actuation reuses the retention planner's live-horizon path
//! ([`Ctx::apply_keepalive`]): each control tick the release age is
//! recorded as the function's horizon and every idle container already
//! past it is expired through the indexed sweep, credited as saved; the
//! override is then restored to the profile window so that all early
//! expiries flow through the tick-time sweep — which is what keeps the
//! release counter exact (`survival_releases == adaptive_expiries`, the
//! conservation law the integration tests pin), at the cost of at most
//! one control interval of extra idle versus leaving the shrunk horizon
//! live between ticks. Dispatch stays purely reactive (no shaping, no
//! prewarm — lifecycle control is the whole policy), and each control
//! tick also feeds a survival-weighted per-function demand vector to
//! the migration pass ([`Ctx::migrate_rebalance`]), which closes the
//! "migration under reactive policies" carry-over: the same
//! demand-gap/idle-spread planners run, just fed survival scores
//! instead of MPC lead-window forecasts.

use std::time::Instant;

use crate::cluster::RequestId;
use crate::config::{secs, to_secs, ControllerConfig, Micros};
use crate::coordinator::{Ctx, Scheduler, SurvivalTelemetry};
use crate::workload::tenant::FunctionId;

/// Break-even idle window in seconds: holding an idle container this
/// long costs exactly one avoided cold start. Guards mirror
/// [`crate::coordinator::keepalive::break_even_rate`]: a non-finite or
/// non-positive saving means retention never pays (zero window); a
/// non-finite or non-positive idle cost means it is free (infinite
/// window — retain to the profile).
pub fn break_even_window_s(idle_cost_per_s: f64, cold_save_s: f64) -> f64 {
    if !cold_save_s.is_finite() || cold_save_s <= 0.0 {
        return 0.0;
    }
    if !idle_cost_per_s.is_finite() || idle_cost_per_s <= 0.0 {
        return f64::INFINITY;
    }
    cold_save_s / idle_cost_per_s
}

/// Empirical conditional reuse probability: of the observed gaps longer
/// than `age_s`, the fraction landing within the next `window_s`. Zero
/// when no observed gap exceeds `age_s` (the history offers no evidence
/// the container will ever be reused at this age). NaN gaps compare
/// false on both tests and therefore never count.
pub fn survival_probability(gaps: &[f64], age_s: f64, window_s: f64) -> f64 {
    let mut alive = 0u32;
    let mut hits = 0u32;
    for &g in gaps {
        if g > age_s {
            alive += 1;
            if g <= age_s + window_s {
                hits += 1;
            }
        }
    }
    if alive == 0 {
        return 0.0;
    }
    hits as f64 / alive as f64
}

/// The release rule: the smallest idle age at which the conditional
/// reuse probability over the next `window_s` drops below `threshold`.
/// Candidate ages are `0` and each observed gap (the survival function
/// is a step function — it only changes where a gap ends). `None` means
/// the probability never drops below the threshold at any observed age:
/// retain to the profile window.
pub fn release_age(gaps_sorted: &[f64], window_s: f64, threshold: f64) -> Option<f64> {
    // NaN threshold compares false → retain (the conservative outcome)
    if survival_probability(gaps_sorted, 0.0, window_s) < threshold {
        return Some(0.0);
    }
    for &g in gaps_sorted {
        if !g.is_finite() {
            continue;
        }
        if survival_probability(gaps_sorted, g, window_s) < threshold {
            return Some(g);
        }
    }
    None
}

/// One function's survival state: the trailing inter-arrival gaps (the
/// empirical distribution) and the last arrival instant.
#[derive(Debug, Clone, Default)]
struct FnSurvival {
    last_arrival: Option<Micros>,
    /// Trailing gaps in seconds, arrival order (a bounded push-pop
    /// window; sorted copies are taken per decision).
    gaps: Vec<f64>,
}

/// The slot-survival scheduler: reactive dispatch + per-container
/// lifecycle control from empirical inter-arrival survival estimates.
pub struct SurvivalScheduler {
    cc: ControllerConfig,
    fns: Vec<FnSurvival>,
    /// Per-function EWMA of interval arrivals (the survival-weighted
    /// migration demand's magnitude term; same 0.7/0.3 blend as
    /// IceBreaker's fairness split).
    fn_recent: Vec<f64>,
    fn_arrivals: Vec<u32>,
    // --- telemetry (RunReport survival fields) ---
    releases: u64,
    retained: u64,
    p_sum: f64,
    p_count: u64,
}

impl SurvivalScheduler {
    pub fn new(cc: ControllerConfig) -> Self {
        SurvivalScheduler {
            cc,
            fns: vec![FnSurvival::default()],
            fn_recent: vec![0.0],
            fn_arrivals: vec![0],
            releases: 0,
            retained: 0,
            p_sum: 0.0,
            p_count: 0,
        }
    }

    /// Size the per-function estimators for an `n`-function workload.
    pub fn with_functions(mut self, n: usize) -> Self {
        let n = n.max(1);
        self.fns = vec![FnSurvival::default(); n];
        self.fn_recent = vec![0.0; n];
        self.fn_arrivals = vec![0; n];
        self
    }

    /// One function's planned keep-alive horizon this tick, or `None`
    /// while its gap history is too short to out-judge the profile
    /// window. Also returns the at-age-zero reuse probability for the
    /// telemetry trajectory.
    fn plan(&self, f: usize, ctx: &Ctx) -> Option<(Micros, f64)> {
        let st = &self.fns[f];
        if st.gaps.len() < self.cc.survival.min_samples.max(1) {
            return None;
        }
        let profile = ctx.fleet.profile(f as FunctionId);
        let idle_cost = profile.idle_cost.unwrap_or(self.cc.keepalive.idle_cost_per_s);
        let weight = profile
            .cold_cost_weight
            .unwrap_or(self.cc.keepalive.cold_cost_weight);
        // live effective L_cold(f): under the image cache a cache-warm
        // fleet shrinks the break-even window exactly as it shrinks the
        // retention planner's saving
        let cold_save_s = weight * to_secs(ctx.fleet.effective_l_cold(f as FunctionId));
        let t_be = break_even_window_s(idle_cost, cold_save_s);
        let mut gaps = st.gaps.clone();
        gaps.sort_unstable_by(f64::total_cmp);
        let p0 = survival_probability(&gaps, 0.0, t_be);
        let max = profile.keep_alive;
        let min = self.cc.keepalive.min.min(max);
        let horizon = match release_age(&gaps, t_be, self.cc.survival.threshold) {
            Some(age_s) => secs(age_s).clamp(min, max),
            None => max,
        };
        Some((horizon, p0))
    }
}

impl Scheduler for SurvivalScheduler {
    fn on_arrival(&mut self, req: RequestId, ctx: &mut Ctx) {
        let f = (ctx.func_of(req) as usize).min(self.fns.len().saturating_sub(1));
        let st = &mut self.fns[f];
        if let Some(prev) = st.last_arrival {
            st.gaps.push(to_secs(ctx.now.saturating_sub(prev)));
            let cap = self.cc.survival.window.max(1);
            if st.gaps.len() > cap {
                st.gaps.remove(0);
            }
        }
        st.last_arrival = Some(ctx.now);
        self.fn_arrivals[f] += 1;
        ctx.dispatch(req); // reactive: lifecycle control is the policy
    }

    fn on_control_tick(&mut self, ctx: &mut Ctx) {
        for (recent, arr) in self.fn_recent.iter_mut().zip(&mut self.fn_arrivals) {
            *recent = 0.7 * *recent + 0.3 * *arr as f64;
            *arr = 0;
        }

        // estimation pass (the "forecast" of this policy): survival
        // horizons per function, timed like the baselines' predictors
        let t0 = Instant::now();
        let plans: Vec<Option<(Micros, f64)>> =
            (0..self.fns.len()).map(|f| self.plan(f, ctx)).collect();
        let forecast_ns = t0.elapsed().as_nanos() as f64;

        // decision/actuation pass: install horizons (live overrides +
        // indexed expiry sweep) and run the survival-weighted migration
        let t1 = Instant::now();
        let dt_s = to_secs(self.cc.dt);
        let mut demand = vec![0.0; self.fns.len()];
        for (f, plan) in plans.into_iter().enumerate() {
            let Some((horizon, p0)) = plan else {
                // no history verdict: the profile window stands, and the
                // EWMA alone carries the migration demand
                demand[f] = self.fn_recent[f];
                continue;
            };
            self.p_sum += p0;
            self.p_count += 1;
            let profile_window = ctx.fleet.profile(f as FunctionId).keep_alive;
            if horizon < profile_window {
                // early release through the retention actuator (horizon
                // recording + live override + indexed expiry sweep), then
                // restore the profile window: leaving the shrunk override
                // installed would let the *scheduled* keep-alive checks
                // expire containers between ticks, early expiries this
                // counter never sees — releasing only through the
                // tick-time sweep costs at most one control interval of
                // extra idle but keeps every early expiry attributed, the
                // release-credit law the integration tests pin
                // (survival_releases == adaptive_expiries, exactly)
                let expired = ctx.apply_keepalive(f as FunctionId, horizon);
                self.releases += expired as u64;
                ctx.fleet.set_keepalive_override(f as FunctionId, None);
            } else {
                // retain: the profile window is the platform default —
                // record the decision, clear any stale override, and let
                // the scheduled keep-alive checks do their normal work
                self.retained += 1;
                ctx.recorder
                    .on_keepalive_horizon(ctx.now, f as FunctionId, horizon);
                ctx.fleet.set_keepalive_override(f as FunctionId, None);
            }
            // survival-weighted demand: recent arrivals scaled by the
            // odds the next one lands within a control interval
            let mut gaps = self.fns[f].gaps.clone();
            gaps.sort_unstable_by(f64::total_cmp);
            demand[f] = self.fn_recent[f] * survival_probability(&gaps, 0.0, dt_s);
        }
        ctx.migrate_rebalance(&demand);
        let decide_ns = t1.elapsed().as_nanos() as f64;
        ctx.recorder.on_control_overhead(forecast_ns, decide_ns);
    }

    fn tick_interval(&self) -> Option<Micros> {
        Some(self.cc.dt)
    }

    fn survival_telemetry(&self) -> Option<SurvivalTelemetry> {
        Some(SurvivalTelemetry {
            releases: self.releases,
            retained: self.retained,
            mean_survival: if self.p_count > 0 {
                self.p_sum / self.p_count as f64
            } else {
                0.0
            },
        })
    }

    fn name(&self) -> &'static str {
        "survival"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Fleet;
    use crate::config::ExperimentConfig;
    use crate::coordinator::Ev;
    use crate::metrics::Recorder;
    use crate::simulator::EventQueue;

    #[test]
    fn break_even_window_edges() {
        assert_eq!(break_even_window_s(1.0, 168.0), 168.0);
        assert_eq!(break_even_window_s(2.0, 168.0), 84.0);
        assert_eq!(break_even_window_s(1.0, 0.0), 0.0);
        assert_eq!(break_even_window_s(1.0, f64::NAN), 0.0);
        assert_eq!(break_even_window_s(1.0, f64::INFINITY), 0.0);
        assert_eq!(break_even_window_s(0.0, 10.0), f64::INFINITY);
        assert_eq!(break_even_window_s(f64::NAN, 10.0), f64::INFINITY);
    }

    #[test]
    fn survival_probability_conditions_on_age() {
        // bimodal bursty gaps: 6 intra-burst (1 s), 2 inter-burst (300 s)
        let gaps = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 300.0, 300.0];
        // fresh idle: 6 of 8 gaps land within a 10 s window
        assert_eq!(survival_probability(&gaps, 0.0, 10.0), 0.75);
        // having survived past the burst mass, only the 300 s tail
        // remains — and a 10 s window catches none of it
        assert_eq!(survival_probability(&gaps, 5.0, 10.0), 0.0);
        // ...but a window reaching the tail catches all of it
        assert_eq!(survival_probability(&gaps, 5.0, 400.0), 1.0);
        // no gap exceeds the age: no evidence of reuse
        assert_eq!(survival_probability(&gaps, 500.0, 1e9), 0.0);
        // NaN gaps never count on either side
        let poisoned = [f64::NAN, 1.0, 1.0];
        assert_eq!(survival_probability(&poisoned, 0.0, 10.0), 1.0);
    }

    #[test]
    fn release_age_walks_the_survival_steps() {
        let mut gaps = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 300.0, 300.0];
        gaps.sort_unstable_by(f64::total_cmp);
        // a 10 s break-even window: fresh containers are 75% likely to
        // be reused, but past the burst mass the odds hit zero — release
        // at the 1 s step
        assert_eq!(release_age(&gaps, 10.0, 0.5), Some(1.0));
        // an unbeatable threshold releases immediately
        assert_eq!(release_age(&gaps, 10.0, 1.1), Some(0.0));
        // a zero threshold never releases (p < 0 is impossible)
        assert_eq!(release_age(&gaps, 10.0, 0.0), None);
        // a window spanning the tail survives every *observed* age — but
        // past the largest gap there is no reuse evidence left, so the
        // release age lands exactly there
        assert_eq!(release_age(&gaps, 400.0, 0.5), Some(300.0));
        // NaN threshold compares false everywhere → retain
        assert_eq!(release_age(&gaps, 10.0, f64::NAN), None);
    }

    fn make() -> (SurvivalScheduler, Fleet, EventQueue<Ev>, Recorder, ExperimentConfig) {
        let cfg = ExperimentConfig::default();
        let sched = SurvivalScheduler::new(cfg.controller.clone());
        let fleet = Fleet::new(&cfg.fleet, &cfg.platform, 5);
        (sched, fleet, EventQueue::new(), Recorder::new(64), cfg)
    }

    #[test]
    fn forwards_immediately_and_tracks_gaps() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        for (i, t) in [0u64, 2_000_000, 5_000_000].into_iter().enumerate() {
            let mut ctx = Ctx {
                now: t,
                fleet: &mut fleet,
                events: &mut events,
                recorder: &mut rec,
                cfg: &cfg,
            };
            ctx.recorder.on_arrival(i as u64, t);
            sched.on_arrival(i as u64, &mut ctx);
        }
        // no shaping: every arrival dispatched (first cold, rest queued
        // behind the cold start or cold again)
        assert_eq!(sched.queue_len(), 0);
        assert!(fleet.counters().cold_starts >= 1);
        // two gaps recorded: 2 s and 3 s
        assert_eq!(sched.fns[0].gaps, vec![2.0, 3.0]);
    }

    #[test]
    fn thin_history_keeps_the_profile_window() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        // fewer gaps than min_samples: plan() must defer to the profile
        sched.fns[0].gaps = vec![1.0; cfg.controller.survival.min_samples - 1];
        let mut ctx = Ctx {
            now: secs(100.0),
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        assert!(sched.plan(0, &ctx).is_none());
        sched.on_control_tick(&mut ctx);
        let t = sched.survival_telemetry().unwrap();
        assert_eq!(t.releases, 0);
        assert_eq!(t.retained, 0);
        assert_eq!(t.mean_survival, 0.0);
    }

    #[test]
    fn bursty_history_plans_an_early_release_horizon() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        // bimodal history: intra-burst 1 s gaps, inter-burst 500 s gaps.
        // T_be = 16 × 10.5 / 1 = 168 s, so past the burst mass the reuse
        // odds over the break-even window are 0 < 0.5 → release at ~1 s,
        // clamped up to the 30 s keep-alive floor.
        sched.fns[0].gaps = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 500.0, 500.0];
        let ctx = Ctx {
            now: secs(100.0),
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        let (horizon, p0) = sched.plan(0, &ctx).unwrap();
        assert_eq!(horizon, cfg.controller.keepalive.min);
        assert_eq!(p0, 0.75);
        // a steady 100 s cadence inside the break-even window holds the
        // container just past the cadence — not for the full profile
        // window (beyond the largest observed gap the reuse evidence
        // runs out), which is the adaptive win over a fixed keep-alive
        sched.fns[0].gaps = vec![100.0; 8];
        let (horizon, p0) = sched.plan(0, &ctx).unwrap();
        assert_eq!(horizon, secs(100.0));
        assert_eq!(p0, 1.0);
    }

    #[test]
    fn control_tick_actuates_and_records_overhead() {
        let (mut sched, mut fleet, mut events, mut rec, cfg) = make();
        sched.fns[0].gaps = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 500.0, 500.0];
        let mut ctx = Ctx {
            now: secs(100.0),
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        sched.on_control_tick(&mut ctx);
        let t = sched.survival_telemetry().unwrap();
        assert_eq!(t.retained, 0, "a floor horizon is not a retain decision");
        assert_eq!(t.releases, 0, "an empty fleet has nothing to expire");
        assert!((t.mean_survival - 0.75).abs() < 1e-12);
    }

    #[test]
    fn unbeatable_threshold_never_releases_and_counts_retains() {
        let (mut sched, mut fleet, mut events, mut rec, mut cfg) = make();
        cfg.controller.survival.threshold = 0.0; // p < 0 is impossible
        sched.cc.survival.threshold = 0.0;
        sched.fns[0].gaps = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 500.0, 500.0];
        let mut ctx = Ctx {
            now: secs(100.0),
            fleet: &mut fleet,
            events: &mut events,
            recorder: &mut rec,
            cfg: &cfg,
        };
        sched.on_control_tick(&mut ctx);
        let t = sched.survival_telemetry().unwrap();
        assert_eq!(t.retained, 1);
        assert_eq!(t.releases, 0);
    }
}
