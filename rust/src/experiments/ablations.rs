//! Ablations for the design choices DESIGN.md calls out (Sec. V-E
//! discusses the sensitivity): request shaping on/off, horizon length,
//! clipping confidence γ, and cost-weight sensitivity.

use crate::config::{secs, ExperimentConfig, Policy, TraceKind};
use crate::experiments::runner::{make_scheduler, run_experiment, run_with_scheduler};
use crate::metrics::RunReport;
use crate::workload::synthetic::{self, SyntheticConfig};
use crate::workload::Trace;

fn bursty_trace(duration_s: f64, seed: u64) -> Trace {
    synthetic::generate(
        &SyntheticConfig {
            idle_scale: 0.15,
            ..Default::default()
        },
        secs(duration_s),
        seed,
    )
}

fn base_cfg(duration_s: f64, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        trace: TraceKind::SyntheticBursty,
        duration: secs(duration_s),
        seed,
        ..Default::default()
    }
}

/// Shaping ablation: MPC vs "MPC without shaping" (β very high would
/// also work, but the honest ablation is structural: dispatch immediately
/// like IceBreaker while keeping MPC prewarming). Implemented by setting
/// the shaping guard to zero so every queued request is force-dispatched
/// at the next tick.
pub fn shaping_ablation(duration_s: f64, seed: u64) -> (RunReport, RunReport) {
    let trace = bursty_trace(duration_s, seed);
    let cfg = base_cfg(duration_s, seed);
    let with_shaping = run_experiment(&cfg, Policy::Mpc, &trace);

    let mut cfg_no = cfg.clone();
    cfg_no.controller.max_shaping_delay = 0;
    let sched = make_scheduler(&cfg_no, Policy::Mpc);
    let without_shaping = run_with_scheduler(&cfg_no, sched, &trace);
    (with_shaping, without_shaping)
}

/// Horizon sweep: solve quality/latency trade-off (Sec. V-E tuning).
pub fn horizon_sweep(duration_s: f64, seed: u64, horizons: &[usize]) -> Vec<(usize, RunReport)> {
    let trace = bursty_trace(duration_s, seed);
    horizons
        .iter()
        .map(|&h| {
            let mut cfg = base_cfg(duration_s, seed);
            cfg.controller.horizon = h.max(cfg.controller.cold_steps + 2);
            let r = run_experiment(&cfg, Policy::Mpc, &trace);
            (cfg.controller.horizon, r)
        })
        .collect()
}

/// Clipping-confidence sweep (Eq. 2's γ).
pub fn gamma_sweep(duration_s: f64, seed: u64, gammas: &[f64]) -> Vec<(f64, RunReport)> {
    let trace = bursty_trace(duration_s, seed);
    gammas
        .iter()
        .map(|&g| {
            let mut cfg = base_cfg(duration_s, seed);
            cfg.controller.gamma_clip = g;
            (g, run_experiment(&cfg, Policy::Mpc, &trace))
        })
        .collect()
}

/// Cold-start weight sweep (α): higher α should mean fewer cold requests.
pub fn alpha_sweep(duration_s: f64, seed: u64, alphas: &[f64]) -> Vec<(f64, RunReport)> {
    let trace = bursty_trace(duration_s, seed);
    alphas
        .iter()
        .map(|&a| {
            let mut cfg = base_cfg(duration_s, seed);
            cfg.controller.weights.alpha = a;
            (a, run_experiment(&cfg, Policy::Mpc, &trace))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shaping_reduces_cold_requests() {
        let (with, without) = shaping_ablation(600.0, 17);
        assert_eq!(with.dropped, 0);
        assert_eq!(without.dropped, 0);
        assert!(
            with.cold_requests <= without.cold_requests,
            "shaping did not help: with={} without={}",
            with.cold_requests,
            without.cold_requests
        );
    }

    #[test]
    fn horizon_sweep_runs() {
        let rows = horizon_sweep(300.0, 19, &[16, 24]);
        assert_eq!(rows.len(), 2);
        for (_, r) in rows {
            assert_eq!(r.dropped, 0);
        }
    }
}
