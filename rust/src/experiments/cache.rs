//! Image-cache sweep (the `cache-sweep` CLI subcommand and the fig14
//! bench target): the constant-`L_cold` baseline (`--image-cache off`)
//! against the LRU layer cache across a capacity ladder, under the MPC
//! scheduler on a multi-node, multi-function workload.
//!
//! The quantity under test is the **cache-size vs P99 frontier**: a
//! larger per-node layer store absorbs more of each cold start's image
//! distribution (pulled MiB falls, layer hit-rate rises), so the
//! effective `L_cold(f, n)` the controller plans against shrinks toward
//! the irreducible init slice and the tail follows. The sweep reports
//! the hit/miss and pull-byte telemetry alongside the latency columns
//! so the trend is auditable, not inferred.

use crate::config::{
    secs, ExperimentConfig, FleetConfig, ImageCacheConfig, ImageCacheMode, PlacementPolicy,
    Policy, TenantConfig, TraceKind,
};
use crate::experiments::runner::run_tenant;
use crate::metrics::RunReport;
use crate::util::bench::Table;
use crate::workload::TenantWorkload;

/// Shared knobs for every cell of a cache sweep.
#[derive(Debug, Clone)]
pub struct CacheParams {
    pub duration_s: f64,
    pub seed: u64,
    pub nodes: u32,
    pub functions: u32,
    pub zipf_s: f64,
    pub trace: TraceKind,
    /// Registry pull bandwidth (MiB/s) for the enabled cells.
    pub bandwidth_mibps: f64,
    /// Fraction of the profile `L_cold` that is runtime init.
    pub init_fraction: f64,
    /// The capacity ladder (MiB per node); each entry is one LRU cell.
    pub capacities_mib: Vec<u32>,
}

impl Default for CacheParams {
    fn default() -> Self {
        let ic = ImageCacheConfig::default();
        CacheParams {
            duration_s: 3600.0,
            seed: 42,
            nodes: 4,
            functions: 8,
            zipf_s: 1.1,
            trace: TraceKind::SyntheticBursty,
            bandwidth_mibps: ic.bandwidth_mibps,
            init_fraction: ic.init_fraction,
            capacities_mib: vec![256, 512, 1024, 2048, 4096],
        }
    }
}

/// One sweep cell: the off baseline (`capacity_mib == None`) or one LRU
/// capacity rung.
#[derive(Debug, Clone)]
pub struct CacheCell {
    pub capacity_mib: Option<u32>,
    pub report: RunReport,
}

impl CacheCell {
    /// Layer hit rate in percent (0 when the cache never ran).
    pub fn hit_pct(&self) -> f64 {
        let c = &self.report.counters;
        let total = c.layer_hits + c.layer_misses;
        if total == 0 {
            return 0.0;
        }
        100.0 * c.layer_hits as f64 / total as f64
    }
}

/// Experiment config for one cell. `capacity_mib == None` is the
/// constant-`L_cold` baseline (cache off — the regression-pinned seed
/// path); `Some(mib)` enables the LRU store at that per-node capacity.
pub fn cell_config(p: &CacheParams, capacity_mib: Option<u32>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        trace: p.trace,
        fleet: FleetConfig {
            nodes: p.nodes,
            placement: PlacementPolicy::WarmFirst,
            ..Default::default()
        },
        tenancy: TenantConfig {
            functions: p.functions,
            zipf_s: p.zipf_s,
        },
        duration: secs(p.duration_s),
        seed: p.seed,
        ..Default::default()
    };
    cfg.platform.image = match capacity_mib {
        None => ImageCacheConfig::default(),
        Some(mib) => ImageCacheConfig {
            mode: ImageCacheMode::Lru,
            capacity_mib: mib,
            bandwidth_mibps: p.bandwidth_mibps,
            init_fraction: p.init_fraction,
        },
    };
    cfg
}

/// Run the sweep under the MPC scheduler: the off baseline first, then
/// one cell per capacity rung, all against the *same* generated
/// workload (the image model changes costs, never arrivals).
pub fn run_sweep(p: &CacheParams) -> Vec<CacheCell> {
    let base = cell_config(p, None);
    let workload = TenantWorkload::generate(
        p.trace,
        base.duration,
        p.seed,
        p.functions,
        p.zipf_s,
        &base.platform,
    );
    let mut cells = Vec::with_capacity(p.capacities_mib.len() + 1);
    cells.push(CacheCell {
        capacity_mib: None,
        report: run_tenant(&base, Policy::Mpc, &workload),
    });
    for &mib in &p.capacities_mib {
        let cfg = cell_config(p, Some(mib));
        cells.push(CacheCell {
            capacity_mib: Some(mib),
            report: run_tenant(&cfg, Policy::Mpc, &workload),
        });
    }
    cells
}

/// Print the sweep table plus the capacity-frontier verdict.
pub fn print_table(cells: &[CacheCell]) {
    let mut t = Table::new(&[
        "cache MiB",
        "p50 ms",
        "p99 ms",
        "cold %",
        "eff L_cold s",
        "hits",
        "misses",
        "hit %",
        "pulled MiB",
    ]);
    for c in cells {
        let r = &c.report;
        let cold_pct = if r.completed > 0 {
            100.0 * r.cold_requests as f64 / r.completed as f64
        } else {
            0.0
        };
        t.row(&[
            c.capacity_mib
                .map_or("off".to_string(), |m| m.to_string()),
            format!("{:.0}", r.p50_ms),
            format!("{:.0}", r.p99_ms),
            format!("{cold_pct:.1}"),
            format!("{:.2}", r.counters.mean_effective_l_cold_s()),
            r.counters.layer_hits.to_string(),
            r.counters.layer_misses.to_string(),
            format!("{:.1}", c.hit_pct()),
            r.counters.pull_mib.to_string(),
        ]);
    }
    t.print();
    // frontier verdict over the LRU rungs: pulled bytes must trend down
    // as capacity grows (LRU inclusion), and P99 should follow
    let lru: Vec<&CacheCell> = cells.iter().filter(|c| c.capacity_mib.is_some()).collect();
    if lru.len() >= 2 {
        let first = lru.first().unwrap();
        let last = lru.last().unwrap();
        let pull_monotone = lru
            .windows(2)
            .all(|w| w[1].report.counters.pull_mib <= w[0].report.counters.pull_mib);
        println!(
            "capacity {} -> {} MiB: pulled {} -> {} MiB ({}), hit-rate {:.1}% -> {:.1}%, \
             P99 {:.0} -> {:.0} ms",
            first.capacity_mib.unwrap(),
            last.capacity_mib.unwrap(),
            first.report.counters.pull_mib,
            last.report.counters.pull_mib,
            if pull_monotone {
                "monotone frontier"
            } else {
                "non-monotone: inspect the ladder"
            },
            first.hit_pct(),
            last.hit_pct(),
            first.report.p99_ms,
            last.report.p99_ms,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> CacheParams {
        CacheParams {
            duration_s: 600.0,
            seed: 5,
            nodes: 2,
            functions: 4,
            capacities_mib: vec![64, 4096],
            ..Default::default()
        }
    }

    #[test]
    fn cell_config_threads_the_knobs() {
        let p = CacheParams {
            bandwidth_mibps: 25.0,
            init_fraction: 0.5,
            ..quick()
        };
        let off = cell_config(&p, None);
        assert!(!off.platform.image.enabled());
        assert_eq!(off.platform.image, ImageCacheConfig::default());
        let on = cell_config(&p, Some(512));
        assert_eq!(on.platform.image.mode, ImageCacheMode::Lru);
        assert_eq!(on.platform.image.capacity_mib, 512);
        assert_eq!(on.platform.image.bandwidth_mibps, 25.0);
        assert_eq!(on.platform.image.init_fraction, 0.5);
        assert_eq!(on.fleet.nodes, 2);
        assert_eq!(on.tenancy.functions, 4);
    }

    #[test]
    fn sweep_baseline_is_silent_and_capacity_shrinks_pulls() {
        let cells = run_sweep(&quick());
        assert_eq!(cells.len(), 3);
        // the off baseline never touches the cache counters
        let off = &cells[0].report.counters;
        assert_eq!(cells[0].capacity_mib, None);
        assert_eq!(off.layer_hits, 0);
        assert_eq!(off.layer_misses, 0);
        assert_eq!(off.pull_mib, 0);
        assert_eq!(off.cold_charges, 0);
        assert_eq!(cells[0].report.counters.mean_effective_l_cold_s(), 0.0);
        // enabled cells pull real bytes and charge effective costs
        for c in &cells[1..] {
            let r = &c.report.counters;
            assert!(r.pull_mib > 0, "{:?}", c.capacity_mib);
            assert!(r.layer_misses > 0);
            assert!(r.cold_charges > 0);
            assert!(c.report.counters.mean_effective_l_cold_s() > 0.0);
            assert_eq!(c.report.dropped, 0);
        }
        // a thrashing 64 MiB store (smaller than the runtime layer) must
        // pull far more than a store that holds the whole layer set
        let tiny = &cells[1].report.counters;
        let big = &cells[2].report.counters;
        assert!(
            big.pull_mib < tiny.pull_mib,
            "pulls did not shrink: {} -> {}",
            tiny.pull_mib,
            big.pull_mib
        );
        assert!(cells[2].hit_pct() > cells[1].hit_pct());
        print_table(&cells); // table rendering must not panic
    }
}
