//! Chaos scenarios (the `scenario` and `chaos-sweep` CLI subcommands):
//! run the correlated fault presets from `cluster::chaos` — failure
//! storm, rolling restart, flash crowd — under each scheduling policy
//! and surface the resilience telemetry the run report carries: retry /
//! timeout / spawn-failure counters, dropped (budget-exhausted)
//! requests, and the usual latency/cold-start columns.
//!
//! Every cell is deterministic in `(seed, preset, policy)`: the chaos
//! engine rolls one seeded RNG stream in event order, and the presets'
//! node schedules are pure functions of the fleet shape (see
//! `tests/chaos.rs` for the repeated-run and threads-vs-sequential
//! identity suites).

use crate::config::{ChaosConfig, ChaosMode, ExperimentConfig, FleetConfig, Policy, TenantConfig, TraceKind, secs};
use crate::experiments::runner::run_tenant;
use crate::metrics::RunReport;
use crate::util::bench::Table;
use crate::workload::TenantWorkload;

/// Shared workload/fleet shape for every cell of a chaos run.
#[derive(Debug, Clone)]
pub struct ScenarioParams {
    pub trace: TraceKind,
    pub duration_s: f64,
    pub seed: u64,
    pub nodes: u32,
    pub functions: u32,
    /// Knob values shared by every cell; the `mode` inside is a
    /// placeholder — each cell overrides it with its own preset.
    pub chaos: ChaosConfig,
}

impl Default for ScenarioParams {
    fn default() -> Self {
        ScenarioParams {
            trace: TraceKind::SyntheticBursty,
            duration_s: 3600.0,
            seed: 42,
            nodes: 4,
            functions: 8,
            chaos: ChaosConfig::default(),
        }
    }
}

/// One sweep cell: the run report for (chaos preset, scheduling policy).
#[derive(Debug, Clone)]
pub struct ChaosCell {
    pub mode: ChaosMode,
    pub policy: Policy,
    pub report: RunReport,
}

/// Experiment config for one cell.
pub fn cell_config(p: &ScenarioParams, mode: ChaosMode) -> ExperimentConfig {
    ExperimentConfig {
        trace: p.trace,
        fleet: FleetConfig {
            nodes: p.nodes,
            ..Default::default()
        },
        tenancy: TenantConfig {
            functions: p.functions,
            ..Default::default()
        },
        duration: secs(p.duration_s),
        seed: p.seed,
        chaos: ChaosConfig { mode, ..p.chaos },
        ..Default::default()
    }
}

/// Run one (preset, policy) cell. The workload is generated from the
/// cell config, so every cell of a sweep sees identical arrivals (the
/// flash-crowd remap happens inside the runner, per cell).
pub fn run_cell(p: &ScenarioParams, mode: ChaosMode, policy: Policy) -> ChaosCell {
    let cfg = cell_config(p, mode);
    let workload = TenantWorkload::generate(
        p.trace,
        cfg.duration,
        p.seed,
        p.functions,
        cfg.tenancy.zipf_s,
        &cfg.platform,
    );
    ChaosCell {
        mode,
        policy,
        report: run_tenant(&cfg, policy, &workload),
    }
}

/// Sweep every (preset × policy) combination over one shared workload.
pub fn run_sweep(p: &ScenarioParams, modes: &[ChaosMode], policies: &[Policy]) -> Vec<ChaosCell> {
    let mut cells = Vec::new();
    for &mode in modes {
        for &policy in policies {
            cells.push(run_cell(p, mode, policy));
        }
    }
    cells
}

/// Print one cell's run report plus a chaos-telemetry summary line.
pub fn print_report(cell: &ChaosCell) {
    let r = &cell.report;
    println!("{}", r.to_json());
    println!(
        "chaos: retries={} timeouts={} spawn-fails={} dropped={}",
        r.counters.retries, r.counters.timeouts, r.counters.spawn_failures, r.dropped
    );
}

/// Print the sweep table: latency/cold columns plus the chaos counters.
pub fn print_table(cells: &[ChaosCell]) {
    let mut t = Table::new(&[
        "preset",
        "policy",
        "p50 ms",
        "p99 ms",
        "cold %",
        "retries",
        "timeouts",
        "spawn fails",
        "dropped",
    ]);
    for c in cells {
        let r = &c.report;
        let cold_pct = if r.completed > 0 {
            100.0 * r.cold_requests as f64 / r.completed as f64
        } else {
            0.0
        };
        t.row(&[
            c.mode.name().to_string(),
            c.policy.name().to_string(),
            format!("{:.0}", r.p50_ms),
            format!("{:.0}", r.p99_ms),
            format!("{cold_pct:.1}"),
            r.counters.retries.to_string(),
            r.counters.timeouts.to_string(),
            r.counters.spawn_failures.to_string(),
            r.dropped.to_string(),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ScenarioParams {
        ScenarioParams {
            duration_s: 600.0,
            nodes: 3,
            functions: 2,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn cell_config_carries_the_preset_and_shared_knobs() {
        let mut p = quick();
        p.chaos.spawn_fail_p = 0.2;
        let cfg = cell_config(&p, ChaosMode::RollingRestart);
        assert_eq!(cfg.chaos.mode, ChaosMode::RollingRestart);
        assert_eq!(cfg.chaos.spawn_fail_p, 0.2);
        assert_eq!(cfg.fleet.nodes, 3);
        assert!(cfg.fleet.failures.is_empty(), "presets schedule in the runner, not the config");
    }

    #[test]
    fn a_preset_cell_completes_and_reports_chaos_telemetry() {
        let p = quick();
        let cell = run_cell(&p, ChaosMode::FailureStorm, Policy::OpenWhisk);
        let r = &cell.report;
        assert!(r.completed > 0, "the storm must not wedge the run");
        // with all fault kinds enabled for 600 s, some chaos counter
        // should have ticked (probabilities are per-invocation)
        assert!(
            r.counters.retries + r.counters.timeouts + r.counters.spawn_failures > 0,
            "chaos counters silent under an active preset"
        );
    }

    #[test]
    fn sweep_covers_the_grid_deterministically() {
        let p = quick();
        let a = run_sweep(&p, &[ChaosMode::Faults], &[Policy::OpenWhisk, Policy::Mpc]);
        let b = run_sweep(&p, &[ChaosMode::Faults], &[Policy::OpenWhisk, Policy::Mpc]);
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.report.completed, y.report.completed);
            assert_eq!(x.report.p99_ms, y.report.p99_ms);
            assert_eq!(x.report.counters.retries, y.report.counters.retries);
            assert_eq!(x.report.counters.timeouts, y.report.counters.timeouts);
            assert_eq!(x.report.counters.spawn_failures, y.report.counters.spawn_failures);
        }
    }
}
