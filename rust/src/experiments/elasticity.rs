//! Fleet-elasticity sweep (the `elasticity-sweep` CLI subcommand and the
//! fig12 bench target): one drain → rejoin scenario, swept across
//! migration policies for a scheduling policy.
//!
//! The scenario exercises the full capacity lifecycle from the
//! "Fleet elasticity" chapter of docs/ARCHITECTURE.md: a node drains
//! mid-run (its work redistributes), the fleet serves on reduced
//! capacity with the MPC's `w_max` re-scaled down, the node rejoins cold
//! at the restore time (budget re-scales back up), and — when a
//! migration policy is active — the rebalancing pass moves idle warm
//! capacity toward the forecast demand, including onto the cold
//! rejoiner. The per-node report's post-restore counters are the
//! acceptance signal: a healthy rejoin shows nonzero post-restore
//! dispatches and prewarms on the drained node.

use crate::config::{
    secs, ExperimentConfig, FleetConfig, MigrationConfig, MigrationPolicy, NodeFailure,
    NodeRestore, PlacementPolicy, Policy, TenantConfig, TraceKind,
};
use crate::experiments::runner::run_tenant;
use crate::metrics::RunReport;
use crate::util::bench::Table;
use crate::workload::TenantWorkload;

/// Shared scenario shape for every cell of an elasticity sweep.
#[derive(Debug, Clone)]
pub struct ElasticityParams {
    pub trace: TraceKind,
    pub duration_s: f64,
    pub seed: u64,
    pub nodes: u32,
    pub functions: u32,
    pub placement: PlacementPolicy,
    /// Node that drains at `fail_at_s` and rejoins at `restore_at_s`.
    pub fail_node: u32,
    pub fail_at_s: f64,
    pub restore_at_s: f64,
    pub migration_latency_s: f64,
}

impl Default for ElasticityParams {
    fn default() -> Self {
        ElasticityParams {
            trace: TraceKind::SyntheticBursty,
            duration_s: 3600.0,
            seed: 42,
            nodes: 4,
            functions: 4,
            placement: PlacementPolicy::WarmFirst,
            fail_node: 1,
            fail_at_s: 600.0,
            restore_at_s: 1200.0,
            migration_latency_s: 2.0,
        }
    }
}

/// One sweep cell: the run report for (scheduling policy, migration
/// policy) under the shared drain → rejoin scenario.
#[derive(Debug, Clone)]
pub struct ElasticityCell {
    pub policy: Policy,
    pub migration: MigrationPolicy,
    pub report: RunReport,
}

/// Experiment config for one cell of the scenario.
pub fn cell_config(p: &ElasticityParams, migration: MigrationPolicy) -> ExperimentConfig {
    ExperimentConfig {
        trace: p.trace,
        fleet: FleetConfig {
            nodes: p.nodes,
            placement: p.placement,
            failures: vec![NodeFailure {
                node: p.fail_node,
                at: secs(p.fail_at_s),
            }],
            restores: vec![NodeRestore {
                node: p.fail_node,
                at: secs(p.restore_at_s),
                cap: None,
            }],
            migration: MigrationConfig {
                policy: migration,
                latency: secs(p.migration_latency_s),
                ..Default::default()
            },
            ..Default::default()
        },
        tenancy: TenantConfig {
            functions: p.functions,
            ..Default::default()
        },
        duration: secs(p.duration_s),
        seed: p.seed,
        ..Default::default()
    }
}

/// Run one (policy, migration) cell of the scenario.
pub fn run_cell(p: &ElasticityParams, policy: Policy, migration: MigrationPolicy) -> ElasticityCell {
    let cfg = cell_config(p, migration);
    let workload = TenantWorkload::generate(
        p.trace,
        cfg.duration,
        p.seed,
        p.functions,
        cfg.tenancy.zipf_s,
        &cfg.platform,
    );
    ElasticityCell {
        policy,
        migration,
        report: run_tenant(&cfg, policy, &workload),
    }
}

/// Sweep every (policy × migration) combination over one scenario.
pub fn run_sweep(
    p: &ElasticityParams,
    policies: &[Policy],
    migrations: &[MigrationPolicy],
) -> Vec<ElasticityCell> {
    let mut cells = Vec::new();
    for &policy in policies {
        for &migration in migrations {
            cells.push(run_cell(p, policy, migration));
        }
    }
    cells
}

/// Print the sweep table: latency/cold-start columns plus the elasticity
/// evidence — fleet-wide migrations, and the drained node's post-restore
/// dispatch and prewarm counts.
pub fn print_table(cells: &[ElasticityCell], fail_node: u32) {
    let mut t = Table::new(&[
        "policy",
        "migration",
        "p50 ms",
        "p99 ms",
        "cold %",
        "migrations",
        "rejoin invocations",
        "rejoin prewarms",
    ]);
    for c in cells {
        let r = &c.report;
        let cold_pct = if r.completed > 0 {
            100.0 * r.cold_requests as f64 / r.completed as f64
        } else {
            0.0
        };
        let post = r
            .per_node
            .iter()
            .find(|n| n.node == fail_node)
            .and_then(|n| n.post_restore());
        let (ri, rp) = post.map_or((0, 0), |p| (p.invocations, p.prewarms_started));
        t.row(&[
            c.policy.name().to_string(),
            c.migration.name().to_string(),
            format!("{:.0}", r.p50_ms),
            format!("{:.0}", r.p99_ms),
            format!("{cold_pct:.1}"),
            r.counters.migrations_in.to_string(),
            ri.to_string(),
            rp.to_string(),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ElasticityParams {
        ElasticityParams {
            duration_s: 900.0,
            nodes: 3,
            functions: 2,
            fail_at_s: 200.0,
            restore_at_s: 400.0,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn cell_config_schedules_fail_and_restore() {
        let p = quick();
        let cfg = cell_config(&p, MigrationPolicy::DemandGap);
        let f = cfg.fleet.failures[0];
        let r = cfg.fleet.restores[0];
        assert_eq!(f.node, r.node);
        assert!(f.at < r.at, "restore must come after the drain");
        assert_eq!(cfg.fleet.migration.policy, MigrationPolicy::DemandGap);
        assert_eq!(cfg.fleet.migration.latency, secs(2.0));
    }

    #[test]
    fn sweep_covers_the_grid_and_completes() {
        let p = quick();
        let cells = run_sweep(
            &p,
            &[Policy::OpenWhisk],
            &[MigrationPolicy::Off, MigrationPolicy::IdleSpread],
        );
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.report.dropped, 0, "{:?}/{:?}", c.policy, c.migration);
            assert_eq!(c.report.per_node.len(), 3);
        }
        // the Off cell never migrates
        assert_eq!(cells[0].report.counters.migrations_in, 0);
        print_table(&cells, p.fail_node); // table rendering must not panic
    }
}
