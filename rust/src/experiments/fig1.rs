//! Fig. 1 reproduction: 50 random-arrival requests on the OpenWhisk default
//! policy from a cold platform — per-request response times (a) and the
//! warm-container staircase (b).

use crate::config::{secs, to_secs, ExperimentConfig, Policy, TraceKind};
use crate::experiments::runner::run_experiment;
use crate::metrics::RunReport;
use crate::workload::fig1;

#[derive(Debug)]
pub struct Fig1Result {
    /// Response time per request in seconds, arrival order.
    pub response_times_s: Vec<f64>,
    /// Warm-container gauge over time (1-second samples for the staircase).
    pub warm_over_time: Vec<(f64, u32)>,
    pub cold_starts: u64,
    pub warm_exec_mean_s: f64,
    pub cold_response_mean_s: f64,
    pub report: RunReport,
}

pub fn run(seed: u64) -> Fig1Result {
    let trace = fig1::generate(fig1::default_span(), seed);
    let cfg = ExperimentConfig {
        duration: fig1::default_span(),
        trace: TraceKind::AzureLike, // label only; trace passed explicitly
        sample_interval: secs(1.0),  // fine-grained staircase for Fig. 1b
        seed,
        ..Default::default()
    };
    let report = run_experiment(&cfg, Policy::OpenWhisk, &trace);

    let mut warm_samples = Vec::new();
    let mut cold_sum = 0.0;
    let mut cold_n = 0;
    let mut warm_sum = 0.0;
    let mut warm_n = 0;
    for rt in &report.response_times_s {
        if *rt > to_secs(cfg.platform.l_cold) * 0.5 {
            cold_sum += rt;
            cold_n += 1;
        } else {
            warm_sum += rt;
            warm_n += 1;
        }
    }
    for (t, w) in &report.warm_series {
        warm_samples.push((to_secs(*t), *w));
    }
    Fig1Result {
        response_times_s: report.response_times_s.clone(),
        warm_over_time: warm_samples,
        cold_starts: report.counters.cold_starts,
        warm_exec_mean_s: if warm_n > 0 { warm_sum / warm_n as f64 } else { 0.0 },
        cold_response_mean_s: if cold_n > 0 { cold_sum / cold_n as f64 } else { 0.0 },
        report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_shape() {
        let r = run(42);
        assert_eq!(r.response_times_s.len(), 50);
        // the paper observes 8 cold starts; random arrivals give a handful
        assert!(
            (2..=20).contains(&(r.cold_starts as i64)),
            "cold starts = {}",
            r.cold_starts
        );
        // warm ~ 280 ms, cold ~ 10.5 s: the 38x gap is the paper's headline
        assert!((r.warm_exec_mean_s - 0.28).abs() < 0.1, "{}", r.warm_exec_mean_s);
        assert!(r.cold_response_mean_s > 5.0, "{}", r.cold_response_mean_s);
        let ratio = r.cold_response_mean_s / r.warm_exec_mean_s.max(1e-9);
        assert!(ratio > 15.0, "cold/warm ratio {ratio}");
        // staircase: warm container count is non-decreasing during the run
        // (10-minute keep-alive outlives the 7-minute experiment)
        let counts: Vec<u32> = r.warm_over_time.iter().map(|&(_, w)| w).collect();
        let max = *counts.iter().max().unwrap();
        assert_eq!(max as u64, r.cold_starts, "staircase peak == cold starts");
    }
}
