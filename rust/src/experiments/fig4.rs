//! Fig. 4 reproduction: rolling forecast accuracy of the Fourier predictor
//! vs the ARIMA baseline on (a) the Azure-like trace and (b) the synthetic
//! bursty trace, plus the per-call runtime comparison the paper highlights
//! (Fourier ~0.1 ms vs ARIMA ~10 ms).

use std::time::Instant;

use crate::config::{secs, Micros, TraceKind};
use crate::forecast::{accuracy, ArimaForecaster, Forecaster, FourierForecaster};
use crate::workload::{azure, synthetic, Trace};

#[derive(Debug, Clone)]
pub struct ForecastEval {
    pub predictor: String,
    pub trace: String,
    pub accuracy_pct: f64,
    pub wape: f64,
    pub smape: f64,
    pub rmse: f64,
    pub mean_runtime_ms: f64,
    pub evaluations: usize,
}

/// Rolling horizon evaluation: at each step feed the last `window` bins and
/// score the full `horizon`-step prediction against the truth — the
/// quantity the MPC actually consumes (one-step scores flatter ARIMA,
/// which mean-reverts over the horizon the controller plans on).
pub fn rolling_eval(
    f: &mut dyn Forecaster,
    bins: &[f64],
    window: usize,
    trace_name: &str,
) -> ForecastEval {
    rolling_eval_h(f, bins, window, 24, trace_name)
}

pub fn rolling_eval_h(
    f: &mut dyn Forecaster,
    bins: &[f64],
    window: usize,
    horizon: usize,
    trace_name: &str,
) -> ForecastEval {
    let mut preds = Vec::new();
    let mut actuals = Vec::new();
    let mut runtime_ns = 0.0;
    let mut n = 0usize;
    let start = window;
    let stride = (horizon / 2).max(1);
    let mut t = start;
    while t + horizon <= bins.len() {
        let hist = &bins[t - window..t];
        let t0 = Instant::now();
        let p = f.forecast(hist, horizon);
        runtime_ns += t0.elapsed().as_nanos() as f64;
        n += 1;
        preds.extend_from_slice(&p);
        actuals.extend_from_slice(&bins[t..t + horizon]);
        t += stride;
    }
    ForecastEval {
        predictor: f.name().to_string(),
        trace: trace_name.to_string(),
        accuracy_pct: accuracy::accuracy_pct(&preds, &actuals),
        wape: accuracy::wape(&preds, &actuals),
        smape: accuracy::smape(&preds, &actuals),
        rmse: accuracy::rmse(&preds, &actuals),
        mean_runtime_ms: runtime_ns / n.max(1) as f64 / 1e6,
        evaluations: n,
    }
}

pub fn trace_for(kind: TraceKind, duration: Micros, seed: u64) -> Trace {
    match kind {
        TraceKind::AzureLike => azure::generate(&azure::AzureLikeConfig::default(), duration, seed),
        TraceKind::SyntheticBursty => {
            synthetic::generate(&synthetic::SyntheticConfig::default(), duration, seed)
        }
    }
}

/// Run the full Fig. 4 comparison (both predictors on both traces).
pub fn run(duration_s: f64, seed: u64) -> Vec<ForecastEval> {
    let window = 120; // matches the controller/artifact forecast window
    let mut out = Vec::new();
    for kind in [TraceKind::AzureLike, TraceKind::SyntheticBursty] {
        let trace = trace_for(kind, secs(duration_s), seed);
        let bins: Vec<f64> = trace
            .binned(secs(30.0)) // the controller's dt
            .iter()
            .map(|&b| b as f64)
            .collect();
        let mut fourier = FourierForecaster::default();
        let mut arima = ArimaForecaster::default();
        out.push(rolling_eval(&mut fourier, &bins, window, kind.name()));
        out.push(rolling_eval(&mut arima, &bins, window, kind.name()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fourier_beats_arima_on_periodic_azure_like_load() {
        let evals = run(14400.0, 11); // 4 h -> 360 rolling evals at 30 s bins
        let get = |pred: &str, trace: &str| {
            evals
                .iter()
                .find(|e| e.predictor == pred && e.trace == trace)
                .unwrap()
                .clone()
        };
        let f_az = get("fourier", "azure");
        let a_az = get("arima", "azure");
        // paper: Fourier 86.2% vs ARIMA 82.5% — shape: fourier >= arima
        assert!(
            f_az.accuracy_pct >= a_az.accuracy_pct - 1.0,
            "fourier {:.1}% < arima {:.1}%",
            f_az.accuracy_pct,
            a_az.accuracy_pct
        );
        assert!(f_az.accuracy_pct > 60.0, "fourier too weak: {f_az:?}");
        // runtime: both predictors must be far below the control interval.
        // (The paper's 100x runtime gap reflects statsmodels' MLE ARIMA;
        // our Hannan-Rissanen CLS fit is itself fast, so the gap here is
        // small — see EXPERIMENTS.md Fig. 4 notes.)
        assert!(f_az.mean_runtime_ms < 5.0, "fourier too slow: {f_az:?}");
        assert!(a_az.mean_runtime_ms < 50.0, "arima too slow: {a_az:?}");
    }

    #[test]
    fn rolling_eval_counts() {
        let bins: Vec<f64> = (0..300).map(|t| 10.0 + (t % 5) as f64).collect();
        let mut f = FourierForecaster::default();
        let e = rolling_eval(&mut f, &bins, 120, "unit");
        assert_eq!(e.evaluations, 14); // stride H/2 over 300 bins
        assert!(e.accuracy_pct > 50.0);
    }
}
