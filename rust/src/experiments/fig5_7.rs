//! Figs. 5-7 reproduction: the policy × workload matrix.
//!
//! One 60-minute run per (policy, trace) pair from a cold platform, then:
//! * Fig. 5 — % improvement in mean/p90/p95 response time over OpenWhisk;
//! * Fig. 6 — % reduction in warm-container usage (1-minute samples);
//! * Fig. 7 — % reduction in keep-alive duration.

use crate::config::{secs, ExperimentConfig, FleetConfig, Policy, TraceKind};
use crate::experiments::fig4::trace_for;
use crate::experiments::runner::run_experiment;
use crate::metrics::RunReport;
use crate::workload::Trace;

#[derive(Debug, Clone)]
pub struct MatrixResult {
    pub trace: TraceKind,
    pub openwhisk: RunReport,
    pub icebreaker: RunReport,
    pub mpc: RunReport,
}

#[derive(Debug, Clone, Copy)]
pub struct Improvement {
    pub mean_pct: f64,
    pub p90_pct: f64,
    pub p95_pct: f64,
    pub warm_usage_pct: f64,
    pub keepalive_pct: f64,
}

impl MatrixResult {
    pub fn improvement(&self, which: Policy) -> Improvement {
        let r = match which {
            Policy::IceBreaker => &self.icebreaker,
            Policy::Mpc => &self.mpc,
            // the Fig. 5-7 matrix is the paper's three-policy grid; any
            // policy outside it reads as the baseline (zero improvement)
            Policy::OpenWhisk | Policy::Survival => &self.openwhisk,
        };
        let b = &self.openwhisk;
        let imp = RunReport::improvement_pct;
        Improvement {
            mean_pct: imp(r.mean_ms, b.mean_ms),
            p90_pct: imp(r.p90_ms, b.p90_ms),
            p95_pct: imp(r.p95_ms, b.p95_ms),
            warm_usage_pct: imp(r.mean_warm, b.mean_warm),
            keepalive_pct: imp(r.keepalive_total_s, b.keepalive_total_s),
        }
    }
}

const POLICIES: [Policy; 3] = [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc];

/// Run the full matrix for one trace kind.
pub fn run_matrix(trace: TraceKind, duration_s: f64, seed: u64) -> MatrixResult {
    run_matrix_all(&[trace], duration_s, seed, &FleetConfig::default())
        .pop()
        .expect("one matrix per trace kind")
}

/// Run the (policy × trace) matrix with cells in parallel, at most one
/// thread per available core. Each cell derives its inputs only from
/// (cfg.seed, trace kind, policy), so the per-cell seeds — and therefore
/// the reports — are identical to a serial run regardless of wave
/// boundaries or completion order, and results come back in the given
/// trace order.
pub fn run_matrix_all(
    kinds: &[TraceKind],
    duration_s: f64,
    seed: u64,
    fleet: &FleetConfig,
) -> Vec<MatrixResult> {
    let cfgs: Vec<ExperimentConfig> = kinds
        .iter()
        .map(|&k| ExperimentConfig {
            trace: k,
            duration: secs(duration_s),
            seed,
            fleet: fleet.clone(),
            ..Default::default()
        })
        .collect();
    let traces: Vec<Trace> = cfgs
        .iter()
        .map(|c| trace_for(c.trace, c.duration, c.seed))
        .collect();

    // slot matrix indexed (trace, policy) keeps the output ordering
    // stable no matter which thread finishes first
    let mut slots: Vec<[Option<RunReport>; 3]> = kinds.iter().map(|_| [None, None, None]).collect();
    // spawning kinds × 3 threads unconditionally oversubscribes small
    // hosts as the trace list grows; run the cell list in core-sized
    // waves instead (cells are seed-deterministic, so waves don't affect
    // results, only scheduling)
    let max_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let cells: Vec<(usize, usize)> = (0..kinds.len())
        .flat_map(|ti| (0..POLICIES.len()).map(move |pi| (ti, pi)))
        .collect();
    for wave in cells.chunks(max_workers.max(1)) {
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for &(ti, pi) in wave {
                let cfg = &cfgs[ti];
                let tr = &traces[ti];
                let policy = POLICIES[pi];
                handles.push(((ti, pi), s.spawn(move || run_experiment(cfg, policy, tr))));
            }
            for ((ti, pi), h) in handles {
                slots[ti][pi] = Some(h.join().expect("matrix cell panicked"));
            }
        });
    }

    kinds
        .iter()
        .zip(slots)
        .map(|(&trace, [ow, ib, mpc])| MatrixResult {
            trace,
            openwhisk: ow.expect("cell ran"),
            icebreaker: ib.expect("cell ran"),
            mpc: mpc.expect("cell ran"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Manual tuning probe: `cargo test --lib tuning_sweep -- --ignored --nocapture`
    #[test]
    #[ignore]
    fn tuning_sweep() {
        let cfg0 = ExperimentConfig {
            trace: TraceKind::SyntheticBursty,
            duration: secs(3600.0),
            seed: 3,
            ..Default::default()
        };
        let arrivals = crate::workload::synthetic::generate(
            &crate::workload::synthetic::SyntheticConfig::default(),
            cfg0.duration,
            cfg0.seed,
        );
        println!("requests: {}", arrivals.len());
        let ow = run_experiment(&cfg0, Policy::OpenWhisk, &arrivals);
        let ib = run_experiment(&cfg0, Policy::IceBreaker, &arrivals);
        println!(
            "IB   mean={:.0} p90={:.0} p95={:.0} cold={} warm={:.1} ka={:.0}",
            ib.mean_ms, ib.p90_ms, ib.p95_ms, ib.counters.cold_starts, ib.mean_warm, ib.keepalive_total_s
        );
        println!(
            "OW   mean={:.0} p90={:.0} p95={:.0} cold={} warm={:.1} ka={:.0}",
            ow.mean_ms, ow.p90_ms, ow.p95_ms, ow.counters.cold_starts, ow.mean_warm, ow.keepalive_total_s
        );
        for (alpha, gamma, rho1, eta, gclip, drain_s) in [
            (8.0, 0.0002, 0.1, 0.01, 5.0, 3.0),
            (8.0, 0.0002, 0.1, 0.01, 5.0, 1.5),
            (16.0, 0.0002, 0.1, 0.01, 5.0, 3.0),
            (16.0, 0.0002, 0.2, 0.005, 6.0, 1.5),
            (32.0, 0.0001, 0.2, 0.005, 6.0, 1.5),
        ] {
            let (beta, guard_s) = (107.0, 12.0);
            let mut cfg = cfg0.clone();
            cfg.controller.weights.mu = drain_s / 0.280;
            let _ = &mut cfg;
            cfg.controller.weights.alpha = alpha;
            cfg.controller.weights.beta = beta;
            cfg.controller.weights.gamma = gamma;
            cfg.controller.weights.rho1 = rho1;
            cfg.controller.weights.eta = eta;
            cfg.controller.max_shaping_delay = secs(guard_s);
            cfg.controller.gamma_clip = gclip;
            let r = run_experiment(&cfg, Policy::Mpc, &arrivals);
            println!(
                "MPC a={alpha} g={gamma} r1={rho1} e={eta} clip={gclip} dr={drain_s} b={beta} gd={guard_s}: mean={:.0} p90={:.0} p95={:.0} cold={} warm={:.1} ka={:.0}",
                r.mean_ms, r.p90_ms, r.p95_ms, r.counters.cold_starts, r.mean_warm, r.keepalive_total_s
            );
        }
    }

    #[test]
    fn parallel_matrix_is_deterministic_and_ordered() {
        let kinds = [TraceKind::AzureLike, TraceKind::SyntheticBursty];
        let a = run_matrix_all(&kinds, 120.0, 5, &FleetConfig::default());
        let b = run_matrix_all(&kinds, 120.0, 5, &FleetConfig::default());
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].trace, TraceKind::AzureLike);
        assert_eq!(a[1].trace, TraceKind::SyntheticBursty);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mpc.mean_ms, y.mpc.mean_ms);
            assert_eq!(x.icebreaker.p95_ms, y.icebreaker.p95_ms);
            assert_eq!(
                x.openwhisk.counters.cold_starts,
                y.openwhisk.counters.cold_starts
            );
        }
    }

    #[test]
    fn matrix_cells_are_independent_of_trace_order() {
        // each cell must depend only on its own (seed, trace, policy) —
        // never on which other cells share the run or the wave layout —
        // so reversing the trace list permutes, not perturbs, the results
        let fwd = run_matrix_all(
            &[TraceKind::AzureLike, TraceKind::SyntheticBursty],
            120.0,
            5,
            &FleetConfig::default(),
        );
        let rev = run_matrix_all(
            &[TraceKind::SyntheticBursty, TraceKind::AzureLike],
            120.0,
            5,
            &FleetConfig::default(),
        );
        for (a, b) in [(&fwd[0], &rev[1]), (&fwd[1], &rev[0])] {
            assert_eq!(a.trace, b.trace);
            assert_eq!(a.mpc.mean_ms, b.mpc.mean_ms);
            assert_eq!(a.mpc.counters.cold_starts, b.mpc.counters.cold_starts);
            assert_eq!(a.icebreaker.p95_ms, b.icebreaker.p95_ms);
            assert_eq!(a.openwhisk.keepalive_total_s, b.openwhisk.keepalive_total_s);
        }
    }

    /// The paper's headline ordering on the bursty workload (Fig. 5b/6b/7b
    /// shape): MPC beats OpenWhisk on tail latency (p90), cold starts, and
    /// resource usage. Where measured shape deviates from the paper's
    /// magnitudes, EXPERIMENTS.md discusses it; these are the robust subset.
    #[test]
    fn bursty_workload_ordering_holds() {
        let cfg = ExperimentConfig {
            trace: TraceKind::SyntheticBursty,
            duration: secs(3600.0),
            seed: 3,
            ..Default::default()
        };
        let arrivals = crate::workload::synthetic::generate(
            &crate::workload::synthetic::SyntheticConfig::default(),
            cfg.duration,
            cfg.seed,
        );
        assert!(arrivals.len() > 500, "workload too sparse: {}", arrivals.len());
        let ow = run_experiment(&cfg, Policy::OpenWhisk, &arrivals);
        let mpc = run_experiment(&cfg, Policy::Mpc, &arrivals);
        assert_eq!(ow.dropped, 0);
        assert_eq!(mpc.dropped, 0);
        assert!(
            mpc.p90_ms < ow.p90_ms,
            "MPC p90 {:.0} ms !< OpenWhisk p90 {:.0} ms",
            mpc.p90_ms,
            ow.p90_ms
        );
        assert!(
            mpc.counters.cold_starts < ow.counters.cold_starts,
            "MPC cold starts {} !< OW {}",
            mpc.counters.cold_starts,
            ow.counters.cold_starts
        );
        assert!(
            mpc.mean_warm < ow.mean_warm,
            "MPC warm usage {:.1} !< OW {:.1}",
            mpc.mean_warm,
            ow.mean_warm
        );
        assert!(
            mpc.keepalive_total_s < ow.keepalive_total_s,
            "MPC keep-alive {:.0} !< OW {:.0}",
            mpc.keepalive_total_s,
            ow.keepalive_total_s
        );
    }
}
