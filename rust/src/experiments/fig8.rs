//! Fig. 8 reproduction: per-control-step overhead breakdown — forecast
//! time vs optimizer time — measured on both the in-process mirror and
//! (when artifacts are available) the deployed HLO runtime.

use std::time::Instant;

use crate::config::Weights;
use crate::forecast::{Forecaster, FourierForecaster};
use crate::mpc::{MpcInput, MpcSolver, RustSolver};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

#[derive(Debug)]
pub struct OverheadResult {
    pub backend: String,
    pub forecast_ms: Summary,
    pub solve_ms: Summary,
}

/// Measure `iters` control steps on arbitrary forecaster/solver backends.
pub fn measure(
    backend: &str,
    forecaster: &mut dyn Forecaster,
    solver: &mut dyn MpcSolver,
    horizon: usize,
    window: usize,
    iters: u32,
    seed: u64,
) -> OverheadResult {
    let mut rng = Rng::new(seed);
    let mut forecast_ms = Summary::new();
    let mut solve_ms = Summary::new();
    let mut warm = vec![0.0; 3 * horizon];
    for _ in 0..iters {
        let hist: Vec<f64> = (0..window)
            .map(|t| 15.0 + 5.0 * (t as f64 / 30.0).sin() + rng.normal(0.0, 1.0))
            .collect();
        let t0 = Instant::now();
        let lam = forecaster.forecast(&hist, horizon);
        forecast_ms.add(t0.elapsed().as_nanos() as f64 / 1e6);

        let input = MpcInput {
            lam,
            rdy: vec![0.0; horizon],
            q0: rng.range_f64(0.0, 20.0),
            w0: rng.range_f64(0.0, 20.0),
            x_prev: 0.0,
        };
        let t1 = Instant::now();
        let (z, _) = solver.solve(&warm, &input);
        solve_ms.add(t1.elapsed().as_nanos() as f64 / 1e6);
        warm = z;
    }
    OverheadResult {
        backend: backend.to_string(),
        forecast_ms,
        solve_ms,
    }
}

/// Fig. 8 with the in-process backends.
pub fn run_rust(iters: u32) -> OverheadResult {
    let mut f = FourierForecaster::default();
    let mut s = RustSolver::new(Weights::default(), 300, 1);
    measure("rust-mirror", &mut f, &mut s, 24, 120, iters, 99)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_step_fits_the_interval() {
        let mut r = run_rust(10);
        // paper: forecast 0.1 ms, optimizer 38 ms, against a 1 s interval.
        // the shape constraint: forecast << solve << dt
        assert!(r.forecast_ms.mean() < r.solve_ms.mean() * 2.0 + 1.0);
        assert!(
            r.solve_ms.mean() < 1000.0,
            "solve {} ms exceeds the control interval",
            r.solve_ms.mean()
        );
        assert!(r.forecast_ms.p95() < 50.0, "forecast too slow");
    }
}
