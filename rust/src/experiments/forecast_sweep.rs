//! Forecast model zoo sweep (the `forecast-sweep` CLI subcommand): every
//! backend — fourier, arima, histogram, attn, and the online `auto`
//! selector — against three demand shapes: the synthetic bursty trace,
//! the Azure-like trace, and a diurnal (sinusoidal-rate Poisson) trace
//! the fixed generators don't cover.
//!
//! Each cell reports two things: the rolling forecast accuracy of the
//! backend on the trace's 30 s demand bins (the Fig. 4 protocol,
//! extended to the zoo), and the end-to-end MPC run driven through that
//! backend (P99 / cold starts / selector telemetry). Everything except
//! the wall-clock runtime column is deterministic in `(seed, trace,
//! backend)` — see the tests here and `tests/forecast_zoo.rs`.

use std::time::Instant;

use crate::config::{
    secs, ControllerConfig, ExperimentConfig, ForecastBackend, ForecastConfig, Micros, Policy,
    TraceKind,
};
use crate::experiments::fig4::{self, rolling_eval_h, ForecastEval};
use crate::experiments::runner::run_experiment;
use crate::forecast::selector::{make_backend, AutoSelector};
use crate::forecast::{accuracy, Forecaster};
use crate::metrics::RunReport;
use crate::util::bench::Table;
use crate::util::rng::Rng;
use crate::workload::Trace;

/// The demand shapes the sweep covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepTrace {
    Bursty,
    Azure,
    Diurnal,
}

impl SweepTrace {
    pub const ALL: [SweepTrace; 3] = [SweepTrace::Bursty, SweepTrace::Azure, SweepTrace::Diurnal];

    pub fn name(self) -> &'static str {
        match self {
            SweepTrace::Bursty => "bursty",
            SweepTrace::Azure => "azure",
            SweepTrace::Diurnal => "diurnal",
        }
    }
}

/// Generate the trace for one sweep row. Bursty and azure reuse the
/// Fig. 4 generators verbatim; diurnal is local to the sweep.
pub fn trace_for(trace: SweepTrace, duration: Micros, seed: u64) -> Trace {
    match trace {
        SweepTrace::Bursty => fig4::trace_for(TraceKind::SyntheticBursty, duration, seed),
        SweepTrace::Azure => fig4::trace_for(TraceKind::AzureLike, duration, seed),
        SweepTrace::Diurnal => diurnal(duration, seed),
    }
}

/// Diurnal trace: a Poisson process whose rate follows a compressed
/// "day" — `base + amp * sin(2π t / period)`, floored above zero. The
/// smooth periodicity is the regime the Fourier predictor was built
/// for, which makes this the control trace of the sweep (a backend
/// that loses to fourier here is not being mis-scored by the selector).
pub fn diurnal(duration: Micros, seed: u64) -> Trace {
    // distinct stream from the azure/synthetic generators under equal seeds
    let mut rng = Rng::new(seed ^ 0x00D1_0BA7);
    let end = duration as f64 / 1e6;
    let period = 3600.0; // one "day" per simulated hour
    let (base, amp) = (6.0, 5.0);
    let mut arrivals = Vec::new();
    let mut t = 0.0f64;
    loop {
        // piecewise evaluation of the inhomogeneous rate at the current
        // time — fine at these rates, where steps are ≪ the period
        let rate = (base + amp * (std::f64::consts::TAU * t / period).sin()).max(0.2);
        t += rng.exp(rate);
        if t >= end {
            break;
        }
        arrivals.push(secs(t));
    }
    Trace::new(arrivals)
}

/// Shared shape for every cell of a forecast sweep.
#[derive(Debug, Clone)]
pub struct SweepParams {
    pub duration_s: f64,
    pub seed: u64,
    /// Forecast history window fed per evaluation (30 s bins).
    pub window: usize,
    /// Forecast horizon scored per evaluation (30 s bins).
    pub horizon: usize,
}

impl Default for SweepParams {
    fn default() -> Self {
        SweepParams {
            duration_s: 14400.0,
            seed: 42,
            window: 120, // matches the controller/artifact forecast window
            horizon: 24,
        }
    }
}

/// One sweep cell: rolling accuracy + the MPC run for (trace, backend).
#[derive(Debug, Clone)]
pub struct ForecastCell {
    pub trace: SweepTrace,
    pub backend: ForecastBackend,
    pub eval: ForecastEval,
    pub report: RunReport,
}

/// Experiment config for one cell's MPC run. The diurnal trace has no
/// `TraceKind`; its cells borrow the synthetic kind for the config (the
/// runner consumes the explicitly generated trace either way).
pub fn cell_config(p: &SweepParams, trace: SweepTrace, backend: ForecastBackend) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        trace: match trace {
            SweepTrace::Azure => TraceKind::AzureLike,
            SweepTrace::Bursty | SweepTrace::Diurnal => TraceKind::SyntheticBursty,
        },
        duration: secs(p.duration_s),
        seed: p.seed,
        ..Default::default()
    };
    cfg.controller.forecast = ForecastConfig {
        backend,
        ..Default::default()
    };
    cfg
}

/// Score one backend on a binned demand series with the Fig. 4 rolling
/// protocol. Fixed backends go through [`rolling_eval_h`] unchanged;
/// `auto` additionally sees every realized bin (the selector's scoring
/// input) before each evaluation point, exactly as the controller
/// feeds it.
pub fn eval_backend(
    backend: ForecastBackend,
    bins: &[f64],
    window: usize,
    horizon: usize,
    trace_name: &str,
) -> ForecastEval {
    let gamma_clip = ControllerConfig::default().gamma_clip;
    if backend != ForecastBackend::Auto {
        let mut f = make_backend(backend, gamma_clip);
        return rolling_eval_h(&mut *f, bins, window, horizon, trace_name);
    }
    let fc = ForecastConfig {
        backend: ForecastBackend::Auto,
        ..Default::default()
    };
    let mut sel = AutoSelector::new(&fc, gamma_clip);
    let mut preds = Vec::new();
    let mut actuals = Vec::new();
    let mut runtime_ns = 0.0;
    let mut n = 0usize;
    let stride = (horizon / 2).max(1);
    let mut fed = 0usize;
    let mut t = window;
    while t + horizon <= bins.len() {
        // catch the selector up on every bin realized since the last
        // evaluation point, so routing reflects the live scores
        while fed < t {
            sel.observe(&bins[..=fed], bins[fed]);
            fed += 1;
        }
        let hist = &bins[t - window..t];
        let t0 = Instant::now();
        let p = sel.forecast(hist, horizon);
        runtime_ns += t0.elapsed().as_nanos() as f64;
        n += 1;
        preds.extend_from_slice(&p);
        actuals.extend_from_slice(&bins[t..t + horizon]);
        t += stride;
    }
    ForecastEval {
        predictor: sel.name().to_string(),
        trace: trace_name.to_string(),
        accuracy_pct: accuracy::accuracy_pct(&preds, &actuals),
        wape: accuracy::wape(&preds, &actuals),
        smape: accuracy::smape(&preds, &actuals),
        rmse: accuracy::rmse(&preds, &actuals),
        mean_runtime_ms: runtime_ns / n.max(1) as f64 / 1e6,
        evaluations: n,
    }
}

/// Run one (trace, backend) cell: the rolling accuracy eval on the
/// trace's 30 s bins plus the end-to-end MPC run through the backend.
pub fn run_cell(p: &SweepParams, trace: SweepTrace, backend: ForecastBackend) -> ForecastCell {
    let t = trace_for(trace, secs(p.duration_s), p.seed);
    let bins: Vec<f64> = t.binned(secs(30.0)).iter().map(|&b| b as f64).collect();
    let eval = eval_backend(backend, &bins, p.window, p.horizon, trace.name());
    let cfg = cell_config(p, trace, backend);
    let mut report = run_experiment(&cfg, Policy::Mpc, &t);
    // the config's TraceKind is a stand-in for the diurnal rows; label
    // the report with the sweep trace the cell actually ran
    report.trace = trace.name().to_string();
    ForecastCell {
        trace,
        backend,
        eval,
        report,
    }
}

/// Sweep every backend over every trace (one shared workload per trace).
pub fn run_sweep(p: &SweepParams) -> Vec<ForecastCell> {
    let mut cells = Vec::new();
    for trace in SweepTrace::ALL {
        for backend in ForecastBackend::ALL {
            cells.push(run_cell(p, trace, backend));
        }
    }
    cells
}

/// Print the sweep table: accuracy columns from the rolling eval, tail
/// latency and selector telemetry from the MPC run. Every column is
/// deterministic (the wall-clock runtime column is deliberately
/// omitted).
pub fn print_table(cells: &[ForecastCell]) {
    let mut t = Table::new(&[
        "trace",
        "backend",
        "acc %",
        "wape",
        "p99 ms",
        "cold",
        "switches",
        "model",
    ]);
    for c in cells {
        let r = &c.report;
        let model = match r.per_function.first() {
            Some(f) => f.forecast_model.clone(),
            None => "-".to_string(),
        };
        t.row(&[
            c.trace.name().to_string(),
            c.backend.name().to_string(),
            format!("{:.1}", c.eval.accuracy_pct),
            format!("{:.3}", c.eval.wape),
            format!("{:.0}", r.p99_ms),
            r.counters.cold_starts.to_string(),
            r.selector_switches.to_string(),
            model,
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Short enough to keep the grid cheap, long enough for the rolling
    /// eval: 3600 s = 120 bins against window 60 + horizon 12.
    fn quick() -> SweepParams {
        SweepParams {
            duration_s: 3600.0,
            seed: 7,
            window: 60,
            horizon: 12,
        }
    }

    #[test]
    fn diurnal_trace_is_deterministic_and_periodic() {
        let a = diurnal(secs(3600.0), 7);
        let b = diurnal(secs(3600.0), 7);
        assert_eq!(a.arrivals, b.arrivals);
        assert_ne!(a.arrivals, diurnal(secs(3600.0), 8).arrivals);
        assert!(a.duration() <= secs(3600.0));
        // the rate swings between 1 and 11 req/s over the hour: the
        // densest minute must clearly dominate the sparsest
        let bins = a.binned(secs(60.0));
        let (min, max) = (bins.iter().min().unwrap(), bins.iter().max().unwrap());
        assert!(max > &(min + 60), "no diurnal swing: min={min} max={max}");
    }

    #[test]
    fn a_cell_carries_backend_telemetry_end_to_end() {
        let cell = run_cell(&quick(), SweepTrace::Diurnal, ForecastBackend::Histogram);
        assert_eq!(cell.eval.predictor, "histogram");
        assert_eq!(cell.eval.trace, "diurnal");
        assert!(cell.eval.evaluations > 0);
        let r = &cell.report;
        assert!(r.completed > 0);
        assert_eq!(r.trace, "diurnal");
        assert_eq!(r.forecast, "histogram");
        assert_eq!(r.selector_switches, 0, "fixed backends never switch");
        assert!(r
            .per_function
            .iter()
            .all(|f| f.forecast_model == "histogram"));
    }

    #[test]
    fn sweep_covers_the_grid_and_auto_is_never_worst() {
        let cells = run_sweep(&quick());
        assert_eq!(cells.len(), SweepTrace::ALL.len() * ForecastBackend::ALL.len());
        for trace in SweepTrace::ALL {
            let row: Vec<&ForecastCell> = cells.iter().filter(|c| c.trace == trace).collect();
            let auto = row
                .iter()
                .find(|c| c.backend == ForecastBackend::Auto)
                .unwrap();
            let worst_fixed = row
                .iter()
                .filter(|c| c.backend != ForecastBackend::Auto)
                .map(|c| c.eval.accuracy_pct)
                .fold(f64::INFINITY, f64::min);
            // the acceptance bar: online selection may not do worse than
            // pinning the worst zoo member (same tolerance as Fig. 4)
            assert!(
                auto.eval.accuracy_pct >= worst_fixed - 1.0,
                "{}: auto {:.1}% < worst fixed {:.1}%",
                trace.name(),
                auto.eval.accuracy_pct,
                worst_fixed
            );
        }
    }

    #[test]
    fn auto_cell_is_deterministic_across_runs() {
        let p = quick();
        let a = run_cell(&p, SweepTrace::Bursty, ForecastBackend::Auto);
        let b = run_cell(&p, SweepTrace::Bursty, ForecastBackend::Auto);
        assert_eq!(a.eval.accuracy_pct, b.eval.accuracy_pct);
        assert_eq!(a.eval.wape, b.eval.wape);
        assert_eq!(a.report.p99_ms, b.report.p99_ms);
        assert_eq!(a.report.counters.cold_starts, b.report.counters.cold_starts);
        assert_eq!(a.report.selector_switches, b.report.selector_switches);
        let models: Vec<&str> = a
            .report
            .per_function
            .iter()
            .map(|f| f.forecast_model.as_str())
            .collect();
        let models_b: Vec<&str> = b
            .report
            .per_function
            .iter()
            .map(|f| f.forecast_model.as_str())
            .collect();
        assert_eq!(models, models_b);
    }
}
