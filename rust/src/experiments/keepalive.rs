//! Keep-alive sweep (the `keepalive-sweep` CLI subcommand and the fig13
//! bench target): fixed profile retention vs the MPC's adaptive
//! retention planner, across the scenarios the acceptance criteria name
//! — a single-tenant bursty run and Zipf-skewed multi-tenant runs.
//!
//! The quantity under test is the **resource-time vs P99 frontier**:
//! adaptive retention should strictly reduce idle/keep-alive
//! container-seconds (the paper's headline 34% resource-usage axis)
//! while the prewarm planner protects tail latency — the forecasts that
//! shrink a function's horizon during a lull are the same ones that
//! re-prewarm it before the next burst, so the trade is asymmetric by
//! construction.

use crate::config::{
    secs, ExperimentConfig, FleetConfig, KeepAliveConfig, KeepAlivePolicy, Policy, TenantConfig,
    TraceKind,
};
use crate::experiments::runner::run_tenant;
use crate::metrics::RunReport;
use crate::util::bench::Table;
use crate::workload::TenantWorkload;

/// One scenario of the sweep: a trace family and a tenancy shape.
#[derive(Debug, Clone, Copy)]
pub struct KeepAliveScenario {
    pub name: &'static str,
    pub trace: TraceKind,
    pub functions: u32,
}

/// The acceptance grid: bursty single-tenant, bursty Zipf multi-tenant,
/// azure Zipf multi-tenant.
pub const DEFAULT_SCENARIOS: [KeepAliveScenario; 3] = [
    KeepAliveScenario {
        name: "bursty/1fn",
        trace: TraceKind::SyntheticBursty,
        functions: 1,
    },
    KeepAliveScenario {
        name: "bursty/zipf",
        trace: TraceKind::SyntheticBursty,
        functions: 8,
    },
    KeepAliveScenario {
        name: "azure/zipf",
        trace: TraceKind::AzureLike,
        functions: 8,
    },
];

/// Shared knobs for every cell of a keep-alive sweep.
#[derive(Debug, Clone)]
pub struct KeepAliveParams {
    pub duration_s: f64,
    pub seed: u64,
    pub nodes: u32,
    pub zipf_s: f64,
    /// Adaptive horizon floor (seconds).
    pub min_s: f64,
    pub idle_cost: f64,
    pub cold_weight: f64,
    pub pressure: f64,
}

impl Default for KeepAliveParams {
    fn default() -> Self {
        let ka = KeepAliveConfig::default();
        KeepAliveParams {
            duration_s: 3600.0,
            seed: 42,
            nodes: 1,
            zipf_s: 1.1,
            min_s: ka.min as f64 / 1e6,
            idle_cost: ka.idle_cost_per_s,
            cold_weight: ka.cold_cost_weight,
            pressure: ka.pressure_weight,
        }
    }
}

/// One sweep cell: (scenario, retention policy) under the MPC scheduler.
#[derive(Debug, Clone)]
pub struct KeepAliveCell {
    pub scenario: &'static str,
    pub policy: KeepAlivePolicy,
    pub report: RunReport,
}

/// Experiment config for one cell.
pub fn cell_config(
    p: &KeepAliveParams,
    sc: &KeepAliveScenario,
    policy: KeepAlivePolicy,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        trace: sc.trace,
        fleet: FleetConfig {
            nodes: p.nodes,
            ..Default::default()
        },
        tenancy: TenantConfig {
            functions: sc.functions,
            zipf_s: p.zipf_s,
        },
        duration: secs(p.duration_s),
        seed: p.seed,
        ..Default::default()
    };
    cfg.controller.keepalive = KeepAliveConfig {
        policy,
        min: secs(p.min_s),
        idle_cost_per_s: p.idle_cost,
        cold_cost_weight: p.cold_weight,
        pressure_weight: p.pressure,
    };
    cfg
}

/// Run every scenario under both retention policies (MPC scheduler; the
/// fixed cell per scenario is the baseline its adaptive twin is judged
/// against). Cells come back ordered scenario-major, fixed before
/// adaptive.
pub fn run_sweep(p: &KeepAliveParams, scenarios: &[KeepAliveScenario]) -> Vec<KeepAliveCell> {
    let mut cells = Vec::with_capacity(scenarios.len() * 2);
    for sc in scenarios {
        let base = cell_config(p, sc, KeepAlivePolicy::Fixed);
        let workload = TenantWorkload::generate(
            sc.trace,
            base.duration,
            p.seed,
            sc.functions,
            p.zipf_s,
            &base.platform,
        );
        for policy in KeepAlivePolicy::ALL {
            let cfg = cell_config(p, sc, policy);
            cells.push(KeepAliveCell {
                scenario: sc.name,
                policy,
                report: run_tenant(&cfg, Policy::Mpc, &workload),
            });
        }
    }
    cells
}

/// Print the sweep table plus the per-scenario frontier verdict
/// (resource-time delta at the P99 delta).
pub fn print_table(cells: &[KeepAliveCell]) {
    let mut t = Table::new(&[
        "scenario",
        "keep-alive",
        "p50 ms",
        "p99 ms",
        "cold %",
        "idle s",
        "keep-alive s",
        "saved s",
        "early exp",
        "mean horizon s",
    ]);
    for c in cells {
        let r = &c.report;
        let cold_pct = if r.completed > 0 {
            100.0 * r.cold_requests as f64 / r.completed as f64
        } else {
            0.0
        };
        t.row(&[
            c.scenario.to_string(),
            c.policy.name().to_string(),
            format!("{:.0}", r.p50_ms),
            format!("{:.0}", r.p99_ms),
            format!("{cold_pct:.1}"),
            format!("{:.0}", r.idle_total_s),
            format!("{:.0}", r.keepalive_total_s),
            format!("{:.0}", r.idle_saved_s),
            r.counters.adaptive_expiries.to_string(),
            format!("{:.0}", r.mean_horizon_s),
        ]);
    }
    t.print();
    // frontier verdicts: adaptive vs its fixed twin, scenario by scenario
    for pair in cells.chunks(2) {
        let [fixed, adaptive] = pair else { continue };
        let idle_red = 100.0 * (fixed.report.idle_total_s - adaptive.report.idle_total_s)
            / fixed.report.idle_total_s.max(1e-9);
        let p99_delta = adaptive.report.p99_ms - fixed.report.p99_ms;
        let verdict = if idle_red > 0.0 && p99_delta <= 0.0 {
            "strictly better (less resource-time at equal-or-better P99)"
        } else if idle_red > 0.0 {
            "resource win at a P99 cost (inspect the trade)"
        } else {
            "no resource win here"
        };
        println!(
            "{}: adaptive idle-time {:+.1}% ({:.0} -> {:.0} s), P99 {:+.0} ms — {}",
            fixed.scenario,
            -idle_red,
            fixed.report.idle_total_s,
            adaptive.report.idle_total_s,
            p99_delta,
            verdict
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> KeepAliveParams {
        KeepAliveParams {
            duration_s: 600.0,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn cell_config_threads_the_knobs() {
        let p = KeepAliveParams {
            min_s: 12.0,
            idle_cost: 2.0,
            cold_weight: 8.0,
            pressure: 0.5,
            ..quick()
        };
        let cfg = cell_config(&p, &DEFAULT_SCENARIOS[1], KeepAlivePolicy::Adaptive);
        let ka = cfg.controller.keepalive;
        assert_eq!(ka.policy, KeepAlivePolicy::Adaptive);
        assert_eq!(ka.min, secs(12.0));
        assert_eq!(ka.idle_cost_per_s, 2.0);
        assert_eq!(ka.cold_cost_weight, 8.0);
        assert_eq!(ka.pressure_weight, 0.5);
        assert_eq!(cfg.tenancy.functions, 8);
        assert_eq!(cfg.trace, TraceKind::SyntheticBursty);
    }

    #[test]
    fn sweep_pairs_fixed_and_adaptive_per_scenario() {
        let cells = run_sweep(&quick(), &DEFAULT_SCENARIOS[..1]);
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].policy, KeepAlivePolicy::Fixed);
        assert_eq!(cells[1].policy, KeepAlivePolicy::Adaptive);
        for c in &cells {
            assert_eq!(c.report.dropped, 0, "{:?}", c.policy);
            assert_eq!(c.report.keepalive_policy, c.policy.name());
        }
        // the fixed cell records no retention trajectory or savings
        assert_eq!(cells[0].report.mean_horizon_s, 0.0);
        assert_eq!(cells[0].report.idle_saved_s, 0.0);
        assert_eq!(cells[0].report.counters.adaptive_expiries, 0);
        print_table(&cells); // table rendering must not panic
    }
}
