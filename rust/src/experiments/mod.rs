//! Experiment drivers — one per figure of the paper's evaluation section
//! (see DESIGN.md per-experiment index). Shared by the `cargo bench`
//! targets, the examples, and the CLI.

pub mod ablations;
pub mod cache;
pub mod chaos;
pub mod elasticity;
pub mod fig1;
pub mod fig4;
pub mod fig5_7;
pub mod fig8;
pub mod forecast_sweep;
pub mod keepalive;
pub mod runner;
pub mod sharded;
pub mod survival;
pub mod tenant;
pub mod throughput;

pub use runner::{make_scheduler, run_experiment, run_tenant, run_with_scheduler};
