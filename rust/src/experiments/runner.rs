//! Discrete-event experiment runner: drives a [`Scheduler`] policy against
//! a workload trace on the platform substrate and produces a [`RunReport`].
//!
//! Event flow (all times virtual): Arrival → policy (dispatch or shape) →
//! platform outcomes → Ready/Done events → completions + idle-capacity
//! callbacks → keep-alive checks. Control and Sample ticks fire at their
//! configured cadences until the trace duration elapses; a grace window
//! lets in-flight work drain before the books close.

use crate::baselines::{IceBreaker, OpenWhiskDefault};
use crate::cluster::platform::{CompleteOutcome, KeepAliveVerdict, Platform, ReadyOutcome};
use crate::config::{secs, ExperimentConfig, Micros, Policy};
use crate::coordinator::controller::MpcScheduler;
use crate::coordinator::{Ctx, Ev, Scheduler};
use crate::forecast::FourierForecaster;
use crate::metrics::{Recorder, RunReport};
use crate::mpc::RustSolver;
use crate::simulator::EventQueue;
use crate::workload::Trace;

/// Post-duration grace for in-flight work (forced dispatch + cold start +
/// execution all fit comfortably).
pub fn grace() -> Micros {
    secs(60.0)
}

/// Build the default (in-process solver) scheduler for a policy.
pub fn make_scheduler(cfg: &ExperimentConfig, policy: Policy) -> Box<dyn Scheduler> {
    match policy {
        Policy::OpenWhisk => Box::new(OpenWhiskDefault),
        Policy::IceBreaker => Box::new(IceBreaker::new(
            cfg.controller.clone(),
            Box::new(FourierForecaster {
                gamma_clip: cfg.controller.gamma_clip,
                ..Default::default()
            }),
        )),
        Policy::Mpc => Box::new(MpcScheduler::new(
            cfg.controller.clone(),
            Box::new(FourierForecaster {
                gamma_clip: cfg.controller.gamma_clip,
                ..Default::default()
            }),
            Box::new(RustSolver::new(
                cfg.controller.weights,
                cfg.controller.pgd_iters,
                cfg.controller.cold_steps,
            )),
        )),
    }
}

/// Run `policy` (by name) on `trace` under `cfg`.
pub fn run_experiment(cfg: &ExperimentConfig, policy: Policy, trace: &Trace) -> RunReport {
    run_with_scheduler(cfg, make_scheduler(cfg, policy), trace)
}

/// Run an explicit scheduler instance (e.g. HLO-backed) on `trace`.
pub fn run_with_scheduler(
    cfg: &ExperimentConfig,
    mut sched: Box<dyn Scheduler>,
    trace: &Trace,
) -> RunReport {
    let mut platform = Platform::new(cfg.platform.clone(), cfg.seed ^ 0x9_1A7F0);
    let mut events: EventQueue<Ev> = EventQueue::new();
    let mut recorder = Recorder::new(trace.len());

    for (i, &t) in trace.arrivals.iter().enumerate() {
        events.push(t, Ev::Arrival(i as u64));
    }
    if let Some(dt) = sched.tick_interval() {
        events.push(dt, Ev::Control);
    }
    events.push(cfg.sample_interval, Ev::Sample);

    let cutoff = cfg.duration + grace();

    while let Some(s) = events.pop_until(cutoff) {
        let now = s.time;
        match s.event {
            Ev::Arrival(req) => {
                recorder.on_arrival(req, now);
                let mut ctx = Ctx {
                    now,
                    platform: &mut platform,
                    events: &mut events,
                    recorder: &mut recorder,
                    cfg,
                };
                sched.on_arrival(req, &mut ctx);
            }
            Ev::Ready(cid) => match platform.container_ready(cid, now) {
                ReadyOutcome::Started { done_at, .. } => {
                    events.push(done_at, Ev::Done(cid));
                }
                ReadyOutcome::Idle => {
                    let mut ctx = Ctx {
                        now,
                        platform: &mut platform,
                        events: &mut events,
                        recorder: &mut recorder,
                        cfg,
                    };
                    ctx.schedule_keepalive(cid);
                    sched.on_idle_capacity(&mut ctx);
                }
            },
            Ev::Done(cid) => {
                let CompleteOutcome { completed, next } = platform.exec_complete(cid, now);
                recorder.on_complete(completed, now);
                match next {
                    Some((_req, done_at)) => events.push(done_at, Ev::Done(cid)),
                    None => {
                        let mut ctx = Ctx {
                            now,
                            platform: &mut platform,
                            events: &mut events,
                            recorder: &mut recorder,
                            cfg,
                        };
                        ctx.schedule_keepalive(cid);
                        sched.on_idle_capacity(&mut ctx);
                    }
                }
            }
            Ev::Control => {
                let mut ctx = Ctx {
                    now,
                    platform: &mut platform,
                    events: &mut events,
                    recorder: &mut recorder,
                    cfg,
                };
                sched.on_control_tick(&mut ctx);
                // keep ticking through the grace window while work remains
                let dt = sched.tick_interval().unwrap_or(cfg.controller.dt);
                if now < cfg.duration || sched.queue_len() > 0 {
                    events.push(now + dt, Ev::Control);
                }
            }
            Ev::Sample => {
                recorder.on_gauge(platform.gauge(now, sched.queue_len()));
                if now < cfg.duration {
                    events.push(now + cfg.sample_interval, Ev::Sample);
                }
            }
            Ev::KeepAlive(cid) => match platform.keepalive_check(cid, now) {
                KeepAliveVerdict::Recheck(t) => events.push(t, Ev::KeepAlive(cid)),
                KeepAliveVerdict::Expired | KeepAliveVerdict::NotApplicable => {}
            },
        }
    }

    let end = cutoff.max(events.now());
    let (keepalive, idle_totals) = platform.finalize(end);
    RunReport::from_recorder(
        sched.name(),
        cfg.trace.name(),
        cfg.duration,
        &recorder,
        platform.counters,
        &keepalive,
        &idle_totals,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Trace;

    fn quick_cfg(duration_s: f64) -> ExperimentConfig {
        ExperimentConfig {
            duration: secs(duration_s),
            ..Default::default()
        }
    }

    /// A steady 4 req/s trace for 120 s.
    fn steady_trace() -> Trace {
        Trace::new((0..480).map(|i| i as u64 * 250_000).collect())
    }

    #[test]
    fn openwhisk_completes_all_requests() {
        let cfg = quick_cfg(120.0);
        let report = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
        assert_eq!(report.dropped, 0, "{report:?}");
        assert_eq!(report.completed, 480);
        assert!(report.counters.cold_starts >= 1);
        // steady load at 4 req/s: a handful of containers absorb it after
        // the initial cold-start wave
        assert!(report.mean_warm >= 1.0);
    }

    #[test]
    fn mpc_completes_all_requests() {
        let cfg = quick_cfg(120.0);
        let report = run_experiment(&cfg, Policy::Mpc, &steady_trace());
        assert_eq!(report.dropped, 0, "{report:?}");
        assert_eq!(report.completed, 480);
        // control overhead recorded every tick
        assert!(report.solve_overhead_ms > 0.0);
    }

    #[test]
    fn icebreaker_completes_all_requests() {
        let cfg = quick_cfg(120.0);
        let report = run_experiment(&cfg, Policy::IceBreaker, &steady_trace());
        assert_eq!(report.dropped, 0, "{report:?}");
        assert_eq!(report.completed, 480);
    }

    #[test]
    fn empty_trace_is_fine() {
        let cfg = quick_cfg(10.0);
        let report = run_experiment(&cfg, Policy::Mpc, &Trace::default());
        assert_eq!(report.completed, 0);
        assert_eq!(report.counters.cold_starts, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg(60.0);
        let a = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
        let b = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
        assert_eq!(a.mean_ms, b.mean_ms);
        assert_eq!(a.counters.cold_starts, b.counters.cold_starts);
    }

    #[test]
    fn gauges_sampled_at_one_minute_cadence() {
        let cfg = quick_cfg(180.0);
        let report = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
        assert!(report.warm_series.len() >= 3, "{:?}", report.warm_series);
    }
}
