//! Discrete-event experiment runner: drives a [`Scheduler`] policy against
//! a workload trace on the invoker fleet and produces a [`RunReport`].
//!
//! Event flow (all times virtual): Arrival → policy (dispatch or shape) →
//! placement → per-node platform outcomes → Ready/Done events →
//! completions + idle-capacity callbacks → keep-alive checks. Control and
//! Sample ticks fire at their configured cadences until the trace duration
//! elapses; a grace window lets in-flight work drain before the books
//! close. An optional NodeFail event takes an invoker offline mid-run and
//! redispatches its lost work through the placement layer.

use crate::baselines::{IceBreaker, OpenWhiskDefault};
use crate::cluster::chaos::{self, ChaosEngine};
use crate::cluster::fleet::Fleet;
use crate::cluster::platform::{CompleteOutcome, KeepAliveVerdict, ReadyOutcome};
use crate::config::{secs, to_secs, ExperimentConfig, Micros, Policy};
use crate::coordinator::controller::MpcScheduler;
use crate::coordinator::survival::SurvivalScheduler;
use crate::coordinator::{Ctx, Ev, Scheduler};
use crate::forecast::FourierForecaster;
use crate::metrics::{Recorder, RunReport};
use crate::mpc::RustSolver;
use crate::simulator::{EventQueue, Scheduled};
use crate::workload::{TenantWorkload, Trace};

use super::sharded;

/// Post-duration grace for in-flight work (forced dispatch + cold start +
/// execution all fit comfortably).
pub fn grace() -> Micros {
    secs(60.0)
}

/// Build the default (in-process solver) scheduler for a policy.
///
/// Config-derived adjustments happen here: the MPC's planning pool bound
/// `w_max` scales with the fleet's total capacity (the ROADMAP
/// `w_max × nodes` follow-up — exactly 1× for the legacy single node,
/// and 1× in capacity-preserving sweeps where a fixed total is split
/// across nodes), and both proactive policies learn the workload's
/// function count for their per-function prewarm splits. The MPC
/// additionally gets live-capacity scaling (elasticity): it re-derives
/// the same bound from the *online* capacity at every control step, so a
/// drained node shrinks the prewarm plan and a rejoined one grows it
/// back — with the whole fleet online the re-derived value is
/// bit-identical to the startup scaling below.
pub fn make_scheduler(cfg: &ExperimentConfig, policy: Policy) -> Box<dyn Scheduler> {
    let mut cc = cfg.controller.clone();
    let base_w_max = cc.weights.w_max;
    let scale =
        cfg.fleet.total_capacity(&cfg.platform) as f64 / cfg.platform.resource_cap().max(1) as f64;
    cc.weights.w_max *= scale;
    let functions = cfg.tenancy.functions as usize;
    match policy {
        Policy::OpenWhisk => Box::new(OpenWhiskDefault),
        Policy::IceBreaker => Box::new(
            IceBreaker::new(
                cc.clone(),
                Box::new(FourierForecaster {
                    gamma_clip: cc.gamma_clip,
                    ..Default::default()
                }),
            )
            .with_functions(functions),
        ),
        Policy::Mpc => Box::new(
            MpcScheduler::new(
                cc.clone(),
                Box::new(FourierForecaster {
                    gamma_clip: cc.gamma_clip,
                    ..Default::default()
                }),
                Box::new(RustSolver::new(cc.weights, cc.pgd_iters, cc.cold_steps)),
            )
            .with_functions(functions)
            .with_live_capacity(cfg.platform.resource_cap(), base_w_max)
            // adaptive keep-alive rides the MPC control loop (a no-op
            // under the default fixed policy); the reactive baselines
            // keep their profile windows
            .with_keepalive(cc.keepalive)
            // graceful degradation (chaos runs only): floor the live
            // pool bound during storms and discount stale forecasts
            // after flash crowds
            .with_degradation(cfg.chaos.enabled())
            // forecast-zoo backend selection (a no-op under the default
            // fourier backend, which keeps the seed path bit-identical)
            .with_forecast(&cc.forecast),
        ),
        // slot-survival lifecycle control: reactive dispatch, per-container
        // retention from empirical inter-arrival survival estimates
        Policy::Survival => Box::new(SurvivalScheduler::new(cc.clone()).with_functions(functions)),
    }
}

/// Run `policy` (by name) on `trace` under `cfg`.
pub fn run_experiment(cfg: &ExperimentConfig, policy: Policy, trace: &Trace) -> RunReport {
    run_with_scheduler(cfg, make_scheduler(cfg, policy), trace)
}

/// Run an explicit scheduler instance (e.g. HLO-backed) on `trace`.
pub fn run_with_scheduler(
    cfg: &ExperimentConfig,
    sched: Box<dyn Scheduler>,
    trace: &Trace,
) -> RunReport {
    run_tenant_with_scheduler(cfg, sched, &TenantWorkload::single(trace, &cfg.platform))
}

/// Run `policy` on a multi-tenant workload under `cfg`. Per-function
/// P50/P99 come back in `RunReport::per_function`; set
/// `cfg.tenancy.functions` to the workload's function count so the
/// proactive policies split their prewarm budgets per function.
pub fn run_tenant(cfg: &ExperimentConfig, policy: Policy, workload: &TenantWorkload) -> RunReport {
    run_tenant_with_scheduler(cfg, make_scheduler(cfg, policy), workload)
}

/// Run an explicit scheduler on a multi-tenant workload — the shared
/// event loop every experiment path funnels through.
pub fn run_tenant_with_scheduler(
    cfg: &ExperimentConfig,
    mut sched: Box<dyn Scheduler>,
    workload: &TenantWorkload,
) -> RunReport {
    // chaos: a flash-crowd run remaps the workload up front (the Zipf
    // inversion is a property of the workload, not of the event loop) —
    // with any other mode, including off, the borrow passes through
    let flashed;
    let workload = match chaos::flash_window(cfg) {
        Some(win) => {
            flashed = chaos::apply_flash(workload, win);
            &flashed
        }
        None => workload,
    };
    // the legacy single-platform seed; node 0 receives it unchanged so a
    // one-node fleet reproduces the pre-fleet metrics exactly
    let mut fleet = Fleet::with_registry(
        &cfg.fleet,
        &cfg.platform,
        &workload.registry,
        cfg.seed ^ 0x9_1A7F0,
    );
    if cfg.chaos.enabled() {
        // the engine's salted RNG stream exists only on chaos runs, so
        // the seed path never draws from it (byte-identity off-path)
        fleet.set_chaos(ChaosEngine::new(cfg.chaos, cfg.seed, &workload.registry));
    }
    let mut events: EventQueue<Ev> = EventQueue::new();
    let mut recorder = Recorder::new(workload.len());
    let wall_start = std::time::Instant::now();

    for (i, &t) in workload.arrivals.iter().enumerate() {
        events.push(t, Ev::Arrival(i as u64));
    }
    if let Some(dt) = sched.tick_interval() {
        events.push(dt, Ev::Control);
    }
    events.push(cfg.sample_interval, Ev::Sample);
    // node-fault timeline: the explicit --fail-node/--restore-node
    // schedule plus whatever the chaos preset derives (empty for off)
    let (preset_failures, preset_restores) = chaos::schedule_for(cfg);
    for f in cfg.fleet.failures.iter().chain(preset_failures.iter()) {
        events.push(f.at, Ev::NodeFail(f.node));
    }
    for r in cfg.fleet.restores.iter().chain(preset_restores.iter()) {
        events.push(r.at, Ev::NodeRestore(r.node, r.cap));
    }

    let cutoff = cfg.duration + grace();
    let threads = cfg.threads.max(1);

    if threads > 1 {
        sharded::drive(
            cfg,
            workload,
            &mut *sched,
            &mut fleet,
            &mut events,
            &mut recorder,
            cutoff,
            threads as usize,
        );
    } else {
        while let Some(s) = events.pop_until(cutoff) {
            step(
                s,
                cfg,
                workload,
                &mut *sched,
                &mut fleet,
                &mut events,
                &mut recorder,
            );
        }
    }

    let wall_secs = wall_start.elapsed().as_secs_f64();
    let end = cutoff.max(events.now());
    // per-node snapshot before finalize drains the idle pools, so the
    // report shows the end-of-run container population
    let per_node = fleet.node_reports();
    let (keepalive, idle_totals) = fleet.finalize(end);
    let mut report = RunReport::from_recorder(
        sched.name(),
        cfg.trace.name(),
        cfg.duration,
        &recorder,
        fleet.counters(),
        &keepalive,
        &idle_totals,
    );
    report.nodes = fleet.node_count() as u32;
    report.threads = threads;
    report.placement = cfg.fleet.placement.name().to_string();
    report.keepalive_policy = cfg.controller.keepalive.policy.name().to_string();
    report.idle_saved_s = to_secs(fleet.idle_saved());
    // forecast-zoo telemetry: policies with a forecast registry report
    // the backend, selector activity, and per-function model rows; the
    // reactive baselines keep the structural defaults (fourier / 0)
    if let Some(ft) = sched.forecast_telemetry() {
        report.forecast = ft.backend.to_string();
        report.selector_switches = ft.selector_switches;
        for f in &mut report.per_function {
            if let Some(&(_, model, acc)) =
                ft.per_function.iter().find(|&&(func, _, _)| func == f.func)
            {
                f.forecast_model = model.to_string();
                f.forecast_accuracy_pct = acc;
            }
        }
    }
    // slot-survival telemetry: the survival policy reports its release /
    // retain decisions and mean reuse probability, and labels the
    // retention column with its own policy name (it actuates through the
    // same live-horizon path as the adaptive planner); everything else
    // keeps the structural zeros
    if let Some(st) = sched.survival_telemetry() {
        report.keepalive_policy = sched.name().to_string();
        report.survival_releases = st.releases;
        report.survival_retained = st.retained;
        report.survival_mean_p = st.mean_survival;
    }
    report.per_node = per_node;
    report.set_throughput(events.processed(), wall_secs);
    report
}

/// Apply one popped event to the simulation — the sequential event
/// loop's body, extracted so the sharded engine (`experiments::sharded`)
/// can fall back to it verbatim for global events and unbatchable
/// stretches. Any behavior change here changes *both* execution modes,
/// which is what keeps them bit-identical.
pub(crate) fn step(
    s: Scheduled<Ev>,
    cfg: &ExperimentConfig,
    workload: &TenantWorkload,
    sched: &mut dyn Scheduler,
    fleet: &mut Fleet,
    events: &mut EventQueue<Ev>,
    recorder: &mut Recorder,
) {
    let now = s.time;
    match s.event {
        Ev::Arrival(req) => {
            recorder.on_arrival_for(req, now, workload.func_of(req));
            let mut ctx = Ctx {
                now,
                fleet: &mut *fleet,
                events: &mut *events,
                recorder: &mut *recorder,
                cfg,
            };
            sched.on_arrival(req, &mut ctx);
        }
        Ev::Ready(node, cid) => match fleet.container_ready(node, cid, now) {
            Some(ReadyOutcome::Started { request, done_at }) => {
                let mut ctx = Ctx {
                    now,
                    fleet: &mut *fleet,
                    events: &mut *events,
                    recorder: &mut *recorder,
                    cfg,
                };
                ctx.push_exec(node, cid, request, done_at);
            }
            Some(ReadyOutcome::Idle) => {
                let mut ctx = Ctx {
                    now,
                    fleet: &mut *fleet,
                    events: &mut *events,
                    recorder: &mut *recorder,
                    cfg,
                };
                ctx.schedule_keepalive(node, cid);
                sched.on_idle_capacity(&mut ctx);
            }
            Some(ReadyOutcome::Respawned { req, cid: ncid, ready_at }) => {
                // multi-tenant recycle: the container was traded for a
                // cold start bound to a stranded foreign-function
                // waiter, which therefore pays that cold start — and,
                // being a request-bound spawn, rolls the chaos spawn
                // fault like any other
                let mut ctx = Ctx {
                    now,
                    fleet: &mut *fleet,
                    events: &mut *events,
                    recorder: &mut *recorder,
                    cfg,
                };
                if ctx.fleet.chaos_spawn_fails() {
                    ctx.fleet.abort_spawn(node, ncid, now);
                    ctx.chaos_retry_or_drop(req, node);
                } else {
                    ctx.recorder.on_cold(req);
                    ctx.events.push(ready_at, Ev::Ready(node, ncid));
                }
            }
            None => {
                // stale event: the node drained, or chaos killed the
                // container first — structurally dropped, never a panic
                crate::log_debug!(
                    "stale Ready dropped: node {node} container {cid} at t={now}us"
                );
            }
        },
        Ev::Done(node, cid) => match fleet.exec_complete(node, cid, now) {
            Some(CompleteOutcome {
                completed,
                next,
                respawn,
            }) => {
                let mut ctx = Ctx {
                    now,
                    fleet: &mut *fleet,
                    events: &mut *events,
                    recorder: &mut *recorder,
                    cfg,
                };
                if ctx.fleet.chaos_exec_fails() {
                    // execution-level fault: the container ran (and goes
                    // idle normally, its resource-time charged) but the
                    // result failed — the request retries instead of
                    // completing
                    ctx.chaos_retry_or_drop(completed, node);
                } else {
                    ctx.recorder.on_complete(completed, now);
                }
                match (next, respawn) {
                    (Some((req, done_at)), _) => {
                        ctx.push_exec(node, cid, req, done_at);
                    }
                    (None, Some((rreq, ncid, ready_at))) => {
                        if ctx.fleet.chaos_spawn_fails() {
                            ctx.fleet.abort_spawn(node, ncid, now);
                            ctx.chaos_retry_or_drop(rreq, node);
                        } else {
                            ctx.recorder.on_cold(rreq);
                            ctx.events.push(ready_at, Ev::Ready(node, ncid));
                        }
                    }
                    (None, None) => {
                        ctx.schedule_keepalive(node, cid);
                        sched.on_idle_capacity(&mut ctx);
                    }
                }
            }
            None => {
                crate::log_debug!(
                    "stale Done dropped: node {node} container {cid} at t={now}us"
                );
            }
        },
        Ev::Control => {
            let mut ctx = Ctx {
                now,
                fleet: &mut *fleet,
                events: &mut *events,
                recorder: &mut *recorder,
                cfg,
            };
            sched.on_control_tick(&mut ctx);
            // keep ticking through the grace window while work remains
            let dt = sched.tick_interval().unwrap_or(cfg.controller.dt);
            if now < cfg.duration || sched.queue_len() > 0 {
                events.push(now + dt, Ev::Control);
            }
        }
        Ev::Sample => {
            recorder.on_gauge(fleet.gauge(now, sched.queue_len()));
            if now < cfg.duration {
                events.push(now + cfg.sample_interval, Ev::Sample);
            }
        }
        Ev::KeepAlive(node, cid) => match fleet.keepalive_check(node, cid, now) {
            KeepAliveVerdict::Recheck(t) => events.push(t, Ev::KeepAlive(node, cid)),
            KeepAliveVerdict::Expired | KeepAliveVerdict::NotApplicable => {}
        },
        Ev::NodeFail(node) => {
            // drain scenario: the node's in-flight work and backlog
            // redistribute through the placement layer immediately
            let lost = fleet.fail_node(node, now);
            let mut ctx = Ctx {
                now,
                fleet: &mut *fleet,
                events: &mut *events,
                recorder: &mut *recorder,
                cfg,
            };
            for req in lost {
                ctx.dispatch(req);
            }
        }
        Ev::NodeRestore(node, cap) => {
            // rejoin scenario: the node comes back cold; placement
            // sees it immediately, and the MPC's live-capacity
            // re-scaling grows the prewarm budget back at its next
            // control step (which is when the node starts reabsorbing
            // load through prewarms and spill placement). A capacity
            // suffix on the restore spec rebinds the node's replica
            // cap (heterogeneous replacement hardware); the event
            // carries it so repeated restores need no config lookup.
            fleet.restore_node(node, now, cap);
        }
        Ev::ChaosRetry(req) => {
            // a faulted request's backoff elapsed: redispatch through
            // the placement layer like a fresh submission (its latency
            // clock still runs from the original arrival)
            let mut ctx = Ctx {
                now,
                fleet: &mut *fleet,
                events: &mut *events,
                recorder: &mut *recorder,
                cfg,
            };
            ctx.dispatch(req);
        }
        Ev::ChaosTimeout(node, cid) => match fleet.abort_exec(node, cid, now) {
            Some(req) => {
                // straggler killed at its deadline; the request retries
                let mut ctx = Ctx {
                    now,
                    fleet: &mut *fleet,
                    events: &mut *events,
                    recorder: &mut *recorder,
                    cfg,
                };
                ctx.chaos_retry_or_drop(req, node);
            }
            None => {
                crate::log_debug!(
                    "stale ChaosTimeout dropped: node {node} container {cid} at t={now}us"
                );
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NodeFailure, PlacementPolicy};
    use crate::workload::Trace;

    fn quick_cfg(duration_s: f64) -> ExperimentConfig {
        ExperimentConfig {
            duration: secs(duration_s),
            ..Default::default()
        }
    }

    /// A steady 4 req/s trace for 120 s.
    fn steady_trace() -> Trace {
        Trace::new((0..480).map(|i| i as u64 * 250_000).collect())
    }

    #[test]
    fn openwhisk_completes_all_requests() {
        let cfg = quick_cfg(120.0);
        let report = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
        assert_eq!(report.dropped, 0, "{report:?}");
        assert_eq!(report.completed, 480);
        assert!(report.counters.cold_starts >= 1);
        // steady load at 4 req/s: a handful of containers absorb it after
        // the initial cold-start wave
        assert!(report.mean_warm >= 1.0);
    }

    #[test]
    fn mpc_completes_all_requests() {
        let cfg = quick_cfg(120.0);
        let report = run_experiment(&cfg, Policy::Mpc, &steady_trace());
        assert_eq!(report.dropped, 0, "{report:?}");
        assert_eq!(report.completed, 480);
        // control overhead recorded every tick
        assert!(report.solve_overhead_ms > 0.0);
    }

    #[test]
    fn icebreaker_completes_all_requests() {
        let cfg = quick_cfg(120.0);
        let report = run_experiment(&cfg, Policy::IceBreaker, &steady_trace());
        assert_eq!(report.dropped, 0, "{report:?}");
        assert_eq!(report.completed, 480);
    }

    #[test]
    fn empty_trace_is_fine() {
        let cfg = quick_cfg(10.0);
        let report = run_experiment(&cfg, Policy::Mpc, &Trace::default());
        assert_eq!(report.completed, 0);
        assert_eq!(report.counters.cold_starts, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg(60.0);
        let a = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
        let b = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
        assert_eq!(a.mean_ms, b.mean_ms);
        assert_eq!(a.counters.cold_starts, b.counters.cold_starts);
    }

    #[test]
    fn gauges_sampled_at_one_minute_cadence() {
        let cfg = quick_cfg(180.0);
        let report = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
        assert!(report.warm_series.len() >= 3, "{:?}", report.warm_series);
    }

    #[test]
    fn single_node_metrics_identical_across_placements() {
        // with one node every placement policy must collapse to the same
        // node choice, so metrics are bit-identical (the determinism
        // guarantee that keeps the existing figures valid)
        let mut reports = Vec::new();
        for placement in PlacementPolicy::ALL {
            let mut cfg = quick_cfg(120.0);
            cfg.fleet.placement = placement;
            reports.push(run_experiment(&cfg, Policy::Mpc, &steady_trace()));
        }
        for r in &reports[1..] {
            assert_eq!(r.mean_ms, reports[0].mean_ms);
            assert_eq!(r.p99_ms, reports[0].p99_ms);
            assert_eq!(r.counters.cold_starts, reports[0].counters.cold_starts);
            assert_eq!(r.warm_series, reports[0].warm_series);
            assert_eq!(r.keepalive_total_s, reports[0].keepalive_total_s);
        }
    }

    #[test]
    fn multi_node_fleet_completes_under_each_placement() {
        for placement in PlacementPolicy::ALL {
            let mut cfg = quick_cfg(120.0);
            cfg.fleet.nodes = 4;
            cfg.fleet.placement = placement;
            let report = run_experiment(&cfg, Policy::OpenWhisk, &steady_trace());
            assert_eq!(report.dropped, 0, "{placement:?}: {report:?}");
            assert_eq!(report.completed, 480, "{placement:?}");
            assert_eq!(report.nodes, 4);
            assert_eq!(report.placement, placement.name());
        }
    }

    #[test]
    fn node_failure_redistributes_backlog() {
        // node 1 dies a third into the run; every request must still
        // complete on the survivors
        let mut cfg = quick_cfg(120.0);
        cfg.fleet.nodes = 4;
        cfg.fleet.placement = PlacementPolicy::RoundRobin;
        cfg.fleet.failures = vec![NodeFailure {
            node: 1,
            at: secs(40.0),
        }];
        for policy in [Policy::OpenWhisk, Policy::Mpc] {
            let report = run_experiment(&cfg, policy, &steady_trace());
            assert_eq!(report.dropped, 0, "{}: {report:?}", report.policy);
            assert_eq!(report.completed, 480, "{}", report.policy);
        }
    }

    #[test]
    fn warm_first_beats_round_robin_on_cold_starts() {
        // spraying a steady trickle across 4 nodes fragments the warm
        // pool; warm-first concentrates reuse, so it can never cold-start
        // more often than round-robin on this workload
        let mk = |placement| {
            let mut cfg = quick_cfg(120.0);
            cfg.fleet.nodes = 4;
            cfg.fleet.placement = placement;
            run_experiment(&cfg, Policy::OpenWhisk, &steady_trace())
        };
        let wf = mk(PlacementPolicy::WarmFirst);
        let rr = mk(PlacementPolicy::RoundRobin);
        assert!(
            wf.counters.cold_starts <= rr.counters.cold_starts,
            "warm-first {} cold starts > round-robin {}",
            wf.counters.cold_starts,
            rr.counters.cold_starts
        );
    }
}
