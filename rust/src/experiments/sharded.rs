//! Sharded parallel execution of the fleet event loop.
//!
//! The sequential runner walks one event heap; at fleet scale the walk is
//! dominated by *node-local* events — `Ready`/`Done`/`KeepAlive` touch
//! only their node's [`Platform`](crate::cluster::platform::Platform) and
//! spawn only same-node follow-ups. This module exploits that: it pops a
//! *batch window* of consecutive node-local events off the heap,
//! partitions the window's nodes into contiguous shards, processes each
//! shard on a `std::thread::scope` worker, and then **commits** the
//! workers' recorded effects back through the real event queue in the
//! exact `(time, seq)` order the sequential loop would have produced.
//! Results are bit-identical to `--threads 1` by construction:
//!
//! * **Window bound.** A batch extends at most `min_spawn_delay` past its
//!   first event (and never past the next global event or the run
//!   cutoff). Every event a node-local handler can spawn lands at least
//!   that far in the future — warm completions (`jitter(l_warm)`), cold
//!   readies (`jitter(l_cold)`, init-fraction-scaled when the image
//!   cache is live), keep-alive windows (profile / adaptive floor) — so
//!   nothing spawned inside the window can fire inside it, except
//!   keep-alive *rechecks* (absolute due times), which workers consume
//!   locally in order.
//! * **Global state.** Arrival/Control/Sample/NodeFail/NodeRestore touch
//!   placement, the scheduler, or the online set; they never enter a
//!   batch (collection stops at the first one), and a batch only forms
//!   while the shaping queue is empty — which makes the skipped
//!   `on_idle_capacity` callback a provable no-op (see the contract test
//!   in `coordinator::controller`). The queue only grows at `Arrival`, a
//!   global event, so emptiness is stable across the window.
//! * **Deterministic merge.** Workers record pushes and recorder ops;
//!   the commit phase replays them in `(time, seq)` order (ties broken
//!   by the global push sequence, exactly the heap's FIFO rule), calling
//!   [`EventQueue::push`] in the sequential push order — so future seq
//!   numbers, `processed()`, per-node RNG streams, and every recorded
//!   metric match the sequential run byte for byte.
//!
//! `min_spawn_delay == 0` (e.g. a zero-latency profile under jitter, or
//! an image cache with a zero init fraction) degrades to the sequential
//! path permanently — correct, just unaccelerated.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::container::ContainerId;
use crate::cluster::fleet::{Fleet, InvokerNode, NodeId};
use crate::cluster::platform::{CompleteOutcome, KeepAliveVerdict, ReadyOutcome};
use crate::cluster::RequestId;
use crate::config::{ExperimentConfig, KeepAlivePolicy, Micros};
use crate::coordinator::{Ev, Scheduler};
use crate::metrics::Recorder;
use crate::simulator::{EventQueue, Scheduled};
use crate::workload::tenant::FunctionRegistry;
use crate::workload::TenantWorkload;

/// The node a shard-batchable event belongs to; None for global events
/// (which never enter a batch).
fn node_of(ev: &Ev) -> Option<NodeId> {
    match *ev {
        Ev::Ready(n, _) | Ev::Done(n, _) | Ev::KeepAlive(n, _) => Some(n),
        // ChaosTimeout names a node, but its abort feeds the retry
        // dispatcher (cross-node placement), so it stays global — moot in
        // practice: chaos forces min_spawn_delay to 0 (sequential path).
        Ev::Arrival(_)
        | Ev::Control
        | Ev::Sample
        | Ev::NodeFail(_)
        | Ev::NodeRestore(_, _)
        | Ev::ChaosRetry(_)
        | Ev::ChaosTimeout(_, _) => None,
    }
}

/// Conservative lower bound (µs) on the delay of *any* event a
/// node-local handler can spawn: the minimum over every function's warm
/// latency, cold-start cost floor (init fraction only when the image
/// cache is live — a fully cached pull is free), and keep-alive window
/// (including the adaptive planner's floor, which bounds every live
/// override it can install), scaled by the worst-case downward jitter
/// with a 2 µs rounding guard. Zero means "never batch".
pub fn min_spawn_delay(cfg: &ExperimentConfig, registry: &FunctionRegistry) -> Micros {
    if cfg.chaos.enabled() {
        // chaos couples node-local handlers to global state (spawn/exec
        // fault rolls advance one shared RNG stream, and retries re-enter
        // cross-node placement), so shard isolation no longer holds —
        // chaos runs always take the sequential stepper
        return 0;
    }
    let mut bound = cfg.platform.keep_alive;
    for p in registry.profiles() {
        bound = bound.min(p.l_warm);
        let cold_floor = if cfg.platform.image.enabled() {
            (p.l_cold as f64 * cfg.platform.image.init_fraction.clamp(0.0, 1.0)).floor() as Micros
        } else {
            p.l_cold
        };
        bound = bound.min(cold_floor);
        bound = bound.min(p.keep_alive);
    }
    if cfg.controller.keepalive.policy == KeepAlivePolicy::Adaptive {
        bound = bound.min(cfg.controller.keepalive.min);
    }
    let j = cfg.platform.latency_jitter.clamp(0.0, 1.0);
    let scaled = (bound as f64 * (1.0 - j)).floor() as Micros;
    scaled.saturating_sub(2)
}

/// One event popped into a batch window, with its original heap identity.
struct BatchEv {
    time: Micros,
    seq: u64,
    ev: Ev,
}

/// Where a processed record came from: a real heap event (carrying its
/// original seq) or a keep-alive recheck consumed inside the window
/// (its seq is assigned at commit, when its generating push replays).
enum Origin {
    Batch(u64),
    Recheck,
}

/// One side effect of a processed event, recorded in handler order.
enum Action {
    /// Out-of-window event push, replayed through [`EventQueue::push`].
    Push(Micros, Ev),
    /// In-window keep-alive recheck, consumed locally: index of its
    /// record in the same node's record list. Replaying this assigns the
    /// seq the sequential push would have and schedules the record.
    ConsumeRecheck(usize),
    Cold(RequestId),
    Complete(RequestId, Micros),
}

/// One processed event (batch event or consumed recheck) on one node.
struct Rec {
    time: Micros,
    origin: Origin,
    actions: Vec<Action>,
}

/// Pending locally consumed rechecks on one node: `(due, spawn order,
/// container, record index)`, earliest first. Spawn order stands in for
/// the global seq — within a node the sequential push order is exactly
/// the worker's processing order, so it tie-breaks identically.
type LocalHeap = BinaryHeap<Reverse<(Micros, u64, ContainerId, usize)>>;

/// Drive the event loop to `cutoff` with `threads` shard workers.
/// Sequential stretches (global events at the head, a non-empty shaping
/// queue, or a zero spawn-delay bound) fall through to
/// [`runner::step`](super::runner) — the literal `--threads 1` path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn drive(
    cfg: &ExperimentConfig,
    workload: &TenantWorkload,
    sched: &mut dyn Scheduler,
    fleet: &mut Fleet,
    events: &mut EventQueue<Ev>,
    recorder: &mut Recorder,
    cutoff: Micros,
    threads: usize,
) {
    let delta = min_spawn_delay(cfg, &workload.registry);
    loop {
        let (head_time, head_is_node) = match events.peek() {
            Some(s) if s.time <= cutoff => (s.time, node_of(&s.event).is_some()),
            _ => break,
        };
        if delta == 0 || !head_is_node || sched.queue_len() > 0 {
            let s = events.pop_until(cutoff).expect("peeked event within cutoff");
            super::runner::step(s, cfg, workload, sched, fleet, events, recorder);
            continue;
        }
        // ---- batch window: consecutive node-local events in
        // [head_time, t_end), never past the cutoff ----
        let t_end = head_time.saturating_add(delta).min(cutoff.saturating_add(1));
        let mut batch: Vec<Scheduled<Ev>> = Vec::new();
        while let Some(s) = events.peek() {
            if s.time >= t_end || node_of(&s.event).is_none() {
                break;
            }
            batch.push(events.pop().expect("peeked event"));
        }
        run_batch(batch, t_end, threads, cfg, fleet, events, recorder);
    }
}

/// Partition a batch by node, process each node's stream (threaded over
/// contiguous node shards when more than one node has work), then commit
/// the recorded effects in global `(time, seq)` order.
fn run_batch(
    batch: Vec<Scheduled<Ev>>,
    t_end: Micros,
    threads: usize,
    cfg: &ExperimentConfig,
    fleet: &mut Fleet,
    events: &mut EventQueue<Ev>,
    recorder: &mut Recorder,
) {
    let n_nodes = fleet.node_count();
    let mut work: Vec<Vec<BatchEv>> = (0..n_nodes).map(|_| Vec::new()).collect();
    for s in batch {
        let node = node_of(&s.event).expect("batch holds only node events") as usize;
        work[node].push(BatchEv {
            time: s.time,
            seq: s.seq,
            ev: s.event,
        });
    }
    let mut results: Vec<Vec<Rec>> = (0..n_nodes).map(|_| Vec::new()).collect();
    let nodes = fleet.nodes_mut();
    let active = work.iter().filter(|w| !w.is_empty()).count();
    if active <= 1 {
        // one busy node (or a single-event window): threading would only
        // add scope overhead — process inline, same code path as a worker
        for (i, w) in work.iter_mut().enumerate() {
            if !w.is_empty() {
                results[i] = process_node(&mut nodes[i], std::mem::take(w), t_end, cfg);
            }
        }
    } else {
        let shard = n_nodes.div_ceil(threads).max(1);
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for ((node_shard, work_shard), res_shard) in nodes
                .chunks_mut(shard)
                .zip(work.chunks_mut(shard))
                .zip(results.chunks_mut(shard))
            {
                if work_shard.iter().all(|w| w.is_empty()) {
                    continue;
                }
                handles.push(scope.spawn(move || {
                    for ((nd, w), res) in node_shard
                        .iter_mut()
                        .zip(work_shard.iter_mut())
                        .zip(res_shard.iter_mut())
                    {
                        if !w.is_empty() {
                            *res = process_node(nd, std::mem::take(w), t_end, cfg);
                        }
                    }
                }));
            }
            for h in handles {
                h.join().expect("shard worker panicked");
            }
        });
    }
    commit(&results, events, recorder);
}

/// Walk one node's batch events merged with its locally consumed
/// keep-alive rechecks, in the order the sequential loop would pop them:
/// `(time, seq)`, where every batch event outranks every in-window
/// recheck at equal times (batch events were pushed — and so sequenced —
/// before the window began).
fn process_node(
    nd: &mut InvokerNode,
    work: Vec<BatchEv>,
    t_end: Micros,
    cfg: &ExperimentConfig,
) -> Vec<Rec> {
    let mut records: Vec<Rec> = Vec::with_capacity(work.len());
    let mut local: LocalHeap = BinaryHeap::new();
    let mut spawn_ctr = 0u64;
    let mut wi = 0usize;
    loop {
        let batch_next = match (work.get(wi), local.peek()) {
            (Some(w), Some(&Reverse((lt, _, _, _)))) => w.time <= lt,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break,
        };
        if batch_next {
            let w = &work[wi];
            wi += 1;
            let idx = records.len();
            records.push(Rec {
                time: w.time,
                origin: Origin::Batch(w.seq),
                actions: Vec::new(),
            });
            let actions = handle(
                nd,
                w.ev,
                w.time,
                t_end,
                cfg,
                &mut records,
                &mut local,
                &mut spawn_ctr,
            );
            records[idx].actions = actions;
        } else {
            let Reverse((due, _, cid, idx)) = local.pop().expect("peeked recheck");
            let node = nd.id;
            let mut actions = Vec::new();
            match nd.keepalive_check(cid, due) {
                KeepAliveVerdict::Recheck(t) => push_keepalive(
                    t,
                    node,
                    cid,
                    t_end,
                    &mut actions,
                    &mut records,
                    &mut local,
                    &mut spawn_ctr,
                ),
                KeepAliveVerdict::Expired | KeepAliveVerdict::NotApplicable => {}
            }
            records[idx].actions = actions;
        }
    }
    records
}

/// The node-local mirror of the runner's `Ready`/`Done`/`KeepAlive` match
/// arms (same handlers, through the same [`InvokerNode`] guards), with
/// pushes and recorder ops *recorded* instead of applied. The
/// `on_idle_capacity` callback is intentionally absent: batches only form
/// while the shaping queue is empty, where it is a no-op.
#[allow(clippy::too_many_arguments)]
fn handle(
    nd: &mut InvokerNode,
    ev: Ev,
    now: Micros,
    t_end: Micros,
    cfg: &ExperimentConfig,
    records: &mut Vec<Rec>,
    local: &mut LocalHeap,
    spawn_ctr: &mut u64,
) -> Vec<Action> {
    let mut acts = Vec::new();
    match ev {
        Ev::Ready(node, cid) => match nd.container_ready(cid, now) {
            Some(ReadyOutcome::Started { done_at, .. }) => {
                acts.push(Action::Push(done_at, Ev::Done(node, cid)));
            }
            Some(ReadyOutcome::Idle) => {
                let ka = nd.keepalive_of(cid).unwrap_or(cfg.platform.keep_alive);
                push_keepalive(now + ka, node, cid, t_end, &mut acts, records, local, spawn_ctr);
            }
            Some(ReadyOutcome::Respawned {
                req,
                cid: ncid,
                ready_at,
            }) => {
                acts.push(Action::Cold(req));
                acts.push(Action::Push(ready_at, Ev::Ready(node, ncid)));
            }
            None => {} // stale event (offline node / drained container)
        },
        Ev::Done(node, cid) => match nd.exec_complete(cid, now) {
            Some(CompleteOutcome {
                completed,
                next,
                respawn,
            }) => {
                acts.push(Action::Complete(completed, now));
                match (next, respawn) {
                    (Some((_req, done_at)), _) => {
                        acts.push(Action::Push(done_at, Ev::Done(node, cid)));
                    }
                    (None, Some((rreq, ncid, ready_at))) => {
                        acts.push(Action::Cold(rreq));
                        acts.push(Action::Push(ready_at, Ev::Ready(node, ncid)));
                    }
                    (None, None) => {
                        let ka = nd.keepalive_of(cid).unwrap_or(cfg.platform.keep_alive);
                        push_keepalive(
                            now + ka,
                            node,
                            cid,
                            t_end,
                            &mut acts,
                            records,
                            local,
                            spawn_ctr,
                        );
                    }
                }
            }
            None => {} // stale event
        },
        Ev::KeepAlive(node, cid) => match nd.keepalive_check(cid, now) {
            KeepAliveVerdict::Recheck(t) => {
                push_keepalive(t, node, cid, t_end, &mut acts, records, local, spawn_ctr);
            }
            KeepAliveVerdict::Expired | KeepAliveVerdict::NotApplicable => {}
        },
        Ev::Arrival(_)
        | Ev::Control
        | Ev::Sample
        | Ev::NodeFail(_)
        | Ev::NodeRestore(_, _)
        | Ev::ChaosRetry(_)
        | Ev::ChaosTimeout(_, _) => {
            unreachable!("global events never enter a shard batch")
        }
    }
    acts
}

/// Route a keep-alive push: inside the window it becomes a locally
/// consumed recheck (placeholder record + local-heap entry, processed in
/// merge order); at or past `t_end` it is a plain deferred push.
#[allow(clippy::too_many_arguments)]
fn push_keepalive(
    at: Micros,
    node: NodeId,
    cid: ContainerId,
    t_end: Micros,
    acts: &mut Vec<Action>,
    records: &mut Vec<Rec>,
    local: &mut LocalHeap,
    spawn_ctr: &mut u64,
) {
    if at < t_end {
        let idx = records.len();
        records.push(Rec {
            time: at,
            origin: Origin::Recheck,
            actions: Vec::new(),
        });
        local.push(Reverse((at, *spawn_ctr, cid, idx)));
        *spawn_ctr += 1;
        acts.push(Action::ConsumeRecheck(idx));
    } else {
        acts.push(Action::Push(at, Ev::KeepAlive(node, cid)));
    }
}

/// Replay every recorded effect in the order the sequential loop would
/// have produced it: records pop in `(time, seq)` order, their pushes
/// re-enter the real queue in the sequential push order (reproducing the
/// seq stream), consumed rechecks take their seq via
/// [`EventQueue::consume_inline`] (which also books the pop the
/// sequential loop performed) and then schedule their own record.
fn commit(results: &[Vec<Rec>], events: &mut EventQueue<Ev>, recorder: &mut Recorder) {
    // (time, seq, node, record index); node can never tie-break (seqs
    // are globally unique) but keeps the key total for clarity
    let mut order: BinaryHeap<Reverse<(Micros, u64, usize, usize)>> = BinaryHeap::new();
    for (node, recs) in results.iter().enumerate() {
        for (idx, r) in recs.iter().enumerate() {
            if let Origin::Batch(seq) = r.origin {
                order.push(Reverse((r.time, seq, node, idx)));
            }
        }
    }
    while let Some(Reverse((_t, _seq, node, idx))) = order.pop() {
        for act in &results[node][idx].actions {
            match *act {
                Action::Push(t, ev) => events.push(t, ev),
                Action::ConsumeRecheck(ridx) => {
                    let seq = events.consume_inline();
                    order.push(Reverse((results[node][ridx].time, seq, node, ridx)));
                }
                Action::Cold(req) => recorder.on_cold(req),
                Action::Complete(req, t) => recorder.on_complete(req, t),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{secs, ImageCacheConfig, ImageCacheMode};

    #[test]
    fn spawn_delay_floor_is_the_jittered_warm_latency_by_default() {
        let cfg = ExperimentConfig::default();
        let reg = FunctionRegistry::single(&cfg.platform);
        // l_warm 280 ms is the binding floor; 5% downward jitter and the
        // 2 µs rounding guard come off it
        assert_eq!(min_spawn_delay(&cfg, &reg), 265_998);
    }

    #[test]
    fn spawn_delay_respects_the_cached_cold_floor_and_degenerates_safely() {
        let mut cfg = ExperimentConfig::default();
        cfg.platform.image = ImageCacheConfig {
            mode: ImageCacheMode::Lru,
            ..Default::default()
        };
        // a fully cached cold start floors at init_fraction × l_cold =
        // 2.625 s — still above l_warm, so the bound is unchanged
        let reg = FunctionRegistry::single(&cfg.platform);
        assert_eq!(min_spawn_delay(&cfg, &reg), 265_998);
        // zero init fraction → a cold ready can land arbitrarily soon →
        // the engine must refuse to batch
        cfg.platform.image.init_fraction = 0.0;
        assert_eq!(min_spawn_delay(&cfg, &reg), 0);
        // full jitter likewise
        let mut jit = ExperimentConfig::default();
        jit.platform.latency_jitter = 1.0;
        let reg = FunctionRegistry::single(&jit.platform);
        assert_eq!(min_spawn_delay(&jit, &reg), 0);
    }

    #[test]
    fn spawn_delay_tracks_the_adaptive_keepalive_floor() {
        let mut cfg = ExperimentConfig::default();
        cfg.controller.keepalive.min = secs(0.1); // 100 ms, below l_warm
        let reg = FunctionRegistry::single(&cfg.platform);
        // fixed policy: the override floor is never installed, so the
        // warm latency still binds
        assert_eq!(min_spawn_delay(&cfg, &reg), 265_998);
        cfg.controller.keepalive.policy = crate::config::KeepAlivePolicy::Adaptive;
        assert_eq!(min_spawn_delay(&cfg, &reg), 94_998);
    }

    #[test]
    fn global_events_are_never_batchable() {
        assert_eq!(node_of(&Ev::Ready(3, 7)), Some(3));
        assert_eq!(node_of(&Ev::Done(0, 1)), Some(0));
        assert_eq!(node_of(&Ev::KeepAlive(2, 9)), Some(2));
        assert_eq!(node_of(&Ev::Arrival(0)), None);
        assert_eq!(node_of(&Ev::Control), None);
        assert_eq!(node_of(&Ev::Sample), None);
        assert_eq!(node_of(&Ev::NodeFail(1)), None);
        assert_eq!(node_of(&Ev::NodeRestore(1, None)), None);
        assert_eq!(node_of(&Ev::ChaosRetry(0)), None);
        assert_eq!(node_of(&Ev::ChaosTimeout(1, 2)), None);
    }

    /// The whole engine against the sequential loop on a real workload —
    /// the in-crate smoke version of the `tests/sharded.rs` differential
    /// suite (which sweeps policies × nodes × threads).
    #[test]
    fn sharded_run_matches_sequential_run() {
        let mut cfg = ExperimentConfig {
            duration: secs(600.0),
            seed: 9,
            ..Default::default()
        };
        cfg.fleet.nodes = 4;
        cfg.tenancy.functions = 4;
        let w = TenantWorkload::generate(
            cfg.trace,
            cfg.duration,
            cfg.seed,
            cfg.tenancy.functions,
            cfg.tenancy.zipf_s,
            &cfg.platform,
        );
        let seq = crate::experiments::run_tenant(&cfg, crate::config::Policy::Mpc, &w);
        cfg.threads = 4;
        let par = crate::experiments::run_tenant(&cfg, crate::config::Policy::Mpc, &w);
        assert_eq!(par.threads, 4);
        assert_eq!(par.completed, seq.completed);
        assert_eq!(par.mean_ms, seq.mean_ms);
        assert_eq!(par.p99_ms, seq.p99_ms);
        assert_eq!(par.counters.cold_starts, seq.counters.cold_starts);
        assert_eq!(par.events_processed, seq.events_processed);
        assert_eq!(par.warm_series, seq.warm_series);
        assert_eq!(par.keepalive_total_s, seq.keepalive_total_s);
    }
}
