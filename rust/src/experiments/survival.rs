//! Survival-frontier sweep (the `survival-frontier` CLI subcommand): the
//! three-way policy comparison the ROADMAP's direction 4 asks for — the
//! paper's MPC against the slot-survival lifecycle policy
//! (arXiv:2604.05465) and the IceBreaker baseline, on the same
//! resource-time vs P99 frontier the keep-alive sweep measures.
//!
//! The question each scenario answers: how much of the MPC's frontier
//! win comes from *fleet-level planning* (forecast-driven prewarm +
//! shaping) versus *per-container lifecycle control* (survival-driven
//! release)? Survival carries no prewarm and no shaping, so the gap
//! between its row and the MPC's is the value of planning, while the gap
//! to IceBreaker is the value of conditional retention over a fixed
//! utility window.

use crate::config::{secs, ExperimentConfig, FleetConfig, Policy, SurvivalConfig, TenantConfig};
use crate::experiments::keepalive::{KeepAliveScenario, DEFAULT_SCENARIOS};
use crate::experiments::runner::run_tenant;
use crate::metrics::RunReport;
use crate::util::bench::Table;
use crate::workload::TenantWorkload;

/// The three-way frontier, in output order: the paper's controller, the
/// survival rival, the reactive baseline.
pub const POLICIES: [Policy; 3] = [Policy::Mpc, Policy::Survival, Policy::IceBreaker];

/// The shared scenario grid — the same bursty/azure/zipf acceptance
/// scenarios the keep-alive sweep runs, so the two frontiers compose.
pub const SCENARIOS: [KeepAliveScenario; 3] = DEFAULT_SCENARIOS;

/// Shared knobs for every cell of a survival-frontier sweep.
#[derive(Debug, Clone)]
pub struct SurvivalParams {
    pub duration_s: f64,
    pub seed: u64,
    pub nodes: u32,
    pub zipf_s: f64,
    /// Estimator knobs (`--survival-*`); inert in the mpc/icebreaker
    /// cells, which is exactly what the byte-identity tests pin.
    pub survival: SurvivalConfig,
}

impl Default for SurvivalParams {
    fn default() -> Self {
        SurvivalParams {
            duration_s: 3600.0,
            seed: 42,
            nodes: 1,
            zipf_s: 1.1,
            survival: SurvivalConfig::default(),
        }
    }
}

/// One sweep cell: (scenario, scheduling policy).
#[derive(Debug, Clone)]
pub struct SurvivalCell {
    pub scenario: &'static str,
    pub policy: Policy,
    pub report: RunReport,
}

/// Experiment config for one cell. The survival knobs are threaded into
/// every cell — the non-survival policies must not read them.
pub fn cell_config(p: &SurvivalParams, sc: &KeepAliveScenario) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        trace: sc.trace,
        fleet: FleetConfig {
            nodes: p.nodes,
            ..Default::default()
        },
        tenancy: TenantConfig {
            functions: sc.functions,
            zipf_s: p.zipf_s,
        },
        duration: secs(p.duration_s),
        seed: p.seed,
        ..Default::default()
    };
    cfg.controller.survival = p.survival;
    cfg
}

/// Run every scenario under every frontier policy. One workload is
/// generated per scenario and shared across its three cells (seeded from
/// the config alone), so rows differ only by policy. Cells come back
/// scenario-major in [`POLICIES`] order.
pub fn run_sweep(p: &SurvivalParams, scenarios: &[KeepAliveScenario]) -> Vec<SurvivalCell> {
    let mut cells = Vec::with_capacity(scenarios.len() * POLICIES.len());
    for sc in scenarios {
        let cfg = cell_config(p, sc);
        let workload = TenantWorkload::generate(
            sc.trace,
            cfg.duration,
            p.seed,
            sc.functions,
            p.zipf_s,
            &cfg.platform,
        );
        for policy in POLICIES {
            cells.push(SurvivalCell {
                scenario: sc.name,
                policy,
                report: run_tenant(&cfg, policy, &workload),
            });
        }
    }
    cells
}

/// Print the sweep table plus the per-scenario frontier verdicts:
/// survival judged against both the MPC (the planning gap) and
/// IceBreaker (the retention gap).
pub fn print_table(cells: &[SurvivalCell]) {
    let mut t = Table::new(&[
        "scenario",
        "policy",
        "p50 ms",
        "p99 ms",
        "cold %",
        "idle s",
        "keep-alive s",
        "releases",
        "retained",
        "mean p",
    ]);
    for c in cells {
        let r = &c.report;
        let cold_pct = if r.completed > 0 {
            100.0 * r.cold_requests as f64 / r.completed as f64
        } else {
            0.0
        };
        t.row(&[
            c.scenario.to_string(),
            c.policy.name().to_string(),
            format!("{:.0}", r.p50_ms),
            format!("{:.0}", r.p99_ms),
            format!("{cold_pct:.1}"),
            format!("{:.0}", r.idle_total_s),
            format!("{:.0}", r.keepalive_total_s),
            r.survival_releases.to_string(),
            r.survival_retained.to_string(),
            format!("{:.2}", r.survival_mean_p),
        ]);
    }
    t.print();
    // frontier verdicts, scenario by scenario (cells are scenario-major
    // [mpc, survival, icebreaker] triples)
    for tri in cells.chunks(POLICIES.len()) {
        let [mpc, surv, ib] = tri else { continue };
        let vs = |base: &SurvivalCell| {
            let idle_pct = 100.0 * (surv.report.idle_total_s - base.report.idle_total_s)
                / base.report.idle_total_s.max(1e-9);
            let p99 = surv.report.p99_ms - base.report.p99_ms;
            format!("idle {idle_pct:+.1}%, P99 {p99:+.0} ms")
        };
        println!(
            "{}: survival vs mpc: {} | vs icebreaker: {}",
            mpc.scenario,
            vs(mpc),
            vs(ib)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> SurvivalParams {
        SurvivalParams {
            duration_s: 600.0,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn cell_config_threads_the_estimator_knobs() {
        let p = SurvivalParams {
            survival: SurvivalConfig {
                window: 32,
                threshold: 0.25,
                min_samples: 4,
            },
            ..quick()
        };
        let cfg = cell_config(&p, &SCENARIOS[1]);
        assert_eq!(cfg.controller.survival.window, 32);
        assert_eq!(cfg.controller.survival.threshold, 0.25);
        assert_eq!(cfg.controller.survival.min_samples, 4);
        assert_eq!(cfg.tenancy.functions, 8);
    }

    #[test]
    fn sweep_emits_policy_triples_per_scenario() {
        let cells = run_sweep(&quick(), &SCENARIOS[..1]);
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].policy, Policy::Mpc);
        assert_eq!(cells[1].policy, Policy::Survival);
        assert_eq!(cells[2].policy, Policy::IceBreaker);
        for c in &cells {
            assert_eq!(c.report.dropped, 0, "{:?}", c.policy);
            assert_eq!(c.report.policy, c.policy.name());
        }
        // survival telemetry is structurally zero off-policy and labels
        // the retention column on-policy
        assert_eq!(cells[1].report.keepalive_policy, "survival");
        for c in [&cells[0], &cells[2]] {
            assert_eq!(c.report.survival_releases, 0);
            assert_eq!(c.report.survival_retained, 0);
            assert_eq!(c.report.survival_mean_p, 0.0);
        }
        // enough bursty traffic flows that the estimator actually decided:
        // every decision lands a horizon sample and a p(0) observation
        assert!(cells[1].report.mean_horizon_s > 0.0);
        assert!(cells[1].report.survival_mean_p > 0.0);
        print_table(&cells); // table rendering must not panic
    }

    #[test]
    fn sweep_is_deterministic_in_its_params() {
        let a = run_sweep(&quick(), &SCENARIOS[..1]);
        let b = run_sweep(&quick(), &SCENARIOS[..1]);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.report.mean_ms, y.report.mean_ms);
            assert_eq!(x.report.p99_ms, y.report.p99_ms);
            assert_eq!(x.report.survival_releases, y.report.survival_releases);
            assert_eq!(x.report.survival_mean_p, y.report.survival_mean_p);
        }
    }
}
