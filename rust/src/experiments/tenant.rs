//! Multi-tenant experiment driver (the `tenant-sweep` CLI subcommand and
//! the fig10 bench target): one shared N-function workload, every policy
//! run against it, aggregate and per-function latency side by side.
//!
//! The scenario the paper's headline P99 claim lives in: functions with
//! heavy-tailed popularity contend for one fleet's warm capacity, so the
//! tail functions pay cold starts under reactive scheduling while the
//! MPC's shaping + per-function prewarm split absorbs them.

use crate::config::{secs, ExperimentConfig, FleetConfig, Policy, TenantConfig, TraceKind};
use crate::experiments::runner::run_tenant;
use crate::metrics::RunReport;
use crate::workload::TenantWorkload;

/// Results of one tenant sweep cell set: the workload plus one report per
/// policy, in [`TenantMatrix::POLICIES`] order.
#[derive(Debug)]
pub struct TenantMatrix {
    pub workload: TenantWorkload,
    pub reports: Vec<RunReport>,
}

impl TenantMatrix {
    pub const POLICIES: [Policy; 3] = [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc];

    pub fn report(&self, policy: Policy) -> &RunReport {
        let idx = Self::POLICIES
            .iter()
            .position(|&p| p == policy)
            .expect("policy in matrix");
        &self.reports[idx]
    }
}

/// Run every policy against one generated `functions`-tenant workload.
/// Cells run on their own threads (the workload is shared read-only), and
/// each derives its inputs only from the config, so results are identical
/// to a serial run.
pub fn run_tenant_matrix(
    kind: TraceKind,
    duration_s: f64,
    seed: u64,
    functions: u32,
    zipf_s: f64,
    fleet: &FleetConfig,
) -> TenantMatrix {
    let cfg = ExperimentConfig {
        trace: kind,
        duration: secs(duration_s),
        seed,
        fleet: fleet.clone(),
        tenancy: TenantConfig {
            functions,
            zipf_s,
        },
        ..Default::default()
    };
    let workload = TenantWorkload::generate(
        kind,
        cfg.duration,
        seed,
        functions,
        zipf_s,
        &cfg.platform,
    );
    let mut slots: Vec<Option<RunReport>> = TenantMatrix::POLICIES.iter().map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (i, policy) in TenantMatrix::POLICIES.into_iter().enumerate() {
            let cfg = &cfg;
            let workload = &workload;
            handles.push((i, s.spawn(move || run_tenant(cfg, policy, workload))));
        }
        for (i, h) in handles {
            slots[i] = Some(h.join().expect("tenant cell panicked"));
        }
    });
    TenantMatrix {
        workload,
        reports: slots.into_iter().map(|r| r.expect("cell ran")).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_runs_all_policies_on_a_shared_workload() {
        let m = run_tenant_matrix(
            TraceKind::SyntheticBursty,
            300.0,
            7,
            4,
            1.1,
            &FleetConfig::default(),
        );
        assert_eq!(m.reports.len(), 3);
        let n = m.workload.len();
        for r in &m.reports {
            assert_eq!(r.dropped, 0, "{}: {r:?}", r.policy);
            assert_eq!(r.completed, n, "{}", r.policy);
            assert!(!r.per_function.is_empty(), "{}", r.policy);
        }
        assert_eq!(m.report(Policy::Mpc).policy, "mpc");
        assert_eq!(m.report(Policy::OpenWhisk).policy, "openwhisk");
    }
}
