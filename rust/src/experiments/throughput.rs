//! Simulator throughput macro-benchmark (the `bench-throughput` CLI
//! subcommand and the fig11 bench target): sweep nodes × functions ×
//! load and measure the *simulator itself* — events processed, wall
//! clock, events/second — instead of the simulated latency metrics.
//!
//! This is the workload behind the BENCH trajectory for the indexed
//! platform-state refactor: every cell's per-event cost used to grow
//! with `nodes × functions × containers` (the controller's gauges were
//! full container scans); with the incremental indices it must stay flat
//! as the fleet and the function count grow. Each cell is fully
//! deterministic in everything except the wall-clock columns.

use crate::config::{
    secs, ExperimentConfig, FleetConfig, Micros, PlacementPolicy, Policy, TenantConfig, TraceKind,
};
use crate::experiments::fig4;
use crate::experiments::runner::run_tenant;
use crate::util::json::Json;
use crate::workload::tenant::FunctionRegistry;
use crate::workload::{TenantWorkload, Trace};

/// One sweep cell: the fleet/workload shape plus the measured simulator
/// throughput for it.
#[derive(Debug, Clone)]
pub struct ThroughputCell {
    pub nodes: u32,
    /// Event-loop worker threads the cell ran with (the sharded engine's
    /// scaling axis; 1 = the sequential seed path). Every simulated
    /// column is bit-identical across thread counts — only the wall
    /// clock may move.
    pub threads: u32,
    pub functions: u32,
    /// Load multiplier: how many independent base traces are
    /// superimposed (1 = the paper's base arrival rate).
    pub load: u32,
    pub requests: usize,
    pub completed: usize,
    pub events: u64,
    pub wall_ms: f64,
    pub events_per_sec: f64,
    /// Simulated aggregate P99 (ms) — carried along so a throughput run
    /// doubles as a regression canary for the simulated metrics.
    pub p99_ms: f64,
}

impl ThroughputCell {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::Num(self.nodes as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("functions", Json::Num(self.functions as f64)),
            ("load", Json::Num(self.load as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("events", Json::Num(self.events as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            ("events_per_sec", Json::Num(self.events_per_sec)),
            ("p99_ms", Json::Num(self.p99_ms)),
        ])
    }
}

/// A full sweep: the shared run parameters plus one cell per
/// (nodes, threads, functions, load) combination, in sweep order.
#[derive(Debug, Clone)]
pub struct ThroughputSweep {
    pub policy: Policy,
    pub trace: TraceKind,
    pub duration_s: f64,
    pub seed: u64,
    pub cells: Vec<ThroughputCell>,
}

impl ThroughputSweep {
    /// Print the sweep as the standard 8-column table (shared by the
    /// `bench-throughput` CLI and the fig11 bench target).
    pub fn print_table(&self) {
        let mut t = crate::util::bench::Table::new(&[
            "nodes", "threads", "functions", "load", "requests", "events", "wall ms", "events/sec",
        ]);
        for c in &self.cells {
            t.row(&[
                c.nodes.to_string(),
                c.threads.to_string(),
                c.functions.to_string(),
                c.load.to_string(),
                c.requests.to_string(),
                c.events.to_string(),
                format!("{:.1}", c.wall_ms),
                format!("{:.0}", c.events_per_sec),
            ]);
        }
        t.print();
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("throughput".to_string())),
            ("policy", Json::Str(self.policy.name().to_string())),
            ("trace", Json::Str(self.trace.name().to_string())),
            ("duration_s", Json::Num(self.duration_s)),
            ("seed", Json::Num(self.seed as f64)),
            (
                "cells",
                Json::Arr(self.cells.iter().map(|c| c.to_json()).collect()),
            ),
        ])
    }
}

/// Build a `load`-times superimposed multi-tenant workload: `load`
/// independent base traces (decorrelated seeds) merged by arrival time,
/// functions assigned by the registry's Zipf popularity. `load == 1`
/// with the bursty generator reproduces `TenantWorkload::generate`
/// exactly; higher loads scale the arrival *rate* while keeping the
/// temporal burst structure.
pub fn scaled_workload(
    kind: TraceKind,
    duration: Micros,
    seed: u64,
    functions: u32,
    zipf_s: f64,
    load: u32,
    pc: &crate::config::PlatformConfig,
) -> TenantWorkload {
    let mut arrivals: Vec<Micros> = Vec::new();
    for i in 0..u64::from(load.max(1)) {
        let t = fig4::trace_for(kind, duration, seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        arrivals.extend(t.arrivals);
    }
    arrivals.sort_unstable();
    let trace = Trace { arrivals };
    let registry = FunctionRegistry::synthesize(functions, zipf_s, pc, seed);
    TenantWorkload::assign(&trace, registry, seed)
}

/// Run one sweep cell. Nodes here add capacity (every node carries the
/// full per-node replica budget) — this measures fleet *scale*, unlike
/// `fleet-sweep`'s fixed-total-capacity fragmentation sweep. `threads`
/// picks the event-loop execution mode (1 = sequential; >1 = the
/// sharded engine, same simulated results, different wall clock).
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    policy: Policy,
    kind: TraceKind,
    duration_s: f64,
    seed: u64,
    nodes: u32,
    threads: u32,
    functions: u32,
    load: u32,
    placement: PlacementPolicy,
) -> ThroughputCell {
    let cfg = ExperimentConfig {
        trace: kind,
        fleet: FleetConfig {
            nodes,
            placement,
            ..Default::default()
        },
        tenancy: TenantConfig {
            functions,
            zipf_s: 1.1,
        },
        duration: secs(duration_s),
        seed,
        threads,
        ..Default::default()
    };
    let workload = scaled_workload(kind, cfg.duration, seed, functions, 1.1, load, &cfg.platform);
    let r = run_tenant(&cfg, policy, &workload);
    ThroughputCell {
        nodes,
        threads,
        functions,
        load,
        requests: workload.len(),
        completed: r.completed,
        events: r.events_processed,
        wall_ms: r.wall_clock_ms,
        events_per_sec: r.events_per_sec,
        p99_ms: r.p99_ms,
    }
}

/// Sweep the full nodes × threads × functions × load grid (cells run
/// serially so wall-clock numbers are not polluted by core contention;
/// the threads axis is the sharded engine's scaling measurement).
#[allow(clippy::too_many_arguments)]
pub fn run_sweep(
    policy: Policy,
    kind: TraceKind,
    duration_s: f64,
    seed: u64,
    nodes_list: &[u32],
    threads_list: &[u32],
    functions_list: &[u32],
    load_list: &[u32],
    placement: PlacementPolicy,
) -> ThroughputSweep {
    let mut cells = Vec::new();
    for &nodes in nodes_list {
        for &threads in threads_list {
            for &functions in functions_list {
                for &load in load_list {
                    cells.push(run_cell(
                        policy, kind, duration_s, seed, nodes, threads, functions, load,
                        placement,
                    ));
                }
            }
        }
    }
    ThroughputSweep {
        policy,
        trace: kind,
        duration_s,
        seed,
        cells,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_one_reproduces_the_generated_workload() {
        let pc = crate::config::PlatformConfig::default();
        let a = scaled_workload(TraceKind::SyntheticBursty, secs(300.0), 7, 4, 1.1, 1, &pc);
        let b = TenantWorkload::generate(TraceKind::SyntheticBursty, secs(300.0), 7, 4, 1.1, &pc);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.funcs, b.funcs);
    }

    #[test]
    fn load_scales_the_request_count() {
        let pc = crate::config::PlatformConfig::default();
        let one = scaled_workload(TraceKind::SyntheticBursty, secs(300.0), 7, 2, 1.1, 1, &pc);
        let four = scaled_workload(TraceKind::SyntheticBursty, secs(300.0), 7, 2, 1.1, 4, &pc);
        assert!(four.len() > 2 * one.len(), "{} vs {}", four.len(), one.len());
        // merged arrivals stay sorted (the runner requires arrival order)
        assert!(four.arrivals.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn cell_measures_events_and_wall_clock() {
        let c = run_cell(
            Policy::OpenWhisk,
            TraceKind::SyntheticBursty,
            120.0,
            3,
            2,
            1,
            2,
            1,
            PlacementPolicy::WarmFirst,
        );
        assert!(c.requests > 0);
        assert_eq!(c.completed, c.requests, "no drops on the base load");
        // every request contributes at least an Arrival event
        assert!(c.events >= c.requests as u64, "{c:?}");
        assert!(c.wall_ms > 0.0);
        assert!(c.events_per_sec > 0.0);
        let j = c.to_json();
        assert_eq!(j.path("nodes").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.path("threads").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn threads_axis_changes_only_the_wall_clock_columns() {
        let run = |threads| {
            run_cell(
                Policy::Mpc,
                TraceKind::SyntheticBursty,
                120.0,
                3,
                4,
                threads,
                4,
                1,
                PlacementPolicy::WarmFirst,
            )
        };
        let seq = run(1);
        let par = run(4);
        assert_eq!(par.threads, 4);
        assert_eq!(par.requests, seq.requests);
        assert_eq!(par.completed, seq.completed);
        assert_eq!(par.events, seq.events, "event count must not depend on threads");
        assert_eq!(par.p99_ms, seq.p99_ms, "simulated latency must not depend on threads");
        assert_eq!(par.to_json().path("threads").unwrap().as_f64(), Some(4.0));
    }
}
