//! Forecast error metrics for the Fig. 4 reproduction and the online
//! model selector.
//!
//! The paper reports a scalar "accuracy %" (e.g. Fourier 86.2% on Azure).
//! We define accuracy = 100 x (1 - WAPE) clamped to [0, 100], with
//! WAPE = sum|pred - actual| / sum|actual| — the standard weighted absolute
//! percentage error, well-behaved on rate series that touch zero (where
//! per-point MAPE blows up). sMAPE is also provided for reference.
//!
//! Mismatched lengths are clamped to the common prefix rather than
//! asserted: the online selector scores forecasts against partially
//! realized windows mid-run, and a length mismatch there must degrade to
//! "score what overlaps", never panic the simulation.

/// Weighted absolute percentage error in [0, inf). Extra trailing
/// entries on either slice are ignored (common-prefix comparison).
pub fn wape(pred: &[f64], actual: &[f64]) -> f64 {
    let n = pred.len().min(actual.len());
    let (pred, actual) = (&pred[..n], &actual[..n]);
    let denom: f64 = actual.iter().map(|a| a.abs()).sum();
    if denom < 1e-12 {
        return if pred.iter().all(|p| p.abs() < 1e-12) {
            0.0
        } else {
            f64::INFINITY
        };
    }
    let num: f64 = pred
        .iter()
        .zip(actual)
        .map(|(p, a)| (p - a).abs())
        .sum();
    num / denom
}

/// Symmetric MAPE in [0, 2]. Clamps to the common prefix like [`wape`].
pub fn smape(pred: &[f64], actual: &[f64]) -> f64 {
    let n = pred.len().min(actual.len());
    if n == 0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for (p, a) in pred[..n].iter().zip(&actual[..n]) {
        let denom = (p.abs() + a.abs()) / 2.0;
        if denom > 1e-12 {
            acc += (p - a).abs() / denom;
        }
    }
    acc / n as f64
}

/// Root mean squared error. Clamps to the common prefix like [`wape`].
pub fn rmse(pred: &[f64], actual: &[f64]) -> f64 {
    let n = pred.len().min(actual.len());
    if n == 0 {
        return 0.0;
    }
    let s: f64 = pred[..n]
        .iter()
        .zip(&actual[..n])
        .map(|(p, a)| (p - a).powi(2))
        .sum();
    (s / n as f64).sqrt()
}

/// The paper's headline number: accuracy % = 100 (1 - WAPE), clamped.
pub fn accuracy_pct(pred: &[f64], actual: &[f64]) -> f64 {
    (100.0 * (1.0 - wape(pred, actual))).clamp(0.0, 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_forecast_is_100() {
        let a = [3.0, 5.0, 7.0];
        assert_eq!(accuracy_pct(&a, &a), 100.0);
        assert_eq!(wape(&a, &a), 0.0);
        assert_eq!(rmse(&a, &a), 0.0);
    }

    #[test]
    fn wape_known_value() {
        // |1| + |1| over |10| + |10| = 0.1 -> 90%
        let pred = [11.0, 9.0];
        let actual = [10.0, 10.0];
        assert!((wape(&pred, &actual) - 0.1).abs() < 1e-12);
        assert!((accuracy_pct(&pred, &actual) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn zero_actuals_handled() {
        assert_eq!(wape(&[0.0, 0.0], &[0.0, 0.0]), 0.0);
        assert!(wape(&[1.0, 0.0], &[0.0, 0.0]).is_infinite());
        assert_eq!(accuracy_pct(&[1.0], &[0.0]), 0.0); // clamped
    }

    #[test]
    fn smape_bounds() {
        assert!((smape(&[1.0], &[-1.0]) - 2.0).abs() < 1e-12);
        assert_eq!(smape(&[2.0], &[2.0]), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn length_mismatch_scores_common_prefix() {
        // extra trailing entries on either side are ignored, so a
        // partially realized window scores like its overlap
        let pred = [11.0, 9.0, 99.0, 7.0];
        let actual = [10.0, 10.0];
        assert!((wape(&pred, &actual) - 0.1).abs() < 1e-12);
        assert_eq!(wape(&actual, &pred), wape(&pred, &actual));
        assert!((rmse(&[0.0, 0.0, 50.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
        assert!((smape(&[2.0], &[2.0, 100.0]) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_benign() {
        assert_eq!(wape(&[], &[]), 0.0);
        assert_eq!(smape(&[], &[]), 0.0);
        assert_eq!(rmse(&[], &[]), 0.0);
        // empty on one side: no overlap, nothing to score
        assert_eq!(wape(&[], &[5.0, 6.0]), 0.0);
        assert_eq!(wape(&[5.0, 6.0], &[]), 0.0);
        assert_eq!(accuracy_pct(&[], &[]), 100.0);
    }

    #[test]
    fn all_zero_actuals_never_panic() {
        // zero denominator: perfect when pred is also zero, +inf (and a
        // clamped 0% accuracy) when pred claims load that never arrived
        let zeros = [0.0; 8];
        assert_eq!(wape(&zeros, &zeros), 0.0);
        assert!(wape(&[0.1; 8], &zeros).is_infinite());
        assert_eq!(accuracy_pct(&[0.1; 8], &zeros), 0.0);
        assert_eq!(rmse(&zeros, &zeros), 0.0);
        assert_eq!(smape(&zeros, &zeros), 0.0);
    }
}
