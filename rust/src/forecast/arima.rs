//! ARIMA(p,d,q) baseline forecaster (the Fig. 4 comparator).
//!
//! Fitted by the Hannan-Rissanen two-stage procedure: (1) a long-order AR
//! regression estimates the innovation sequence, (2) OLS of the
//! differenced series on its own p lags and q lagged innovations gives the
//! ARMA coefficients. Forecasts recurse with future innovations set to
//! zero, then integrate the d differences back. This matches the rolling
//! re-fit usage in the paper (ARIMA re-estimated every control step, which
//! is why it is ~100x slower than the Fourier predictor).

use crate::forecast::linalg::ols;
use crate::forecast::Forecaster;

#[derive(Debug, Clone)]
pub struct ArimaForecaster {
    pub p: usize,
    pub d: usize,
    pub q: usize,
    /// Long-AR order for the stage-1 innovation estimate.
    pub ar_boot: usize,
}

impl Default for ArimaForecaster {
    fn default() -> Self {
        // ARIMA(2,1,2): a common default for rate series with drift
        ArimaForecaster {
            p: 2,
            d: 1,
            q: 2,
            ar_boot: 12,
        }
    }
}

fn difference(series: &[f64]) -> Vec<f64> {
    series.windows(2).map(|w| w[1] - w[0]).collect()
}

/// Fitted ARMA model on the differenced series.
#[derive(Debug, Clone)]
struct ArmaFit {
    mean: f64,
    ar: Vec<f64>,
    ma: Vec<f64>,
    /// trailing observations (centered) newest-last
    tail_y: Vec<f64>,
    /// trailing innovation estimates newest-last
    tail_e: Vec<f64>,
}

impl ArimaForecaster {
    fn fit_arma(&self, y: &[f64]) -> Option<ArmaFit> {
        let n = y.len();
        let p = self.p;
        let q = self.q;
        let mean = y.iter().sum::<f64>() / n.max(1) as f64;
        let yc: Vec<f64> = y.iter().map(|v| v - mean).collect();

        // stage 1: long AR to estimate innovations
        let m = self.ar_boot.min(n / 3).max(p);
        if n <= m + p + q + 2 {
            return None;
        }
        let rows = n - m;
        let mut x1 = Vec::with_capacity(rows * m);
        let mut t1 = Vec::with_capacity(rows);
        for t in m..n {
            for l in 1..=m {
                x1.push(yc[t - l]);
            }
            t1.push(yc[t]);
        }
        let phi_boot = ols(&x1, &t1, rows, m)?;
        let mut eps = vec![0.0; n];
        for t in m..n {
            let pred: f64 = (1..=m).map(|l| phi_boot[l - 1] * yc[t - l]).sum();
            eps[t] = yc[t] - pred;
        }

        // stage 2: regress y_t on p lags of y and q lags of eps
        let start = m + q.max(p);
        let rows2 = n - start;
        let cols = p + q;
        if rows2 < cols + 2 {
            return None;
        }
        let mut x2 = Vec::with_capacity(rows2 * cols);
        let mut t2 = Vec::with_capacity(rows2);
        for t in start..n {
            for l in 1..=p {
                x2.push(yc[t - l]);
            }
            for l in 1..=q {
                x2.push(eps[t - l]);
            }
            t2.push(yc[t]);
        }
        let beta = ols(&x2, &t2, rows2, cols)?;
        let (ar, ma) = beta.split_at(p);

        let tail = p.max(q).max(1);
        Some(ArmaFit {
            mean,
            ar: ar.to_vec(),
            ma: ma.to_vec(),
            tail_y: yc[n - tail..].to_vec(),
            tail_e: eps[n - tail..].to_vec(),
        })
    }
}

impl ArmaFit {
    fn forecast(&self, horizon: usize) -> Vec<f64> {
        let p = self.ar.len();
        let q = self.ma.len();
        let mut ys = self.tail_y.clone();
        let mut es = self.tail_e.clone();
        let mut out = Vec::with_capacity(horizon);
        for _ in 0..horizon {
            let mut v = 0.0;
            for l in 1..=p {
                if ys.len() >= l {
                    v += self.ar[l - 1] * ys[ys.len() - l];
                }
            }
            for l in 1..=q {
                if es.len() >= l {
                    v += self.ma[l - 1] * es[es.len() - l];
                }
            }
            ys.push(v);
            es.push(0.0); // future innovations: expectation zero
            out.push(v + self.mean);
        }
        out
    }
}

impl Forecaster for ArimaForecaster {
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64> {
        // difference d times, keeping the integration anchors
        let mut levels: Vec<f64> = Vec::with_capacity(self.d);
        let mut series = history.to_vec();
        for _ in 0..self.d {
            if series.len() < 2 {
                break;
            }
            levels.push(*series.last().unwrap());
            series = difference(&series);
        }

        let fitted = self.fit_arma(&series);
        let mut fc = match fitted {
            Some(f) => f.forecast(horizon),
            // degenerate history: naive persistence
            None => vec![*series.last().unwrap_or(&0.0); horizon],
        };

        // integrate back
        for anchor in levels.iter().rev() {
            let mut level = *anchor;
            for v in fc.iter_mut() {
                level += *v;
                *v = level;
            }
        }
        fc.into_iter().map(|v| v.max(0.0)).collect()
    }

    fn name(&self) -> &'static str {
        "arima"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn difference_basics() {
        assert_eq!(difference(&[1.0, 3.0, 6.0]), vec![2.0, 3.0]);
    }

    #[test]
    fn constant_series_predicts_constant() {
        let mut f = ArimaForecaster::default();
        let pred = f.forecast(&vec![7.0; 120], 10);
        for p in pred {
            assert!((p - 7.0).abs() < 0.5, "{p}");
        }
    }

    #[test]
    fn linear_trend_followed() {
        // y = 2 t: after d=1 differencing this is a constant 2
        let y: Vec<f64> = (0..120).map(|t| 2.0 * t as f64).collect();
        let mut f = ArimaForecaster::default();
        let pred = f.forecast(&y, 5);
        for (h, p) in pred.iter().enumerate() {
            let want = 2.0 * (120 + h) as f64;
            assert!((p - want).abs() < 6.0, "h={h}: {p} vs {want}");
        }
    }

    #[test]
    fn ar1_process_one_step_accuracy() {
        // strongly autocorrelated AR(1); one-step forecasts should beat the
        // unconditional mean on in-sample continuation
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        let phi = 0.9;
        let mut y = vec![0.0f64];
        for _ in 0..400 {
            let prev = *y.last().unwrap();
            y.push(10.0 + phi * (prev - 10.0) + rng.normal(0.0, 0.5));
        }
        let mut f = ArimaForecaster {
            p: 1,
            d: 0,
            q: 0,
            ar_boot: 8,
        };
        let mut err_model = 0.0;
        let mut err_mean = 0.0;
        let mean_all = y.iter().sum::<f64>() / y.len() as f64;
        for t in 300..399 {
            let pred = f.forecast(&y[..t], 1)[0];
            err_model += (pred - y[t]).abs();
            err_mean += (mean_all - y[t]).abs();
        }
        assert!(
            err_model < err_mean * 0.8,
            "AR(1) fit no better than mean: {err_model} vs {err_mean}"
        );
    }

    #[test]
    fn short_history_does_not_panic() {
        let mut f = ArimaForecaster::default();
        for n in 0..12 {
            let y: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let pred = f.forecast(&y, 4);
            assert_eq!(pred.len(), 4);
            assert!(pred.iter().all(|p| p.is_finite()));
        }
    }

    #[test]
    fn output_nonnegative() {
        let y: Vec<f64> = (0..120).map(|t| 100.0 - t as f64).collect();
        let mut f = ArimaForecaster::default();
        let pred = f.forecast(&y, 30);
        assert!(pred.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn history_shorter_than_ar_boot_uses_persistence() {
        // Hannan-Rissanen stage 1 needs n > m + p + q + 2 rows; below
        // that the fit must decline and the forecast fall back to naive
        // persistence of the last (differenced) level
        let f = ArimaForecaster::default();
        let y: Vec<f64> = (0..8).map(|t| 5.0 + t as f64).collect(); // n=8 < ar_boot=12
        assert!(f.fit_arma(&y).is_none(), "fit must refuse a short series");
        let mut f = ArimaForecaster::default();
        let pred = f.forecast(&y, 6);
        assert_eq!(pred.len(), 6);
        // d=1 persistence of a unit-slope ramp continues the ramp
        for (h, p) in pred.iter().enumerate() {
            let want = 12.0 + (h + 1) as f64;
            assert!((p - want).abs() < 1e-9, "h={h}: {p} vs {want}");
        }
    }

    #[test]
    fn zero_variance_series_is_finite_and_constant() {
        // a zero-variance (constant) series makes every OLS design
        // matrix singular; the ridge term resolves it to all-zero
        // coefficients, so the forecast is exactly the series mean —
        // finite, never NaN
        let f = ArimaForecaster { d: 0, ..Default::default() };
        let fit = f.fit_arma(&vec![4.0; 200]).expect("ridge resolves the singular design");
        assert!(fit.ar.iter().chain(&fit.ma).all(|c| c.abs() < 1e-6), "{fit:?}");
        for d in [0, 1, 2] {
            let mut f = ArimaForecaster { d, ..Default::default() };
            let pred = f.forecast(&vec![4.0; 200], 12);
            assert_eq!(pred.len(), 12);
            assert!(pred.iter().all(|p| p.is_finite()), "d={d}: {pred:?}");
            for p in &pred {
                assert!((p - 4.0).abs() < 1e-6, "d={d}: {p}");
            }
        }
    }
}
