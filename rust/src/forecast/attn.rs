//! Attention-inspired pattern-matching forecaster (arXiv:2504.11338).
//!
//! Transformer predictors forecast serverless load by attending over
//! past subsequences that resemble the present. This backend keeps the
//! mechanism and drops the learned weights: the query is the trailing
//! `context` bins; every historical window of the same length is a key
//! whose following `horizon` bins are its value; attention weights are a
//! softmax over negative mean squared distance between query and key
//! (temperature-scaled), and the forecast is the weight-averaged value.
//! Regime shifts are where this wins — when the recent past matches an
//! earlier regime better than the global trend, the matched episode's
//! continuation dominates the average — while pure-parametric models
//! keep extrapolating the stale fit.
//!
//! Cost is O(history × context) per call, comfortably inside the 30 s
//! control interval for the 120-bin windows the controller keeps.

use crate::forecast::Forecaster;

#[derive(Debug, Clone)]
pub struct AttnForecaster {
    /// Query/key length in bins.
    pub context: usize,
    /// Softmax temperature on the mean squared distance; lower is
    /// sharper (closer to nearest-neighbor lookup).
    pub temperature: f64,
}

impl Default for AttnForecaster {
    fn default() -> Self {
        AttnForecaster {
            context: 24,
            temperature: 4.0,
        }
    }
}

impl AttnForecaster {
    /// Mean squared distance between the query and the key starting at
    /// `start`.
    fn key_dist(history: &[f64], start: usize, query: &[f64]) -> f64 {
        let c = query.len();
        let mut d = 0.0;
        for i in 0..c {
            let e = history[start + i] - query[i];
            d += e * e;
        }
        d / c as f64
    }
}

impl Forecaster for AttnForecaster {
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64> {
        let n = history.len();
        let c = self.context.max(1);
        // too little history for even one (key, value) pair: persistence
        if n < c + 1 {
            let last = history.last().copied().unwrap_or(0.0).max(0.0);
            return vec![last; horizon];
        }
        let query = &history[n - c..];
        // keys end strictly before the query starts being its own value:
        // key at `s` covers [s, s+c), its value is [s+c, s+c+horizon)
        // clipped to the realized history
        let last_key = n - c - 1;
        let mut scores = Vec::with_capacity(last_key + 1);
        let mut best = f64::NEG_INFINITY;
        for s in 0..=last_key {
            let sc = -Self::key_dist(history, s, query) / self.temperature.max(1e-9);
            best = best.max(sc);
            scores.push(sc);
        }
        // softmax, max-subtracted for stability
        let mut wsum = 0.0;
        for sc in scores.iter_mut() {
            *sc = (*sc - best).exp();
            wsum += *sc;
        }
        let mut out = vec![0.0; horizon];
        let mut used = vec![0.0; horizon];
        for (s, w) in scores.iter().enumerate() {
            for (h, slot) in out.iter_mut().enumerate() {
                let idx = s + c + h;
                if idx < n {
                    *slot += w * history[idx];
                    used[h] += w;
                }
            }
        }
        let last = history[n - 1].max(0.0);
        for (h, slot) in out.iter_mut().enumerate() {
            // steps no episode reaches fall back to persistence
            *slot = if used[h] > 1e-12 * wsum.max(1e-12) {
                (*slot / used[h]).max(0.0)
            } else {
                last
            };
        }
        out
    }

    fn name(&self) -> &'static str {
        "attn"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_history_predicts_constant() {
        let mut f = AttnForecaster::default();
        let pred = f.forecast(&vec![6.0; 120], 12);
        for p in pred {
            assert!((p - 6.0).abs() < 1e-9, "{p}");
        }
    }

    #[test]
    fn short_history_falls_back_to_persistence() {
        let mut f = AttnForecaster::default();
        assert_eq!(f.forecast(&[], 3), vec![0.0; 3]);
        assert_eq!(f.forecast(&[4.0, 8.0], 2), vec![8.0, 8.0]);
    }

    #[test]
    fn periodic_pattern_is_continued() {
        // period-8 square wave over 15 periods: the query matches the
        // in-phase episodes, so the continuation tracks the next phase
        let hist: Vec<f64> = (0..120)
            .map(|t| if (t / 4) % 2 == 0 { 20.0 } else { 2.0 })
            .collect();
        let mut f = AttnForecaster {
            context: 8,
            temperature: 1.0,
        };
        let pred = f.forecast(&hist, 8);
        for (h, p) in pred.iter().enumerate() {
            let t = 120 + h;
            let want = if (t / 4) % 2 == 0 { 20.0 } else { 2.0 };
            assert!((p - want).abs() < 4.0, "h={h}: {p} vs {want}");
        }
    }

    #[test]
    fn regime_shift_recalls_the_matching_regime() {
        // an old high-load regime, a long quiet stretch, then the first
        // bins of the high regime again: attention should recall high
        let mut hist = vec![30.0; 40];
        hist.extend(vec![1.0; 60]);
        hist.extend(vec![30.0; 24]);
        let mut f = AttnForecaster {
            context: 12,
            temperature: 0.5,
        };
        let pred = f.forecast(&hist, 4);
        assert!(pred[0] > 10.0, "stale quiet regime won: {pred:?}");
    }

    #[test]
    fn outputs_are_finite_and_nonnegative_on_spiky_input() {
        let hist: Vec<f64> = (0..200)
            .map(|t| if t % 31 == 0 { 1e6 } else { 0.0 })
            .collect();
        let mut f = AttnForecaster::default();
        let pred = f.forecast(&hist, 24);
        assert_eq!(pred.len(), 24);
        assert!(pred.iter().all(|p| p.is_finite() && *p >= 0.0), "{pred:?}");
    }
}
