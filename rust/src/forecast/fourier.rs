//! Pure-Rust mirror of the L2 Fourier forecast graph (Eq. 1-2).
//!
//! Semantically identical to `python/compile/model.py::forecast` — same
//! quadratic-trend normal equations, same explicit-projection DFT, same
//! stable top-K harmonic selection, same statistical clipping — so the
//! HLO artifact and this mirror can be differentially tested (tolerance
//! reflects f32 vs f64 arithmetic). Used as the fast in-process fallback
//! for the big simulation sweeps; the HLO path is the deployed one.

use std::f64::consts::TAU;

use crate::forecast::Forecaster;

#[derive(Debug, Clone)]
pub struct FourierForecaster {
    /// K harmonics kept (paper reuses IceBreaker's predictor).
    pub harmonics: usize,
    /// Statistical-clipping confidence γ (Eq. 2).
    pub gamma_clip: f64,
    /// Trailing samples for the clipping mean/std (M).
    pub recent: usize,
}

impl Default for FourierForecaster {
    fn default() -> Self {
        FourierForecaster {
            harmonics: 8,
            gamma_clip: 3.0,
            recent: 60,
        }
    }
}

/// Quadratic trend coefficients (c, b, a) in sample units, via the same
/// normalized-t normal equations as the L2 graph.
pub fn quadratic_trend(history: &[f64]) -> [f64; 3] {
    let w = history.len();
    let wf = w as f64;
    // normal equations for V = [1, t, t^2] with t in [0,1)
    let mut a = [[0.0f64; 3]; 3];
    let mut b = [0.0f64; 3];
    for (i, &y) in history.iter().enumerate() {
        let t = i as f64 / wf;
        let row = [1.0, t, t * t];
        for p in 0..3 {
            b[p] += row[p] * y;
            for q in 0..3 {
                a[p][q] += row[p] * row[q];
            }
        }
    }
    let flat: Vec<f64> = a.iter().flatten().copied().collect();
    let c = crate::forecast::linalg::solve(&flat, &b, 3)
        .unwrap_or_else(|| vec![b[0] / a[0][0].max(1e-12), 0.0, 0.0]);
    [c[0], c[1] / wf, c[2] / (wf * wf)]
}

/// Explicit-projection real DFT: X_j for j = 0..W/2 (matches `_dft_matmul`).
pub fn dft(resid: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let w = resid.len();
    let nbins = w / 2 + 1;
    let mut re = vec![0.0; nbins];
    let mut im = vec![0.0; nbins];
    for j in 0..nbins {
        let mut cr = 0.0;
        let mut ci = 0.0;
        for (t, &y) in resid.iter().enumerate() {
            let ang = TAU * j as f64 * t as f64 / w as f64;
            cr += ang.cos() * y;
            ci -= ang.sin() * y;
        }
        re[j] = cr;
        im[j] = ci;
    }
    (re, im)
}

/// Extracted harmonic model.
#[derive(Debug, Clone)]
pub struct HarmonicModel {
    pub coeffs: [f64; 3],
    pub amps: Vec<f64>,
    pub freqs: Vec<f64>,
    pub phases: Vec<f64>,
    pub window: usize,
}

impl HarmonicModel {
    /// Fit Eq. 1 to a full history window.
    pub fn fit(history: &[f64], harmonics: usize) -> HarmonicModel {
        let w = history.len();
        let coeffs = quadratic_trend(history);
        let resid: Vec<f64> = history
            .iter()
            .enumerate()
            .map(|(i, &y)| {
                let t = i as f64;
                y - (coeffs[0] + coeffs[1] * t + coeffs[2] * t * t)
            })
            .collect();
        let (re, im) = dft(&resid);
        let mut power: Vec<f64> = re
            .iter()
            .zip(&im)
            .map(|(r, i)| r * r + i * i)
            .collect();
        power[0] = -1.0; // exclude DC, as the L2 graph does
        // stable descending sort by power (ties keep lower bin first)
        let mut order: Vec<usize> = (0..power.len()).collect();
        order.sort_by(|&a, &b| power[b].partial_cmp(&power[a]).unwrap().then(a.cmp(&b)));
        let k = harmonics.min(order.len());
        let top = &order[..k];
        HarmonicModel {
            coeffs,
            amps: top
                .iter()
                .map(|&j| 2.0 * (power[j].max(0.0) + 1e-12).sqrt() / w as f64)
                .collect(),
            freqs: top.iter().map(|&j| j as f64 / w as f64).collect(),
            phases: top.iter().map(|&j| im[j].atan2(re[j])).collect(),
            window: w,
        }
    }

    /// Evaluate Eq. 1 at absolute sample index `t` (kernel mirror).
    pub fn eval(&self, t: f64) -> f64 {
        let mut y = self.coeffs[0] + self.coeffs[1] * t + self.coeffs[2] * t * t;
        for i in 0..self.amps.len() {
            y += self.amps[i] * (TAU * self.freqs[i] * t + self.phases[i]).cos();
        }
        y
    }
}

impl FourierForecaster {
    /// Raw (unclipped) forecast — for Fig. 4 error analysis.
    pub fn forecast_raw(&self, history: &[f64], horizon: usize) -> Vec<f64> {
        let model = HarmonicModel::fit(history, self.harmonics);
        (0..horizon)
            .map(|h| model.eval((history.len() + h) as f64))
            .collect()
    }
}

impl Forecaster for FourierForecaster {
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64> {
        // empty window: nothing to fit (the controller always feeds a
        // padded fixed-shape window, so this only guards direct callers)
        if history.is_empty() {
            return vec![0.0; horizon];
        }
        let raw = self.forecast_raw(history, horizon);
        // Eq. 2: statistical clipping to [0, mean + gamma * std]
        let m = self.recent.min(history.len());
        let recent = &history[history.len() - m..];
        let mean = recent.iter().sum::<f64>() / m.max(1) as f64;
        let var = recent.iter().map(|y| (y - mean).powi(2)).sum::<f64>() / m.max(1) as f64;
        let hi = mean + self.gamma_clip * var.sqrt();
        raw.into_iter().map(|y| y.clamp(0.0, hi)).collect()
    }

    fn name(&self) -> &'static str {
        "fourier"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trend_recovers_quadratic() {
        let w = 240;
        let y: Vec<f64> = (0..w)
            .map(|t| 3.0 + 0.05 * t as f64 - 1e-4 * (t as f64).powi(2))
            .collect();
        let c = quadratic_trend(&y);
        assert!((c[0] - 3.0).abs() < 1e-6, "{c:?}");
        assert!((c[1] - 0.05).abs() < 1e-7);
        assert!((c[2] + 1e-4).abs() < 1e-9);
    }

    #[test]
    fn dft_parseval() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(4);
        let y: Vec<f64> = (0..64).map(|_| rng.normal(0.0, 1.0)).collect();
        let (re, im) = dft(&y);
        // Parseval for the real DFT: sum y^2 = (1/W)(X0^2 + 2 sum |Xj|^2 + XN^2)
        let w = y.len() as f64;
        let time_e: f64 = y.iter().map(|v| v * v).sum();
        let mut freq_e = re[0] * re[0] + im[0] * im[0];
        for j in 1..re.len() - 1 {
            freq_e += 2.0 * (re[j] * re[j] + im[j] * im[j]);
        }
        let last = re.len() - 1;
        freq_e += re[last] * re[last] + im[last] * im[last];
        assert!((time_e - freq_e / w).abs() < 1e-6 * time_e.max(1.0));
    }

    #[test]
    fn pure_harmonic_extrapolates() {
        let w = 240;
        let period = 40.0;
        let y: Vec<f64> = (0..w)
            .map(|t| 20.0 + 6.0 * (TAU * t as f64 / period + 0.7).cos())
            .collect();
        let f = FourierForecaster::default();
        let pred = f.forecast_raw(&y, 24);
        for (h, p) in pred.iter().enumerate() {
            let t = (w + h) as f64;
            let want = 20.0 + 6.0 * (TAU * t / period + 0.7).cos();
            assert!((p - want).abs() < 1.5, "h={h}: {p} vs {want}"); // small leakage from trend-absorbed energy
        }
    }

    #[test]
    fn clipping_bounds_hold() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let y: Vec<f64> = (0..240).map(|_| rng.normal(30.0, 15.0).max(0.0)).collect();
        let mut f = FourierForecaster {
            gamma_clip: 2.0,
            ..Default::default()
        };
        let pred = f.forecast(&y, 24);
        let recent = &y[180..];
        let mean = recent.iter().sum::<f64>() / 60.0;
        let var = recent.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / 60.0;
        let hi = mean + 2.0 * var.sqrt() + 1e-9;
        for p in pred {
            assert!((0.0..=hi).contains(&p), "{p} outside [0, {hi}]");
        }
    }

    #[test]
    fn constant_history_predicts_constant() {
        let y = vec![12.0; 240];
        let mut f = FourierForecaster::default();
        let pred = f.forecast(&y, 24);
        for p in pred {
            assert!((p - 12.0).abs() < 0.3, "{p}");
        }
    }

    #[test]
    fn matches_python_reference_case() {
        // same case as the python smoke: 20 + 5 cos(2 pi t / 60) + 0.01 t
        // python (f32) produced [27.3726, 27.3599, 27.2946, 27.1776, ...]
        let y: Vec<f64> = (0..240)
            .map(|t| 20.0 + 5.0 * (TAU * t as f64 / 60.0).cos() + 0.01 * t as f64)
            .collect();
        let mut f = FourierForecaster::default();
        let pred = f.forecast(&y, 24);
        let want = [27.3727, 27.3601, 27.2948, 27.1779];
        for (p, w) in pred.iter().zip(want) {
            assert!((p - w).abs() < 0.05, "{p} vs {w}");
        }
    }
}
