//! Histogram rate forecaster (the SPES-style non-parametric backend,
//! arXiv:2403.17574).
//!
//! SPES predicts idle-window durations from an inter-arrival histogram
//! and provisions at a quantile of that distribution rather than at a
//! point estimate. Translated to this simulator's per-interval rate
//! series: keep the trailing `window` realized bin counts as an
//! empirical distribution and forecast a fixed `quantile` of it for
//! every horizon step. On sparse/bursty functions — long idle stretches
//! punctuated by spikes — this is hard to beat: the quantile sits just
//! above the idle mass, so the controller holds a small warm floor
//! without chasing every spike, while parametric models (Fourier,
//! ARIMA) ring or mean-revert.
//!
//! The forecast is deliberately flat across the horizon: a histogram
//! has no phase information, and pretending otherwise only injects
//! noise into the MPC's terminal steps.

use crate::forecast::Forecaster;

#[derive(Debug, Clone)]
pub struct HistogramForecaster {
    /// Trailing bins kept as the empirical distribution.
    pub window: usize,
    /// Quantile of the distribution forecast for every step; SPES uses a
    /// high percentile for its keep-alive bound, but on rate series the
    /// controller's own clipping handles the tail, so we default just
    /// above the median.
    pub quantile: f64,
}

impl Default for HistogramForecaster {
    fn default() -> Self {
        HistogramForecaster {
            window: 60,
            quantile: 0.6,
        }
    }
}

impl HistogramForecaster {
    /// The `quantile` of the trailing `window` samples (nearest-rank on
    /// the sorted copy). Zero for an empty history.
    fn level(&self, history: &[f64]) -> f64 {
        let m = self.window.min(history.len());
        if m == 0 {
            return 0.0;
        }
        let mut recent: Vec<f64> = history[history.len() - m..].to_vec();
        recent.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let q = self.quantile.clamp(0.0, 1.0);
        let idx = ((m - 1) as f64 * q).round() as usize;
        recent[idx.min(m - 1)].max(0.0)
    }
}

impl Forecaster for HistogramForecaster {
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64> {
        vec![self.level(history); horizon]
    }

    fn name(&self) -> &'static str {
        "histogram"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_history_predicts_constant() {
        let mut f = HistogramForecaster::default();
        let pred = f.forecast(&vec![9.0; 120], 24);
        assert_eq!(pred, vec![9.0; 24]);
    }

    #[test]
    fn quantile_sits_above_the_idle_mass_on_bursty_series() {
        // 90% idle bins, 10% spikes of 50: the 0.6 quantile is the idle
        // level, so the forecast does not chase spikes
        let hist: Vec<f64> = (0..100)
            .map(|t| if t % 10 == 0 { 50.0 } else { 0.0 })
            .collect();
        let mut f = HistogramForecaster::default();
        let pred = f.forecast(&hist, 8);
        assert!(pred.iter().all(|&p| p == 0.0), "{pred:?}");
        // a high quantile does provision for the spikes
        let mut hi = HistogramForecaster {
            quantile: 0.95,
            ..Default::default()
        };
        let pred = hi.forecast(&hist, 8);
        assert!(pred.iter().all(|&p| p == 50.0), "{pred:?}");
    }

    #[test]
    fn empty_and_short_histories_are_benign() {
        let mut f = HistogramForecaster::default();
        assert_eq!(f.forecast(&[], 4), vec![0.0; 4]);
        assert_eq!(f.forecast(&[3.0], 2), vec![3.0, 3.0]);
    }

    #[test]
    fn window_limits_lookback() {
        // old regime (100s) outside the window must not leak in
        let mut hist = vec![100.0; 200];
        hist.extend(vec![2.0; 60]);
        let mut f = HistogramForecaster::default();
        assert_eq!(f.forecast(&hist, 3), vec![2.0; 3]);
    }

    #[test]
    fn extreme_quantiles_clamp_to_min_and_max() {
        let hist = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut lo = HistogramForecaster {
            window: 5,
            quantile: 0.0,
        };
        let mut hi = HistogramForecaster {
            window: 5,
            quantile: 1.0,
        };
        assert_eq!(lo.forecast(&hist, 1), vec![1.0]);
        assert_eq!(hi.forecast(&hist, 1), vec![5.0]);
    }
}
