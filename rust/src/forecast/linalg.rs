//! Small dense linear algebra for the forecasters (normal equations, OLS).

/// Solve A x = b by Gaussian elimination with partial pivoting.
/// A is row-major n x n. Returns None if singular to working precision.
pub fn solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut m = a.to_vec();
    let mut rhs = b.to_vec();
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = m[col * n + col].abs();
        for row in col + 1..n {
            let v = m[row * n + col].abs();
            if v > best {
                best = v;
                piv = row;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for k in 0..n {
                m.swap(col * n + k, piv * n + k);
            }
            rhs.swap(col, piv);
        }
        // eliminate below
        for row in col + 1..n {
            let f = m[row * n + col] / m[col * n + col];
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                m[row * n + k] -= f * m[col * n + k];
            }
            rhs[row] -= f * rhs[col];
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = rhs[row];
        for k in row + 1..n {
            acc -= m[row * n + k] * x[k];
        }
        x[row] = acc / m[row * n + row];
    }
    Some(x)
}

/// Ordinary least squares: minimize ||X beta - y||^2 via normal equations.
/// X is row-major rows x cols. Returns None if X'X is singular.
pub fn ols(x: &[f64], y: &[f64], rows: usize, cols: usize) -> Option<Vec<f64>> {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(y.len(), rows);
    let mut xtx = vec![0.0; cols * cols];
    let mut xty = vec![0.0; cols];
    for r in 0..rows {
        for i in 0..cols {
            let xi = x[r * cols + i];
            xty[i] += xi * y[r];
            for j in i..cols {
                xtx[i * cols + j] += xi * x[r * cols + j];
            }
        }
    }
    // mirror the upper triangle
    for i in 0..cols {
        for j in 0..i {
            xtx[i * cols + j] = xtx[j * cols + i];
        }
    }
    // tiny ridge for numerical safety on near-collinear designs
    for i in 0..cols {
        xtx[i * cols + i] += 1e-9;
    }
    solve(&xtx, &xty, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_known_system() {
        // [2 1; 1 3] x = [3; 5] -> x = [4/5, 7/5]
        let x = solve(&[2.0, 1.0, 1.0, 3.0], &[3.0, 5.0], 2).unwrap();
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        // [0 1; 1 0] x = [2; 3]
        let x = solve(&[0.0, 1.0, 1.0, 0.0], &[2.0, 3.0], 2).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn singular_returns_none() {
        assert!(solve(&[1.0, 2.0, 2.0, 4.0], &[1.0, 2.0], 2).is_none());
    }

    #[test]
    fn ols_recovers_line() {
        // y = 3 + 2 t
        let rows = 50;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for t in 0..rows {
            x.push(1.0);
            x.push(t as f64);
            y.push(3.0 + 2.0 * t as f64);
        }
        let beta = ols(&x, &y, rows, 2).unwrap();
        assert!((beta[0] - 3.0).abs() < 1e-6);
        assert!((beta[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn ols_random_residual_orthogonality() {
        use crate::prop_assert;
        use crate::util::prop::prop_check;
        prop_check("ols residual orthogonal to design", 50, |g| {
            let rows = g.usize(8, 40);
            let cols = g.usize(1, 4);
            let x: Vec<f64> = (0..rows * cols).map(|_| g.f64(-2.0, 2.0)).collect();
            let y: Vec<f64> = (0..rows).map(|_| g.f64(-2.0, 2.0)).collect();
            let Some(beta) = ols(&x, &y, rows, cols) else {
                return Ok(()); // singular design: nothing to check
            };
            // X'(y - X beta) ~ 0
            for j in 0..cols {
                let mut dot = 0.0;
                for r in 0..rows {
                    let pred: f64 =
                        (0..cols).map(|k| x[r * cols + k] * beta[k]).sum();
                    dot += x[r * cols + j] * (y[r] - pred);
                }
                prop_assert!(dot.abs() < 1e-5, "residual not orthogonal: {dot}");
            }
            Ok(())
        });
    }
}
