//! Invocation forecasting (Sec. III-A): the Fourier predictor (Eq. 1-2),
//! the ARIMA baseline (Fig. 4), and error metrics.
//!
//! # Math-to-code mapping (paper Sec. III-A)
//!
//! Given the last `W` per-interval invocation counts, the predictor
//! extrapolates `H` future counts:
//!
//! | Paper | Code |
//! |-------|------|
//! | Eq. 1 (harmonic regression: trend + top-K DFT components) | `fourier::quadratic_trend` (normal-equations trend fit), `fourier::dft` (explicit-projection real DFT), and the stable top-K harmonic selection inside [`fourier::FourierForecaster`] |
//! | Eq. 2 (statistical clipping at γ·σ over the trailing M samples) | the `gamma_clip`/`recent` fields of [`fourier::FourierForecaster`] |
//! | ARIMA baseline (Fig. 4) | [`arima::ArimaForecaster`], normal equations via [`linalg::solve`] |
//! | accuracy / WAPE / sMAPE / RMSE (Fig. 4's scores) | [`accuracy`] |
//!
//! Beyond the paper's pair, the runtime zoo adds a SPES-style histogram
//! quantile model ([`histogram::HistogramForecaster`]) and an
//! attention-inspired pattern matcher ([`attn::AttnForecaster`]), with
//! [`selector::AutoSelector`] picking per function online by rolling
//! WAPE (`--forecast auto`).
//!
//! The deployed forecast path executes the AOT HLO artifact through
//! `runtime::modules::ForecastModule`; [`fourier::FourierForecaster`] is
//! the bit-level Rust mirror used for fast simulation sweeps and
//! differential testing. In a multi-tenant run the MPC keeps one
//! aggregate forecaster for the horizon problem plus one per function to
//! split the prewarm budget by predicted demand.

pub mod accuracy;
pub mod arima;
pub mod attn;
pub mod fourier;
pub mod histogram;
pub mod linalg;
pub mod selector;

/// A rolling-horizon forecaster of per-interval arrival counts.
pub trait Forecaster {
    /// Predict the next `horizon` per-interval arrival counts given the
    /// most recent `history` (oldest first). Implementations must return
    /// exactly `horizon` finite values.
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64>;

    fn name(&self) -> &str;
}

pub use arima::ArimaForecaster;
pub use attn::AttnForecaster;
pub use fourier::FourierForecaster;
pub use histogram::HistogramForecaster;
pub use selector::AutoSelector;

#[cfg(test)]
mod tests {
    use super::*;

    fn zoo() -> Vec<Box<dyn Forecaster>> {
        vec![
            Box::new(FourierForecaster::default()),
            Box::new(ArimaForecaster::default()),
            Box::new(HistogramForecaster::default()),
            Box::new(AttnForecaster::default()),
        ]
    }

    #[test]
    fn trait_objects_work() {
        let mut fs = zoo();
        let hist: Vec<f64> = (0..240).map(|t| 10.0 + (t % 7) as f64).collect();
        for f in fs.iter_mut() {
            let out = f.forecast(&hist, 24);
            assert_eq!(out.len(), 24, "{}", f.name());
            assert!(out.iter().all(|v| v.is_finite()), "{}", f.name());
        }
    }

    #[test]
    fn trait_contract_holds_for_every_backend() {
        // the property satellite: exactly `horizon` values, all finite,
        // all non-negative after clipping, on adversarial history shapes
        use crate::prop_assert;
        use crate::util::prop::prop_check;
        prop_check("forecaster trait contract", 60, |g| {
            let shape = g.usize(0, 4);
            let n = g.usize(0, 260);
            let hist: Vec<f64> = match shape {
                0 => vec![0.0; n],                                     // all-zero
                1 => vec![g.f64(0.0, 50.0); n],                        // constant
                2 => (0..n)
                    .map(|t| if t == n / 2 { g.f64(100.0, 1e5) } else { 0.0 })
                    .collect(),                                        // spike
                3 => {
                    let slope = g.f64(0.0, 3.0);
                    (0..n).map(|t| slope * t as f64).collect()         // ramp
                }
                _ => (0..n).map(|_| g.f64(0.0, 200.0)).collect(),      // noise
            };
            let horizon = g.usize(1, 48);
            for f in zoo().iter_mut() {
                let out = f.forecast(&hist, horizon);
                prop_assert!(
                    out.len() == horizon,
                    "{}: {} values for horizon {horizon} (n={n}, shape={shape})",
                    f.name(),
                    out.len()
                );
                prop_assert!(
                    out.iter().all(|v| v.is_finite()),
                    "{}: non-finite output (n={n}, shape={shape}): {out:?}",
                    f.name()
                );
                prop_assert!(
                    out.iter().all(|&v| v >= 0.0),
                    "{}: negative output (n={n}, shape={shape}): {out:?}",
                    f.name()
                );
            }
            Ok(())
        });
    }
}
