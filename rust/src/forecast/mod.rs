//! Invocation forecasting (Sec. III-A): the Fourier predictor (Eq. 1-2),
//! the ARIMA baseline (Fig. 4), and error metrics.
//!
//! # Math-to-code mapping (paper Sec. III-A)
//!
//! Given the last `W` per-interval invocation counts, the predictor
//! extrapolates `H` future counts:
//!
//! | Paper | Code |
//! |-------|------|
//! | Eq. 1 (harmonic regression: trend + top-K DFT components) | `fourier::quadratic_trend` (normal-equations trend fit), `fourier::dft` (explicit-projection real DFT), and the stable top-K harmonic selection inside [`fourier::FourierForecaster`] |
//! | Eq. 2 (statistical clipping at γ·σ over the trailing M samples) | the `gamma_clip`/`recent` fields of [`fourier::FourierForecaster`] |
//! | ARIMA baseline (Fig. 4) | [`arima::ArimaForecaster`], normal equations via [`linalg::solve`] |
//! | accuracy / WAPE / sMAPE / RMSE (Fig. 4's scores) | [`accuracy`] |
//!
//! The deployed forecast path executes the AOT HLO artifact through
//! `runtime::modules::ForecastModule`; [`fourier::FourierForecaster`] is
//! the bit-level Rust mirror used for fast simulation sweeps and
//! differential testing. In a multi-tenant run the MPC keeps one
//! aggregate forecaster for the horizon problem plus one per function to
//! split the prewarm budget by predicted demand.

pub mod accuracy;
pub mod arima;
pub mod fourier;
pub mod linalg;

/// A rolling-horizon forecaster of per-interval arrival counts.
pub trait Forecaster {
    /// Predict the next `horizon` per-interval arrival counts given the
    /// most recent `history` (oldest first). Implementations must return
    /// exactly `horizon` finite values.
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64>;

    fn name(&self) -> &str;
}

pub use arima::ArimaForecaster;
pub use fourier::FourierForecaster;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trait_objects_work() {
        let mut fs: Vec<Box<dyn Forecaster>> = vec![
            Box::new(FourierForecaster::default()),
            Box::new(ArimaForecaster::default()),
        ];
        let hist: Vec<f64> = (0..240).map(|t| 10.0 + (t % 7) as f64).collect();
        for f in fs.iter_mut() {
            let out = f.forecast(&hist, 24);
            assert_eq!(out.len(), 24, "{}", f.name());
            assert!(out.iter().all(|v| v.is_finite()), "{}", f.name());
        }
    }
}
