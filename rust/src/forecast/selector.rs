//! Online per-function forecast model selection (`--forecast auto`).
//!
//! Each function keeps one instance of every zoo backend. At every
//! control tick the selector scores each backend's previous one-step
//! prediction against the bin that actually realized, accumulating a
//! rolling WAPE (via [`accuracy`]) over the last `score_window` scored
//! bins, and routes the function's forecasts — prewarm split, lead
//! window, adaptive keep-alive horizon — through the current-best model.
//!
//! Selection is deliberately sticky: a challenger only displaces the
//! incumbent when its rolling WAPE beats the incumbent's by the relative
//! `hysteresis` margin, and never before `warmup_bins` bins have been
//! scored. Ties resolve to the lowest backend index (the zoo order is
//! fixed), so the whole procedure is a pure function of the realized bin
//! sequence — deterministic across runs and shard counts.

use std::collections::VecDeque;

use crate::config::{ForecastBackend, ForecastConfig};
use crate::forecast::{
    accuracy, ArimaForecaster, AttnForecaster, Forecaster, FourierForecaster, HistogramForecaster,
};

/// The zoo, in scoring/tie-break order.
const ZOO: [ForecastBackend; 4] = [
    ForecastBackend::Fourier,
    ForecastBackend::Arima,
    ForecastBackend::Histogram,
    ForecastBackend::Attn,
];

/// Construct a boxed instance of a fixed backend. The Fourier instance
/// carries the controller's clipping γ exactly as the pre-zoo hard-coded
/// field did, which is what keeps `--forecast fourier` byte-identical.
pub fn make_backend(backend: ForecastBackend, gamma_clip: f64) -> Box<dyn Forecaster> {
    match backend {
        // Auto is handled by AutoSelector; mapping it to the default
        // backend here keeps this constructor total
        ForecastBackend::Fourier | ForecastBackend::Auto => Box::new(FourierForecaster {
            gamma_clip,
            ..Default::default()
        }),
        ForecastBackend::Arima => Box::new(ArimaForecaster::default()),
        ForecastBackend::Histogram => Box::new(HistogramForecaster::default()),
        ForecastBackend::Attn => Box::new(AttnForecaster::default()),
    }
}

/// Online selector over the full zoo for one function's demand series.
pub struct AutoSelector {
    backends: Vec<Box<dyn Forecaster>>,
    /// Last one-step prediction per backend, scored against the next
    /// realized bin.
    pending: Vec<Option<f64>>,
    /// Rolling (pred, actual) pairs per backend, newest-last.
    scored: Vec<VecDeque<(f64, f64)>>,
    current: usize,
    switches: u64,
    score_window: usize,
    hysteresis: f64,
    warmup_bins: usize,
}

impl AutoSelector {
    pub fn new(cfg: &ForecastConfig, gamma_clip: f64) -> Self {
        AutoSelector {
            backends: ZOO.iter().map(|&b| make_backend(b, gamma_clip)).collect(),
            pending: vec![None; ZOO.len()],
            scored: (0..ZOO.len()).map(|_| VecDeque::new()).collect(),
            current: 0,
            switches: 0,
            score_window: cfg.score_window.max(1),
            hysteresis: cfg.hysteresis.max(0.0),
            warmup_bins: cfg.warmup_bins,
        }
    }

    /// Rolling WAPE of backend `i` over its scored window.
    fn score(&self, i: usize) -> f64 {
        let (preds, actuals): (Vec<f64>, Vec<f64>) = self.scored[i].iter().copied().unzip();
        accuracy::wape(&preds, &actuals)
    }

    fn maybe_switch(&mut self) {
        if self.scored[self.current].len() < self.warmup_bins.max(1) {
            return;
        }
        let scores: Vec<f64> = (0..self.backends.len()).map(|i| self.score(i)).collect();
        let mut best = 0;
        for (i, s) in scores.iter().enumerate().skip(1) {
            if *s < scores[best] {
                best = i;
            }
        }
        // the challenger must beat the incumbent by the relative margin;
        // an infinite incumbent WAPE (all-zero window, nonzero preds) is
        // beaten by any finite challenger
        if best != self.current && scores[best] < scores[self.current] * (1.0 - self.hysteresis) {
            self.current = best;
            self.switches += 1;
        }
    }

    /// One control tick worth of bookkeeping. `history` is the demand
    /// window *after* the just-realized bin was pushed (oldest first,
    /// newest == `realized`): score every backend's pending one-step
    /// prediction against `realized`, re-select, then stage each
    /// backend's next one-step prediction from the updated window.
    pub fn observe(&mut self, history: &[f64], realized: f64) {
        for i in 0..self.backends.len() {
            if let Some(p) = self.pending[i].take() {
                let w = &mut self.scored[i];
                w.push_back((p, realized));
                while w.len() > self.score_window {
                    w.pop_front();
                }
            }
        }
        self.maybe_switch();
        for i in 0..self.backends.len() {
            self.pending[i] = self.backends[i].forecast(history, 1).first().copied();
        }
    }

    /// The currently selected backend.
    pub fn current_backend(&self) -> ForecastBackend {
        ZOO[self.current]
    }

    /// Name of the currently selected backend.
    pub fn current_name(&self) -> &'static str {
        self.current_backend().name()
    }

    /// How many times selection has moved off the incumbent.
    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Rolling accuracy % (100 × (1 − WAPE), clamped) of the current
    /// backend; 100 before anything has been scored.
    pub fn rolling_accuracy_pct(&self) -> f64 {
        let (preds, actuals): (Vec<f64>, Vec<f64>) =
            self.scored[self.current].iter().copied().unzip();
        accuracy::accuracy_pct(&preds, &actuals)
    }
}

impl Forecaster for AutoSelector {
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64> {
        self.backends[self.current].forecast(history, horizon)
    }

    fn name(&self) -> &'static str {
        "auto"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ForecastConfig {
        ForecastConfig {
            backend: ForecastBackend::Auto,
            score_window: 8,
            hysteresis: 0.1,
            warmup_bins: 4,
        }
    }

    /// Drive the selector through a realized series the way the
    /// controller does: push bin, observe, forecast.
    fn drive(sel: &mut AutoSelector, series: &[f64]) {
        let mut hist: Vec<f64> = Vec::new();
        for &x in series {
            hist.push(x);
            sel.observe(&hist, x);
        }
    }

    #[test]
    fn starts_on_fourier_and_never_panics_on_zero_series() {
        let mut sel = AutoSelector::new(&quick_cfg(), 3.0);
        assert_eq!(sel.current_name(), "fourier");
        drive(&mut sel, &vec![0.0; 40]);
        let out = sel.forecast(&vec![0.0; 40], 6);
        assert_eq!(out.len(), 6);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn selection_is_deterministic() {
        let series: Vec<f64> = (0..120)
            .map(|t| if t % 9 == 0 { 40.0 } else { (t % 5) as f64 })
            .collect();
        let mut a = AutoSelector::new(&quick_cfg(), 3.0);
        let mut b = AutoSelector::new(&quick_cfg(), 3.0);
        drive(&mut a, &series);
        drive(&mut b, &series);
        assert_eq!(a.current_name(), b.current_name());
        assert_eq!(a.switches(), b.switches());
        assert_eq!(a.forecast(&series, 12), b.forecast(&series, 12));
    }

    #[test]
    fn no_switch_before_warmup() {
        let mut sel = AutoSelector::new(&quick_cfg(), 3.0);
        // three scored bins < warmup_bins = 4: selection must not move
        drive(&mut sel, &[0.0, 50.0, 0.0]);
        assert_eq!(sel.switches(), 0);
        assert_eq!(sel.current_name(), "fourier");
    }

    #[test]
    fn infinite_hysteresis_pins_the_incumbent() {
        let cfg = ForecastConfig {
            hysteresis: 1.0,
            ..quick_cfg()
        };
        let mut sel = AutoSelector::new(&cfg, 3.0);
        let series: Vec<f64> = (0..200)
            .map(|t| if t % 7 == 0 { 80.0 } else { 0.0 })
            .collect();
        drive(&mut sel, &series);
        // a challenger must be 100% better, i.e. WAPE 0 while the
        // incumbent's is positive — the spiky series denies that
        assert_eq!(sel.switches(), 0);
        assert_eq!(sel.current_name(), "fourier");
    }

    #[test]
    fn rolling_accuracy_is_bounded() {
        let mut sel = AutoSelector::new(&quick_cfg(), 3.0);
        assert_eq!(sel.rolling_accuracy_pct(), 100.0); // unscored
        let series: Vec<f64> = (0..60).map(|t| 10.0 + (t % 4) as f64).collect();
        drive(&mut sel, &series);
        let acc = sel.rolling_accuracy_pct();
        assert!((0.0..=100.0).contains(&acc), "{acc}");
    }

    #[test]
    fn make_backend_covers_the_zoo() {
        for b in ZOO {
            let mut f = make_backend(b, 3.0);
            assert_eq!(f.name(), b.name());
            let out = f.forecast(&vec![5.0; 130], 10);
            assert_eq!(out.len(), 10);
        }
    }
}
