//! # mpc-serverless
//!
//! Reproduction of *"Taming Cold Starts: Proactive Serverless Scheduling
//! with Model Predictive Control"* (MASCOTS 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the MPC scheduler and every substrate it needs:
//!   an OpenWhisk/Kubernetes cluster analog (multi-invoker fleet with
//!   per-function warm pools), single- and multi-tenant workload
//!   generators, the request-shaping coordinator, baselines (OpenWhisk
//!   default policy, IceBreaker), metrics (aggregate and per-function),
//!   and the experiment drivers for every figure in the paper's
//!   evaluation. See `docs/ARCHITECTURE.md` for the layer map and the
//!   event-loop lifecycle of one invocation.
//! * **L2/L1 (python/, build-time only)** — the controller's compute
//!   graphs (Fourier forecast, horizon-QP projected-gradient solver,
//!   detector payload) authored in JAX with Pallas kernels and AOT-lowered
//!   to HLO text artifacts.
//! * **runtime** — loads `artifacts/*.hlo.txt` through the PJRT C API
//!   (`xla` crate) so Python is never on the request path.
//!
//! See DESIGN.md for the full system inventory and the per-experiment
//! index, and EXPERIMENTS.md for paper-vs-measured results.

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod forecast;
pub mod metrics;
pub mod mpc;
pub mod runtime;
pub mod simulator;
pub mod util;
pub mod workload;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
