//! `mpc-serverless` CLI — leader entrypoint.
//!
//! Subcommands:
//!   simulate     run one policy on one trace (optionally multi-node / multi-tenant /
//!                elastic: drain + rejoin + migration; adaptive keep-alive), print the run report
//!   matrix       run the full Fig. 5-7 policy x trace matrix (parallel cells)
//!   fleet-sweep  sweep node count x placement policy at fixed total capacity
//!   tenant-sweep run every policy on one multi-tenant workload, per-function P50/P99
//!   elasticity-sweep  drain → rejoin scenario swept across migration policies
//!   keepalive-sweep   fixed vs adaptive retention; resource-time vs P99 frontier
//!   survival-frontier mpc vs survival vs icebreaker; three-way resource-time vs P99 frontier
//!   cache-sweep       image-cache capacity ladder vs the constant-L_cold baseline
//!   scenario     run one chaos preset (failure-storm | rolling-restart | flash-crowd) under one policy
//!   chaos-sweep  every chaos preset x every policy; retry/timeout/drop telemetry
//!   forecast-sweep    every forecast backend x {bursty, azure, diurnal}; accuracy + MPC tail latency
//!   bench-throughput  sweep nodes x functions x load, report simulator events/sec (BENCH JSON)
//!   forecast     Fig. 4 forecast comparison
//!   overhead     Fig. 8 control overhead (rust mirror + HLO if available)
//!   fig1         the 50-request motivation scenario
//!   gen-trace    emit a workload trace as CSV to stdout
//!
//! The full flag-by-flag reference lives in README.md ("CLI reference").

use mpc_serverless::config::{
    parse_failure_spec, parse_restore_spec, secs, validate_fault_schedule, ChaosConfig, ChaosMode,
    ExperimentConfig, FleetConfig, ForecastBackend, ForecastConfig, ImageCacheConfig,
    ImageCacheMode, KeepAliveConfig, KeepAlivePolicy, MigrationConfig, MigrationPolicy,
    NodeFailure, NodeRestore, PlacementPolicy, Policy, SurvivalConfig, TenantConfig, TraceKind,
};
use mpc_serverless::experiments::cache::{self, CacheParams};
use mpc_serverless::experiments::chaos::{self as chaos_exp, ScenarioParams};
use mpc_serverless::experiments::forecast_sweep::{self, SweepParams};
use mpc_serverless::experiments::elasticity::{self, ElasticityParams};
use mpc_serverless::experiments::keepalive::{self, KeepAliveParams};
use mpc_serverless::experiments::survival::{self as survival_exp, SurvivalParams};
use mpc_serverless::experiments::tenant::run_tenant_matrix;
use mpc_serverless::experiments::{fig1, fig4, fig5_7, fig8, run_experiment, run_tenant};
use mpc_serverless::util::bench::Table;
use mpc_serverless::util::cli::{Args, Cli, CliError};
use mpc_serverless::workload::tenant::parse_skew;
use mpc_serverless::workload::{FunctionRegistry, TenantWorkload, Trace};

fn main() {
    mpc_serverless::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { vec![] } else { argv[1..].to_vec() };
    let code = match cmd {
        "simulate" => simulate(&rest),
        "matrix" => matrix(&rest),
        "fleet-sweep" => fleet_sweep(&rest),
        "tenant-sweep" => tenant_sweep(&rest),
        "elasticity-sweep" => elasticity_sweep(&rest),
        "keepalive-sweep" => keepalive_sweep(&rest),
        "survival-frontier" => survival_frontier(&rest),
        "cache-sweep" => cache_sweep(&rest),
        "scenario" => scenario(&rest),
        "chaos-sweep" => chaos_sweep(&rest),
        "forecast-sweep" => forecast_sweep_cmd(&rest),
        "bench-throughput" => bench_throughput(&rest),
        "forecast" => forecast(&rest),
        "overhead" => overhead(),
        "fig1" => {
            let r = fig1::run(42);
            println!("cold starts: {} | warm mean {:.3} s | cold mean {:.2} s",
                     r.cold_starts, r.warm_exec_mean_s, r.cold_response_mean_s);
            0
        }
        "gen-trace" => gen_trace(&rest),
        _ => {
            eprintln!("mpc-serverless {}\n\nUSAGE: mpc-serverless <simulate|matrix|fleet-sweep|tenant-sweep|elasticity-sweep|keepalive-sweep|survival-frontier|cache-sweep|scenario|chaos-sweep|forecast-sweep|bench-throughput|forecast|overhead|fig1|gen-trace> [flags]\nRun a subcommand with --help for flags.",
                      mpc_serverless::version());
            if cmd == "help" { 0 } else { 2 }
        }
    };
    std::process::exit(code);
}

fn common_cli(name: &str, about: &str) -> Cli {
    Cli::new(name, about)
        .flag("policy", "mpc", "openwhisk | icebreaker | mpc | survival")
        .flag("trace", "synthetic", "azure | synthetic")
        .flag("duration-s", "3600", "experiment duration (seconds)")
        .flag("seed", "42", "rng seed")
}

fn parse_or_exit(cli: &Cli, rest: &[String]) -> Args {
    match cli.parse(rest) {
        Ok(a) => a,
        Err(CliError::Help) => {
            println!("{}", cli.usage());
            std::process::exit(0);
        }
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            std::process::exit(2);
        }
    }
}

/// Parse the shared fleet flags (--nodes / --placement) into a config.
fn fleet_from_args(a: &Args) -> Result<FleetConfig, String> {
    let nodes = a.get_u64("nodes").map_err(|e| e.to_string())? as u32;
    if nodes == 0 {
        return Err("--nodes must be at least 1".into());
    }
    let placement = PlacementPolicy::parse(a.get("placement"))
        .ok_or_else(|| format!("unknown placement '{}'", a.get("placement")))?;
    Ok(FleetConfig {
        nodes,
        placement,
        ..Default::default()
    })
}

fn simulate(rest: &[String]) -> i32 {
    let cli = common_cli("simulate", "run one policy on one workload")
        .flag("nodes", "1", "invoker node count")
        .flag("threads", "1", "event-loop worker threads (results are bit-identical to --threads 1)")
        .flag("placement", "warm-first", "round-robin | least-loaded | warm-first")
        .flag("functions", "1", "distinct functions sharing the fleet (1 = legacy single-tenant)")
        .flag("skew", "zipf:1.1", "function popularity: zipf:<s> | uniform")
        .flag("trace-file", "", "replay an arrival CSV (overrides --trace)")
        .multi_flag("fail-node", "drain a node mid-run: <id>@<seconds> (or a bare <id>, at --fail-at-s)")
        .flag("fail-at-s", "600", "outage time for bare --fail-node ids (seconds)")
        .multi_flag("restore-node", "rejoin a drained node: <id>@<seconds>[:cap], e.g. 1@900 or 1@900:8")
        .flag("chaos", "off", "fault injection: off | faults | failure-storm | rolling-restart | flash-crowd")
        .flag("chaos-spawn-fail-p", "0.05", "probability a request-bound container spawn fails")
        .flag("chaos-exec-fail-p", "0.05", "probability a completed execution still fails and retries")
        .flag("chaos-straggler-p", "0.02", "probability an execution straggles (duration stretches)")
        .flag("chaos-straggler-factor", "12", "duration multiplier for straggling executions")
        .flag("chaos-max-retries", "3", "retry budget per request across all fault kinds")
        .flag("chaos-retry-backoff-s", "1", "base retry backoff; attempt n waits backoff x 2^(n-1)")
        .flag("chaos-timeout-factor", "8", "per-function execution timeout as a multiple of L_warm")
        .flag("migration", "off", "cross-node rebalancing: off | demand-gap | idle-spread")
        .flag("migration-latency-s", "2", "warm-state transfer latency (seconds)")
        .flag("reclaim-pressure", "0", "memory-pressure weight in the fleet reclaim ranking (0 = off)")
        .flag("forecast", "fourier", "forecast backend: fourier | arima | histogram | attn | auto (non-fourier needs --policy mpc)")
        .flag("forecast-window", "16", "auto selector: scored bins kept in each backend's rolling WAPE window")
        .flag("forecast-hysteresis", "0.1", "auto selector: relative WAPE margin a challenger must beat (anti-thrash)")
        .flag("forecast-warmup", "8", "auto selector: scored bins required before the first switch")
        .flag("keepalive-policy", "fixed", "container retention: fixed | adaptive (adaptive needs --policy mpc)")
        .flag("keepalive-min-s", "30", "adaptive retention horizon floor (seconds)")
        .flag("keepalive-idle-cost", "1", "idle cost rate in the retention break-even (per container-second)")
        .flag("keepalive-cold-weight", "16", "cold-start cost weight (x L_cold) in the retention break-even")
        .flag("keepalive-pressure", "0", "memory-pressure shrink weight on adaptive horizons (0 = off)")
        .flag("survival-window", "64", "survival estimator: trailing inter-arrival gaps kept per function")
        .flag("survival-threshold", "0.5", "release below this reuse probability over the break-even window")
        .flag("survival-min-samples", "8", "gaps required before survival overrides the profile keep-alive")
        .flag("image-cache", "off", "per-node image/layer cache: off | lru (dynamic per-node L_cold)")
        .flag("image-cache-mib", "2048", "per-node layer store capacity (MiB) for --image-cache lru")
        .flag("image-bandwidth-mibps", "100", "registry pull bandwidth (MiB/s) for missing layers")
        .flag("image-init-frac", "0.25", "fraction of L_cold that is runtime init (the rest scales with pulled bytes)");
    let a = parse_or_exit(&cli, rest);
    let policy = match Policy::parse(a.get("policy")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy '{}'", a.get("policy"));
            return 2;
        }
    };
    let trace_kind = match TraceKind::parse(a.get("trace")) {
        Some(t) => t,
        None => {
            eprintln!("unknown trace '{}'", a.get("trace"));
            return 2;
        }
    };
    let mut fleet = match fleet_from_args(&a) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // fault schedule: each --fail-node is <id>@<seconds> (or a bare <id>
    // taking its time from --fail-at-s, the legacy single-drain form),
    // each --restore-node is <id>@<seconds>[:cap]; the merged schedule is
    // cross-validated against the fleet shape and run duration below,
    // once the duration is final
    let mut failures: Vec<NodeFailure> = Vec::new();
    for spec in a.get_all("fail-node") {
        let f = if spec.contains('@') {
            parse_failure_spec(spec)
        } else {
            match (spec.trim().parse::<u32>(), a.get_f64("fail-at-s")) {
                (Ok(node), Ok(t)) if t.is_finite() && t >= 0.0 => {
                    Some(NodeFailure { node, at: secs(t) })
                }
                _ => None,
            }
        };
        match f {
            Some(f) => failures.push(f),
            None => {
                eprintln!("bad --fail-node '{spec}' (expected <id>@<seconds> or a bare <id>)");
                return 2;
            }
        }
    }
    let mut restores: Vec<NodeRestore> = Vec::new();
    for spec in a.get_all("restore-node") {
        match parse_restore_spec(spec) {
            Some(r) => restores.push(r),
            None => {
                eprintln!(
                    "bad --restore-node '{spec}' (expected <id>@<seconds>[:cap], e.g. 1@900 or 1@900:8)"
                );
                return 2;
            }
        }
    }
    let chaos = match parse_chaos_flags(&a) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // the storm/rolling presets schedule their own drains; merging them
    // with a hand-written schedule would need cross-validation against
    // generated times the user cannot see — refuse the combination
    if chaos.mode.has_node_schedule() && (!failures.is_empty() || !restores.is_empty()) {
        eprintln!(
            "--chaos {} schedules its own node drains; drop --fail-node/--restore-node (or use --chaos faults)",
            chaos.mode.name()
        );
        return 2;
    }
    let migration_policy = match MigrationPolicy::parse(a.get("migration")) {
        Some(p) => p,
        None => {
            eprintln!("unknown migration policy '{}'", a.get("migration"));
            return 2;
        }
    };
    // a migration policy that can never actuate must be an error, not a
    // silent no-op run masquerading as a rebalancing measurement: the
    // pass needs a control loop feeding it per-function demand — the
    // MPC's lead-window forecasts or the survival policy's
    // survival-weighted arrival rates; the other reactive policies run
    // no such loop and never migrate
    if migration_policy != MigrationPolicy::Off
        && !matches!(policy, Policy::Mpc | Policy::Survival)
    {
        eprintln!(
            "--migration {} only actuates under --policy mpc or survival (the rebalancing pass consumes a control-loop demand estimate); use --migration off with --policy {}",
            migration_policy.name(),
            policy.name()
        );
        return 2;
    }
    let migration_latency = match a.get_f64("migration-latency-s") {
        Ok(s) if s > 0.0 => secs(s),
        _ => {
            eprintln!("--migration-latency-s must be a positive number");
            return 2;
        }
    };
    fleet.migration = MigrationConfig {
        policy: migration_policy,
        latency: migration_latency,
        ..Default::default()
    };
    let reclaim_pressure = match a.get_f64("reclaim-pressure") {
        Ok(w) if w >= 0.0 && w.is_finite() => w,
        _ => {
            eprintln!("--reclaim-pressure must be a non-negative number");
            return 2;
        }
    };
    let keepalive = match parse_keepalive_flags(&a, policy) {
        Ok(ka) => ka,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let forecast = match parse_forecast_flags(&a, policy) {
        Ok(fc) => fc,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let survival = match parse_survival_knobs(&a) {
        Ok(sv) => sv,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let image = match parse_image_flags(&a) {
        Ok(ic) => ic,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let functions = match a.get_u64("functions") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--functions must be a positive integer");
            return 2;
        }
    };
    let threads = match a.get_u64("threads") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--threads must be at least 1");
            return 2;
        }
    };
    let zipf_s = match parse_skew(a.get("skew")) {
        Some(s) => s,
        None => {
            eprintln!(
                "bad --skew '{}' (expected zipf:<s> with 0 <= s <= 64, or uniform)",
                a.get("skew")
            );
            return 2;
        }
    };
    let mut duration = secs(a.get_f64("duration-s").unwrap_or(3600.0));
    let seed = a.get_u64("seed").unwrap_or(42);
    // the trace is built here only for the paths that consume it as-is;
    // a generated multi-tenant workload builds its own traces
    let trace: Option<Trace> = if !a.get("trace-file").is_empty() {
        let path = a.get("trace-file");
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("reading {path}: {e}");
                return 2;
            }
        };
        match Trace::from_csv(&text) {
            Ok(t) => Some(t),
            Err(e) => {
                eprintln!("parsing {path}: {e}");
                return 2;
            }
        }
    } else if functions == 1 {
        Some(fig4::trace_for(trace_kind, duration, seed))
    } else {
        None
    };
    // a replayed file defines its own span: never truncate it silently
    if let Some(t) = &trace {
        duration = duration.max(t.duration());
    }
    // the merged schedule must be executable: in-range ids, a surviving
    // node, strictly alternating drain -> restore per node, nothing at
    // or past the (now final) run end
    if let Err(e) = validate_fault_schedule(&failures, &restores, fleet.nodes, duration) {
        eprintln!("{e}");
        return 2;
    }
    fleet.failures = failures;
    fleet.restores = restores;
    let mut cfg = ExperimentConfig {
        trace: trace_kind,
        fleet,
        tenancy: TenantConfig {
            functions,
            zipf_s,
        },
        duration,
        seed,
        ..Default::default()
    };
    cfg.threads = threads;
    cfg.platform.reclaim_pressure_weight = reclaim_pressure;
    cfg.platform.image = image;
    cfg.controller.keepalive = keepalive;
    cfg.controller.forecast = forecast;
    cfg.controller.survival = survival;
    cfg.chaos = chaos;
    // --functions 1 takes the untouched legacy path: bit-identical to the
    // pre-tenancy simulator (regression-tested)
    let mut r = if functions > 1 {
        let workload = match &trace {
            // replayed arrivals keep their timing; tenants are assigned
            // by popularity sampling
            Some(t) => {
                let registry = FunctionRegistry::synthesize(functions, zipf_s, &cfg.platform, seed);
                TenantWorkload::assign(t, registry, seed)
            }
            None => TenantWorkload::generate(
                trace_kind,
                duration,
                seed,
                functions,
                zipf_s,
                &cfg.platform,
            ),
        };
        run_tenant(&cfg, policy, &workload)
    } else {
        run_experiment(&cfg, policy, trace.as_ref().expect("single-tenant trace built above"))
    };
    if !a.get("trace-file").is_empty() {
        // label the report with the replayed file, not the unrelated
        // --trace generator default
        r.trace = format!("file:{}", a.get("trace-file"));
    }
    println!("{}", r.to_json());
    0
}

fn tenant_sweep(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "tenant-sweep",
        "every policy on one multi-tenant workload; aggregate + per-function P50/P99",
    )
    .flag("trace", "synthetic", "azure | synthetic")
    .flag("duration-s", "3600", "experiment duration (seconds)")
    .flag("seed", "42", "rng seed")
    .flag("nodes", "1", "invoker node count")
    .flag("placement", "warm-first", "round-robin | least-loaded | warm-first")
    .flag("functions", "8", "distinct functions sharing the fleet")
    .flag("skew", "zipf:1.1", "function popularity: zipf:<s> | uniform");
    let a = parse_or_exit(&cli, rest);
    let trace_kind = match TraceKind::parse(a.get("trace")) {
        Some(t) => t,
        None => {
            eprintln!("unknown trace '{}'", a.get("trace"));
            return 2;
        }
    };
    let fleet = match fleet_from_args(&a) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let functions = match a.get_u64("functions") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--functions must be a positive integer");
            return 2;
        }
    };
    let zipf_s = match parse_skew(a.get("skew")) {
        Some(s) => s,
        None => {
            eprintln!(
                "bad --skew '{}' (expected zipf:<s> with 0 <= s <= 64, or uniform)",
                a.get("skew")
            );
            return 2;
        }
    };
    let duration_s = a.get_f64("duration-s").unwrap_or(3600.0);
    let seed = a.get_u64("seed").unwrap_or(42);
    let m = run_tenant_matrix(trace_kind, duration_s, seed, functions, zipf_s, &fleet);
    println!(
        "tenant-sweep: trace={} functions={} skew={} requests={} nodes={}",
        trace_kind.name(),
        functions,
        a.get("skew"),
        m.workload.len(),
        fleet.nodes
    );
    let mut agg = Table::new(&[
        "policy", "p50 ms", "p99 ms", "cold %", "evictions", "mean warm", "keep-alive s",
    ]);
    for r in &m.reports {
        let cold_pct = if r.completed > 0 {
            100.0 * r.cold_requests as f64 / r.completed as f64
        } else {
            0.0
        };
        agg.row(&[
            r.policy.clone(),
            format!("{:.0}", r.p50_ms),
            format!("{:.0}", r.p99_ms),
            format!("{cold_pct:.1}"),
            r.counters.evictions.to_string(),
            format!("{:.1}", r.mean_warm),
            format!("{:.0}", r.keepalive_total_s),
        ]);
    }
    agg.print();
    // per-function tail latency, side by side (functions ordered by id =
    // descending popularity under zipf)
    println!("\nper-function P50/P99 (ms):");
    let mut t = Table::new(&[
        "func", "share %", "requests", "ow p50", "ow p99", "ib p99", "mpc p50", "mpc p99",
    ]);
    let ow = m.report(Policy::OpenWhisk);
    let ib = m.report(Policy::IceBreaker);
    let mpc = m.report(Policy::Mpc);
    for p in m.workload.registry.profiles() {
        let find = |r: &mpc_serverless::metrics::RunReport| {
            r.per_function.iter().find(|f| f.func == p.id).cloned()
        };
        let (Some(fo), Some(fi), Some(fm)) = (find(ow), find(ib), find(mpc)) else {
            continue; // function received no traffic
        };
        t.row(&[
            p.name.clone(),
            format!("{:.1}", p.share * 100.0),
            (fo.completed + fo.dropped).to_string(),
            format!("{:.0}", fo.p50_ms),
            format!("{:.0}", fo.p99_ms),
            format!("{:.0}", fi.p99_ms),
            format!("{:.0}", fm.p50_ms),
            format!("{:.0}", fm.p99_ms),
        ]);
    }
    t.print();
    let verdict = if mpc.p99_ms < ow.p99_ms && mpc.p99_ms < ib.p99_ms {
        "MPC beats both baselines on aggregate P99"
    } else if mpc.p99_ms < ow.p99_ms {
        "MPC beats openwhisk on aggregate P99"
    } else {
        "MPC does not beat the baselines here (inspect the table)"
    };
    println!(
        "\naggregate P99: mpc {:.0} ms vs openwhisk {:.0} ms vs icebreaker {:.0} ms — {}",
        mpc.p99_ms, ow.p99_ms, ib.p99_ms, verdict
    );
    0
}

fn elasticity_sweep(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "elasticity-sweep",
        "drain -> rejoin scenario swept across migration policies; per-node rejoin evidence",
    )
    .flag("policy", "mpc", "openwhisk | icebreaker | mpc (migration actuates under mpc)")
    .flag("trace", "synthetic", "azure | synthetic")
    .flag("duration-s", "3600", "experiment duration (seconds)")
    .flag("seed", "42", "rng seed")
    .flag("nodes", "4", "invoker node count (>= 2: one of them drains)")
    .flag("placement", "warm-first", "round-robin | least-loaded | warm-first")
    .flag("functions", "4", "distinct functions sharing the fleet")
    .flag("fail-node", "1", "node that drains and later rejoins")
    .flag("fail-at-s", "600", "drain time (seconds)")
    .flag("restore-at-s", "1200", "rejoin time (seconds, after the drain)")
    .flag("migrations", "off,demand-gap,idle-spread", "comma-separated migration policies to sweep")
    .flag("migration-latency-s", "2", "warm-state transfer latency (seconds)");
    let a = parse_or_exit(&cli, rest);
    let policy = match Policy::parse(a.get("policy")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy '{}'", a.get("policy"));
            return 2;
        }
    };
    let trace = match TraceKind::parse(a.get("trace")) {
        Some(t) => t,
        None => {
            eprintln!("unknown trace '{}'", a.get("trace"));
            return 2;
        }
    };
    let placement = match PlacementPolicy::parse(a.get("placement")) {
        Some(p) => p,
        None => {
            eprintln!("unknown placement '{}'", a.get("placement"));
            return 2;
        }
    };
    let migrations: Vec<MigrationPolicy> = {
        let mut v = Vec::new();
        for tok in a.get("migrations").split(',') {
            match MigrationPolicy::parse(tok.trim()) {
                Some(m) => v.push(m),
                None => {
                    eprintln!("unknown migration policy '{tok}' in --migrations");
                    return 2;
                }
            }
        }
        v
    };
    let nodes = a.get_u64("nodes").unwrap_or(4) as u32;
    let fail_node = a.get_u64("fail-node").unwrap_or(1) as u32;
    let fail_at_s = a.get_f64("fail-at-s").unwrap_or(600.0);
    let restore_at_s = a.get_f64("restore-at-s").unwrap_or(1200.0);
    let duration_s = a.get_f64("duration-s").unwrap_or(3600.0);
    if nodes < 2 || fail_node >= nodes {
        eprintln!("need --nodes >= 2 and --fail-node < --nodes (the fleet must keep serving)");
        return 2;
    }
    if !(fail_at_s < restore_at_s && restore_at_s < duration_s) {
        eprintln!("need fail-at-s < restore-at-s < duration-s, got {fail_at_s} / {restore_at_s} / {duration_s}");
        return 2;
    }
    let params = ElasticityParams {
        trace,
        duration_s,
        seed: a.get_u64("seed").unwrap_or(42),
        nodes,
        functions: a.get_u64("functions").unwrap_or(4).max(1) as u32,
        placement,
        fail_node,
        fail_at_s,
        restore_at_s,
        migration_latency_s: a.get_f64("migration-latency-s").unwrap_or(2.0).max(0.001),
    };
    println!(
        "elasticity-sweep: policy={} trace={} nodes={} drain node {} @ {:.0}s, rejoin @ {:.0}s",
        policy.name(),
        trace.name(),
        nodes,
        fail_node,
        fail_at_s,
        restore_at_s
    );
    let cells = elasticity::run_sweep(&params, &[policy], &migrations);
    elasticity::print_table(&cells, fail_node);
    println!(
        "\nrejoin columns = the drained node's post-restore activity (nonzero = it reabsorbed load);"
    );
    println!("migration policies actuate from the MPC control loop (off under reactive policies).");
    0
}

/// Parse the shared retention flags (`--keepalive-*`). Adaptive
/// retention actuates from the MPC control loop (the planner consumes
/// the controller's forecasts), so — mirroring `--migration` — it must
/// be an error under a reactive policy, not a silent fixed-window run
/// masquerading as an adaptive measurement.
fn parse_keepalive_flags(a: &Args, policy: Policy) -> Result<KeepAliveConfig, String> {
    let ka_policy = KeepAlivePolicy::parse(a.get("keepalive-policy")).ok_or_else(|| {
        format!(
            "unknown keep-alive policy '{}' (expected fixed | adaptive)",
            a.get("keepalive-policy")
        )
    })?;
    if ka_policy == KeepAlivePolicy::Adaptive && policy != Policy::Mpc {
        return Err(format!(
            "--keepalive-policy adaptive only actuates under --policy mpc (the retention planner consumes the controller's forecasts); use --keepalive-policy fixed with --policy {}",
            policy.name()
        ));
    }
    let (min_s, idle_cost, cold_weight, pressure) = parse_keepalive_knobs(a)?;
    Ok(KeepAliveConfig {
        policy: ka_policy,
        min: secs(min_s),
        idle_cost_per_s: idle_cost,
        cold_cost_weight: cold_weight,
        pressure_weight: pressure,
    })
}

/// Validate the four shared `--keepalive-*` numeric knobs — one rule
/// set for every subcommand that carries them (the floor strictly
/// positive, costs/weights finite and non-negative). Returns
/// `(min_s, idle_cost, cold_weight, pressure)`.
fn parse_keepalive_knobs(a: &Args) -> Result<(f64, f64, f64, f64), String> {
    let min_s = match a.get_f64("keepalive-min-s") {
        Ok(s) if s > 0.0 && s.is_finite() => s,
        _ => return Err("--keepalive-min-s must be a positive number".into()),
    };
    let idle_cost = match a.get_f64("keepalive-idle-cost") {
        Ok(c) if c >= 0.0 && c.is_finite() => c,
        _ => return Err("--keepalive-idle-cost must be a non-negative number".into()),
    };
    let cold_weight = match a.get_f64("keepalive-cold-weight") {
        Ok(w) if w >= 0.0 && w.is_finite() => w,
        _ => return Err("--keepalive-cold-weight must be a non-negative number".into()),
    };
    let pressure = match a.get_f64("keepalive-pressure") {
        Ok(w) if w >= 0.0 && w.is_finite() => w,
        _ => return Err("--keepalive-pressure must be a non-negative number".into()),
    };
    Ok((min_s, idle_cost, cold_weight, pressure))
}

/// Validate the three `--survival-*` estimator knobs. Unlike
/// `--keepalive-policy adaptive` or `--forecast`, these carry no policy
/// gate: they are structurally inert under every policy but `survival`
/// (the differential tests pin byte-identity with the knobs set), so a
/// knobs-without-policy run is harmless rather than misleading.
fn parse_survival_knobs(a: &Args) -> Result<SurvivalConfig, String> {
    let window = match a.get_u64("survival-window") {
        Ok(n) if n >= 1 => n as usize,
        _ => return Err("--survival-window must be a positive integer (gaps)".into()),
    };
    let threshold = match a.get_f64("survival-threshold") {
        Ok(t) if t >= 0.0 && t.is_finite() => t,
        _ => return Err("--survival-threshold must be a finite non-negative number".into()),
    };
    let min_samples = match a.get_u64("survival-min-samples") {
        Ok(n) if n >= 1 => n as usize,
        _ => return Err("--survival-min-samples must be a positive integer (gaps)".into()),
    };
    Ok(SurvivalConfig {
        window,
        threshold,
        min_samples,
    })
}

/// Parse the `--forecast*` model-zoo flags. A non-default backend routes
/// the MPC's demand forecasts through the zoo, so — mirroring
/// `--migration` and `--keepalive-policy` — it must be an error under a
/// reactive policy, not a silent fourier run masquerading as a zoo
/// measurement.
fn parse_forecast_flags(a: &Args, policy: Policy) -> Result<ForecastConfig, String> {
    let backend = ForecastBackend::parse(a.get("forecast")).ok_or_else(|| {
        format!(
            "unknown --forecast '{}' (expected fourier | arima | histogram | attn | auto)",
            a.get("forecast")
        )
    })?;
    if backend != ForecastBackend::Fourier && policy != Policy::Mpc {
        return Err(format!(
            "--forecast {} only actuates under --policy mpc (the model zoo serves the controller's forecasts); use --forecast fourier with --policy {}",
            backend.name(),
            policy.name()
        ));
    }
    let score_window = match a.get_u64("forecast-window") {
        Ok(n) if n >= 1 => n as usize,
        _ => return Err("--forecast-window must be a positive integer (bins)".into()),
    };
    let hysteresis = match a.get_f64("forecast-hysteresis") {
        Ok(h) if (0.0..=1.0).contains(&h) => h,
        _ => return Err("--forecast-hysteresis must be within [0, 1]".into()),
    };
    let warmup_bins = match a.get_u64("forecast-warmup") {
        Ok(n) => n as usize,
        _ => return Err("--forecast-warmup must be a non-negative integer (bins)".into()),
    };
    Ok(ForecastConfig {
        backend,
        score_window,
        hysteresis,
        warmup_bins,
    })
}

/// Parse the `--chaos-*` knob flags into a chaos config around the
/// already-parsed `mode`. The knobs are validated even with chaos off,
/// so a typo never rides silently into a later `--chaos faults` run.
fn parse_chaos_knobs(a: &Args, mode: ChaosMode) -> Result<ChaosConfig, String> {
    let prob = |flag: &str| -> Result<f64, String> {
        match a.get_f64(flag) {
            Ok(p) if (0.0..=1.0).contains(&p) => Ok(p),
            _ => Err(format!("--{flag} must be a probability within [0, 1]")),
        }
    };
    let spawn_fail_p = prob("chaos-spawn-fail-p")?;
    let exec_fail_p = prob("chaos-exec-fail-p")?;
    let straggler_p = prob("chaos-straggler-p")?;
    let straggler_factor = match a.get_f64("chaos-straggler-factor") {
        Ok(f) if f >= 1.0 && f.is_finite() => f,
        _ => return Err("--chaos-straggler-factor must be a finite number >= 1".into()),
    };
    let max_retries = match a.get_u64("chaos-max-retries") {
        Ok(n) if n <= 64 => n as u32,
        _ => return Err("--chaos-max-retries must be an integer within [0, 64]".into()),
    };
    let retry_backoff = match a.get_f64("chaos-retry-backoff-s") {
        Ok(s) if s > 0.0 && s.is_finite() => secs(s),
        _ => return Err("--chaos-retry-backoff-s must be a positive number".into()),
    };
    let timeout_factor = match a.get_f64("chaos-timeout-factor") {
        Ok(f) if f >= 1.0 && f.is_finite() => f,
        _ => return Err("--chaos-timeout-factor must be a finite number >= 1".into()),
    };
    Ok(ChaosConfig {
        mode,
        spawn_fail_p,
        exec_fail_p,
        straggler_p,
        straggler_factor,
        max_retries,
        retry_backoff,
        timeout_factor,
    })
}

/// Parse `--chaos <mode>` plus the shared knob flags (simulate's form).
fn parse_chaos_flags(a: &Args) -> Result<ChaosConfig, String> {
    let mode = ChaosMode::parse(a.get("chaos")).ok_or_else(|| {
        format!(
            "unknown --chaos '{}' (expected off | faults | failure-storm | rolling-restart | flash-crowd)",
            a.get("chaos")
        )
    })?;
    parse_chaos_knobs(a, mode)
}

/// Register the shared `--chaos-*` knob flags on a chaos subcommand.
fn chaos_knob_flags(cli: Cli) -> Cli {
    cli.flag("chaos-spawn-fail-p", "0.05", "probability a request-bound container spawn fails")
        .flag("chaos-exec-fail-p", "0.05", "probability a completed execution still fails and retries")
        .flag("chaos-straggler-p", "0.02", "probability an execution straggles (duration stretches)")
        .flag("chaos-straggler-factor", "12", "duration multiplier for straggling executions")
        .flag("chaos-max-retries", "3", "retry budget per request across all fault kinds")
        .flag("chaos-retry-backoff-s", "1", "base retry backoff; attempt n waits backoff x 2^(n-1)")
        .flag("chaos-timeout-factor", "8", "per-function execution timeout as a multiple of L_warm")
}

fn scenario(rest: &[String]) -> i32 {
    let cli = chaos_knob_flags(
        common_cli("scenario", "one chaos preset under one policy; run report + chaos telemetry")
            .flag("preset", "failure-storm", "failure-storm | rolling-restart | flash-crowd | faults")
            .flag("nodes", "4", "invoker node count")
            .flag("functions", "8", "distinct functions sharing the fleet"),
    );
    let a = parse_or_exit(&cli, rest);
    let policy = match Policy::parse(a.get("policy")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy '{}'", a.get("policy"));
            return 2;
        }
    };
    let mode = match ChaosMode::parse(a.get("preset")) {
        Some(m) if m != ChaosMode::Off => m,
        _ => {
            eprintln!(
                "unknown --preset '{}' (expected failure-storm | rolling-restart | flash-crowd | faults)",
                a.get("preset")
            );
            return 2;
        }
    };
    let params = match scenario_params(&a, mode) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!(
        "scenario: preset={} policy={} trace={} nodes={} functions={} duration={:.0}s",
        mode.name(),
        policy.name(),
        params.trace.name(),
        params.nodes,
        params.functions,
        params.duration_s
    );
    let cell = chaos_exp::run_cell(&params, mode, policy);
    chaos_exp::print_report(&cell);
    0
}

fn chaos_sweep(rest: &[String]) -> i32 {
    let cli = chaos_knob_flags(
        Cli::new(
            "chaos-sweep",
            "every chaos preset x every policy on one workload; retry/timeout/drop telemetry",
        )
        .flag("trace", "synthetic", "azure | synthetic")
        .flag("duration-s", "3600", "experiment duration (seconds)")
        .flag("seed", "42", "rng seed")
        .flag("nodes", "4", "invoker node count")
        .flag("functions", "8", "distinct functions sharing the fleet"),
    );
    let a = parse_or_exit(&cli, rest);
    let params = match scenario_params(&a, ChaosMode::Faults) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!(
        "chaos-sweep: trace={} nodes={} functions={} duration={:.0}s",
        params.trace.name(),
        params.nodes,
        params.functions,
        params.duration_s
    );
    let cells = chaos_exp::run_sweep(&params, &ChaosMode::PRESETS, &Policy::ALL);
    chaos_exp::print_table(&cells);
    println!("\nretries/timeouts/spawn-fails = chaos counters (structurally zero with --chaos off);");
    println!("dropped = requests whose retry budget was exhausted mid-storm.");
    0
}

fn forecast_sweep_cmd(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "forecast-sweep",
        "every forecast backend x {bursty, azure, diurnal}: rolling accuracy + the MPC run it drives",
    )
    .flag("duration-s", "14400", "trace duration per cell (seconds)")
    .flag("seed", "42", "rng seed")
    .flag("window", "120", "forecast history window per evaluation (30 s bins)")
    .flag("horizon", "24", "forecast horizon scored per evaluation (30 s bins)");
    let a = parse_or_exit(&cli, rest);
    let duration_s = match a.get_f64("duration-s") {
        Ok(d) if d > 0.0 && d.is_finite() => d,
        _ => {
            eprintln!("--duration-s must be a positive number");
            return 2;
        }
    };
    let window = match a.get_u64("window") {
        Ok(n) if n >= 2 => n as usize,
        _ => {
            eprintln!("--window must be an integer >= 2 (bins)");
            return 2;
        }
    };
    let horizon = match a.get_u64("horizon") {
        Ok(n) if n >= 1 => n as usize,
        _ => {
            eprintln!("--horizon must be a positive integer (bins)");
            return 2;
        }
    };
    // the rolling protocol needs at least one full window + horizon of
    // 30 s bins, or every cell would report zero evaluations
    let need_s = (window + horizon) as f64 * 30.0;
    if duration_s < need_s {
        eprintln!(
            "--duration-s {duration_s:.0} too short: window {window} + horizon {horizon} bins need >= {need_s:.0} s"
        );
        return 2;
    }
    let p = SweepParams {
        duration_s,
        seed: a.get_u64("seed").unwrap_or(42),
        window,
        horizon,
    };
    println!(
        "forecast-sweep: traces=bursty,azure,diurnal backends=fourier,arima,histogram,attn,auto duration={duration_s:.0}s seed={} window={window} horizon={horizon}",
        p.seed
    );
    let cells = forecast_sweep::run_sweep(&p);
    forecast_sweep::print_table(&cells);
    println!(
        "\nacc %/wape = Fig. 4 rolling-horizon scores on the trace's 30 s bins; p99/cold = the MPC run"
    );
    println!("routed through the backend; switches/model = the auto selector's telemetry (zero when fixed).");
    0
}

/// Parse the flags shared by `scenario` and `chaos-sweep` into params
/// (the chaos mode inside is a placeholder — each cell overrides it).
fn scenario_params(a: &Args, mode: ChaosMode) -> Result<ScenarioParams, String> {
    let trace = TraceKind::parse(a.get("trace"))
        .ok_or_else(|| format!("unknown trace '{}'", a.get("trace")))?;
    let nodes = match a.get_u64("nodes") {
        Ok(n) if n >= 1 => n as u32,
        _ => return Err("--nodes must be at least 1".into()),
    };
    let functions = match a.get_u64("functions") {
        Ok(n) if n >= 1 => n as u32,
        _ => return Err("--functions must be a positive integer".into()),
    };
    let duration_s = match a.get_f64("duration-s") {
        Ok(d) if d > 0.0 && d.is_finite() => d,
        _ => return Err("--duration-s must be a positive number".into()),
    };
    Ok(ScenarioParams {
        trace,
        duration_s,
        seed: a.get_u64("seed").map_err(|e| e.to_string())?,
        nodes,
        functions,
        chaos: parse_chaos_knobs(a, mode)?,
    })
}

/// Parse the `--image-*` flags into a cache config. The numeric knobs
/// are validated even with the cache off, so a typo never rides
/// silently into a later `--image-cache lru` run.
fn parse_image_flags(a: &Args) -> Result<ImageCacheConfig, String> {
    let mode = ImageCacheMode::parse(a.get("image-cache")).ok_or_else(|| {
        format!(
            "unknown --image-cache '{}' (expected off | lru)",
            a.get("image-cache")
        )
    })?;
    let capacity_mib = match a.get_u64("image-cache-mib") {
        Ok(m) if m >= 1 && m <= u32::MAX as u64 => m as u32,
        _ => return Err("--image-cache-mib must be a positive integer (MiB)".into()),
    };
    let (bandwidth_mibps, init_fraction) = parse_image_knobs(a)?;
    Ok(ImageCacheConfig {
        mode,
        capacity_mib,
        bandwidth_mibps,
        init_fraction,
    })
}

/// Validate the two shared `--image-*` cost knobs (pull bandwidth
/// strictly positive, init fraction inside [0, 1]). Returns
/// `(bandwidth_mibps, init_fraction)`.
fn parse_image_knobs(a: &Args) -> Result<(f64, f64), String> {
    let bandwidth_mibps = match a.get_f64("image-bandwidth-mibps") {
        Ok(b) if b > 0.0 && b.is_finite() => b,
        _ => return Err("--image-bandwidth-mibps must be a positive number".into()),
    };
    let init_fraction = match a.get_f64("image-init-frac") {
        Ok(f) if (0.0..=1.0).contains(&f) => f,
        _ => return Err("--image-init-frac must be within [0, 1]".into()),
    };
    Ok((bandwidth_mibps, init_fraction))
}

fn keepalive_sweep(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "keepalive-sweep",
        "fixed vs adaptive retention (MPC) across bursty/Zipf scenarios; resource-time vs P99 frontier",
    )
    .flag("duration-s", "3600", "experiment duration (seconds)")
    .flag("seed", "42", "rng seed")
    .flag("nodes", "1", "invoker node count")
    .flag("functions", "8", "functions in the multi-tenant scenarios")
    .flag("skew", "zipf:1.1", "function popularity: zipf:<s> | uniform")
    .flag("keepalive-min-s", "30", "adaptive retention horizon floor (seconds)")
    .flag("keepalive-idle-cost", "1", "idle cost rate in the retention break-even (per container-second)")
    .flag("keepalive-cold-weight", "16", "cold-start cost weight (x L_cold) in the retention break-even")
    .flag("keepalive-pressure", "0", "memory-pressure shrink weight on adaptive horizons (0 = off)");
    let a = parse_or_exit(&cli, rest);
    let nodes = match a.get_u64("nodes") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--nodes must be at least 1");
            return 2;
        }
    };
    let functions = match a.get_u64("functions") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--functions must be a positive integer");
            return 2;
        }
    };
    let zipf_s = match parse_skew(a.get("skew")) {
        Some(s) => s,
        None => {
            eprintln!(
                "bad --skew '{}' (expected zipf:<s> with 0 <= s <= 64, or uniform)",
                a.get("skew")
            );
            return 2;
        }
    };
    let (min_s, idle_cost, cold_weight, pressure) = match parse_keepalive_knobs(&a) {
        Ok(knobs) => knobs,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let params = KeepAliveParams {
        duration_s: a.get_f64("duration-s").unwrap_or(3600.0),
        seed: a.get_u64("seed").unwrap_or(42),
        nodes,
        zipf_s,
        min_s,
        idle_cost,
        cold_weight,
        pressure,
    };
    // the acceptance scenarios, with the multi-tenant cells at the
    // requested function count
    let scenarios = [
        keepalive::DEFAULT_SCENARIOS[0],
        keepalive::KeepAliveScenario {
            functions,
            ..keepalive::DEFAULT_SCENARIOS[1]
        },
        keepalive::KeepAliveScenario {
            functions,
            ..keepalive::DEFAULT_SCENARIOS[2]
        },
    ];
    println!(
        "keepalive-sweep: policy=mpc nodes={} functions={} skew={} min={}s idle-cost={} cold-weight={} pressure={}",
        nodes,
        functions,
        a.get("skew"),
        min_s,
        idle_cost,
        cold_weight,
        pressure
    );
    let cells = keepalive::run_sweep(&params, &scenarios);
    keepalive::print_table(&cells);
    println!(
        "\nidle/keep-alive s = resource-time the retention policy controls; saved s + early exp = adaptive's"
    );
    println!("earlier-than-profile expiries; the frontier lines above judge each scenario.");
    0
}

fn survival_frontier(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "survival-frontier",
        "mpc vs survival vs icebreaker across bursty/azure/zipf scenarios; three-way resource-time vs P99 frontier",
    )
    .flag("duration-s", "3600", "experiment duration (seconds)")
    .flag("seed", "42", "rng seed")
    .flag("nodes", "1", "invoker node count")
    .flag("functions", "8", "functions in the multi-tenant scenarios")
    .flag("skew", "zipf:1.1", "function popularity: zipf:<s> | uniform")
    .flag("survival-window", "64", "survival estimator: trailing inter-arrival gaps kept per function")
    .flag("survival-threshold", "0.5", "release below this reuse probability over the break-even window")
    .flag("survival-min-samples", "8", "gaps required before survival overrides the profile keep-alive");
    let a = parse_or_exit(&cli, rest);
    let nodes = match a.get_u64("nodes") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--nodes must be at least 1");
            return 2;
        }
    };
    let functions = match a.get_u64("functions") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--functions must be a positive integer");
            return 2;
        }
    };
    let zipf_s = match parse_skew(a.get("skew")) {
        Some(s) => s,
        None => {
            eprintln!(
                "bad --skew '{}' (expected zipf:<s> with 0 <= s <= 64, or uniform)",
                a.get("skew")
            );
            return 2;
        }
    };
    let survival = match parse_survival_knobs(&a) {
        Ok(sv) => sv,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let params = SurvivalParams {
        duration_s: a.get_f64("duration-s").unwrap_or(3600.0),
        seed: a.get_u64("seed").unwrap_or(42),
        nodes,
        zipf_s,
        survival,
    };
    // the shared acceptance scenarios, with the multi-tenant cells at the
    // requested function count (mirrors keepalive-sweep)
    let scenarios = [
        survival_exp::SCENARIOS[0],
        keepalive::KeepAliveScenario {
            functions,
            ..survival_exp::SCENARIOS[1]
        },
        keepalive::KeepAliveScenario {
            functions,
            ..survival_exp::SCENARIOS[2]
        },
    ];
    println!(
        "survival-frontier: policies=mpc,survival,icebreaker nodes={} functions={} skew={} window={} threshold={} min-samples={}",
        nodes,
        functions,
        a.get("skew"),
        survival.window,
        survival.threshold,
        survival.min_samples
    );
    let cells = survival_exp::run_sweep(&params, &scenarios);
    survival_exp::print_table(&cells);
    println!(
        "\nsurvival rows: releases = containers expired early by the survival rule, retained = full-window"
    );
    println!(
        "decisions, mean p = mean at-age-zero reuse probability; the survival-vs-mpc gap is the value of"
    );
    println!("fleet-level planning, the survival-vs-icebreaker gap the value of conditional retention.");
    0
}

fn cache_sweep(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "cache-sweep",
        "image-cache capacity ladder vs the constant-L_cold baseline (MPC); pull-byte + hit-rate telemetry",
    )
    .flag("trace", "synthetic", "azure | synthetic")
    .flag("duration-s", "3600", "experiment duration (seconds)")
    .flag("seed", "42", "rng seed")
    .flag("nodes", "4", "invoker node count")
    .flag("functions", "8", "distinct functions sharing the fleet")
    .flag("skew", "zipf:1.1", "function popularity: zipf:<s> | uniform")
    .flag("capacities-mib", "256,512,1024,2048,4096", "comma-separated per-node cache capacities (MiB), one LRU cell each")
    .flag("image-bandwidth-mibps", "100", "registry pull bandwidth (MiB/s) for missing layers")
    .flag("image-init-frac", "0.25", "fraction of L_cold that is runtime init (the rest scales with pulled bytes)");
    let a = parse_or_exit(&cli, rest);
    let trace = match TraceKind::parse(a.get("trace")) {
        Some(t) => t,
        None => {
            eprintln!("unknown trace '{}'", a.get("trace"));
            return 2;
        }
    };
    let nodes = match a.get_u64("nodes") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--nodes must be at least 1");
            return 2;
        }
    };
    let functions = match a.get_u64("functions") {
        Ok(n) if n >= 1 => n as u32,
        _ => {
            eprintln!("--functions must be a positive integer");
            return 2;
        }
    };
    let zipf_s = match parse_skew(a.get("skew")) {
        Some(s) => s,
        None => {
            eprintln!(
                "bad --skew '{}' (expected zipf:<s> with 0 <= s <= 64, or uniform)",
                a.get("skew")
            );
            return 2;
        }
    };
    let capacities_mib: Vec<u32> = {
        let mut v = Vec::new();
        for tok in a.get("capacities-mib").split(',') {
            match tok.trim().parse::<u32>() {
                Ok(m) if m >= 1 => v.push(m),
                _ => {
                    eprintln!("bad entry '{tok}' in --capacities-mib (positive integers, MiB)");
                    return 2;
                }
            }
        }
        v
    };
    let (bandwidth_mibps, init_fraction) = match parse_image_knobs(&a) {
        Ok(knobs) => knobs,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let params = CacheParams {
        duration_s: a.get_f64("duration-s").unwrap_or(3600.0),
        seed: a.get_u64("seed").unwrap_or(42),
        nodes,
        functions,
        zipf_s,
        trace,
        bandwidth_mibps,
        init_fraction,
        capacities_mib,
    };
    println!(
        "cache-sweep: policy=mpc trace={} nodes={} functions={} skew={} bandwidth={bandwidth_mibps} MiB/s init-frac={init_fraction}",
        trace.name(),
        nodes,
        functions,
        a.get("skew"),
    );
    let cells = cache::run_sweep(&params);
    cache::print_table(&cells);
    println!(
        "\noff = the constant-L_cold baseline (regression-pinned); each LRU rung replans against the"
    );
    println!("dynamic per-node L_cold(f, n) the cache induces — the frontier line above judges the ladder.");
    0
}

fn bench_throughput(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "bench-throughput",
        "sweep nodes x functions x load; report simulator events/sec and wall-clock",
    )
    .flag("policy", "mpc", "openwhisk | icebreaker | mpc")
    .flag("trace", "synthetic", "azure | synthetic")
    .flag("duration-s", "600", "simulated duration per cell (seconds)")
    .flag("seed", "42", "rng seed")
    .flag("placement", "warm-first", "round-robin | least-loaded | warm-first")
    .flag("nodes-list", "1,2,4,8", "comma-separated node counts (each node adds full capacity)")
    .flag("threads-list", "1", "comma-separated event-loop worker-thread counts (scaling axis)")
    .flag("functions-list", "1,8,32", "comma-separated function counts")
    .flag("load-list", "1,4", "comma-separated load multipliers (superimposed base traces)")
    .flag("out", "", "also write the sweep as a BENCH JSON file (e.g. BENCH_throughput.json)");
    let a = parse_or_exit(&cli, rest);
    let policy = match Policy::parse(a.get("policy")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy '{}'", a.get("policy"));
            return 2;
        }
    };
    let trace_kind = match TraceKind::parse(a.get("trace")) {
        Some(t) => t,
        None => {
            eprintln!("unknown trace '{}'", a.get("trace"));
            return 2;
        }
    };
    let placement = match PlacementPolicy::parse(a.get("placement")) {
        Some(p) => p,
        None => {
            eprintln!("unknown placement '{}'", a.get("placement"));
            return 2;
        }
    };
    let parse_list = |flag: &str| -> Result<Vec<u32>, String> {
        let mut v = Vec::new();
        for tok in a.get(flag).split(',') {
            match tok.trim().parse::<u32>() {
                Ok(n) if n >= 1 => v.push(n),
                _ => return Err(format!("bad entry '{tok}' in --{flag} (positive integers)")),
            }
        }
        Ok(v)
    };
    let (nodes_list, threads_list, functions_list, load_list) = match (
        parse_list("nodes-list"),
        parse_list("threads-list"),
        parse_list("functions-list"),
        parse_list("load-list"),
    ) {
        (Ok(n), Ok(t), Ok(f), Ok(l)) => (n, t, f, l),
        (n, t, f, l) => {
            for e in [n.err(), t.err(), f.err(), l.err()].into_iter().flatten() {
                eprintln!("{e}");
            }
            return 2;
        }
    };
    let duration_s = a.get_f64("duration-s").unwrap_or(600.0);
    let seed = a.get_u64("seed").unwrap_or(42);
    println!(
        "bench-throughput: policy={} trace={} duration={duration_s:.0}s placement={}",
        policy.name(),
        trace_kind.name(),
        placement.name()
    );
    let sweep = mpc_serverless::experiments::throughput::run_sweep(
        policy,
        trace_kind,
        duration_s,
        seed,
        &nodes_list,
        &threads_list,
        &functions_list,
        &load_list,
        placement,
    );
    sweep.print_table();
    let json = sweep.to_json();
    let out = a.get("out");
    if out.is_empty() {
        println!("{json}");
    } else if let Err(e) = std::fs::write(out, format!("{json}\n")) {
        eprintln!("writing {out}: {e}");
        return 2;
    } else {
        println!("wrote {out}");
    }
    0
}

fn matrix(rest: &[String]) -> i32 {
    let cli = Cli::new("matrix", "full policy x trace matrix (Figs. 5-7), cells in parallel up to the core count")
        .flag("duration-s", "3600", "experiment duration (seconds)")
        .flag("seed", "42", "rng seed")
        .flag("nodes", "1", "invoker node count")
        .flag("placement", "warm-first", "round-robin | least-loaded | warm-first");
    let a = parse_or_exit(&cli, rest);
    let d = a.get_f64("duration-s").unwrap_or(3600.0);
    let seed = a.get_u64("seed").unwrap_or(42);
    let fleet = match fleet_from_args(&a) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let kinds = [TraceKind::AzureLike, TraceKind::SyntheticBursty];
    for m in fig5_7::run_matrix_all(&kinds, d, seed, &fleet) {
        for r in [&m.openwhisk, &m.icebreaker, &m.mpc] {
            println!("{}", r.to_json());
        }
    }
    0
}

fn fleet_sweep(rest: &[String]) -> i32 {
    let cli = common_cli("fleet-sweep", "sweep node count x placement at fixed total capacity")
        .flag("nodes-list", "1,2,4,8", "comma-separated node counts")
        .flag("placements", "round-robin,least-loaded,warm-first", "comma-separated placement policies")
        .flag("total-cap", "64", "total replica capacity split evenly across nodes");
    let a = parse_or_exit(&cli, rest);
    let policy = match Policy::parse(a.get("policy")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy '{}'", a.get("policy"));
            return 2;
        }
    };
    let trace_kind = match TraceKind::parse(a.get("trace")) {
        Some(t) => t,
        None => {
            eprintln!("unknown trace '{}'", a.get("trace"));
            return 2;
        }
    };
    let node_counts: Vec<u32> = {
        let mut v = Vec::new();
        for tok in a.get("nodes-list").split(',') {
            match tok.trim().parse::<u32>() {
                Ok(n) if n >= 1 => v.push(n),
                _ => {
                    eprintln!("bad node count '{tok}' in --nodes-list");
                    return 2;
                }
            }
        }
        v
    };
    let placements: Vec<PlacementPolicy> = {
        let mut v = Vec::new();
        for tok in a.get("placements").split(',') {
            match PlacementPolicy::parse(tok.trim()) {
                Some(p) => v.push(p),
                None => {
                    eprintln!("unknown placement '{tok}' in --placements");
                    return 2;
                }
            }
        }
        v
    };
    let total_cap = a.get_u64("total-cap").unwrap_or(64).max(1) as u32;
    let duration_s = a.get_f64("duration-s").unwrap_or(3600.0);
    let seed = a.get_u64("seed").unwrap_or(42);

    // one trace shared across every cell so the sweep isolates the fleet
    // shape; total capacity stays fixed so node count shows pure
    // fragmentation/placement effects, not extra hardware
    let trace = fig4::trace_for(trace_kind, secs(duration_s), seed);
    println!(
        "fleet-sweep: policy={} trace={} requests={} total-cap={}",
        policy.name(),
        trace_kind.name(),
        trace.len(),
        total_cap
    );
    let mut t = Table::new(&[
        "nodes", "placement", "p50 ms", "p99 ms", "cold %", "keep-alive s", "mean warm",
    ]);
    for &nodes in &node_counts {
        let capacities = match mpc_serverless::cluster::fleet::split_capacity(total_cap, nodes) {
            Some(c) => c,
            None => {
                eprintln!("--nodes-list entry {nodes} exceeds --total-cap {total_cap}; skipping");
                continue;
            }
        };
        for &placement in &placements {
            let cfg = ExperimentConfig {
                trace: trace_kind,
                fleet: FleetConfig {
                    nodes,
                    capacities: Some(capacities.clone()),
                    placement,
                    ..Default::default()
                },
                duration: secs(duration_s),
                seed,
                ..Default::default()
            };
            let r = run_experiment(&cfg, policy, &trace);
            let cold_pct = if r.completed > 0 {
                100.0 * r.cold_requests as f64 / r.completed as f64
            } else {
                0.0
            };
            t.row(&[
                nodes.to_string(),
                placement.name().to_string(),
                format!("{:.0}", r.p50_ms),
                format!("{:.0}", r.p99_ms),
                format!("{cold_pct:.1}"),
                format!("{:.0}", r.keepalive_total_s),
                format!("{:.1}", r.mean_warm),
            ]);
        }
    }
    t.print();
    0
}

fn forecast(rest: &[String]) -> i32 {
    let cli = Cli::new("forecast", "Fig. 4 forecast comparison")
        .flag("duration-s", "14400", "trace duration (seconds)")
        .flag("seed", "11", "rng seed");
    let a = parse_or_exit(&cli, rest);
    for e in fig4::run(a.get_f64("duration-s").unwrap_or(14400.0), a.get_u64("seed").unwrap_or(11)) {
        println!("{:<10} {:<9} accuracy {:>5.1}% wape {:.3} {:.3} ms/call",
                 e.trace, e.predictor, e.accuracy_pct, e.wape, e.mean_runtime_ms);
    }
    0
}

fn overhead() -> i32 {
    let r = fig8::run_rust(30);
    println!("rust-mirror: forecast {:.3} ms, optimizer {:.3} ms",
             r.forecast_ms.mean(), r.solve_ms.mean());
    0
}

fn gen_trace(rest: &[String]) -> i32 {
    let cli = Cli::new("gen-trace", "emit a workload trace as CSV")
        .flag("trace", "synthetic", "azure | synthetic")
        .flag("duration-s", "3600", "trace duration (seconds)")
        .flag("seed", "42", "rng seed");
    let a = parse_or_exit(&cli, rest);
    let kind = TraceKind::parse(a.get("trace")).unwrap_or(TraceKind::SyntheticBursty);
    let t = fig4::trace_for(kind, secs(a.get_f64("duration-s").unwrap_or(3600.0)),
                            a.get_u64("seed").unwrap_or(42));
    print!("{}", t.to_csv());
    0
}
