//! `mpc-serverless` CLI — leader entrypoint.
//!
//! Subcommands:
//!   simulate   run one policy on one trace, print the run report
//!   matrix     run the full Fig. 5-7 policy x trace matrix
//!   forecast   Fig. 4 forecast comparison
//!   overhead   Fig. 8 control overhead (rust mirror + HLO if available)
//!   fig1       the 50-request motivation scenario
//!   gen-trace  emit a workload trace as CSV to stdout

use mpc_serverless::config::{secs, ExperimentConfig, Policy, TraceKind};
use mpc_serverless::experiments::{fig1, fig4, fig5_7, fig8, run_experiment};
use mpc_serverless::util::cli::{Cli, CliError};

fn main() {
    mpc_serverless::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { vec![] } else { argv[1..].to_vec() };
    let code = match cmd {
        "simulate" => simulate(&rest),
        "matrix" => matrix(&rest),
        "forecast" => forecast(&rest),
        "overhead" => overhead(),
        "fig1" => {
            let r = fig1::run(42);
            println!("cold starts: {} | warm mean {:.3} s | cold mean {:.2} s",
                     r.cold_starts, r.warm_exec_mean_s, r.cold_response_mean_s);
            0
        }
        "gen-trace" => gen_trace(&rest),
        _ => {
            eprintln!("mpc-serverless {}\n\nUSAGE: mpc-serverless <simulate|matrix|forecast|overhead|fig1|gen-trace> [flags]\nRun a subcommand with --help for flags.",
                      mpc_serverless::version());
            if cmd == "help" { 0 } else { 2 }
        }
    };
    std::process::exit(code);
}

fn common_cli(name: &str, about: &str) -> Cli {
    Cli::new(name, about)
        .flag("policy", "mpc", "openwhisk | icebreaker | mpc")
        .flag("trace", "synthetic", "azure | synthetic")
        .flag("duration-s", "3600", "experiment duration (seconds)")
        .flag("seed", "42", "rng seed")
}

fn parse_or_exit(cli: &Cli, rest: &[String]) -> mpc_serverless::util::cli::Args {
    match cli.parse(rest) {
        Ok(a) => a,
        Err(CliError::Help) => {
            println!("{}", cli.usage());
            std::process::exit(0);
        }
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            std::process::exit(2);
        }
    }
}

fn simulate(rest: &[String]) -> i32 {
    let cli = common_cli("simulate", "run one policy on one workload");
    let a = parse_or_exit(&cli, rest);
    let policy = match Policy::parse(a.get("policy")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy '{}'", a.get("policy"));
            return 2;
        }
    };
    let trace_kind = match TraceKind::parse(a.get("trace")) {
        Some(t) => t,
        None => {
            eprintln!("unknown trace '{}'", a.get("trace"));
            return 2;
        }
    };
    let cfg = ExperimentConfig {
        trace: trace_kind,
        duration: secs(a.get_f64("duration-s").unwrap_or(3600.0)),
        seed: a.get_u64("seed").unwrap_or(42),
        ..Default::default()
    };
    let trace = fig4::trace_for(trace_kind, cfg.duration, cfg.seed);
    let r = run_experiment(&cfg, policy, &trace);
    println!("{}", r.to_json());
    0
}

fn matrix(rest: &[String]) -> i32 {
    let cli = Cli::new("matrix", "full policy x trace matrix (Figs. 5-7)")
        .flag("duration-s", "3600", "experiment duration (seconds)")
        .flag("seed", "42", "rng seed");
    let a = parse_or_exit(&cli, rest);
    let d = a.get_f64("duration-s").unwrap_or(3600.0);
    let seed = a.get_u64("seed").unwrap_or(42);
    for kind in [TraceKind::AzureLike, TraceKind::SyntheticBursty] {
        let m = fig5_7::run_matrix(kind, d, seed);
        for r in [&m.openwhisk, &m.icebreaker, &m.mpc] {
            println!("{}", r.to_json());
        }
    }
    0
}

fn forecast(rest: &[String]) -> i32 {
    let cli = Cli::new("forecast", "Fig. 4 forecast comparison")
        .flag("duration-s", "14400", "trace duration (seconds)")
        .flag("seed", "11", "rng seed");
    let a = parse_or_exit(&cli, rest);
    for e in fig4::run(a.get_f64("duration-s").unwrap_or(14400.0), a.get_u64("seed").unwrap_or(11)) {
        println!("{:<10} {:<9} accuracy {:>5.1}% wape {:.3} {:.3} ms/call",
                 e.trace, e.predictor, e.accuracy_pct, e.wape, e.mean_runtime_ms);
    }
    0
}

fn overhead() -> i32 {
    let r = fig8::run_rust(30);
    println!("rust-mirror: forecast {:.3} ms, optimizer {:.3} ms",
             r.forecast_ms.mean(), r.solve_ms.mean());
    0
}

fn gen_trace(rest: &[String]) -> i32 {
    let cli = Cli::new("gen-trace", "emit a workload trace as CSV")
        .flag("trace", "synthetic", "azure | synthetic")
        .flag("duration-s", "3600", "trace duration (seconds)")
        .flag("seed", "42", "rng seed");
    let a = parse_or_exit(&cli, rest);
    let kind = TraceKind::parse(a.get("trace")).unwrap_or(TraceKind::SyntheticBursty);
    let t = fig4::trace_for(kind, secs(a.get_f64("duration-s").unwrap_or(3600.0)),
                            a.get_u64("seed").unwrap_or(42));
    print!("{}", t.to_csv());
    0
}
