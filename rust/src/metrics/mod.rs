//! Experiment metrics: per-request records, container-usage samples,
//! keep-alive accounting, control overhead — everything the paper's
//! evaluation section (Figs. 1, 5-8) reports.

use crate::cluster::fleet::NodeReport;
use crate::cluster::telemetry::{Counters, GaugeSample};
use crate::cluster::RequestId;
use crate::config::{to_secs, Micros};
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::workload::tenant::FunctionId;

/// Per-request lifecycle timestamps.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestRecord {
    pub arrival: Micros,
    pub dispatched: Option<Micros>,
    pub completed: Option<Micros>,
    /// Whether this request's execution waited on a cold start.
    pub cold: bool,
    /// Function this request invokes (0 in single-tenant runs).
    pub func: FunctionId,
}

impl RequestRecord {
    /// End-to-end response time (queueing + cold start + execution).
    pub fn response_time(&self) -> Option<Micros> {
        self.completed.map(|c| c - self.arrival)
    }

    /// Shaping/queueing delay before dispatch.
    pub fn queue_delay(&self) -> Option<Micros> {
        self.dispatched.map(|d| d - self.arrival)
    }
}

/// Event sink driven by the experiment runner.
#[derive(Debug, Default)]
pub struct Recorder {
    requests: Vec<RequestRecord>,
    samples: Vec<GaugeSample>,
    pub forecast_ns: Vec<f64>,
    pub solve_ns: Vec<f64>,
    /// Per-function keep-alive horizon trajectory `(time, func,
    /// horizon)`, one sample per retention actuation (control step).
    /// Empty under the fixed policy.
    pub horizon_samples: Vec<(Micros, FunctionId, Micros)>,
}

impl Recorder {
    pub fn new(expected_requests: usize) -> Self {
        Recorder {
            requests: Vec::with_capacity(expected_requests),
            ..Default::default()
        }
    }

    pub fn on_arrival(&mut self, req: RequestId, t: Micros) {
        self.on_arrival_for(req, t, 0);
    }

    /// Record an arrival with its function (multi-tenant runs).
    pub fn on_arrival_for(&mut self, req: RequestId, t: Micros, func: FunctionId) {
        let idx = req as usize;
        if self.requests.len() <= idx {
            self.requests.resize(idx + 1, RequestRecord::default());
        }
        self.requests[idx].arrival = t;
        self.requests[idx].func = func;
    }

    /// Function of a recorded request (0 for unknown/single-tenant).
    pub fn func_of(&self, req: RequestId) -> FunctionId {
        self.requests
            .get(req as usize)
            .map(|r| r.func)
            .unwrap_or(0)
    }

    pub fn on_dispatch(&mut self, req: RequestId, t: Micros) {
        self.requests[req as usize].dispatched = Some(t);
    }

    pub fn on_cold(&mut self, req: RequestId) {
        self.requests[req as usize].cold = true;
    }

    pub fn on_complete(&mut self, req: RequestId, t: Micros) {
        self.requests[req as usize].completed = Some(t);
    }

    pub fn on_gauge(&mut self, s: GaugeSample) {
        self.samples.push(s);
    }

    pub fn on_control_overhead(&mut self, forecast_ns: f64, solve_ns: f64) {
        self.forecast_ns.push(forecast_ns);
        self.solve_ns.push(solve_ns);
    }

    /// Record one retention-planner horizon decision (adaptive
    /// keep-alive trajectory).
    pub fn on_keepalive_horizon(&mut self, t: Micros, func: FunctionId, horizon: Micros) {
        self.horizon_samples.push((t, func, horizon));
    }

    pub fn requests(&self) -> &[RequestRecord] {
        &self.requests
    }

    pub fn samples(&self) -> &[GaugeSample] {
        &self.samples
    }
}

/// Per-function latency breakdown of one run (the multi-tenant view of
/// the paper's response-time metrics; a single entry for function 0 in
/// single-tenant runs).
#[derive(Debug, Clone)]
pub struct FnReport {
    pub func: FunctionId,
    pub completed: usize,
    pub dropped: usize,
    /// Completed requests whose execution waited on a cold start.
    pub cold_requests: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Mean keep-alive horizon the retention planner held for this
    /// function (seconds; 0 under the fixed policy, which records no
    /// trajectory).
    pub mean_horizon_s: f64,
    /// Forecast model serving this function at the end of the run (set
    /// by the runner; the configured backend under a fixed backend, the
    /// selector's final pick under `auto`, `fourier` for directly-built
    /// reports).
    pub forecast_model: String,
    /// The selector's rolling forecast accuracy % for this function at
    /// the end of the run (0 under any fixed backend — no scoring loop
    /// runs).
    pub forecast_accuracy_pct: f64,
}

/// Aggregated results of one experiment run (one policy, one trace).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub policy: String,
    pub trace: String,
    pub duration_s: f64,
    /// Invoker-node count of the fleet this run used (1 = legacy shape).
    pub nodes: u32,
    /// Worker threads the event loop ran with (set by the runner; 1 =
    /// sequential seed path). Purely provenance: every simulated metric
    /// is bit-identical across thread counts by construction.
    pub threads: u32,
    /// Placement policy name (set by the runner; empty for unit tests
    /// that build reports directly).
    pub placement: String,
    pub completed: usize,
    pub dropped: usize,
    pub cold_requests: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub mean_queue_delay_ms: f64,
    /// Mean warm-container gauge over the 1-minute samples (Fig. 6).
    pub mean_warm: f64,
    pub warm_series: Vec<(Micros, u32)>,
    /// Total keep-alive duration in container-seconds (Fig. 7).
    pub keepalive_total_s: f64,
    /// Total idle (warm-unused) container-seconds.
    pub idle_total_s: f64,
    /// Retention policy of the run (`fixed` | `adaptive`; set by the
    /// runner, `fixed` for directly-built reports).
    pub keepalive_policy: String,
    /// Idle container-seconds saved by adaptive retention (expiries
    /// fired before the profile window would have; 0 under fixed).
    pub idle_saved_s: f64,
    /// Mean planned keep-alive horizon across all functions and control
    /// steps (seconds; 0 under the fixed policy).
    pub mean_horizon_s: f64,
    /// Containers released early by the slot-survival rule (set by the
    /// runner under `--policy survival`; structurally 0 elsewhere).
    pub survival_releases: u64,
    /// Survival decisions that kept the full profile window (0 under
    /// every other policy).
    pub survival_retained: u64,
    /// Mean at-age-zero reuse probability across survival decisions (0
    /// under every other policy).
    pub survival_mean_p: f64,
    /// Forecast backend of the run (`fourier` | `arima` | `histogram` |
    /// `attn` | `auto`; set by the runner, `fourier` for directly-built
    /// reports).
    pub forecast: String,
    /// Total model switches the online selector executed (0 under any
    /// fixed backend).
    pub selector_switches: u64,
    pub counters: Counters,
    pub forecast_overhead_ms: f64,
    pub solve_overhead_ms: f64,
    /// Simulator events processed by the run's event loop (the
    /// `EventQueue::processed()` counter — arrivals, readies,
    /// completions, control/sample ticks, keep-alive checks).
    pub events_processed: u64,
    /// Wall-clock time of the event loop (ms). Unlike every other field
    /// this is *not* deterministic — it measures the simulator itself.
    pub wall_clock_ms: f64,
    /// Simulator throughput: `events_processed` per wall-clock second.
    pub events_per_sec: f64,
    /// Per-request response times in seconds (for downstream analysis).
    pub response_times_s: Vec<f64>,
    /// Per-function P50/P99 breakdown, ordered by function id (one entry
    /// per function that received at least one request).
    pub per_function: Vec<FnReport>,
    /// Per-node accounting (set by the runner; empty for unit tests that
    /// build reports directly). This is where elasticity shows up: a
    /// rejoined node's post-restore dispatches/prewarms, and the
    /// migration in/out counters per invoker.
    pub per_node: Vec<NodeReport>,
}

impl RunReport {
    pub fn from_recorder(
        policy: &str,
        trace: &str,
        duration: Micros,
        rec: &Recorder,
        counters: Counters,
        keepalive: &[Micros],
        idle_totals: &[Micros],
    ) -> RunReport {
        let mut rt = Summary::new();
        let mut qd = Summary::new();
        let mut cold_requests = 0;
        let mut dropped = 0;
        let mut by_fn: std::collections::BTreeMap<FunctionId, (Summary, usize, u64)> =
            std::collections::BTreeMap::new();
        for r in rec.requests() {
            let slot = by_fn.entry(r.func).or_default();
            match r.response_time() {
                Some(t) => {
                    rt.add(to_secs(t));
                    slot.0.add(to_secs(t));
                    if r.cold {
                        cold_requests += 1;
                        slot.2 += 1;
                    }
                    if let Some(d) = r.queue_delay() {
                        qd.add(to_secs(d));
                    }
                }
                None => {
                    dropped += 1;
                    slot.1 += 1;
                }
            }
        }
        // retention trajectory: per-function mean horizon + overall mean
        let mut horizon_by_fn: std::collections::BTreeMap<FunctionId, (f64, u32)> =
            std::collections::BTreeMap::new();
        let mut horizon_sum = 0.0;
        for &(_, f, h) in &rec.horizon_samples {
            let e = horizon_by_fn.entry(f).or_insert((0.0, 0));
            e.0 += to_secs(h);
            e.1 += 1;
            horizon_sum += to_secs(h);
        }
        let mean_horizon_s = if rec.horizon_samples.is_empty() {
            0.0
        } else {
            horizon_sum / rec.horizon_samples.len() as f64
        };
        let per_function = by_fn
            .into_iter()
            .map(|(func, (mut s, fdropped, fcold))| FnReport {
                func,
                completed: s.len(),
                dropped: fdropped,
                cold_requests: fcold,
                mean_ms: s.mean() * 1e3,
                p50_ms: s.p50() * 1e3,
                p99_ms: s.p99() * 1e3,
                mean_horizon_s: horizon_by_fn
                    .get(&func)
                    .map_or(0.0, |&(sum, n)| sum / n as f64),
                forecast_model: "fourier".to_string(),
                forecast_accuracy_pct: 0.0,
            })
            .collect();
        let mean_warm = if rec.samples().is_empty() {
            0.0
        } else {
            rec.samples().iter().map(|s| s.warm as f64).sum::<f64>()
                / rec.samples().len() as f64
        };
        RunReport {
            policy: policy.to_string(),
            trace: trace.to_string(),
            duration_s: to_secs(duration),
            nodes: 1,
            threads: 1,
            placement: String::new(),
            completed: rt.len(),
            dropped,
            cold_requests,
            mean_ms: rt.mean() * 1e3,
            p50_ms: rt.p50() * 1e3,
            p90_ms: rt.p90() * 1e3,
            p95_ms: rt.p95() * 1e3,
            p99_ms: rt.p99() * 1e3,
            max_ms: if rt.is_empty() { 0.0 } else { rt.max() * 1e3 },
            mean_queue_delay_ms: qd.mean() * 1e3,
            mean_warm,
            warm_series: rec.samples().iter().map(|s| (s.time, s.warm)).collect(),
            keepalive_total_s: keepalive.iter().map(|&k| to_secs(k)).sum(),
            idle_total_s: idle_totals.iter().map(|&k| to_secs(k)).sum(),
            keepalive_policy: "fixed".to_string(),
            idle_saved_s: 0.0,
            mean_horizon_s,
            survival_releases: 0,
            survival_retained: 0,
            survival_mean_p: 0.0,
            forecast: "fourier".to_string(),
            selector_switches: 0,
            counters,
            forecast_overhead_ms: mean(&rec.forecast_ns) / 1e6,
            solve_overhead_ms: mean(&rec.solve_ns) / 1e6,
            events_processed: 0,
            wall_clock_ms: 0.0,
            events_per_sec: 0.0,
            response_times_s: rt.samples().to_vec(),
            per_function,
            per_node: Vec::new(),
        }
    }

    /// Record the simulator's own throughput for this run (set by the
    /// experiment runner, which owns the event loop and the wall clock).
    pub fn set_throughput(&mut self, events: u64, wall_secs: f64) {
        self.events_processed = events;
        self.wall_clock_ms = wall_secs * 1e3;
        self.events_per_sec = if wall_secs > 0.0 {
            events as f64 / wall_secs
        } else {
            0.0
        };
    }

    /// Percentage improvement of a latency/usage metric over a baseline
    /// (positive = improvement). The Fig. 5/6/7 quantity.
    pub fn improvement_pct(metric_self: f64, metric_base: f64) -> f64 {
        if metric_base <= 0.0 {
            return 0.0;
        }
        100.0 * (metric_base - metric_self) / metric_base
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("trace", Json::Str(self.trace.clone())),
            ("duration_s", Json::Num(self.duration_s)),
            ("nodes", Json::Num(self.nodes as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("placement", Json::Str(self.placement.clone())),
            ("completed", Json::Num(self.completed as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("cold_requests", Json::Num(self.cold_requests as f64)),
            ("cold_starts", Json::Num(self.counters.cold_starts as f64)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p90_ms", Json::Num(self.p90_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("mean_queue_delay_ms", Json::Num(self.mean_queue_delay_ms)),
            ("mean_warm", Json::Num(self.mean_warm)),
            ("keepalive_total_s", Json::Num(self.keepalive_total_s)),
            ("idle_total_s", Json::Num(self.idle_total_s)),
            ("keepalive_policy", Json::Str(self.keepalive_policy.clone())),
            ("idle_saved_s", Json::Num(self.idle_saved_s)),
            ("mean_horizon_s", Json::Num(self.mean_horizon_s)),
            (
                "adaptive_expiries",
                Json::Num(self.counters.adaptive_expiries as f64),
            ),
            // slot-survival telemetry (structural zeros under every
            // policy but `survival`, so same-binary byte-identity holds)
            (
                "survival_releases",
                Json::Num(self.survival_releases as f64),
            ),
            (
                "survival_retained",
                Json::Num(self.survival_retained as f64),
            ),
            ("survival_mean_p", Json::Num(self.survival_mean_p)),
            // forecast-zoo telemetry (`fourier` / 0 under the default
            // backend, so the default path stays byte-identical to the
            // seed modulo these constant fields)
            ("forecast", Json::Str(self.forecast.clone())),
            (
                "selector_switches",
                Json::Num(self.selector_switches as f64),
            ),
            ("forecast_overhead_ms", Json::Num(self.forecast_overhead_ms)),
            ("solve_overhead_ms", Json::Num(self.solve_overhead_ms)),
            ("events_processed", Json::Num(self.events_processed as f64)),
            ("wall_clock_ms", Json::Num(self.wall_clock_ms)),
            ("events_per_sec", Json::Num(self.events_per_sec)),
            ("evictions", Json::Num(self.counters.evictions as f64)),
            ("migrations", Json::Num(self.counters.migrations_in as f64)),
            // chaos telemetry (all structurally 0 with --chaos off, so
            // the off path stays byte-identical to the seed)
            ("retries", Json::Num(self.counters.retries as f64)),
            ("timeouts", Json::Num(self.counters.timeouts as f64)),
            (
                "spawn_failures",
                Json::Num(self.counters.spawn_failures as f64),
            ),
            // image-cache telemetry (all structurally 0 with the cache
            // off, so the off path stays byte-identical to the seed)
            ("layer_hits", Json::Num(self.counters.layer_hits as f64)),
            ("layer_misses", Json::Num(self.counters.layer_misses as f64)),
            ("pull_mib", Json::Num(self.counters.pull_mib as f64)),
            (
                "mean_effective_l_cold_s",
                Json::Num(self.counters.mean_effective_l_cold_s()),
            ),
            ("functions", Json::Num(self.per_function.len() as f64)),
            (
                "per_function",
                Json::Arr(
                    self.per_function
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("func", Json::Num(f.func as f64)),
                                ("completed", Json::Num(f.completed as f64)),
                                ("dropped", Json::Num(f.dropped as f64)),
                                ("cold_requests", Json::Num(f.cold_requests as f64)),
                                ("mean_ms", Json::Num(f.mean_ms)),
                                ("p50_ms", Json::Num(f.p50_ms)),
                                ("p99_ms", Json::Num(f.p99_ms)),
                                ("mean_horizon_s", Json::Num(f.mean_horizon_s)),
                                ("forecast_model", Json::Str(f.forecast_model.clone())),
                                (
                                    "forecast_accuracy_pct",
                                    Json::Num(f.forecast_accuracy_pct),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "per_node",
                Json::Arr(
                    self.per_node
                        .iter()
                        .map(|n| {
                            let mut fields = vec![
                                ("node", Json::Num(n.node as f64)),
                                ("online", Json::Bool(n.online)),
                                ("capacity", Json::Num(n.capacity as f64)),
                                ("containers", Json::Num(n.containers as f64)),
                                ("invocations", Json::Num(n.counters.invocations as f64)),
                                ("cold_starts", Json::Num(n.counters.cold_starts as f64)),
                                (
                                    "prewarms_started",
                                    Json::Num(n.counters.prewarms_started as f64),
                                ),
                                ("evictions", Json::Num(n.counters.evictions as f64)),
                                (
                                    "migrations_in",
                                    Json::Num(n.counters.migrations_in as f64),
                                ),
                                (
                                    "migrations_out",
                                    Json::Num(n.counters.migrations_out as f64),
                                ),
                                // per-node cache affinity evidence: which
                                // invoker's layer store absorbed the pulls
                                ("layer_hits", Json::Num(n.counters.layer_hits as f64)),
                                (
                                    "layer_misses",
                                    Json::Num(n.counters.layer_misses as f64),
                                ),
                                ("pull_mib", Json::Num(n.counters.pull_mib as f64)),
                                // which invoker absorbed the chaos faults
                                ("retries", Json::Num(n.counters.retries as f64)),
                                ("timeouts", Json::Num(n.counters.timeouts as f64)),
                                (
                                    "spawn_failures",
                                    Json::Num(n.counters.spawn_failures as f64),
                                ),
                            ];
                            if let Some(pr) = n.post_restore() {
                                // the rejoin evidence: work done after the
                                // node's most recent drain
                                fields.push((
                                    "post_restore_invocations",
                                    Json::Num(pr.invocations as f64),
                                ));
                                fields.push((
                                    "post_restore_prewarms",
                                    Json::Num(pr.prewarms_started as f64),
                                ));
                            }
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::secs;

    #[test]
    fn request_record_timing() {
        let r = RequestRecord {
            arrival: secs(1.0),
            dispatched: Some(secs(1.5)),
            completed: Some(secs(2.0)),
            cold: false,
            func: 0,
        };
        assert_eq!(r.response_time(), Some(secs(1.0)));
        assert_eq!(r.queue_delay(), Some(secs(0.5)));
    }

    #[test]
    fn recorder_to_report() {
        let mut rec = Recorder::new(4);
        for (i, (a, d, c, cold)) in [
            (0.0, 0.0, 0.28, false),
            (1.0, 1.0, 11.78, true),
            (2.0, 2.5, 2.78, false),
            (3.0, 3.0, f64::NAN, false), // never completes -> dropped
        ]
        .iter()
        .enumerate()
        {
            let req = i as RequestId;
            rec.on_arrival(req, secs(*a));
            rec.on_dispatch(req, secs(*d));
            if *cold {
                rec.on_cold(req);
            }
            if !c.is_nan() {
                rec.on_complete(req, secs(*c));
            }
        }
        let report = RunReport::from_recorder(
            "test",
            "unit",
            secs(60.0),
            &rec,
            Counters::default(),
            &[secs(30.0), secs(10.0)],
            &[secs(40.0)],
        );
        assert_eq!(report.completed, 3);
        assert_eq!(report.dropped, 1);
        assert_eq!(report.cold_requests, 1);
        assert!((report.mean_ms - (280.0 + 10_780.0 + 780.0) / 3.0).abs() < 0.1);
        assert_eq!(report.keepalive_total_s, 40.0);
        assert_eq!(report.idle_total_s, 40.0);
        // queue delays: 0, 0, 0.5 s -> mean 166.67 ms
        assert!((report.mean_queue_delay_ms - 500.0 / 3.0).abs() < 0.1);
    }

    #[test]
    fn per_function_breakdown_partitions_the_run() {
        let mut rec = Recorder::new(6);
        // fn 0: two fast requests; fn 1: one cold slow request + one drop
        for (req, func, a, c, cold) in [
            (0u64, 0u32, 0.0, 0.28, false),
            (1, 0, 1.0, 1.28, false),
            (2, 1, 2.0, 12.78, true),
            (3, 1, 3.0, f64::NAN, false),
        ] {
            rec.on_arrival_for(req, secs(a), func);
            rec.on_dispatch(req, secs(a));
            if cold {
                rec.on_cold(req);
            }
            if !c.is_nan() {
                rec.on_complete(req, secs(c));
            }
        }
        assert_eq!(rec.func_of(2), 1);
        assert_eq!(rec.func_of(99), 0); // unknown defaults to fn 0
        let report = RunReport::from_recorder(
            "test",
            "unit",
            secs(60.0),
            &rec,
            Counters::default(),
            &[],
            &[],
        );
        assert_eq!(report.per_function.len(), 2);
        let f0 = &report.per_function[0];
        let f1 = &report.per_function[1];
        assert_eq!((f0.func, f0.completed, f0.dropped), (0, 2, 0));
        assert_eq!((f1.func, f1.completed, f1.dropped), (1, 1, 1));
        assert_eq!(f1.cold_requests, 1);
        assert!(f1.p99_ms > f0.p99_ms);
        // partition property: per-function counts sum to the aggregate
        let sum_completed: usize = report.per_function.iter().map(|f| f.completed).sum();
        let sum_dropped: usize = report.per_function.iter().map(|f| f.dropped).sum();
        assert_eq!(sum_completed, report.completed);
        assert_eq!(sum_dropped, report.dropped);
        // JSON surface carries the breakdown
        let j = report.to_json();
        assert_eq!(j.path("functions").unwrap().as_f64(), Some(2.0));
        let arr = j.path("per_function").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].path("cold_requests").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn keepalive_horizon_trajectory_lands_in_the_report() {
        let mut rec = Recorder::new(2);
        for (req, func) in [(0u64, 0u32), (1, 1)] {
            rec.on_arrival_for(req, secs(0.0), func);
            rec.on_dispatch(req, secs(0.0));
            rec.on_complete(req, secs(1.0));
        }
        rec.on_keepalive_horizon(secs(30.0), 0, secs(600.0));
        rec.on_keepalive_horizon(secs(60.0), 0, secs(300.0));
        rec.on_keepalive_horizon(secs(30.0), 1, secs(30.0));
        let report = RunReport::from_recorder(
            "mpc",
            "unit",
            secs(60.0),
            &rec,
            Counters::default(),
            &[],
            &[],
        );
        assert!((report.per_function[0].mean_horizon_s - 450.0).abs() < 1e-9);
        assert!((report.per_function[1].mean_horizon_s - 30.0).abs() < 1e-9);
        assert!((report.mean_horizon_s - 310.0).abs() < 1e-9);
        // the runner stamps the policy; directly-built reports are fixed
        assert_eq!(report.keepalive_policy, "fixed");
        let j = report.to_json();
        assert_eq!(j.path("keepalive_policy").unwrap().as_str(), Some("fixed"));
        assert_eq!(j.path("idle_saved_s").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.path("adaptive_expiries").unwrap().as_f64(), Some(0.0));
        let arr = j.path("per_function").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].path("mean_horizon_s").unwrap().as_f64(), Some(450.0));
    }

    #[test]
    fn forecast_telemetry_defaults_are_structurally_fourier() {
        let mut rec = Recorder::new(1);
        rec.on_arrival_for(0, secs(0.0), 0);
        rec.on_dispatch(0, secs(0.0));
        rec.on_complete(0, secs(1.0));
        let report = RunReport::from_recorder(
            "mpc",
            "unit",
            secs(60.0),
            &rec,
            Counters::default(),
            &[],
            &[],
        );
        // the runner stamps the backend; directly-built reports are the
        // seed default (fourier) with zero selector activity
        assert_eq!(report.forecast, "fourier");
        assert_eq!(report.selector_switches, 0);
        assert_eq!(report.per_function[0].forecast_model, "fourier");
        assert_eq!(report.per_function[0].forecast_accuracy_pct, 0.0);
        let j = report.to_json();
        assert_eq!(j.path("forecast").unwrap().as_str(), Some("fourier"));
        assert_eq!(j.path("selector_switches").unwrap().as_f64(), Some(0.0));
        let arr = j.path("per_function").unwrap().as_arr().unwrap();
        assert_eq!(
            arr[0].path("forecast_model").unwrap().as_str(),
            Some("fourier")
        );
        assert_eq!(
            arr[0].path("forecast_accuracy_pct").unwrap().as_f64(),
            Some(0.0)
        );
    }

    #[test]
    fn per_node_json_surface() {
        let rec = Recorder::new(0);
        let mut report = RunReport::from_recorder(
            "mpc",
            "azure",
            secs(1.0),
            &rec,
            Counters::default(),
            &[],
            &[],
        );
        assert!(report.per_node.is_empty(), "unit reports carry no nodes");
        report.per_node = vec![NodeReport {
            node: 1,
            online: true,
            capacity: 32,
            containers: 3,
            counters: Counters {
                invocations: 7,
                prewarms_started: 4,
                migrations_in: 2,
                ..Default::default()
            },
            counters_at_drain: Some(Counters {
                invocations: 5,
                prewarms_started: 1,
                ..Default::default()
            }),
        }];
        let j = report.to_json();
        let arr = j.path("per_node").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].path("node").unwrap().as_f64(), Some(1.0));
        assert_eq!(arr[0].path("online").unwrap().as_bool(), Some(true));
        assert_eq!(arr[0].path("invocations").unwrap().as_f64(), Some(7.0));
        assert_eq!(arr[0].path("prewarms_started").unwrap().as_f64(), Some(4.0));
        assert_eq!(arr[0].path("migrations_in").unwrap().as_f64(), Some(2.0));
        // drained-then-restored nodes expose their post-rejoin activity
        assert_eq!(
            arr[0].path("post_restore_invocations").unwrap().as_f64(),
            Some(2.0)
        );
        assert_eq!(
            arr[0].path("post_restore_prewarms").unwrap().as_f64(),
            Some(3.0)
        );
    }

    #[test]
    fn cache_telemetry_lands_in_the_json_surface() {
        let rec = Recorder::new(0);
        let mut report = RunReport::from_recorder(
            "mpc",
            "azure",
            secs(1.0),
            &rec,
            Counters {
                layer_hits: 6,
                layer_misses: 4,
                pull_mib: 528,
                cold_cost_us: 15_810_000,
                cold_charges: 2,
                ..Default::default()
            },
            &[],
            &[],
        );
        report.per_node = vec![NodeReport {
            node: 0,
            online: true,
            capacity: 32,
            containers: 0,
            counters: Counters {
                pull_mib: 528,
                layer_misses: 4,
                ..Default::default()
            },
            counters_at_drain: None,
        }];
        let j = report.to_json();
        assert_eq!(j.path("layer_hits").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.path("layer_misses").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.path("pull_mib").unwrap().as_f64(), Some(528.0));
        assert_eq!(
            j.path("mean_effective_l_cold_s").unwrap().as_f64(),
            Some(7.905)
        );
        let arr = j.path("per_node").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].path("pull_mib").unwrap().as_f64(), Some(528.0));
        assert_eq!(arr[0].path("layer_misses").unwrap().as_f64(), Some(4.0));
        // the fields exist (as zeros) even when the cache never ran, so
        // off-mode reports keep a stable schema
        let off = RunReport::from_recorder(
            "mpc",
            "azure",
            secs(1.0),
            &Recorder::new(0),
            Counters::default(),
            &[],
            &[],
        )
        .to_json();
        assert_eq!(off.path("pull_mib").unwrap().as_f64(), Some(0.0));
        assert_eq!(
            off.path("mean_effective_l_cold_s").unwrap().as_f64(),
            Some(0.0)
        );
    }

    #[test]
    fn improvement_pct_signs() {
        assert_eq!(RunReport::improvement_pct(50.0, 100.0), 50.0);
        assert_eq!(RunReport::improvement_pct(150.0, 100.0), -50.0);
        assert_eq!(RunReport::improvement_pct(1.0, 0.0), 0.0);
    }

    #[test]
    fn report_json_fields() {
        let rec = Recorder::new(0);
        let report = RunReport::from_recorder(
            "mpc",
            "azure",
            secs(1.0),
            &rec,
            Counters::default(),
            &[],
            &[],
        );
        let j = report.to_json();
        assert_eq!(j.path("policy").unwrap().as_str(), Some("mpc"));
        assert_eq!(j.path("completed").unwrap().as_f64(), Some(0.0));
    }
}
