//! The paper's optimizer (Sec. III-B): horizon problem, PGD solver, and
//! feasibility repair. The deployed solve path runs the AOT HLO artifact
//! (`runtime::modules::HloSolver`); [`solver::RustSolver`] is the
//! in-process mirror for sweeps and differential tests.
//!
//! # Math-to-code mapping (paper Sec. III-B)
//!
//! The horizon problem optimizes, for each step `k < H`, the decision
//! vector `z = concat(x, r, s)` — prewarms `x_k`, reclaims `r_k`, served
//! requests `s_k`:
//!
//! | Paper | Code |
//! |-------|------|
//! | Eq. 3 (ColdDelay cost)         | `problem::cost`, the `alpha` term |
//! | Eq. 4 (WaitCost)               | `problem::cost`, the `beta` term  |
//! | Eq. 5 (LaunchCost)             | `problem::cost`, the `delta` term |
//! | Eq. 6 (OverProvision)          | `problem::cost`, the `gamma` term |
//! | Eq. 7 (ReclaimReward)          | `problem::cost`, the `eta` term   |
//! | Eq. 8 (smoothness)             | `problem::cost`, `rho1`/`rho2` terms |
//! | Eq. 9 (total objective J)      | [`problem::cost`]                 |
//! | Eq. 10-11 (queue/pool dynamics)| [`problem::rollout`]              |
//! | Eq. 12-17 (hard constraints)   | `kappa`-weighted penalties in [`problem::cost`]; box bounds in [`problem::upper_bounds`]; exact integer form in [`repair::repair`] |
//! | Eq. 18 (x·r exclusivity)       | `rho_me` relaxation in [`problem::cost`]; exact in [`repair::repair`] |
//! | ∇J (hand-derived adjoint)      | [`problem::grad`], differentially tested against finite differences |
//!
//! The PGD solver ([`solver::RustSolver`]) runs Adam-style projected
//! gradient descent on the penalty relaxation; [`repair::repair`] then
//! rounds the relaxed solution and walks the true integer dynamics so
//! every actuated plan satisfies Eq. 12-18 *exactly* (checked by
//! [`repair::verify`] in property tests). Only step 0 actuates
//! (receding horizon); in a multi-tenant run the coordinator splits that
//! first-step prewarm budget across functions by predicted demand, with
//! `w_max` re-scaled to the fleet's *live* online capacity at every
//! control step (elasticity — see `coordinator::controller`; the HLO
//! artifact path keeps the startup-scaled bound, its weights are baked
//! at lowering time).

pub mod problem;
pub mod repair;
pub mod solver;

pub use problem::MpcInput;
pub use repair::{repair, verify, Plan};
pub use solver::{MpcSolver, RustSolver, ADAM_B2, ADAM_EPS};
