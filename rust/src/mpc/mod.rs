//! The paper's optimizer (Sec. III-B): horizon problem, PGD solver, and
//! feasibility repair. The deployed solve path runs the AOT HLO artifact
//! (`runtime::modules::HloSolver`); [`solver::RustSolver`] is the
//! in-process mirror for sweeps and differential tests.

pub mod problem;
pub mod repair;
pub mod solver;

pub use problem::MpcInput;
pub use repair::{repair, verify, Plan};
pub use solver::{MpcSolver, RustSolver, ADAM_B2, ADAM_EPS};
