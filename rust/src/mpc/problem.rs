//! The MPC horizon problem (Sec. III-B, Eq. 3-18): decision layout,
//! system dynamics rollout, objective, and the hand-derived gradient.
//!
//! This is the bit-level Rust mirror of the L1 Pallas kernel
//! (`python/compile/kernels/qp.py`) — same cost terms, same penalty
//! relaxation, same gradient — kept in lockstep for differential testing
//! against the HLO artifact and used as the fast in-process solver for
//! large simulation sweeps.

use crate::config::Weights;

/// Control interval in seconds, baked like `cold_steps` (kernel mirror).
pub const DT_S: f64 = 30.0;
/// Steady-flow utilization target for capacity sizing (kernel mirror).
pub const UTIL_TARGET: f64 = 0.8;

/// Inputs to one MPC solve (state + forecast at a control step).
#[derive(Debug, Clone)]
pub struct MpcInput {
    /// Forecasted arrivals per step, λ̂ (length H).
    pub lam: Vec<f64>,
    /// Pre-horizon cold starts completing at step k (readyCold, k < D).
    pub rdy: Vec<f64>,
    /// Queue length now (Eq. 10 initial state).
    pub q0: f64,
    /// Warm containers now (Eq. 11 initial state).
    pub w0: f64,
    /// Cold starts issued at the previous step (smoothness anchor, Eq. 8).
    pub x_prev: f64,
}

impl MpcInput {
    pub fn horizon(&self) -> usize {
        self.lam.len()
    }
}

/// Split the decision vector z = concat(x, r, s).
pub fn split(z: &[f64], h: usize) -> (&[f64], &[f64], &[f64]) {
    (&z[..h], &z[h..2 * h], &z[2 * h..])
}

/// System dynamics (Eq. 10-11): state at the *start* of each step.
pub fn rollout(z: &[f64], input: &MpcInput, cold_steps: usize) -> (Vec<f64>, Vec<f64>) {
    let h = input.horizon();
    let (x, r, s) = split(z, h);
    let mut q = vec![0.0; h];
    let mut w = vec![0.0; h];
    q[0] = input.q0;
    w[0] = input.w0;
    for k in 0..h - 1 {
        let ready = input.rdy[k] + if k >= cold_steps { x[k - cold_steps] } else { 0.0 };
        q[k + 1] = q[k] + input.lam[k] - s[k];
        w[k + 1] = w[k] + ready - r[k];
    }
    (q, w)
}

#[inline]
fn relu(v: f64) -> f64 {
    v.max(0.0)
}

/// Objective (Eq. 9) + quadratic penalties for the coupled constraints.
pub fn cost(z: &[f64], input: &MpcInput, wts: &Weights, cold_steps: usize) -> f64 {
    let h = input.horizon();
    let (x, r, s) = split(z, h);
    let (q, w) = rollout(z, input, cold_steps);
    // effective demand = utilization-normalized forecast flow + backlog
    // amortized over the cold window; without the backlog term the penalty
    // relaxation has no first-order pressure to provision for queue drain,
    // and without the flow normalization the drain-target mu over-sizes the
    // pool ~10x on steady load (see the kernel docstring)
    let inv_dd = 1.0 / (cold_steps as f64 + 1.0);
    let flow_scale = wts.mu * wts.l_warm / (UTIL_TARGET * DT_S);
    // true per-step serving throughput (Eq. 12's capacity); the drain-
    // target mu only shapes provisioning (Eq. 3/6)
    let mu_full = DT_S / wts.l_warm;
    let mut j = 0.0;
    for k in 0..h {
        let ready = input.rdy[k] + if k >= cold_steps { x[k - cold_steps] } else { 0.0 };
        // excess backlog only: steady state carries one step of flow in q
        let demand = input.lam[k] * flow_scale + relu(q[k] - input.lam[k]) * inv_dd;
        j += wts.alpha * relu(demand - wts.mu * w[k]) * (wts.l_cold + wts.l_warm); // Eq. 3
        j += wts.beta * q[k] * wts.l_warm; // Eq. 4
        j += wts.delta * x[k]; // Eq. 5
        j += wts.gamma * relu(wts.mu * w[k] - demand); // Eq. 6
        j -= wts.eta * r[k]; // Eq. 7
        // Eq. 8 smoothness: dw_{k+1} = ready_k - r_k covers k=0..H-2
        if k < h - 1 {
            j += wts.rho1 * (ready - r[k]).powi(2);
        }
        let dx = x[k] - if k == 0 { input.x_prev } else { x[k - 1] };
        j += wts.rho2 * dx * dx;
        j += wts.rho_me * x[k] * r[k]; // Eq. 18 relaxed
        // penalties (Eq. 12-17)
        let pen = relu(s[k] - q[k]).powi(2)
            + relu(s[k] - mu_full * w[k]).powi(2)
            + relu(r[k] - w[k]).powi(2)
            + relu(w[k] - wts.w_max).powi(2)
            + relu(-q[k]).powi(2)
            + relu(-w[k]).powi(2);
        j += wts.kappa * pen;
    }
    j
}

/// Hand-derived gradient of [`cost`] (mirrors the Pallas kernel exactly).
pub fn grad(z: &[f64], input: &MpcInput, wts: &Weights, cold_steps: usize) -> Vec<f64> {
    let h = input.horizon();
    let (x, r, s) = split(z, h);
    let (q, w) = rollout(z, input, cold_steps);

    // per-state partials
    let inv_dd = 1.0 / (cold_steps as f64 + 1.0);
    let flow_scale = wts.mu * wts.l_warm / (UTIL_TARGET * DT_S);
    let mu_full = DT_S / wts.l_warm;
    let mut g_w = vec![0.0; h];
    let mut g_q = vec![0.0; h];
    for k in 0..h {
        let demand = input.lam[k] * flow_scale + relu(q[k] - input.lam[k]) * inv_dd;
        let h_cold = relu(demand - wts.mu * w[k]);
        let h_over = relu(wts.mu * w[k] - demand);
        let v_sw = relu(s[k] - mu_full * w[k]);
        let v_rw = relu(r[k] - w[k]);
        let v_wmax = relu(w[k] - wts.w_max);
        let v_wneg = relu(-w[k]);
        let v_sq = relu(s[k] - q[k]);
        let v_qneg = relu(-q[k]);
        let m_qpos = f64::from(q[k] - input.lam[k] > 0.0);
        g_w[k] = -wts.alpha * (wts.l_cold + wts.l_warm) * wts.mu * f64::from(h_cold > 0.0)
            + wts.gamma * wts.mu * f64::from(h_over > 0.0)
            + wts.kappa * (-2.0 * mu_full * v_sw - 2.0 * v_rw + 2.0 * v_wmax - 2.0 * v_wneg);
        g_q[k] = wts.beta * wts.l_warm
            + wts.alpha * (wts.l_cold + wts.l_warm) * f64::from(h_cold > 0.0) * m_qpos * inv_dd
            - wts.gamma * f64::from(h_over > 0.0) * m_qpos * inv_dd
            + wts.kappa * (-2.0 * v_sq - 2.0 * v_qneg);
    }

    // adjoint of the prefix sums: g_u[k] = sum_{i > k} g_w[i]
    let mut g_u = vec![0.0; h];
    let mut acc = 0.0;
    for k in (0..h).rev() {
        g_u[k] = acc; // strictly-upper: excludes i == k
        acc += g_w[k];
    }
    // smoothness contribution in u-space (u_k enters dw_{k+1}, k <= H-2)
    for (k, gu) in g_u.iter_mut().enumerate().take(h.saturating_sub(1)) {
        let ready = input.rdy[k] + if k >= cold_steps { x[k - cold_steps] } else { 0.0 };
        *gu += 2.0 * wts.rho1 * (ready - r[k]);
    }
    let mut g_qs = vec![0.0; h];
    let mut accq = 0.0;
    for k in (0..h).rev() {
        g_qs[k] = accq;
        accq += g_q[k];
    }

    let mut g = vec![0.0; 3 * h];
    for k in 0..h {
        // x: shift-transpose of g_u + direct terms
        let from_w = if k + cold_steps < h { g_u[k + cold_steps] } else { 0.0 };
        let dx_k = x[k] - if k == 0 { input.x_prev } else { x[k - 1] };
        let dx_next = if k + 1 < h { x[k + 1] - x[k] } else { 0.0 };
        g[k] = from_w + wts.delta + wts.rho_me * r[k] + 2.0 * wts.rho2 * (dx_k - dx_next);
        // r
        let v_rw = relu(r[k] - w[k]);
        g[h + k] = -g_u[k] - wts.eta + wts.rho_me * x[k] + wts.kappa * 2.0 * v_rw;
        // s
        let v_sq = relu(s[k] - q[k]);
        let v_sw = relu(s[k] - mu_full * w[k]);
        g[2 * h + k] = -g_qs[k] + wts.kappa * (2.0 * v_sq + 2.0 * v_sw);
    }
    g
}

/// Box upper bounds for z (Eq. 14-17; lower bounds are all zero).
pub fn upper_bounds(wts: &Weights, h: usize) -> Vec<f64> {
    let mu_full = DT_S / wts.l_warm;
    let mut ub = vec![wts.w_max; 2 * h];
    ub.extend(std::iter::repeat(mu_full * wts.w_max).take(h));
    ub
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn numeric_grad(z: &[f64], input: &MpcInput, wts: &Weights, d: usize) -> Vec<f64> {
        let eps = 1e-5;
        (0..z.len())
            .map(|i| {
                let mut zp = z.to_vec();
                let mut zm = z.to_vec();
                zp[i] += eps;
                zm[i] -= eps;
                (cost(&zp, input, wts, d) - cost(&zm, input, wts, d)) / (2.0 * eps)
            })
            .collect()
    }

    fn random_input(g: &mut crate::util::prop::Gen, h: usize) -> MpcInput {
        MpcInput {
            lam: g.vec_f64(h, 0.0, 60.0),
            rdy: g.vec_f64(h, 0.0, 3.0),
            q0: g.f64(0.0, 40.0),
            w0: g.f64(0.0, 30.0),
            x_prev: g.f64(0.0, 8.0),
        }
    }

    #[test]
    fn analytic_gradient_matches_numeric() {
        prop_check("mpc gradient vs finite differences", 60, |g| {
            let h = *g.pick(&[4usize, 8, 16, 24]);
            let d = g.usize(0, h - 1);
            let input = random_input(g, h);
            let wts = Weights::default();
            // avoid kink points by nudging off hinge boundaries
            let z: Vec<f64> = (0..3 * h).map(|_| g.f64(0.05, 15.0)).collect();
            let ga = grad(&z, &input, &wts, d);
            let gn = numeric_grad(&z, &input, &wts, d);
            for i in 0..z.len() {
                let scale = 1.0 + ga[i].abs().max(gn[i].abs());
                prop_assert!(
                    (ga[i] - gn[i]).abs() / scale < 2e-3,
                    "grad[{i}] analytic {} vs numeric {} (h={h} d={d})",
                    ga[i],
                    gn[i]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn rollout_conservation() {
        prop_check("rollout queue/warm conservation", 100, |g| {
            let h = g.usize(2, 32);
            let d = g.usize(0, h - 1);
            let input = random_input(g, h);
            let z: Vec<f64> = g.vec_f64(3 * h, 0.0, 10.0);
            let (q, w) = rollout(&z, &input, d);
            let (x, r, s) = split(&z, h);
            // telescoping sums must match endpoint state
            let mut q_sum = input.q0;
            let mut w_sum = input.w0;
            for k in 0..h - 1 {
                q_sum += input.lam[k] - s[k];
                let ready = input.rdy[k] + if k >= d { x[k - d] } else { 0.0 };
                w_sum += ready - r[k];
            }
            prop_assert!((q[h - 1] - q_sum).abs() < 1e-9, "q endpoint mismatch");
            prop_assert!((w[h - 1] - w_sum).abs() < 1e-9, "w endpoint mismatch");
            Ok(())
        });
    }

    #[test]
    fn cost_zero_when_weights_zero() {
        let wts = Weights {
            alpha: 0.0,
            beta: 0.0,
            gamma: 0.0,
            delta: 0.0,
            eta: 0.0,
            rho1: 0.0,
            rho2: 0.0,
            rho_me: 0.0,
            kappa: 0.0,
            ..Weights::default()
        };
        let input = MpcInput {
            lam: vec![10.0; 8],
            rdy: vec![0.0; 8],
            q0: 3.0,
            w0: 2.0,
            x_prev: 1.0,
        };
        let z = vec![1.5; 24];
        assert_eq!(cost(&z, &input, &wts, 2), 0.0);
        assert!(grad(&z, &input, &wts, 2).iter().all(|&g| g == 0.0));
    }

    #[test]
    fn cold_delay_drives_prewarm_gradient_negative() {
        // demand far above capacity after step D: gradient wrt x[0] must be
        // negative (increasing x reduces cost)
        let h = 24;
        let d = 11;
        let input = MpcInput {
            lam: vec![50.0; h],
            rdy: vec![0.0; h],
            q0: 0.0,
            w0: 0.0,
            x_prev: 0.0,
        };
        let wts = Weights::default();
        let z = vec![0.0; 3 * h];
        let g = grad(&z, &input, &wts, d);
        assert!(g[0] < 0.0, "g_x[0] = {} should favour prewarming", g[0]);
    }

    #[test]
    fn upper_bounds_layout() {
        let wts = Weights::default();
        let ub = upper_bounds(&wts, 4);
        assert_eq!(ub.len(), 12);
        assert_eq!(ub[0], 64.0);
        assert_eq!(ub[7], 64.0);
        assert!((ub[8] - 64.0 * (30.0 / 0.280)).abs() < 1e-9); // true throughput ceiling
    }
}
