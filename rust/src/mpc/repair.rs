//! Feasibility repair: turn the relaxed continuous PGD solution into an
//! integer plan that satisfies Eq. 12-18 *exactly*.
//!
//! The penalty relaxation leaves small residuals (and the bilinear
//! exclusivity constraint r_k · x_k = 0 is only softly enforced); this
//! stage rounds and then walks the horizon forward, clamping each decision
//! against the true integer dynamics. Only step 0 actuates (receding
//! horizon), but the full repaired plan seeds the next warm start.

use crate::config::Weights;
use crate::mpc::problem::{split, MpcInput};

/// A feasible integer plan over the horizon.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub x: Vec<u32>,
    pub r: Vec<u32>,
    pub s: Vec<u32>,
    /// The repaired plan re-encoded as a decision vector (warm-start seed).
    pub z: Vec<f64>,
}

impl Plan {
    pub fn horizon(&self) -> usize {
        self.x.len()
    }

    /// First-step actions (the only ones actuated).
    pub fn first(&self) -> (u32, u32, u32) {
        (self.x[0], self.r[0], self.s[0])
    }

    /// Shift one step forward (drop step 0, duplicate the tail) — the
    /// receding-horizon warm start for the next control step.
    pub fn shifted_warm_start(&self) -> Vec<f64> {
        let h = self.horizon();
        let mut z = vec![0.0; 3 * h];
        for (block, series) in [(0, &self.x), (1, &self.r), (2, &self.s)] {
            for k in 0..h {
                let src = (k + 1).min(h - 1);
                z[block * h + k] = series[src] as f64;
            }
        }
        z
    }
}

/// Repair a relaxed solution `z` into a feasible integer [`Plan`].
///
/// `total_cap` is the platform's replica cap (warm + cold-starting ≤ cap);
/// `inflight_cold` is the number of cold starts already in flight now.
pub fn repair(
    z: &[f64],
    input: &MpcInput,
    wts: &Weights,
    cold_steps: usize,
    total_cap: u32,
    inflight_cold: u32,
) -> Plan {
    let h = input.horizon();
    let (xf, rf, sf) = split(z, h);
    let mut x = vec![0u32; h];
    let mut r = vec![0u32; h];
    let mut s = vec![0u32; h];

    let mut q = input.q0.max(0.0);
    let mut w = input.w0.max(0.0);
    // two cold pools: pre-horizon launches (consumed by rdy, robust to an
    // inconsistent rdy schedule) and exact in-horizon launches
    let mut pre_inflight = inflight_cold as f64;
    let mut hor_inflight = 0.0f64;

    for k in 0..h {
        let mut xk = xf[k].round().max(0.0) as u32;
        let mut rk = rf[k].round().max(0.0) as u32;

        // Eq. 18: mutual exclusivity — keep the larger intent
        if xk > 0 && rk > 0 {
            if xk >= rk {
                rk = 0;
            } else {
                xk = 0;
            }
        }

        // Eq. 14 + capacity: cannot exceed the replica pool
        let headroom = (total_cap as f64 - w - pre_inflight - hor_inflight).max(0.0) as u32;
        xk = xk.min(headroom).min(wts.w_max as u32);

        // Eq. 13/15: reclaim bounded by current warm pool
        rk = rk.min(w.floor().max(0.0) as u32);

        // Eq. 12: serving bounded by queue content and true warm throughput
        let cap_serve = (crate::mpc::problem::DT_S / wts.l_warm * w).floor().max(0.0);
        let sk = sf[k]
            .round()
            .max(0.0)
            .min(q.floor())
            .min(cap_serve)
            .max(0.0) as u32;

        x[k] = xk;
        r[k] = rk;
        s[k] = sk;
        hor_inflight += xk as f64;

        // integer dynamics forward: cold containers maturing this step
        let ready_pre = input.rdy[k].min(pre_inflight);
        pre_inflight -= ready_pre;
        let ready_hor = if k >= cold_steps { x[k - cold_steps] as f64 } else { 0.0 };
        hor_inflight = (hor_inflight - ready_hor).max(0.0);
        q = (q + input.lam[k] - sk as f64).max(0.0);
        w = (w + ready_pre + ready_hor - rk as f64).clamp(0.0, wts.w_max);
    }

    let mut zr = vec![0.0; 3 * h];
    for k in 0..h {
        zr[k] = x[k] as f64;
        zr[h + k] = r[k] as f64;
        zr[2 * h + k] = s[k] as f64;
    }
    Plan { x, r, s, z: zr }
}

/// Verify that a plan satisfies every hard constraint when rolled through
/// the integer dynamics. Returns the first violation as a string.
pub fn verify(plan: &Plan, input: &MpcInput, wts: &Weights, cold_steps: usize,
              total_cap: u32, inflight_cold: u32) -> Result<(), String> {
    let h = plan.horizon();
    let mut q = input.q0.max(0.0);
    let mut w = input.w0.max(0.0);
    let mut pre_inflight = inflight_cold as f64;
    let mut hor_inflight = 0.0f64;
    for k in 0..h {
        if plan.x[k] > 0 && plan.r[k] > 0 {
            return Err(format!("step {k}: x and r both nonzero (Eq. 18)"));
        }
        // launches must fit the remaining headroom (the inherited state may
        // itself exceed an arbitrary cap; only new launches are checkable)
        let headroom = (total_cap as f64 - w - pre_inflight - hor_inflight).max(0.0);
        if plan.x[k] as f64 > headroom + 1e-9 {
            return Err(format!("step {k}: capacity exceeded"));
        }
        if plan.r[k] as f64 > w + 1e-9 {
            return Err(format!("step {k}: reclaim {} > warm {w}", plan.r[k]));
        }
        if plan.s[k] as f64 > q + 1e-9 {
            return Err(format!("step {k}: serve {} > queue {q}", plan.s[k]));
        }
        let cap = crate::mpc::problem::DT_S / wts.l_warm * w;
        if plan.s[k] as f64 > cap + 1e-9 {
            return Err(format!("step {k}: serve {} > capacity {cap}", plan.s[k]));
        }
        hor_inflight += plan.x[k] as f64;
        let ready_pre = input.rdy[k].min(pre_inflight);
        pre_inflight -= ready_pre;
        let ready_hor = if k >= cold_steps {
            plan.x[k - cold_steps] as f64
        } else {
            0.0
        };
        hor_inflight = (hor_inflight - ready_hor).max(0.0);
        q = (q + input.lam[k] - plan.s[k] as f64).max(0.0);
        w = (w + ready_pre + ready_hor - plan.r[k] as f64).clamp(0.0, wts.w_max);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn repaired_plans_always_feasible() {
        prop_check("repair yields feasible integer plans", 200, |g| {
            let h = *g.pick(&[8usize, 16, 24]);
            let d = g.usize(0, h - 1);
            let input = MpcInput {
                lam: g.vec_f64(h, 0.0, 80.0),
                rdy: g.vec_f64(h, 0.0, 4.0).iter().map(|v| v.round()).collect(),
                q0: g.f64(0.0, 50.0).round(),
                w0: g.f64(0.0, 30.0).round(),
                x_prev: 0.0,
            };
            let wts = crate::config::Weights::default();
            let z: Vec<f64> = g.vec_f64(3 * h, -5.0, 80.0);
            let cap = g.u64(8, 64) as u32;
            let inflight = g.u64(0, 4) as u32;
            let plan = repair(&z, &input, &wts, d, cap, inflight);
            verify(&plan, &input, &wts, d, cap, inflight).map_err(|e| {
                format!("{e}\n z={z:?}\n plan={plan:?} cap={cap} inflight={inflight}")
            })
        });
    }

    #[test]
    fn exclusivity_keeps_larger_intent() {
        let h = 4;
        let input = MpcInput {
            lam: vec![0.0; h],
            rdy: vec![0.0; h],
            q0: 0.0,
            w0: 10.0,
            x_prev: 0.0,
        };
        let wts = crate::config::Weights::default();
        let mut z = vec![0.0; 3 * h];
        z[0] = 5.0; // x0
        z[h] = 2.0; // r0: smaller -> zeroed
        let plan = repair(&z, &input, &wts, 2, 64, 0);
        assert_eq!(plan.x[0], 5);
        assert_eq!(plan.r[0], 0);
    }

    #[test]
    fn serving_respects_queue_and_capacity() {
        let h = 4;
        let input = MpcInput {
            lam: vec![0.0; h],
            rdy: vec![0.0; h],
            q0: 3.0,
            w0: 1.0,
            x_prev: 0.0,
        };
        let wts = crate::config::Weights::default();
        let mut z = vec![0.0; 3 * h];
        z[2 * h] = 50.0; // wants to serve 50
        let plan = repair(&z, &input, &wts, 2, 64, 0);
        // min(queue 3, floor(mu * 1) = 3) = 3
        assert_eq!(plan.s[0], 3);
    }

    #[test]
    fn shifted_warm_start_layout() {
        let plan = Plan {
            x: vec![1, 2, 3],
            r: vec![0, 0, 1],
            s: vec![4, 5, 6],
            z: vec![],
        };
        let z = plan.shifted_warm_start();
        assert_eq!(&z[0..3], &[2.0, 3.0, 3.0]); // x shifted, tail duplicated
        assert_eq!(&z[3..6], &[0.0, 1.0, 1.0]);
        assert_eq!(&z[6..9], &[5.0, 6.0, 6.0]);
    }

    #[test]
    fn capacity_headroom_counts_inflight() {
        let h = 2;
        let input = MpcInput {
            lam: vec![100.0; h],
            rdy: vec![0.0; h],
            q0: 0.0,
            w0: 60.0,
            x_prev: 0.0,
        };
        let wts = crate::config::Weights::default();
        let mut z = vec![0.0; 3 * h];
        z[0] = 50.0;
        let plan = repair(&z, &input, &wts, 1, 64, 2);
        assert_eq!(plan.x[0], 2); // 64 - 60 warm - 2 inflight
    }
}
