//! Projected-Adam MPC solver (mirror of `model.mpc_solve`): per-coordinate
//! moment normalization with bias correction, gradient clipping, box
//! projection — identical hyperparameters to the AOT artifact so the two
//! backends are interchangeable (and differentially tested). Adam rather
//! than plain PGD is what lets backlog-drain states (huge queue, small
//! warm pool) converge inside the 300-iteration budget: the serving and
//! prewarming blocks have gradient scales two orders of magnitude apart.

use crate::config::Weights;
use crate::mpc::problem::{cost, grad, upper_bounds, MpcInput};

/// Adam second-moment decay — must match constants.ADAM_B2.
pub const ADAM_B2: f64 = 0.999;
pub const ADAM_EPS: f64 = 1e-8;

/// A solver for the horizon QP. Implementations: [`RustSolver`] (in-process
/// mirror) and `runtime::modules::HloSolver` (the deployed AOT artifact).
pub trait MpcSolver {
    /// Solve from warm start `z0`; returns (z*, final objective value).
    fn solve(&mut self, z0: &[f64], input: &MpcInput) -> (Vec<f64>, f64);
    fn name(&self) -> &str;

    /// Re-scale the planning pool bound `w_max` to the fleet's *live*
    /// capacity (elasticity: the bound shrinks when a node drains and
    /// grows back when it rejoins, at every control step). Default no-op:
    /// the AOT HLO artifact bakes its weights at lowering time, so the
    /// HLO path keeps the startup-scaled bound.
    fn set_w_max(&mut self, _w_max: f64) {}
}

/// In-process PGD solver.
#[derive(Debug, Clone)]
pub struct RustSolver {
    pub weights: Weights,
    pub iters: u32,
    pub cold_steps: usize,
}

impl RustSolver {
    pub fn new(weights: Weights, iters: u32, cold_steps: usize) -> Self {
        RustSolver {
            weights,
            iters,
            cold_steps,
        }
    }
}

impl MpcSolver for RustSolver {
    fn solve(&mut self, z0: &[f64], input: &MpcInput) -> (Vec<f64>, f64) {
        let h = input.horizon();
        let wts = &self.weights;
        let ub = upper_bounds(wts, h);
        // a mis-shaped warm start degrades to a cold (zero) start instead
        // of panicking: under fault injection the control plane must
        // clamp and replan, and a cold start only costs extra iterations
        // toward the same projected optimum
        let mut z = if z0.len() == 3 * h {
            z0.to_vec()
        } else {
            vec![0.0; 3 * h]
        };
        // feasible serving seed (mirror of model.mpc_solve): avoids phantom
        // in-model backlog while the s-block ramps from zero
        for k in 0..h {
            z[2 * h + k] = z[2 * h + k].max(input.lam[k]);
        }
        let mut m = vec![0.0; 3 * h];
        let mut v = vec![0.0; 3 * h];
        let b1 = wts.momentum;
        // per-block step scale: serving block ranges ~10x wider (see kernel)
        let s_scale = (crate::mpc::problem::DT_S / wts.l_warm) / wts.mu;
        for i in 1..=self.iters {
            let mut g = grad(&z, input, wts, self.cold_steps);
            for gi in g.iter_mut() {
                *gi = gi.clamp(-wts.grad_clip, wts.grad_clip);
            }
            let bc1 = 1.0 - b1.powi(i as i32);
            let bc2 = 1.0 - ADAM_B2.powi(i as i32);
            for j in 0..z.len() {
                m[j] = b1 * m[j] + (1.0 - b1) * g[j];
                v[j] = ADAM_B2 * v[j] + (1.0 - ADAM_B2) * g[j] * g[j];
                let block_lr = if j >= 2 * h { wts.lr * s_scale } else { wts.lr };
                let step = block_lr * (m[j] / bc1) / ((v[j] / bc2).sqrt() + ADAM_EPS);
                z[j] = (z[j] - step).clamp(0.0, ub[j]);
            }
        }
        let c = cost(&z, input, wts, self.cold_steps);
        (z, c)
    }

    fn name(&self) -> &str {
        "rust-pgd"
    }

    fn set_w_max(&mut self, w_max: f64) {
        self.weights.w_max = w_max;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::problem::split;

    fn solver() -> RustSolver {
        RustSolver::new(Weights::default(), 300, 1)
    }

    fn input(lam: Vec<f64>, q0: f64, w0: f64) -> MpcInput {
        let h = lam.len();
        MpcInput {
            lam,
            rdy: vec![0.0; h],
            q0,
            w0,
            x_prev: 0.0,
        }
    }

    #[test]
    fn descends_from_cold_start() {
        let mut s = solver();
        let inp = input(vec![200.0; 24], 100.0, 0.0);
        let z0 = vec![0.0; 72];
        let c0 = cost(&z0, &inp, &s.weights, 1);
        let (_, c) = s.solve(&z0, &inp);
        assert!(c < c0, "{c} !< {c0}");
    }

    #[test]
    fn burst_triggers_early_prewarming() {
        // predicted burst of 900 requests/step at steps 14-15 (D = 1)
        let mut lam = vec![0.0; 24];
        for k in 14..16 {
            lam[k] = 900.0;
        }
        let mut s = solver();
        let (z, _) = s.solve(&vec![0.0; 72], &input(lam, 0.0, 0.0));
        let (x, _, _) = split(&z, 24);
        let early: f64 = x[..14].iter().sum();
        assert!(early > 3.0, "no early prewarm: {x:?}");
    }

    #[test]
    fn idle_pool_is_reclaimed_not_grown() {
        let mut s = RustSolver::new(
            Weights {
                gamma: 0.05,
                eta: 0.2,
                ..Weights::default()
            },
            300,
            1,
        );
        let inp = input(vec![0.0; 24], 0.0, 20.0);
        let (z, _) = s.solve(&vec![0.0; 72], &inp);
        // judge the *repaired* plan — the relaxed iterate carries x/r churn
        // that the exclusivity projection removes (Eq. 18)
        let plan = crate::mpc::repair(&z, &inp, &s.weights, 1, 64, 0);
        // receding horizon: only step 0 actuates — it must reclaim, not
        // prewarm (tail steps carry relaxation churn that is never acted on)
        let (x0, r0, _) = plan.first();
        assert_eq!(x0, 0, "prewarming an idle pool: {plan:?}");
        assert!(r0 >= 1, "not reclaiming an idle pool: {plan:?}");
    }

    #[test]
    fn iterates_stay_in_box() {
        let mut s = solver();
        let inp = input(vec![3000.0; 24], 1000.0, 0.0);
        let (z, _) = s.solve(&vec![10.0; 72], &inp);
        let ub = upper_bounds(&s.weights, 24);
        for (i, v) in z.iter().enumerate() {
            assert!(*v >= 0.0 && *v <= ub[i] + 1e-9, "z[{i}]={v}");
        }
    }

    #[test]
    fn mis_shaped_warm_start_degrades_to_cold_start() {
        let mut s = solver();
        let inp = input(vec![200.0; 24], 100.0, 0.0);
        // a stale warm start (e.g. the horizon changed under it) must not
        // panic — it solves exactly like the all-zero cold start
        let (z_bad, c_bad) = s.solve(&vec![1.0; 7], &inp);
        let (z_cold, c_cold) = s.solve(&vec![0.0; 72], &inp);
        assert_eq!(z_bad, z_cold);
        assert_eq!(c_bad, c_cold);
    }

    #[test]
    fn warm_start_no_worse_than_cold() {
        let mut s = solver();
        let inp = input(vec![250.0; 24], 50.0, 5.0);
        let (z1, c1) = s.solve(&vec![0.0; 72], &inp);
        let (_, c2) = s.solve(&z1, &inp);
        assert!(c2 <= c1 * 1.05 + 1.0, "warm start regressed: {c2} vs {c1}");
    }

    #[test]
    fn backlog_drives_prewarming() {
        // standing queue with a tiny pool: the plan must scale out hard
        let mut s = solver();
        let (z, _) = s.solve(&vec![0.0; 72], &input(vec![30.0; 24], 900.0, 2.0));
        let (x, _, s_) = split(&z, 24);
        assert!(x[0] >= 2.0, "x0={} too timid for a 900-deep queue", x[0]);
        assert!(s_[0] > 10.0, "s0={} not serving the backlog", s_[0]);
    }
}
