//! Artifact contract: parses `artifacts/meta.json` (written by
//! `python/compile/aot.py`) and cross-checks it against the crate's
//! compile-time constants so a stale artifact set fails loudly.

use std::path::{Path, PathBuf};

use crate::runtime::{RtError, RtResult};
use crate::util::json::Json;

/// Parsed artifact metadata (shapes + baked constants).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub window: usize,
    pub horizon: usize,
    pub cold_steps: usize,
    pub harmonics: usize,
    pub pgd_iters: u32,
    pub l_warm_s: f64,
    pub l_cold_s: f64,
    pub w_max: f64,
    pub img_size: usize,
    pub det_classes: usize,
    pub param_names: Vec<String>,
    pub default_params: Vec<f64>,
}

impl ArtifactMeta {
    /// Load and validate `<dir>/meta.json`.
    pub fn load(dir: &Path) -> RtResult<ArtifactMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| RtError(format!("reading {path:?} (run `make artifacts`): {e}")))?;
        let j = Json::parse(&text).map_err(|e| RtError(format!("parsing {path:?}: {e}")))?;
        let num = |k: &str| -> RtResult<f64> {
            j.path(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| RtError(format!("meta.json missing numeric '{k}'")))
        };
        let meta = ArtifactMeta {
            dir: dir.to_path_buf(),
            window: num("window")? as usize,
            horizon: num("horizon")? as usize,
            cold_steps: num("cold_steps")? as usize,
            harmonics: num("harmonics")? as usize,
            pgd_iters: num("pgd_iters")? as u32,
            l_warm_s: num("l_warm_s")?,
            l_cold_s: num("l_cold_s")?,
            w_max: num("w_max")?,
            img_size: num("img_size")? as usize,
            det_classes: num("det_classes")? as usize,
            param_names: j
                .path("param_names")
                .and_then(Json::as_arr)
                .ok_or_else(|| RtError("meta.json missing param_names".into()))?
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect(),
            default_params: j
                .path("default_params")
                .and_then(Json::as_arr)
                .ok_or_else(|| RtError("meta.json missing default_params".into()))?
                .iter()
                .filter_map(Json::as_f64)
                .collect(),
        };
        meta.validate()?;
        Ok(meta)
    }

    /// The default artifact directory: `$MPC_ARTIFACTS` or `artifacts/`
    /// relative to the crate root (works from `cargo run`/`cargo test`).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("MPC_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Whether the artifact set exists on disk (tests skip HLO paths if not).
    pub fn available() -> bool {
        Self::default_dir().join("meta.json").exists()
    }

    fn validate(&self) -> RtResult<()> {
        if self.param_names.len() != 16 || self.default_params.len() != 16 {
            return Err(RtError(format!(
                "params vector must have 16 entries (got {})",
                self.param_names.len()
            )));
        }
        // cross-check against the constants the Rust mirrors assume
        let expect = [
            ("window", self.window, 120usize),
            ("horizon", self.horizon, 24),
            ("cold_steps", self.cold_steps, 1),
        ];
        for (name, got, want) in expect {
            if got != want {
                return Err(RtError(format!(
                    "artifact {name}={got} but this build expects {want}; re-run `make artifacts`"
                )));
            }
        }
        if (self.l_warm_s - 0.280).abs() > 1e-9 || (self.l_cold_s - 10.5).abs() > 1e-9 {
            return Err(RtError(
                "artifact latency constants diverge from PlatformConfig defaults".into(),
            ));
        }
        Ok(())
    }

    pub fn module_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_meta_when_available() {
        if !ArtifactMeta::available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = ArtifactMeta::load(&ArtifactMeta::default_dir()).unwrap();
        assert_eq!(meta.window, 120);
        assert_eq!(meta.horizon, 24);
        assert_eq!(meta.param_names[0], "alpha");
        assert_eq!(meta.param_names[15], "grad_clip");
        assert!(meta.module_path("forecast").exists());
        assert!(meta.module_path("mpc").exists());
        assert!(meta.module_path("detector").exists());
    }

    #[test]
    fn rejects_stale_meta() {
        let dir = std::env::temp_dir().join(format!("mpc-meta-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"window": 99, "horizon": 24, "cold_steps": 1, "harmonics": 8,
                "pgd_iters": 300, "l_warm_s": 0.28, "l_cold_s": 10.5, "w_max": 64,
                "img_size": 32, "det_classes": 8,
                "param_names": ["a","b","c","d","e","f","g","h","i","j","k","l","m","n","o","p"],
                "default_params": [0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0]}"#,
        )
        .unwrap();
        let err = ArtifactMeta::load(&dir).unwrap_err().to_string();
        assert!(err.contains("window"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
