//! PJRT engine: one CPU client + compiled executables per artifact.
//!
//! The real implementation (feature `xla-runtime`) drives the PJRT C API
//! through the `xla` bindings crate. The default build ships a stub with
//! the same surface whose constructors fail, so the rest of the stack
//! (which always gates on `ArtifactMeta::available()`) compiles and runs
//! without the bindings.

use crate::runtime::{RtError, RtResult};

#[cfg(feature = "xla-runtime")]
mod real {
    use std::path::Path;

    use super::{RtError, RtResult};

    /// Wraps the PJRT CPU client. One engine per process; modules share it.
    pub struct Engine {
        client: xla::PjRtClient,
    }

    impl Engine {
        pub fn cpu() -> RtResult<Engine> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RtError(format!("creating PJRT CPU client: {e}")))?;
            crate::log_info!(
                "PJRT engine up: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            Ok(Engine { client })
        }

        /// Load + compile an HLO-text artifact.
        pub fn load(&self, path: &Path) -> RtResult<LoadedModule> {
            let text_path = path
                .to_str()
                .ok_or_else(|| RtError("non-utf8 artifact path".into()))?;
            let proto = xla::HloModuleProto::from_text_file(text_path)
                .map_err(|e| RtError(format!("parsing HLO text {path:?}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| RtError(format!("compiling {path:?}: {e}")))?;
            Ok(LoadedModule {
                exe,
                name: path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }
    }

    /// A compiled executable. All our artifacts are lowered with
    /// `return_tuple=True`, so outputs decompose uniformly into a literal list.
    pub struct LoadedModule {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl LoadedModule {
        /// Execute with f32 inputs of the given shapes; returns each output
        /// as a flat f32 vector (row-major).
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> RtResult<Vec<Vec<f32>>> {
            let err = |what: &str| move |e| RtError(format!("{what}: {e}"));
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| -> RtResult<xla::Literal> {
                    let lit = xla::Literal::vec1(data);
                    if dims.is_empty() {
                        // scalar input: reshape to rank-0
                        lit.reshape(&[]).map_err(err("reshaping scalar input"))
                    } else {
                        lit.reshape(dims).map_err(err("reshaping input"))
                    }
                })
                .collect::<RtResult<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| RtError(format!("executing module '{}': {e}", self.name)))?;
            let mut root = result[0][0]
                .to_literal_sync()
                .map_err(err("fetching result literal"))?;
            let parts = root
                .decompose_tuple()
                .map_err(err("decomposing output tuple"))?;
            parts
                .iter()
                .map(|lit| lit.to_vec::<f32>().map_err(err("reading f32 output")))
                .collect()
        }
    }
}

#[cfg(feature = "xla-runtime")]
pub use real::{Engine, LoadedModule};

#[cfg(not(feature = "xla-runtime"))]
mod stub {
    use std::path::Path;

    use super::{RtError, RtResult};

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `xla-runtime` feature";

    /// Stub engine: same surface as the PJRT-backed one, always errors.
    pub struct Engine {
        _private: (),
    }

    impl Engine {
        pub fn cpu() -> RtResult<Engine> {
            Err(RtError(UNAVAILABLE.into()))
        }

        pub fn load(&self, _path: &Path) -> RtResult<LoadedModule> {
            Err(RtError(UNAVAILABLE.into()))
        }
    }

    /// Stub compiled executable (never constructible through the stub engine).
    pub struct LoadedModule {
        pub name: String,
    }

    impl LoadedModule {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> RtResult<Vec<Vec<f32>>> {
            Err(RtError(UNAVAILABLE.into()))
        }
    }
}

#[cfg(not(feature = "xla-runtime"))]
pub use stub::{Engine, LoadedModule};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactMeta;

    #[test]
    fn engine_loads_and_runs_forecast_artifact() {
        if !ArtifactMeta::available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = ArtifactMeta::load(&ArtifactMeta::default_dir()).unwrap();
        let engine = match Engine::cpu() {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
        let module = engine.load(&meta.module_path("forecast")).unwrap();
        let hist = vec![10.0f32; meta.window];
        let gamma = [3.0f32];
        let out = module
            .run_f32(&[(&hist, &[meta.window as i64]), (&gamma, &[])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), meta.horizon);
        // constant history -> ~constant forecast
        for v in &out[0] {
            assert!((*v - 10.0).abs() < 0.5, "{v}");
        }
    }

    #[cfg(not(feature = "xla-runtime"))]
    #[test]
    fn stub_engine_reports_unavailable() {
        let err = Engine::cpu().unwrap_err();
        assert!(err.0.contains("xla-runtime"), "{err}");
    }
}
