//! PJRT engine: one CPU client + compiled executables per artifact.

use std::path::Path;

use anyhow::{Context, Result};

/// Wraps the PJRT CPU client. One engine per process; modules share it.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT engine up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client })
    }

    /// Load + compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(LoadedModule {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled executable. All our artifacts are lowered with
/// `return_tuple=True`, so outputs decompose uniformly into a literal list.
pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl LoadedModule {
    /// Execute with f32 inputs of the given shapes; returns each output
    /// as a flat f32 vector (row-major).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| -> Result<xla::Literal> {
                let lit = xla::Literal::vec1(data);
                if dims.is_empty() {
                    // scalar input: reshape to rank-0
                    Ok(lit.reshape(&[])?)
                } else {
                    Ok(lit.reshape(dims)?)
                }
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing module '{}'", self.name))?;
        let mut root = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = root.decompose_tuple().context("decomposing output tuple")?;
        parts
            .iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactMeta;

    #[test]
    fn engine_loads_and_runs_forecast_artifact() {
        if !ArtifactMeta::available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = ArtifactMeta::load(&ArtifactMeta::default_dir()).unwrap();
        let engine = Engine::cpu().unwrap();
        let module = engine.load(&meta.module_path("forecast")).unwrap();
        let hist = vec![10.0f32; meta.window];
        let gamma = [3.0f32];
        let out = module
            .run_f32(&[(&hist, &[meta.window as i64]), (&gamma, &[])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), meta.horizon);
        // constant history -> ~constant forecast
        for v in &out[0] {
            assert!((*v - 10.0).abs() < 0.5, "{v}");
        }
    }
}
