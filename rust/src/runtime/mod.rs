//! PJRT runtime: loads the AOT HLO artifacts and exposes them as typed
//! modules callable from the L3 control loop. Python never runs here —
//! the artifacts are self-contained HLO text compiled once at startup.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod artifacts;
pub mod engine;
pub mod modules;

pub use artifacts::ArtifactMeta;
pub use engine::{Engine, LoadedModule};
pub use modules::{DetectorModule, ForecastModule, HloSolver, HloForecaster, MpcModule};
