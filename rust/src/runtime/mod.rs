//! PJRT runtime: loads the AOT HLO artifacts and exposes them as typed
//! modules callable from the L3 control loop. Python never runs here —
//! the artifacts are self-contained HLO text compiled once at startup.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! The PJRT path needs the external `xla` bindings crate, which the
//! offline toolchain does not ship; without the `xla-runtime` feature the
//! engine is a stub whose constructors return an error, and every caller
//! gates on [`ArtifactMeta::available`] first.

pub mod artifacts;
pub mod engine;
pub mod modules;

use std::fmt;

/// Runtime error (the offline toolchain has no anyhow; this is the
/// message-carrying equivalent for the artifact/engine paths).
#[derive(Debug)]
pub struct RtError(pub String);

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

pub type RtResult<T> = Result<T, RtError>;

pub use artifacts::ArtifactMeta;
pub use engine::{Engine, LoadedModule};
pub use modules::{DetectorModule, ForecastModule, HloForecaster, HloSolver, MpcModule};
