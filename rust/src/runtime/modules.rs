//! Typed wrappers over the three AOT artifacts + adapters implementing the
//! `Forecaster` / `MpcSolver` traits so the control loop can run on the
//! deployed HLO path interchangeably with the Rust mirrors.


use crate::config::Weights;
use crate::forecast::Forecaster;
use crate::mpc::{MpcInput, MpcSolver};
use crate::runtime::artifacts::ArtifactMeta;
use crate::runtime::engine::{Engine, LoadedModule};
use crate::runtime::{RtError, RtResult};

macro_rules! rt_ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(RtError(format!($($fmt)*)));
        }
    };
}

/// The Fourier forecast artifact (Eq. 1-2 as HLO).
pub struct ForecastModule {
    module: LoadedModule,
    pub window: usize,
    pub horizon: usize,
}

impl ForecastModule {
    pub fn load(engine: &Engine, meta: &ArtifactMeta) -> RtResult<Self> {
        Ok(ForecastModule {
            module: engine.load(&meta.module_path("forecast"))?,
            window: meta.window,
            horizon: meta.horizon,
        })
    }

    pub fn forecast(&self, history: &[f32], gamma_clip: f32) -> RtResult<Vec<f32>> {
        rt_ensure!(
            history.len() == self.window,
            "history must have exactly W={} samples (got {})",
            self.window,
            history.len()
        );
        let out = self.module.run_f32(&[
            (history, &[self.window as i64]),
            (&[gamma_clip], &[]),
        ])?;
        Ok(out.into_iter().next().unwrap())
    }
}

/// The MPC solver artifact (Eq. 3-18 PGD as HLO).
pub struct MpcModule {
    module: LoadedModule,
    pub horizon: usize,
}

impl MpcModule {
    pub fn load(engine: &Engine, meta: &ArtifactMeta) -> RtResult<Self> {
        Ok(MpcModule {
            module: engine.load(&meta.module_path("mpc"))?,
            horizon: meta.horizon,
        })
    }

    /// One full solve; returns (z*, final cost).
    pub fn solve(
        &self,
        z0: &[f32],
        lam: &[f32],
        rdy: &[f32],
        state: &[f32; 4],
        params: &[f32; 16],
    ) -> RtResult<(Vec<f32>, f32)> {
        let h = self.horizon as i64;
        rt_ensure!(z0.len() == 3 * self.horizon, "z0 shape");
        rt_ensure!(lam.len() == self.horizon, "lam shape");
        rt_ensure!(rdy.len() == self.horizon, "rdy shape");
        let out = self.module.run_f32(&[
            (z0, &[3 * h]),
            (lam, &[h]),
            (rdy, &[h]),
            (&state[..], &[4]),
            (&params[..], &[16]),
        ])?;
        let mut it = out.into_iter();
        let z = it.next().unwrap();
        let cost = it.next().unwrap()[0];
        Ok((z, cost))
    }
}

/// The detector payload artifact (the serverless function's real compute).
pub struct DetectorModule {
    module: LoadedModule,
    pub img_size: usize,
    pub classes: usize,
}

impl DetectorModule {
    pub fn load(engine: &Engine, meta: &ArtifactMeta) -> RtResult<Self> {
        Ok(DetectorModule {
            module: engine.load(&meta.module_path("detector"))?,
            img_size: meta.img_size,
            classes: meta.det_classes,
        })
    }

    /// Run detection on one NHWC frame (flattened), returning class scores.
    pub fn detect(&self, img: &[f32]) -> RtResult<Vec<f32>> {
        let s = self.img_size as i64;
        rt_ensure!(
            img.len() == (s * s * 3) as usize,
            "image must be {s}x{s}x3 flattened"
        );
        let out = self.module.run_f32(&[(img, &[1, s, s, 3])])?;
        Ok(out.into_iter().next().unwrap())
    }
}

// ---------------------------------------------------------------------------
// trait adapters: drop-in replacements for the Rust mirrors
// ---------------------------------------------------------------------------

/// `Forecaster` backed by the HLO artifact.
pub struct HloForecaster {
    module: ForecastModule,
    pub gamma_clip: f32,
}

impl HloForecaster {
    pub fn new(module: ForecastModule, gamma_clip: f32) -> Self {
        HloForecaster { module, gamma_clip }
    }
}

impl Forecaster for HloForecaster {
    fn forecast(&mut self, history: &[f64], horizon: usize) -> Vec<f64> {
        assert_eq!(horizon, self.module.horizon, "artifact horizon is baked");
        let hist: Vec<f32> = history.iter().map(|&v| v as f32).collect();
        self.module
            .forecast(&hist, self.gamma_clip)
            .expect("HLO forecast failed")
            .into_iter()
            .map(|v| v as f64)
            .collect()
    }

    fn name(&self) -> &'static str {
        "fourier-hlo"
    }
}

/// `MpcSolver` backed by the HLO artifact.
pub struct HloSolver {
    module: MpcModule,
    pub weights: Weights,
}

impl HloSolver {
    pub fn new(module: MpcModule, weights: Weights) -> Self {
        HloSolver { module, weights }
    }
}

impl MpcSolver for HloSolver {
    fn solve(&mut self, z0: &[f64], input: &MpcInput) -> (Vec<f64>, f64) {
        let to32 = |xs: &[f64]| xs.iter().map(|&v| v as f32).collect::<Vec<f32>>();
        let state = [
            input.q0 as f32,
            input.w0 as f32,
            input.x_prev as f32,
            0.0f32,
        ];
        let params = self.weights.to_params_vec();
        let (z, cost) = self
            .module
            .solve(&to32(z0), &to32(&input.lam), &to32(&input.rdy), &state, &params)
            .expect("HLO MPC solve failed");
        (z.into_iter().map(|v| v as f64).collect(), cost as f64)
    }

    fn name(&self) -> &'static str {
        "mpc-hlo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::{MpcSolver as _, RustSolver};

    fn load_all() -> Option<(ArtifactMeta, Engine)> {
        if !ArtifactMeta::available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let meta = ArtifactMeta::load(&ArtifactMeta::default_dir()).unwrap();
        let engine = match Engine::cpu() {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skipping: {e}");
                return None;
            }
        };
        Some((meta, engine))
    }

    #[test]
    fn hlo_forecast_matches_rust_mirror() {
        let Some((meta, engine)) = load_all() else { return };
        let module = ForecastModule::load(&engine, &meta).unwrap();
        let mut hlo = HloForecaster::new(module, 3.0);
        let mut rust = crate::forecast::FourierForecaster::default();
        let hist: Vec<f64> = (0..120)
            .map(|t| 200.0 + 50.0 * (t as f64 / 20.0 * std::f64::consts::TAU).cos() + 0.1 * t as f64)
            .collect();
        let a = hlo.forecast(&hist, 24);
        let b = rust.forecast(&hist, 24);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.5, "hlo {x} vs rust {y}");
        }
    }

    #[test]
    fn hlo_solver_matches_rust_mirror() {
        let Some((meta, engine)) = load_all() else { return };
        let module = MpcModule::load(&engine, &meta).unwrap();
        let weights = Weights::default();
        let mut hlo = HloSolver::new(module, weights);
        let mut rust = RustSolver::new(weights, meta.pgd_iters, meta.cold_steps);
        let input = MpcInput {
            lam: vec![150.0; 24],
            rdy: vec![0.0; 24],
            q0: 40.0,
            w0: 2.0,
            x_prev: 0.0,
        };
        let z0 = vec![0.0; 72];
        let (za, ca) = hlo.solve(&z0, &input);
        let (zb, cb) = rust.solve(&z0, &input);
        // f32 vs f64 over 300 iterations: loose elementwise agreement, but
        // the repaired integer plans must match
        let rel = (ca - cb).abs() / cb.abs().max(1.0);
        assert!(rel < 0.05, "cost hlo {ca} vs rust {cb}");
        let wts = weights;
        let pa = crate::mpc::repair(&za, &input, &wts, 1, 64, 0);
        let pb = crate::mpc::repair(&zb, &input, &wts, 1, 64, 0);
        assert_eq!(pa.first(), pb.first(), "first-step actions diverge");
    }

    #[test]
    fn detector_runs() {
        let Some((meta, engine)) = load_all() else { return };
        let module = DetectorModule::load(&engine, &meta).unwrap();
        let img = vec![0.5f32; meta.img_size * meta.img_size * 3];
        let scores = module.detect(&img).unwrap();
        assert_eq!(scores.len(), meta.det_classes);
        assert!(scores.iter().any(|s| s.abs() > 1e-3), "degenerate scores");
    }
}
