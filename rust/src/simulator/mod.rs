//! Discrete-event simulation core.
//!
//! A binary-heap event queue over microsecond virtual time with FIFO
//! tie-breaking (events scheduled earlier pop first at equal timestamps),
//! so runs are fully deterministic. 60-minute experiments execute in
//! milliseconds of wall-clock.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::Micros;

/// An event with its firing time and an insertion sequence number.
#[derive(Debug, Clone)]
pub struct Scheduled<E> {
    pub time: Micros,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we need earliest-first
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Earliest-first event queue with deterministic FIFO tie-breaking.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    next_seq: u64,
    now: Micros,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            now: 0,
            popped: 0,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> Micros {
        self.now
    }

    /// Total events processed (simulator throughput metric).
    pub fn processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `time`. Scheduling in the past
    /// (before the last popped event) is a logic error in the caller.
    pub fn push(&mut self, time: Micros, event: E) {
        debug_assert!(
            time >= self.now,
            "event scheduled in the past: {time} < {}",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { time, seq, event });
    }

    /// Schedule `event` `delay` after now.
    pub fn push_in(&mut self, delay: Micros, event: E) {
        self.push(self.now + delay, event);
    }

    pub fn peek_time(&self) -> Option<Micros> {
        self.heap.peek().map(|s| s.time)
    }

    /// Earliest scheduled event without popping it — the sharded batch
    /// collector's lookahead (it must inspect the event *kind* to decide
    /// whether the next event is node-local and batchable).
    pub fn peek(&self) -> Option<&Scheduled<E>> {
        self.heap.peek()
    }

    /// Account for an event that the sharded engine pushed and consumed
    /// entirely inside one batch window without touching the heap:
    /// assigns (and returns) the sequence number the sequential loop's
    /// `push` would have handed out, and counts the pop the sequential
    /// loop would have performed. Keeps both the FIFO tie-break stream
    /// and `processed()` bit-identical to the sequential execution.
    pub fn consume_inline(&mut self) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.popped += 1;
        seq
    }

    /// Pop the earliest event, advancing the virtual clock.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        let s = self.heap.pop()?;
        debug_assert!(s.time >= self.now);
        self.now = s.time;
        self.popped += 1;
        Some(s)
    }

    /// Pop only if the earliest event fires at or before `horizon`.
    pub fn pop_until(&mut self, horizon: Micros) -> Option<Scheduled<E>> {
        if self.peek_time()? <= horizon {
            self.pop()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|s| s.event).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(5, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|s| s.event).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(10, ());
        q.push(10, ());
        q.push(50, ());
        let mut last = 0;
        while let Some(s) = q.pop() {
            assert!(s.time >= last);
            last = s.time;
            assert_eq!(q.now(), s.time);
        }
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn pop_until_respects_horizon() {
        let mut q = EventQueue::new();
        q.push(10, "early");
        q.push(100, "late");
        assert_eq!(q.pop_until(50).unwrap().event, "early");
        assert!(q.pop_until(50).is_none());
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_until(200).unwrap().event, "late");
    }

    #[test]
    fn push_in_is_relative_to_now() {
        let mut q = EventQueue::new();
        q.push(100, ());
        q.pop();
        q.push_in(5, ());
        assert_eq!(q.peek_time(), Some(105));
    }

    #[test]
    fn peek_exposes_the_next_pop_without_consuming() {
        let mut q = EventQueue::new();
        q.push(20, "b");
        q.push(10, "a");
        let s = q.peek().unwrap();
        assert_eq!((s.time, s.event), (10, "a"));
        assert_eq!(q.len(), 2);
        assert_eq!(q.processed(), 0);
        assert_eq!(q.pop().unwrap().event, "a");
    }

    #[test]
    fn consume_inline_matches_push_then_pop_bookkeeping() {
        // two queues driven identically, except one routes the middle
        // event through consume_inline instead of push+pop: seq stream
        // and processed() must stay in lockstep (the sharded engine's
        // bit-identity contract)
        let mut seq_q = EventQueue::new();
        let mut inl_q = EventQueue::new();
        seq_q.push(10, "a");
        inl_q.push(10, "a");
        seq_q.pop();
        inl_q.pop();
        // sequential: push the recheck, pop it
        seq_q.push(15, "recheck");
        seq_q.pop();
        // sharded: the recheck never touches the heap
        let seq = inl_q.consume_inline();
        assert_eq!(seq, 1, "inline consume takes the seq push would have");
        assert_eq!(seq_q.processed(), inl_q.processed());
        // the next push on both queues gets the same seq
        seq_q.push(20, "after");
        inl_q.push(20, "after");
        assert_eq!(seq_q.pop().unwrap().seq, inl_q.pop().unwrap().seq);
    }

    #[test]
    fn ordering_property_random() {
        use crate::prop_assert;
        use crate::util::prop::prop_check;
        prop_check("event ordering", 100, |g| {
            let mut q = EventQueue::new();
            let n = g.usize(1, 200);
            for i in 0..n {
                q.push(g.u64(0, 1000), i);
            }
            let mut last_t = 0;
            let mut last_seq_at_t: Option<u64> = None;
            while let Some(s) = q.pop() {
                prop_assert!(s.time >= last_t, "time regressed");
                if s.time != last_t {
                    last_seq_at_t = None;
                }
                if let Some(prev) = last_seq_at_t {
                    prop_assert!(s.seq > prev, "FIFO violated at t={}", s.time);
                }
                last_t = s.time;
                last_seq_at_t = Some(s.seq);
            }
            Ok(())
        });
    }
}
