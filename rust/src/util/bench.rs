//! Bench harness driving the `cargo bench` targets (criterion is not in the
//! vendored crate set; see DESIGN.md substitution table).
//!
//! Provides warmup + timed iterations with mean/std/percentiles, plus a
//! table printer so each bench target emits the paper's rows directly.

use std::time::Instant;

use crate::util::stats::Summary;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.add(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        std_ns: samples.std(),
        p50_ns: samples.p50(),
        p95_ns: samples.p95(),
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub fn print_result(r: &BenchResult) {
    println!(
        "  {:<40} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        r.iters
    );
}

/// Simple fixed-width table printer for paper-style result rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("  ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_work() {
        let r = bench("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 20);
        assert!(r.p50_ns <= r.p95_ns + 1.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one".to_string()]);
    }
}
