//! Declarative command-line flag parser (the vendored crate set has no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeatable
//! `--flag v1 --flag v2` collection, positional arguments, and
//! auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
    is_multi: bool,
}

/// Builder for a subcommand's flags.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    multis: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    BadValue(String, String),
    Help,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(n) => write!(f, "unknown flag --{n}"),
            CliError::MissingValue(n) => write!(f, "flag --{n} expects a value"),
            CliError::BadValue(n, v) => write!(f, "invalid value for --{n}: {v}"),
            CliError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
            is_multi: false,
        });
        self
    }

    pub fn bool_flag(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
            is_multi: false,
        });
        self
    }

    /// A value flag that may repeat: every occurrence is collected, in
    /// order, retrievable with [`Args::get_all`]. Defaults to empty.
    pub fn multi_flag(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
            is_multi: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nFLAGS:\n", self.program, self.about);
        for f in &self.flags {
            let d = match (&f.default, f.is_bool, f.is_multi) {
                (_, true, _) => "  (boolean)".to_string(),
                (_, _, true) => "  (repeatable)".to_string(),
                (Some(d), _, _) if !d.is_empty() => format!("  [default: {d}]"),
                _ => String::new(),
            };
            out.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        out
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for f in &self.flags {
            if let Some(d) = &f.default {
                args.values.insert(f.name.clone(), d.clone());
            }
            if f.is_bool {
                args.bools.insert(f.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help);
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                if spec.is_bool {
                    args.bools.insert(name, true);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    if spec.is_multi {
                        args.multis.entry(name).or_default().push(value);
                    } else {
                        args.values.insert(name, value);
                    }
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    /// Every value a repeatable flag was given, in command-line order
    /// (empty when the flag never appeared).
    pub fn get_all(&self, name: &str) -> &[String] {
        self.multis.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::BadValue(name.into(), self.get(name).into()))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::BadValue(name.into(), self.get(name).into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("test", "about")
            .flag("seed", "42", "rng seed")
            .flag("trace", "azure", "trace kind")
            .bool_flag("verbose", "chatty")
            .multi_flag("tag", "repeatable tag")
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("seed"), "42");
        assert_eq!(a.get_u64("seed").unwrap(), 42);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn parses_separate_and_inline() {
        let a = cli()
            .parse(&argv(&["--seed", "7", "--trace=synthetic", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), 7);
        assert_eq!(a.get("trace"), "synthetic");
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn rejects_unknown() {
        assert!(matches!(
            cli().parse(&argv(&["--nope", "1"])),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn missing_value_detected() {
        assert!(matches!(
            cli().parse(&argv(&["--seed"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_numeric_value() {
        let a = cli().parse(&argv(&["--seed", "xyz"])).unwrap();
        assert!(matches!(a.get_u64("seed"), Err(CliError::BadValue(_, _))));
    }

    #[test]
    fn multi_flag_collects_every_occurrence_in_order() {
        let a = cli().parse(&argv(&[])).unwrap();
        assert!(a.get_all("tag").is_empty());
        let a = cli()
            .parse(&argv(&["--tag", "x", "--tag=y", "--tag", "z"]))
            .unwrap();
        assert_eq!(a.get_all("tag"), ["x", "y", "z"]);
        assert!(cli().usage().contains("(repeatable)"));
    }

    #[test]
    fn help_flag() {
        assert!(matches!(cli().parse(&argv(&["-h"])), Err(CliError::Help)));
        assert!(cli().usage().contains("--seed"));
    }
}
