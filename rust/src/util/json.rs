//! Minimal JSON parser + writer (the vendored crate set has no serde).
//!
//! Used for the artifact contract (`artifacts/meta.json`), experiment
//! configs, and machine-readable reports. Supports the full JSON grammar
//! except exotic float forms; numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- accessors --------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `a.b.c` path lookup.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ---- constructors -----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- parsing ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    let s = &self.bytes[start..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---- writing ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap(),
            &Json::Str("c".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"num":-7,"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_random_structures() {
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let v = random_json(&mut rng, 3);
            let text = v.to_string();
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(back, v, "{text}");
        }
    }

    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        let choices = if depth == 0 { 4 } else { 6 };
        match rng.range_u64(0, choices - 1) {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.f64() * 1e6).round() / 4.0),
            3 => Json::Str(format!("s{}\n\"{}", rng.range_u64(0, 99), rng.range_u64(0, 9))),
            4 => Json::Arr((0..rng.range_usize(0, 4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range_usize(0, 4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
}
