//! Tiny stderr logger (the offline toolchain has no `log` facade crate):
//! level from `MPC_LOG` env (error|warn|info|debug|trace; default warn).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

pub fn max_level() -> u8 {
    MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= max_level()
}

/// Emit one log line if `level` is enabled (used via the `log_*!` macros).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Off => return,
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{tag}] {target}: {args}");
}

/// Install the logger level (idempotent; safe to call from every entrypoint).
pub fn init() {
    let level = match std::env::var("MPC_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        Ok("off") => Level::Off,
        _ => Level::Warn,
    };
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_debug!("smoke");
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        // default (or env-set) level always admits Error, never panics
        log(Level::Error, "test", format_args!("visible at any level"));
    }
}
