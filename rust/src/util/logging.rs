//! Tiny `log`-facade backend: stderr, level from `MPC_LOG` env
//! (error|warn|info|debug|trace; default warn).

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{tag}] {}: {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent; safe to call from every entrypoint).
pub fn init() {
    let level = match std::env::var("MPC_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Warn,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::debug!("smoke");
    }
}
