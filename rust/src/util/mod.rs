//! Foundation utilities: PRNG, statistics, JSON, CLI, ring buffers,
//! property-test framework, bench harness, logging.
//!
//! These replace crates unavailable in the offline vendored set
//! (rand, serde_json, clap, proptest, criterion) — see DESIGN.md.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timeseries;
