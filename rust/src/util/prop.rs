//! Seeded property-test mini-framework (proptest is not in the vendored
//! crate set; see DESIGN.md substitution table).
//!
//! Usage:
//! ```ignore
//! prop_check("queue conservation", 500, |g| {
//!     let n = g.usize(0, 100);
//!     ...
//!     prop_assert!(invariant_holds, "context {n}");
//!     Ok(())
//! });
//! ```
//! Each case gets a fresh deterministic generator; on failure the seed is
//! printed so the case can be replayed with `PROP_SEED=<seed>`.

use crate::util::rng::Rng;

/// Per-case value generator.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }
    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.chance(p_true)
    }
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64(lo, hi)).collect()
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }
}

/// Run `cases` random cases of `body`. Panics (failing the enclosing test)
/// on the first case that returns Err, reporting the replay seed.
pub fn prop_check<F>(name: &str, cases: u32, mut body: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok());
    let (seeds, label): (Vec<u64>, &str) = match base {
        Some(s) => (vec![s], "replay"),
        None => ((0..cases as u64).map(|i| 0x5EED_0000 + i).collect(), "search"),
    };
    for seed in seeds {
        let mut g = Gen {
            rng: Rng::new(seed),
            case_seed: seed,
        };
        if let Err(msg) = body(&mut g) {
            panic!(
                "property '{name}' failed ({label} mode)\n  replay: PROP_SEED={seed}\n  {msg}"
            );
        }
    }
}

/// Assert inside a prop body, yielding Err with context instead of panicking
/// (so prop_check can attach the replay seed).
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check("trivial", 50, |g| {
            count += 1;
            let v = g.f64(0.0, 1.0);
            prop_assert!((0.0..1.0).contains(&v), "v={v}");
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "PROP_SEED=")]
    fn failing_property_reports_seed() {
        prop_check("always fails", 5, |_g| Err("boom".to_string()));
    }

    #[test]
    fn generators_in_bounds() {
        prop_check("gen bounds", 200, |g| {
            let a = g.usize(2, 9);
            prop_assert!((2..=9).contains(&a), "usize out of range: {a}");
            let b = g.f64(-1.0, 1.0);
            prop_assert!((-1.0..1.0).contains(&b), "f64 out of range: {b}");
            let v = g.vec_f64(10, 5.0, 6.0);
            prop_assert!(v.iter().all(|x| (5.0..6.0).contains(x)), "vec out of range");
            let p = *g.pick(&[1, 2, 3]);
            prop_assert!([1, 2, 3].contains(&p), "pick out of set");
            Ok(())
        });
    }
}
