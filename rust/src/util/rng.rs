//! Deterministic PRNG + sampling distributions.
//!
//! The vendored crate set has no `rand`, so the simulator carries its own
//! generator: SplitMix64 for seeding and xoshiro256** for the stream (the
//! reference constants from Blackman & Vigna). Everything downstream
//! (workload generators, property tests) takes an explicit seed so every
//! experiment is reproducible from its config.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        let span = hi - lo + 1;
        // multiply-shift bounded rejection-free mapping (Lemire); bias is
        // negligible for span << 2^64 and irrelevant for simulation use
        lo + ((self.next_u64() as u128 * span as u128) >> 64) as u64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `rate` (mean 1/rate), for Poisson inter-arrivals.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Standard normal via Box-Muller (single value; the pair is dropped —
    /// simplicity over throughput, this is not on a hot path).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Poisson sample (Knuth for small means, normal approximation above 64).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 64.0 {
            return self.normal(mean, mean.sqrt()).round().max(0.0) as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Independent child stream (for per-component determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_u64_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 7);
            assert!((3..=7).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 7;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exp(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(13);
        for target in [0.5, 5.0, 30.0, 120.0] {
            let n = 20_000;
            let sum: u64 = (0..n).map(|_| r.poisson(target)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - target).abs() < target.max(1.0) * 0.05,
                "target={target} mean={mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.1);
        assert!((var.sqrt() - 3.0).abs() < 0.1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
