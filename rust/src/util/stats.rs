//! Summary statistics used by metrics reports and the bench harness.

/// Exact summary over a sample set (stores the samples; percentiles by sort).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Percentile by linear interpolation between closest ranks
    /// (the "exclusive" R-7 definition used by numpy.percentile default).
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            // total_cmp: a NaN-bearing sample set (degenerate latency from
            // a chaos run) must not abort mid-report — NaNs sort to the
            // top and surface as a NaN percentile instead of a panic
            self.samples.sort_unstable_by(f64::total_cmp);
            self.sorted = true;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = p / 100.0 * (self.samples.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.samples[lo]
        } else {
            let frac = rank - lo as f64;
            self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p90(&mut self) -> f64 {
        self.percentile(90.0)
    }
    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Constant-memory running statistics (Welford) for hot-loop telemetry.
#[derive(Debug, Clone, Copy, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::from_slice(&[0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
        assert_eq!(s.percentile(25.0), 2.5);
    }

    #[test]
    fn percentile_matches_naive_on_random_data() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..997).map(|_| rng.f64() * 100.0).collect();
        let mut s = Summary::from_slice(&xs);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0] {
            let rank = p / 100.0 * (sorted.len() - 1) as f64;
            let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
            let frac = rank - lo as f64;
            let want = sorted[lo] * (1.0 - frac) + sorted[hi] * frac;
            assert!((s.percentile(p) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_summary_is_zero() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // total_cmp sorts NaN above every finite value: low percentiles
        // stay meaningful, the top percentile reads NaN, nothing aborts
        let mut s = Summary::from_slice(&[3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert!(s.percentile(100.0).is_nan());
        // a sorted-order probe below the NaN tail is still exact
        assert_eq!(s.percentile(100.0 / 3.0), 2.0);
        // all-NaN degenerates to NaN percentiles, not a panic
        let mut all = Summary::from_slice(&[f64::NAN, f64::NAN]);
        assert!(all.percentile(50.0).is_nan());
    }

    #[test]
    fn online_matches_exact() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(6);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.normal(3.0, 2.0)).collect();
        let exact = Summary::from_slice(&xs);
        let mut online = OnlineStats::new();
        for &x in &xs {
            online.add(x);
        }
        assert!((online.mean() - exact.mean()).abs() < 1e-9);
        assert!((online.std() - exact.std()).abs() < 1e-9);
        assert_eq!(online.count(), 10_000);
    }

    #[test]
    fn online_merge_equals_sequential() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(8);
        let xs: Vec<f64> = (0..1000).map(|_| rng.f64()).collect();
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.add(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..400] {
            a.add(x);
        }
        for &x in &xs[400..] {
            b.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.std() - whole.std()).abs() < 1e-12);
    }
}
